"""Content addressing: chunking, Merkle DAG, verification."""

import hashlib

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cid import (CID, CODEC_DAG, CODEC_RAW, build_dag, chunk,
                            decode_manifest, encode_manifest, reassemble)
from repro.core.blockstore import BlockStore


@settings(max_examples=50, deadline=None)
@given(st.binary(max_size=4096), st.sampled_from([64, 257, 1024]))
def test_dag_roundtrip(data, chunk_size):
    dag = build_dag(data, chunk_size=chunk_size)
    manifest = dag.blocks[dag.root]
    got = reassemble(manifest, dag.blocks)
    assert got == data
    assert dag.root.verify(manifest)
    # every leaf hash-verifies
    children, total, _ = decode_manifest(manifest)
    assert total == len(data)
    for c in children:
        assert c.verify(dag.blocks[c])


def test_cid_determinism():
    d1 = build_dag(b"hello world" * 100, chunk_size=256)
    d2 = build_dag(b"hello world" * 100, chunk_size=256)
    assert d1.root == d2.root
    d3 = build_dag(b"hello world!" * 100, chunk_size=256)
    assert d3.root != d1.root


def test_manifest_meta():
    enc = encode_manifest([CID.for_data(b"a")], 1, meta=b"metadata-bytes")
    children, total, meta = decode_manifest(enc)
    assert meta == b"metadata-bytes" and total == 1 and len(children) == 1


def test_blockstore_rejects_corruption():
    store = BlockStore()
    cid = CID.for_data(b"good")
    with pytest.raises(ValueError):
        store.put(cid, b"evil")
    store.put(cid, b"good")
    assert store.get(cid) == b"good"
    assert store.bytes_stored == 4
    store.delete(cid)
    assert store.bytes_stored == 0 and not store.has(cid)


def test_chunk_boundaries():
    assert chunk(b"", 4) == [b""]
    assert chunk(b"abcdefgh", 4) == [b"abcd", b"efgh"]
    assert chunk(b"abcdefghi", 4) == [b"abcd", b"efgh", b"i"]
