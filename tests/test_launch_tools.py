"""Launch-layer pure helpers: HLO parsing, sharding rules, roofline math.

(The dry-run itself needs a 512-device process and is exercised by
``python -m repro.launch.dryrun``; these tests cover the logic that
doesn't need the big mesh.)
"""

import numpy as np
import pytest

from repro.launch.hlo_stats import CollectiveStats, op_histogram, parse_collectives


HLO = """
HloModule test, num_partitions=16
  %all-reduce.1 = f32[256]{0} all-reduce(%x), channel_id=2, replica_groups=[16,32]<=[512], to_apply=%sum
  %all-gather.2 = bf16[1024,64]{1,0} all-gather(%y), replica_groups=[32,16]<=[512], dimensions={0}
  %rs = f32[128]{0} reduce-scatter(%z), replica_groups=[64,8]<=[512], to_apply=%sum
  %ata = bf16[64,64]{1,0} all-to-all(%w), replica_groups=[128,4]<=[512]
  %cp = f32[32]{0} collective-permute(%v), source_target_pairs={{0,1}}
  %ar-start = f32[16]{0} all-reduce-start(%u), replica_groups=[16,32]<=[512]
  %ar-done = f32[16]{0} all-reduce-done(%ar-start)
"""


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(HLO)
    assert st.counts["all-reduce"] == 2          # incl. the -start, not -done
    assert st.counts["all-gather"] == 1
    assert st.counts["reduce-scatter"] == 1
    assert st.counts["all-to-all"] == 1
    assert st.counts["collective-permute"] == 1
    # all-reduce of f32[256] in groups of 32: 2*1024*(31/32)
    assert st.bytes_by_op["all-reduce"] == pytest.approx(
        2 * 256 * 4 * 31 / 32 + 2 * 16 * 4 * 31 / 32)
    # all-gather bf16[1024,64] groups of 16: size*(g-1)/g
    assert st.bytes_by_op["all-gather"] == pytest.approx(
        1024 * 64 * 2 * 15 / 16)
    assert st.total_bytes > 0


def test_op_histogram():
    hist = dict(op_histogram(HLO))
    assert hist.get("all-reduce", 0) >= 1


def test_roofline_analyzer():
    from benchmarks.roofline import analyze_record, suggest

    rec = {
        "arch": "granite-8b", "shape": "train_4k", "kind": "train",
        "n_devices": 256, "active_params": 8.1e9,
        "hlo_flops_per_dev": 1.5e12, "hlo_bytes_per_dev": 5e10,
        "collective_bytes_per_dev": 3e9,
        "bytes_args_per_dev": 3e8, "bytes_temp_per_dev": 8e9,
        "bytes_out_per_dev": 3e8, "collective_counts": {"all-reduce": 3},
    }
    row = analyze_record(rec)
    assert row["dominant"] in ("compute", "memory", "collective")
    assert row["compute_s"] == pytest.approx(1.5e12 / 197e12)
    assert row["memory_s"] == pytest.approx(5e10 / 819e9)
    assert row["collective_s"] == pytest.approx(3e9 / 50e9)
    # 6·N·D train model flops
    assert row["model_flops_per_dev"] == pytest.approx(
        6 * 8.1e9 * 256 * 4096 / 256)
    assert isinstance(suggest(row), str) and len(suggest(row)) > 10
    assert analyze_record({"skipped": "x"}) is None


def test_param_spec_rules():
    import jax
    from jax.sharding import PartitionSpec as P

    if jax.device_count() < 4:
        import dataclasses

        from repro.configs import get_config
        from repro.launch.shardings import param_spec

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        mesh = FakeMesh()
        cfg = get_config("granite-8b")
        # attention: in-dim FSDP, out-dim TP
        assert param_spec("blocks/attn/wq", (36, 4096, 4096), mesh, cfg,
                          "train") == P(None, "data", "model")
        # serve mode: no FSDP
        assert param_spec("blocks/attn/wq", (36, 4096, 4096), mesh, cfg,
                          "serve") == P(None, None, "model")
        # embeddings: vocab on model, but replicated if not divisible
        assert param_spec("embed", (49152, 4096), mesh, cfg, "serve") == \
            P("model", None)
        cfgw = get_config("whisper-small")
        assert param_spec("embed", (51865, 768), mesh, cfgw, "serve") == \
            P(None, None)       # 51865 % 16 != 0 -> replicate
        # norms replicate
        assert param_spec("blocks/ln1", (36, 4096), mesh, cfg, "train") == \
            P(None, None)
        # xlstm serve under seq-parallelism: weights replicate (the model
        # axis carries segments); plain serve/decode keeps TP sharding
        cfgx = get_config("xlstm-1.3b")
        cfgx_sp = dataclasses.replace(cfgx, seq_segments=16,
                                      act_seq_axis="model")
        assert param_spec("blocks/mlstm/wq", (4096, 4096), mesh, cfgx_sp,
                          "serve") == P(None, None)
        assert param_spec("blocks/mlstm/wq", (4096, 4096), mesh, cfgx,
                          "serve") == P(None, "model")
        assert param_spec("blocks/mlstm/wq", (4096, 4096), mesh, cfgx,
                          "train") == P("data", "model")


def test_cache_spec_rules():
    from jax.sharding import PartitionSpec as P

    from repro.configs import get_config
    from repro.launch.shardings import cache_spec

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    mesh = FakeMesh()
    cfg = get_config("glm4-9b")          # Hk=2: heads don't divide 16
    spec = cache_spec("layers/k", (40, 128, 32768, 2, 128), mesh, cfg)
    assert spec == P(None, "data", "model", None, None)   # T-dim sharded
    cfg2 = get_config("qwen2-moe-a2.7b")  # Hk=16: heads divide
    spec2 = cache_spec("layers/k", (24, 128, 32768, 16, 128), mesh, cfg2)
    assert spec2 == P(None, "data", None, "model", None)
