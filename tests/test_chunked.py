"""Memory-efficient jnp formulations vs naive oracles (values AND grads)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import attention_ref
from repro.models.chunked import flash_attention_jnp
from repro.models.config import ModelConfig
from repro.models.ssm import run_mamba, run_mlstm, init_mamba, init_mlstm


def _bshd(x):
    return jnp.swapaxes(x, 1, 2)


@pytest.mark.parametrize("window", [0, 256])
def test_flash_jnp_forward(window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, hd = 2, 2048, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out = flash_attention_jnp(q, k, v, True, window)
    ref = _bshd(attention_ref(_bshd(q), _bshd(k), _bshd(v),
                              causal=True, window=window))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_jnp_gradients_match_naive():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, hd = 1, 1024, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))

    def loss_flash(q, k, v):
        return jnp.sum(jnp.square(flash_attention_jnp(q, k, v, True, 0)))

    def loss_naive(q, k, v):
        return jnp.sum(jnp.square(_bshd(
            attention_ref(_bshd(q), _bshd(k), _bshd(v), causal=True))))

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gn = jax.grad(loss_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gf, gn):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=3e-4, rtol=3e-4)


def _mk_cfg(**kw):
    base = dict(name="t", arch="hybrid", n_layers=1, d_model=64, n_heads=2,
                n_kv_heads=2, d_ff=128, vocab=128, ssm_state=8, d_inner=128)
    base.update(kw)
    return ModelConfig(**base)


def test_mamba_chunked_equals_unchunked():
    """S=512 (4 chunks of 128) must equal a single-chunk run."""
    cfg = _mk_cfg()
    key = jax.random.PRNGKey(2)
    p = init_mamba(cfg, key, jnp.float32)
    x = jax.random.normal(key, (2, 512, cfg.d_model))
    y_chunked, _ = run_mamba(p, cfg, x)                     # W=128, 4 chunks
    # reference: build via decode-style stepping through prefill chunks
    y_parts = []
    state = (jnp.zeros((2, cfg.d_in, cfg.ssm_state)),
             jnp.zeros((2, 3, cfg.d_in)))
    for i in range(0, 512, 128):
        yc, state = run_mamba(p, cfg, x[:, i:i + 128], state)
        y_parts.append(yc)
    y_ref = jnp.concatenate(y_parts, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_ref),
                               atol=2e-4, rtol=2e-4)


def test_mlstm_chunked_equals_quadratic():
    cfg = _mk_cfg(arch="ssm", d_model=64, n_heads=2)
    key = jax.random.PRNGKey(3)
    p = init_mlstm(cfg, key, jnp.float32)
    x_small = jax.random.normal(key, (2, 256, 64))          # quadratic path
    x_big = jnp.tile(x_small, (1, 2, 1))[:, :512]           # chunked path
    y_small, _ = run_mlstm(p, cfg, x_small)
    y_big, _ = run_mlstm(p, cfg, x_big)
    # first 256 positions of the chunked run must equal the quadratic run
    np.testing.assert_allclose(np.asarray(y_big[:, :256]),
                               np.asarray(y_small),
                               atol=2e-4, rtol=2e-4)


def test_mlstm_sequence_parallel_equals_sequential():
    """The seq-parallel two-pass path (vmap segments + associative state
    scan) must match the sequential chunk scan, with and without an
    incoming state, including the returned state."""
    import dataclasses

    cfg = _mk_cfg(arch="ssm", d_model=64, n_heads=2)
    key = jax.random.PRNGKey(7)
    p = init_mlstm(cfg, key, jnp.float32)
    x = jax.random.normal(key, (2, 2048, 64))
    cfg_sp = dataclasses.replace(cfg, seq_segments=4)
    y_seq, _ = run_mlstm(p, cfg, x)
    y_sp, _ = run_mlstm(p, cfg_sp, x)
    np.testing.assert_allclose(np.asarray(y_sp), np.asarray(y_seq),
                               atol=1e-5, rtol=1e-5)
    d_in, H = 128, 2
    hd = d_in // H
    state = (0.1 * jax.random.normal(key, (2, H, hd, hd)),
             0.1 * jax.random.normal(key, (2, H, hd)),
             jnp.zeros((2, H)))
    y1, s1 = run_mlstm(p, cfg, x, state)
    y2, s2 = run_mlstm(p, cfg_sp, x, state)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               atol=1e-5, rtol=1e-5)
    for a, b in zip(s1, s2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)


def test_moe_groups_equivalence():
    """Grouped dispatch with ample capacity == ungrouped."""
    import dataclasses
    from repro.models.moe import init_moe, run_moe

    cfg = _mk_cfg(arch="moe", n_experts=8, moe_top_k=2, d_expert=64,
                  capacity_factor=8.0)
    p = init_moe(cfg, jax.random.PRNGKey(4), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 32, cfg.d_model))
    y1, aux1 = run_moe(p, cfg, x, no_drop=True)
    cfg4 = dataclasses.replace(cfg, moe_groups=4)
    y4, aux4 = run_moe(p, cfg4, x, no_drop=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y4),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(float(aux1), float(aux4), rtol=1e-5)
