"""Serving v2: continuous batching (paged slots, FIFO admission), the
load-aware router, mid-generation session migration, and pressure-driven
replica spawn."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.core.simnet import Sim
from repro.models import ops_for
from repro.serving.batch import BatchEngine
from repro.serving.engine import GenerationEngine
from repro.serving.pressure import PressureMonitor
from repro.serving.router import LoadAwareRouter
from repro.serving.sharded import ShardClient, ShardModule, serve_fleet


def _cfg():
    return get_config("granite-8b").reduced(n_layers=4, d_model=64, vocab=256)


def _full_module(cfg, params):
    return ShardModule(cfg, params, (0, cfg.n_layers),
                       is_first=True, is_last=True)


@pytest.fixture(scope="module")
def model():
    cfg = _cfg()
    ops = ops_for(cfg)
    params = ops.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


# --------------------------------------------------------------------------
# BatchEngine unit tests (no network)
# --------------------------------------------------------------------------

def test_slot_reuse_after_eviction(model):
    cfg, params = model
    sim = Sim(seed=1)
    eng = BatchEngine(_full_module(cfg, params), sim, n_slots=1, page_size=8)
    x = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                                      cfg.vocab), np.int32)
    sim.run_process(eng.open("A", x, 16))
    slot_a = eng.slot_of("A")
    assert slot_a is not None and eng.slots_used == 1
    eng.close(["A"])
    assert eng.slots_used == 0 and eng.slot_of("A") is None
    sim.run_process(eng.open("B", x, 16))
    assert eng.slot_of("B") == slot_a          # freed slot is recycled
    assert eng.stats["slot_reuse"] == 1
    assert eng.stats["evicted"] == 1
    assert eng.stats["admitted"] == 2


def test_admission_fifo_under_full_slot_table(model):
    cfg, params = model
    sim = Sim(seed=2)
    eng = BatchEngine(_full_module(cfg, params), sim, n_slots=2, page_size=8)
    x = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (1, 4), 0,
                                      cfg.vocab), np.int32)
    sim.run_process(eng.open("A", x, 16))
    sim.run_process(eng.open("B", x, 16))
    assert eng.slots_used == 2

    admitted = []

    def waiter(sid):
        yield from eng.open(sid, x, 16)
        admitted.append(sid)

    sim.process(waiter("C"))
    sim.process(waiter("D"))
    sim.run(until=sim.now + 1)
    assert eng.queue_depth == 2 and admitted == []

    # a freed slot must go to the *oldest* waiter, not the newest
    eng.close(["A"])
    sim.run(until=sim.now + 1)
    assert admitted == ["C"] and eng.queue_depth == 1
    eng.close(["B"])
    sim.run(until=sim.now + 1)
    assert admitted == ["C", "D"]
    assert eng.stats["queue_peak"] == 2


def test_paged_cache_grows_without_perturbing_decode(model):
    """Decode past the first page: capacity grows by whole pages and the
    greedy continuation still matches the unsharded engine."""
    cfg, params = model
    sim = Sim(seed=3)
    eng = BatchEngine(_full_module(cfg, params), sim, n_slots=1, page_size=8)
    x = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (1, 6), 0,
                                      cfg.vocab), np.int32)
    n_new = 12                                  # 6 + 12 crosses the 8-page
    out, _ = sim.run_process(eng.open("S", x, 32))
    toks = [int(np.argmax(out[0]))]
    for _ in range(n_new - 1):
        step_out, served, _ = eng.step(["S"], np.asarray([toks[-1]], np.int32))
        assert served == ["S"]
        toks.append(int(np.argmax(step_out[0])))
    st = eng.by_session["S"]
    assert st.capacity > 8                      # grew past the first page
    local = GenerationEngine(cfg, params, max_len=32)
    want, _ = local.generate({"tokens": jnp.asarray(x)}, n_new)
    np.testing.assert_array_equal(np.asarray(toks, np.int32), want[0])


def _pages_for(eng, n_tokens):
    return -(-n_tokens // eng.page_size)


def test_exact_page_accounting_across_lifecycle(model):
    """stats['pages'] tracks pages actually in use at every point: grows
    with prefill/decode, drops on close, and is exactly 0 once every
    session is gone (fused pool path and unfused dense path both)."""
    cfg, params = model
    for fused in (True, False):
        sim = Sim(seed=6)
        eng = BatchEngine(_full_module(cfg, params), sim, n_slots=4,
                          page_size=8, fused=fused)
        x = np.asarray(jax.random.randint(jax.random.PRNGKey(6), (1, 11), 0,
                                          cfg.vocab), np.int32)
        sim.run_process(eng.open("A", x, 64))
        sim.run_process(eng.open("B", x, 64))
        # 11 prompt tokens + room for the next one = 12 -> 2 pages each
        assert eng.stats["pages"] == 2 * _pages_for(eng, 12), fused
        for _ in range(6):                     # 11 + 6 = 17 -> 3 pages
            eng.step(["A", "B"], np.asarray([1, 2], np.int32))
        assert eng.stats["pages"] == 2 * _pages_for(eng, 17), fused
        eng.close(["A"])
        assert eng.stats["pages"] == _pages_for(eng, 17), fused
        eng.close(["B"])
        assert eng.stats["pages"] == 0, fused
        assert eng.stats["pages_peak"] == 2 * _pages_for(eng, 17), fused
        # a fresh admission after total drain starts from clean accounting
        sim.run_process(eng.open("C", x, 64))
        assert eng.stats["pages"] == _pages_for(eng, 12), fused
        eng.close(["C"])
        assert eng.stats["pages"] == 0, fused


def test_reopen_same_session_frees_old_pages(model):
    """Re-admitting a live session id replaces its storage instead of
    leaking the old pages."""
    cfg, params = model
    sim = Sim(seed=7)
    eng = BatchEngine(_full_module(cfg, params), sim, n_slots=2, page_size=8)
    x = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (1, 20), 0,
                                      cfg.vocab), np.int32)
    sim.run_process(eng.open("A", x, 64))
    first = eng.stats["pages"]
    sim.run_process(eng.open("A", x[:, :4], 64))
    assert eng.stats["pages"] == _pages_for(eng, 5)
    assert eng.stats["pages"] < first
    eng.close(["A"])
    assert eng.stats["pages"] == 0


def test_int8_kv_cache_smaller_and_greedy_consistent(model):
    """The int8 pool must hold well under half the fp32 pool's bytes and
    still decode the same greedy continuation at this scale, with the
    final-step logits within the quantization bound."""
    cfg, params = model
    outs, bytes_used = {}, {}
    x = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (1, 10), 0,
                                      cfg.vocab), np.int32)
    for dtype in ("fp32", "int8"):
        sim = Sim(seed=8)
        eng = BatchEngine(_full_module(cfg, params), sim, n_slots=1,
                          page_size=8, kv_dtype=dtype)
        assert eng.fused, "int8 pool requires the fused path"
        out, _ = sim.run_process(eng.open("S", x, 64))
        toks = [int(np.argmax(out[0]))]
        last = None
        for _ in range(20):
            last, served, _ = eng.step(["S"], np.asarray([toks[-1]], np.int32))
            assert served == ["S"]
            toks.append(int(np.argmax(last[0])))
        outs[dtype] = (toks, np.asarray(last))
        bytes_used[dtype] = eng.kv_bytes()
    assert bytes_used["int8"] <= 0.55 * bytes_used["fp32"]
    assert outs["int8"][0] == outs["fp32"][0]      # same greedy path
    assert np.abs(outs["int8"][1] - outs["fp32"][1]).max() < 0.25


# --------------------------------------------------------------------------
# Router unit tests (no network)
# --------------------------------------------------------------------------

def test_router_prefers_fast_provider_and_ewma_recovers():
    sim = Sim(seed=4)
    router = LoadAwareRouter(sim, alpha=0.3, explore=0.0)
    key = ("shard", 0)
    for _ in range(6):
        router.observe(key, "fast", 0.010, ok=True)
        router.observe(key, "slow", 0.200, ok=True)
    assert router.rank(key, ["slow", "fast"])[0] == "fast"
    assert router.score(key, "slow") > router.score(key, "fast")

    # the slow provider recovers; EWMA decay lets it earn its way back
    for _ in range(20):
        router.observe(key, "slow", 0.002, ok=True)
    assert router.rank(key, ["slow", "fast"])[0] == "slow"


def test_router_error_rate_and_inflight_penalize():
    sim = Sim(seed=5)
    router = LoadAwareRouter(sim, alpha=0.3, explore=0.0)
    key = ("shard", 1)
    router.observe(key, "a", 0.010, ok=True)
    router.observe(key, "b", 0.010, ok=True)
    base = router.score(key, "a")
    router.observe(key, "a", 0.010, ok=False)   # one failure
    assert router.score(key, "a") > base
    assert router.rank(key, ["a", "b"])[0] == "b"
    # in-flight depth shapes the score like queueing delay
    base_b = router.score(key, "b")
    router.begin(key, "b")
    assert router.score(key, "b") > base_b
    router.end(key, "b")
    assert router.score(key, "b") == base_b


# --------------------------------------------------------------------------
# End-to-end: batched serving over the mesh
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def served_v2(model):
    cfg, params = model
    fleet = make_fleet(10, seed=21, same_region="us")
    sim = fleet.sim
    servers = sim.run_process(
        serve_fleet(fleet.peers[:4], cfg, params, "svc", replicas=2,
                    n_slots=4),
        until=sim.now + 900)
    return cfg, params, fleet, servers


def test_batched_greedy_matches_engine_no_kv_bleed(served_v2):
    """Six concurrent sessions through the batched plane decode exactly
    what the unsharded engine produces per prompt — shared slots must not
    leak KV state across sessions."""
    cfg, params, fleet, servers = served_v2
    sim = fleet.sim
    client = ShardClient(fleet.peers[-1], cfg, "svc", n_shards=2)
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(i), (1, 8),
                                             0, cfg.vocab), np.int32)
               for i in range(6)]

    def run():
        reqs = [dict(tokens=p, n_tokens=6) for p in prompts]
        out = yield from client.generate_concurrent(reqs)
        return out

    outs = sim.run_process(run(), until=sim.now + 900)
    local = GenerationEngine(cfg, params, max_len=32)
    for p, o in zip(prompts, outs):
        want, _ = local.generate({"tokens": jnp.asarray(p)}, 6)
        assert o is not None
        np.testing.assert_array_equal(o, want[0])
    assert client.stats["failed_sessions"] == 0
    assert any(s.engine.stats["step_sessions"] > s.engine.stats["steps"]
               for s in servers)                # steps actually batched


def test_same_prompt_different_temperatures_diverge(served_v2):
    """Two sessions over the identical prompt but different temperatures
    must produce different continuations (and share no sampler state)."""
    cfg, params, fleet, servers = served_v2
    sim = fleet.sim
    client = ShardClient(fleet.peers[-2], cfg, "svc", n_shards=2)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(9), (1, 8),
                                           0, cfg.vocab), np.int32)

    def run():
        reqs = [dict(tokens=prompt, n_tokens=8, temperature=0.0),
                dict(tokens=prompt, n_tokens=8, temperature=1.5, seed=7)]
        out = yield from client.generate_concurrent(reqs)
        return out

    greedy, sampled = sim.run_process(run(), until=sim.now + 900)
    assert greedy is not None and sampled is not None
    local = GenerationEngine(cfg, params, max_len=32)
    want, _ = local.generate({"tokens": jnp.asarray(prompt)}, 8)
    np.testing.assert_array_equal(greedy, want[0])   # greedy row unaffected
    assert not np.array_equal(greedy, sampled)


def test_mid_generation_kill_migrates_sessions(served_v2):
    """Killing a busy replica mid-decode migrates its sessions (prefill
    replay on the surviving replica): zero failed sessions, greedy output
    still exact."""
    cfg, params, fleet, servers = served_v2
    sim = fleet.sim
    client = ShardClient(fleet.peers[-1], cfg, "svc", n_shards=2)
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(20 + i),
                                             (1, 8), 0, cfg.vocab), np.int32)
               for i in range(6)]

    def run():
        evs = [client.submit(p, 48) for p in prompts]
        # poll for the first moment a shard-0 replica is actually busy —
        # a fixed sleep races the decode loop, whose virtual-time speed
        # shifts with background message load
        busy = []
        for _ in range(200):
            yield sim.timeout(0.01)
            busy = [s for s in servers
                    if s.alive and s.shard_idx == 0
                    and s.engine.slots_used > 0]
            if busy:
                break
        assert busy, "no busy shard-0 replica to kill"
        busy[0].stop()
        res = []
        for ev in evs:
            res.append((yield ev))
        return res

    outs = sim.run_process(run(), until=sim.now + 1800)
    local = GenerationEngine(cfg, params, max_len=64)
    for p, o in zip(prompts, outs):
        want, _ = local.generate({"tokens": jnp.asarray(p)}, 48)
        assert o is not None
        np.testing.assert_array_equal(o, want[0])
    assert client.stats["failed_sessions"] == 0
    assert client.stats["sessions_migrated"] >= 1


def test_pressure_monitor_spawns_replica_on_hot_shard(served_v2):
    """Sustained saturation of the slot tables must drive an idle peer to
    fetch the shard's params off the content plane and register as a new
    DHT provider."""
    cfg, params, fleet, servers = served_v2
    sim = fleet.sim
    client = ShardClient(fleet.peers[-1], cfg, "svc", n_shards=2)
    idle = fleet.peers[5]
    mon = PressureMonitor(idle, cfg, "svc", hot_occupancy=0.5, sustain=2,
                          interval=0.15, max_replicas=4, n_slots=4)
    sim.process(mon.run())
    prompts = [np.asarray(jax.random.randint(jax.random.PRNGKey(40 + i),
                                             (1, 8), 0, cfg.vocab), np.int32)
               for i in range(8)]

    def run():
        # saturate: far more concurrent sessions than slots, long enough
        # generations that the queue persists across several monitor ticks
        reqs = [dict(tokens=prompts[i % len(prompts)], n_tokens=48)
                for i in range(24)]
        out = yield from client.generate_concurrent(reqs)
        return out

    outs = sim.run_process(run(), until=sim.now + 3600)
    # the workload can drain before the spawned replica finishes fetching
    # its params off the content plane — give the in-flight spawn a bounded
    # grace period before halting the monitor
    for _ in range(400):
        if mon.stats["spawned"] or mon.stats["fetch_failures"]:
            break
        sim.run(until=sim.now + 0.25)
    mon.stop()
    assert all(o is not None for o in outs)
    assert mon.stats["observations"] > 0
    assert mon.stats["spawned"] >= 1
    spawned = getattr(idle, "shard_servers", [])
    assert spawned and all(s.alive for s in spawned)
