import os
import sys

# tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in
# a separate process); keep any inherited flag out of the test env
os.environ.pop("XLA_FLAGS", None)

_root = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, _root)                       # for the benchmarks package
sys.path.insert(0, os.path.join(_root, "src"))

# ---------------------------------------------------------------------------
# hypothesis shim: several modules hard-import hypothesis for property tests.
# When it isn't installed, install a stand-in whose @given/@settings turn the
# decorated test into a clean runtime skip, so the rest of each module's
# (non-property) tests still collect and run instead of aborting collection.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    import pytest as _pytest

    def _skipping_decorator(*_args, **_kwargs):
        def deco(fn):
            def skipper():
                _pytest.skip("hypothesis not installed")
            skipper.__name__ = getattr(fn, "__name__", "property_test")
            skipper.__doc__ = getattr(fn, "__doc__", None)
            return skipper
        return deco

    class _AnyStrategy:
        """Absorbs any strategy-construction expression at import time."""

        def __getattr__(self, _name):
            return self

        def __call__(self, *_args, **_kwargs):
            return self

        def __or__(self, _other):       # st.none() | st.booleans() | ...
            return self

        def __ror__(self, _other):
            return self

    _shim = types.ModuleType("hypothesis")
    _shim.given = _skipping_decorator
    _shim.settings = _skipping_decorator
    _shim.strategies = _AnyStrategy()
    _shim.__is_shim__ = True
    sys.modules["hypothesis"] = _shim
