import os
import sys

# tests must see exactly ONE device (the dry-run sets its own XLA_FLAGS in
# a separate process); keep any inherited flag out of the test env
os.environ.pop("XLA_FLAGS", None)

_root = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, _root)                       # for the benchmarks package
sys.path.insert(0, os.path.join(_root, "src"))
