"""Property tests: CRDT join-semilattice laws, delta-state laws
(``apply_delta(delta_since(vv))`` ≡ full merge), canonical-codec
roundtrips + convergence (hypothesis)."""

import copy

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.crdt import (GCounter, LWWRegister, MVRegister, ORSet,
                             PNCounter, ReplicatedStore, WIRE_MAGIC,
                             canonical_dumps, decode_entry, encode_entry)

REPLICAS = ["r0", "r1", "r2"]


# ---------------------------------------------------------------- op models

def apply_gcounter(c: GCounter, op):
    c.increment(op[0], op[1])


def apply_pncounter(c: PNCounter, op):
    (c.increment if op[2] else c.decrement)(op[0], op[1])


def apply_orset(s: ORSet, op):
    replica, elem, is_add = op
    if is_add:
        s.add(elem, replica)
    else:
        s.remove(elem)


def apply_lww(c: LWWRegister, op):
    replica, val, ts = op
    c.set(f"v{val}", float(ts), replica)


def apply_mv(c: MVRegister, op):
    c.set(op[1], op[0])


def ops_for(kind: str, replicas):
    """Op-list strategy for one kind, writing as one of ``replicas``."""
    r = st.sampled_from(replicas)
    return {
        "g": st.lists(st.tuples(r, st.integers(0, 10)), max_size=20),
        "pn": st.lists(st.tuples(r, st.integers(0, 10), st.booleans()),
                       max_size=20),
        "orset": st.lists(st.tuples(r, st.integers(0, 5), st.booleans()),
                          max_size=24),
        "lww": st.lists(st.tuples(r, st.integers(0, 20), st.integers(0, 9)),
                        max_size=16),
        "mv": st.lists(st.tuples(r, st.integers(0, 10)), max_size=16),
    }[kind]


def ops3_shared(kind):
    """Three op lists sharing the replica-id space (legal for the
    commutative-by-construction kinds; exercises tag collisions)."""
    s = ops_for(kind, REPLICAS)
    return st.tuples(s, s, s)


def ops3_disjoint(kind):
    """Three op lists with disjoint replica ids — the real-world invariant
    (one writer per id); required for the register kinds, where two
    'replicas' writing under one id could tie timestamps / collide vector
    clocks in ways a genuine distributed run cannot."""
    return st.tuples(*(ops_for(kind, [r]) for r in REPLICAS))


def _build(cls, apply_fn, ops_by_replica):
    out = []
    for ops in ops_by_replica:
        c = cls()
        for op in ops:
            apply_fn(c, op)
        out.append(c)
    return out


CASES = [
    (GCounter, apply_gcounter, ops3_shared("g")),
    (PNCounter, apply_pncounter, ops3_shared("pn")),
    (ORSet, apply_orset, ops3_shared("orset")),
    (LWWRegister, apply_lww, ops3_disjoint("lww")),
    (MVRegister, apply_mv, ops3_disjoint("mv")),
]
CASE_IDS = ["gcounter", "pncounter", "orset", "lww", "mv"]

DELTA_CASES = [(cls, fn, kind) for (cls, fn, _), kind
               in zip(CASES, ["g", "pn", "orset", "lww", "mv"])]


@pytest.mark.parametrize("cls,apply_fn,ops3_st", CASES, ids=CASE_IDS)
def test_merge_laws(cls, apply_fn, ops3_st):
    @settings(max_examples=60, deadline=None)
    @given(ops3_st)
    def run(ops3):
        a, b, c = _build(cls, apply_fn, ops3)
        # commutativity: a ⊔ b == b ⊔ a
        ab = copy.deepcopy(a); ab.merge(b)
        ba = copy.deepcopy(b); ba.merge(a)
        assert ab.value() == ba.value()
        # associativity: (a ⊔ b) ⊔ c == a ⊔ (b ⊔ c)
        abc1 = copy.deepcopy(a); abc1.merge(b); abc1.merge(c)
        bc = copy.deepcopy(b); bc.merge(c)
        abc2 = copy.deepcopy(a); abc2.merge(bc)
        assert abc1.value() == abc2.value()
        # idempotence: a ⊔ a == a
        aa = copy.deepcopy(a)
        changed = aa.merge(a)
        assert aa.value() == a.value() and not changed

    run()


@pytest.mark.parametrize("cls,apply_fn,ops3_st", CASES, ids=CASE_IDS)
def test_convergence_any_delivery_order(cls, apply_fn, ops3_st):
    """All replicas converge regardless of merge order/duplication."""
    @settings(max_examples=40, deadline=None)
    @given(ops3_st,
           st.lists(st.tuples(st.integers(0, 2), st.integers(0, 2)),
                    min_size=6, max_size=20))
    def run(ops3, gossip):
        replicas = _build(cls, apply_fn, ops3)
        # arbitrary pairwise gossip (with duplication)...
        for i, j in gossip:
            if i != j:
                replicas[i].merge(replicas[j])
        # ...then a full exchange round to close the gaps
        for i in range(3):
            for j in range(3):
                if i != j:
                    replicas[i].merge(replicas[j])
        vals = [r.value() for r in replicas]
        assert vals[0] == vals[1] == vals[2]

    run()


def test_orset_add_wins():
    a, b = ORSet(), ORSet()
    a.add("x", "r0")
    b.merge(a)
    b.remove("x")          # b observed r0's add and removes it
    a.add("x", "r0")       # concurrent re-add with a NEW tag
    a.merge(b)
    b.merge(a)
    assert a.contains("x") and b.contains("x")


def test_lww_register_total_order():
    a, b = LWWRegister(), LWWRegister()
    a.set("first", 1.0, "r0")
    b.set("second", 2.0, "r1")
    a.merge(b)
    assert a.value() == "second"
    # tie on timestamp → replica id breaks it deterministically
    c, d = LWWRegister(), LWWRegister()
    c.set("cc", 5.0, "ra")
    d.set("dd", 5.0, "rb")
    c2 = copy.deepcopy(c); c2.merge(d)
    d2 = copy.deepcopy(d); d2.merge(c)
    assert c2.value() == d2.value() == "dd"


def test_mv_register_keeps_concurrent_siblings():
    a, b = MVRegister(), MVRegister()
    a.set("va", "r0")
    b.set("vb", "r1")
    a.merge(b)
    assert set(a.value()) == {"va", "vb"}
    # causal overwrite collapses siblings
    a.set("resolved", "r0")
    b.merge(a)
    assert b.value() == ("resolved",)


def test_replicated_store_digest_and_merge():
    s1 = ReplicatedStore("a")
    s2 = ReplicatedStore("b")
    s1.counter("steps").increment("a", 5)
    s1.orset("ckpts").add((1, b"x"), "a")
    s2.counter("steps").increment("b", 7)
    s2.register("latest").set((2, b"y"), 10.0, "b")
    assert s1.digest() != s2.digest()
    s1.merge(s2)
    s2.merge(s1)
    assert s1.digest() == s2.digest()
    assert s1.counter("steps").value() == 12
    # serialize roundtrip preserves digest
    s3 = ReplicatedStore.deserialize(s1.serialize(), "c")
    assert s3.digest() == s1.digest()


def test_deserialize_refuses_hostile_state():
    """Anti-entropy state arrives from arbitrary peers: the decoder must
    resolve only CRDT classes, never attacker-chosen globals."""
    import os
    import pickle

    class Exploit:
        def __reduce__(self):
            return (os.system, ("echo pwned",))

    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": Exploit()}))
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps(["not", "a", "dict"]))
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": "not-a-crdt"}))
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(b"\x80\x04 garbage")
    # allowlisted classes with type-confused internals are rejected up
    # front: merge()/digest() would otherwise raise mid-mutation and
    # poison the local store
    confused = GCounter()
    confused.counts = {"r": "not-an-int"}
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": confused}))
    bad_set = ORSet()
    bad_set.tombstones = {("r", "unsortable-seq")}
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": bad_set}))
    bad_mv = MVRegister()
    bad_mv.versions = {("not", "frozenset"): 1}
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": bad_mv}))
    # every in-tree CRDT kind still round-trips through the allowlist
    s = ReplicatedStore("a")
    s.counter("steps").increment("a", 3)
    s.orset("ckpts").add((1, 0x70, b"\x01" * 32), "a")
    s.register("latest").set((1, 0x70, b"\x01" * 32), 1.0, "a")
    back = ReplicatedStore.deserialize(s.serialize(), "b")
    assert back.digest() == s.digest()


# ---------------------------------------------------------- delta-state laws


def _canon(entry):
    return canonical_dumps(encode_entry(entry))


@pytest.mark.parametrize("cls,apply_fn,kind", DELTA_CASES, ids=CASE_IDS)
def test_delta_since_equals_full_merge(cls, apply_fn, kind):
    """apply_delta(delta_since(vv)) ≡ full-state merge, for random op
    interleavings and arbitrary vv cut points: B last saw A at ``cut``
    (and has concurrent writes of its own), A keeps writing, then the
    delta fragment must land B in exactly the state a full merge would."""
    ops_a = ops_for(kind, ["a0", "a1"])
    ops_b = ops_for(kind, ["b0", "b1"])

    @settings(max_examples=60, deadline=None)
    @given(ops_a, ops_b, st.integers(0, 24))
    def run(a_ops, b_ops, cut):
        cut = min(cut, len(a_ops))
        a = cls()
        for op in a_ops[:cut]:
            apply_fn(a, op)
        b = cls()
        for op in b_ops:
            apply_fn(b, op)
        b.merge(a)                      # B's knowledge of A at the cut
        for op in a_ops[cut:]:
            apply_fn(a, op)

        b_delta = copy.deepcopy(b)
        frag = a.delta_since(b_delta.vv())
        if frag is not None:
            b_delta.merge(frag)
        b_full = copy.deepcopy(b)
        b_full.merge(a)
        assert _canon(b_delta) == _canon(b_full)
        # a second identical application changes nothing (idempotent)
        if frag is not None:
            b_delta.merge(frag)
            assert _canon(b_delta) == _canon(b_full)
        # and between byte-identical replicas the delta dries up entirely
        # (no wasted resend every future sync round)
        a.merge(b_full)
        assert _canon(a) == _canon(b_full)
        assert a.delta_since(b_full.vv()) is None
        assert b_full.delta_since(a.vv()) is None

    run()


@pytest.mark.parametrize("cls,apply_fn,kind", DELTA_CASES, ids=CASE_IDS)
def test_delta_fragment_safe_at_third_replica(cls, apply_fn, kind):
    """A fragment cut for B must be safe to merge at C (who saw less than
    B): C may stay behind, but a follow-up delta_since(C.vv()) must close
    the gap — fragments never poison a replica's causal claims."""
    ops_a = ops_for(kind, ["a0", "a1"])

    @settings(max_examples=50, deadline=None)
    @given(ops_a, st.integers(0, 24), st.integers(0, 24))
    def run(a_ops, cut_b, cut_c):
        cut_b, cut_c = (min(cut_b, len(a_ops)), min(cut_c, len(a_ops)))
        a, b, c = cls(), cls(), cls()
        for i, op in enumerate(a_ops):
            if i == cut_b:
                b.merge(a)
            if i == cut_c:
                c.merge(a)
            apply_fn(a, op)
        frag_for_b = a.delta_since(b.vv())
        if frag_for_b is not None:
            c.merge(frag_for_b)         # gapped delivery at C
        repair = a.delta_since(c.vv())
        if repair is not None:
            c.merge(repair)
        full = cls()
        full.merge(a)
        assert c.value() == full.value()

    run()


def test_store_delta_roundtrip_equals_full_merge():
    """Store-level: delta_since/apply_delta over the wire codec lands the
    receiver in the same state as a full-store merge."""
    @settings(max_examples=40, deadline=None)
    @given(ops_for("g", ["a0"]), ops_for("orset", ["a0"]),
           ops_for("lww", ["a0"]), ops_for("g", ["b0"]),
           st.integers(0, 10))
    def run(g_ops, o_ops, l_ops, bg_ops, cut):
        a = ReplicatedStore("a")
        for op in g_ops[:cut]:
            apply_gcounter(a.counter("steps"), op)
        b = ReplicatedStore("b")
        for op in bg_ops:
            apply_gcounter(b.counter("steps"), op)
        b.apply_delta(a.delta_since(b.vv()))
        for op in g_ops[cut:]:
            apply_gcounter(a.counter("steps"), op)
        for op in o_ops:
            apply_orset(a.orset("reg/k"), op)
        for op in l_ops:
            apply_lww(a.register("reg/latest"), op)

        b_delta = ReplicatedStore.deserialize(b.serialize(), "b2")
        wire = ReplicatedStore.encode_delta(a.delta_since(b_delta.vv()))
        b_delta.apply_delta(ReplicatedStore.decode_delta(wire))
        b_full = ReplicatedStore.deserialize(b.serialize(), "b3")
        b_full.merge(a)
        assert b_delta.digest() == b_full.digest()

    run()


# ------------------------------------------------------------ codec laws


_values = st.recursive(
    st.none() | st.booleans() | st.integers(-2**40, 2**40)
    | st.floats(allow_nan=False, allow_infinity=False)
    | st.text(max_size=6) | st.binary(max_size=8),
    lambda ch: st.tuples(ch, ch) | st.frozensets(ch, max_size=3),
    max_leaves=6)


def test_codec_roundtrip_and_digest_stability():
    """encode→decode is lossless and two separately-built equal-state
    replicas always agree byte-for-byte on the canonical encoding (the
    old pickle digests could differ across Python/protocol versions)."""
    @settings(max_examples=60, deadline=None)
    @given(_values, st.floats(0, 1e9, allow_nan=False))
    def run(value, ts):
        r1, r2 = LWWRegister(), LWWRegister()
        r1.set(value, ts, "r0")
        r2.set(value, ts, "r0")
        assert canonical_dumps(encode_entry(r1)) == \
            canonical_dumps(encode_entry(r2))
        back = decode_entry(encode_entry(r1))
        assert canonical_dumps(encode_entry(back)) == \
            canonical_dumps(encode_entry(r1))
        assert back.value() == r1.value()

    run()


def test_codec_roundtrip_all_kinds():
    @settings(max_examples=40, deadline=None)
    @given(ops_for("g", REPLICAS), ops_for("pn", REPLICAS),
           ops_for("orset", REPLICAS), ops_for("lww", REPLICAS),
           ops_for("mv", ["r0"]))
    def run(g_ops, pn_ops, o_ops, l_ops, m_ops):
        for cls, fn, ops in ((GCounter, apply_gcounter, g_ops),
                             (PNCounter, apply_pncounter, pn_ops),
                             (ORSet, apply_orset, o_ops),
                             (LWWRegister, apply_lww, l_ops),
                             (MVRegister, apply_mv, m_ops)):
            c = cls()
            for op in ops:
                fn(c, op)
            back = decode_entry(encode_entry(c))
            assert type(back) is cls
            assert canonical_dumps(encode_entry(back)) == \
                canonical_dumps(encode_entry(c))
            assert back.value() == c.value()

    run()


def test_codec_rejects_malformed_docs():
    for doc in (None, [], "x", {"k": "nope"}, {"k": "g", "c": {"r": -1}},
                {"k": "g", "c": {"r": "NaN"}}, {"k": "g", "c": {"r": True}},
                {"k": "lww", "t": [1.0], "v": None, "c": {}},
                {"k": "orset", "a": [["e", [["r", 0]]]], "t": [], "s": {}},
                {"k": "orset", "a": [[{"__l": []}, [["r", 1]]]],
                 "t": [], "s": {}},          # unhashable element
                {"k": "mv", "vs": [["bad"]], "c": {}}):
        with pytest.raises(ValueError):
            decode_entry(doc)
    with pytest.raises(ValueError):
        ReplicatedStore.decode_delta(WIRE_MAGIC + b'{"v":2,"d":[]}')
    with pytest.raises(ValueError):
        ReplicatedStore.decode_delta(WIRE_MAGIC + b"not json")
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(WIRE_MAGIC + b'{"v":99,"entries":{}}')


# ------------------------------------------------------------ watch plane


def test_watch_fires_local_and_remote():
    a, b = ReplicatedStore("a"), ReplicatedStore("b")
    events = []
    h = a.watch("reg/", lambda k, v, o: events.append((k, o)))
    a.watch("", lambda k, v, o: events.append(("all:" + k, o)))

    a.counter("steps").increment("a", 1)        # outside the reg/ prefix
    a.orset("reg/k").add("v1", "a")             # local, under the prefix
    assert ("reg/k", "local") in events
    assert ("all:steps", "local") in events and ("steps", "local") not in [
        e for e in events if not e[0].startswith("all:")]

    b.orset("reg/k").add("v2", "b")
    a.apply_delta(b.delta_since(a.vv()))        # remote merge fires too
    assert ("reg/k", "remote") in events

    events.clear()
    a.unwatch(h)
    a.orset("reg/k").add("v3", "a")
    assert ("reg/k", "local") not in events     # handle detached
    assert ("all:reg/k", "local") in events     # other watcher still live


def test_watch_survives_serialization():
    """Listeners are plumbing, not state: snapshots round-trip cleanly and
    deltas cut from a watched store apply at other replicas."""
    a = ReplicatedStore("a")
    a.watch("", lambda k, v, o: None)
    a.counter("steps").increment("a", 2)
    snap = a.serialize()
    back = ReplicatedStore.deserialize(snap, "b")
    assert back.digest() == a.digest()
    import pickle as _p
    legacy = _p.dumps(a.entries)                # legacy path drops listeners
    assert ReplicatedStore.deserialize(legacy, "c").digest() == a.digest()
