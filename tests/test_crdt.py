"""Property tests: CRDT join-semilattice laws + convergence (hypothesis)."""

import copy

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.crdt import (GCounter, LWWRegister, MVRegister, ORSet,
                             PNCounter, ReplicatedStore)

REPLICAS = ["r0", "r1", "r2"]


# ---------------------------------------------------------------- op models

def apply_gcounter(c: GCounter, op):
    c.increment(op[0], op[1])


def apply_pncounter(c: PNCounter, op):
    (c.increment if op[2] else c.decrement)(op[0], op[1])


def apply_orset(s: ORSet, op):
    replica, elem, is_add = op
    if is_add:
        s.add(elem, replica)
    else:
        s.remove(elem)


gcounter_ops = st.lists(st.tuples(st.sampled_from(REPLICAS),
                                  st.integers(0, 10)), max_size=20)
pncounter_ops = st.lists(st.tuples(st.sampled_from(REPLICAS),
                                   st.integers(0, 10), st.booleans()),
                         max_size=20)
orset_ops = st.lists(st.tuples(st.sampled_from(REPLICAS),
                               st.integers(0, 5), st.booleans()),
                     max_size=24)


def _build(cls, apply_fn, ops_by_replica):
    out = []
    for r, ops in zip(REPLICAS, ops_by_replica):
        c = cls()
        for op in ops:
            apply_fn(c, op)
        out.append(c)
    return out


CASES = [
    (GCounter, apply_gcounter, gcounter_ops),
    (PNCounter, apply_pncounter, pncounter_ops),
    (ORSet, apply_orset, orset_ops),
]


@pytest.mark.parametrize("cls,apply_fn,ops_st", CASES,
                         ids=["gcounter", "pncounter", "orset"])
def test_merge_laws(cls, apply_fn, ops_st):
    @settings(max_examples=60, deadline=None)
    @given(st.tuples(ops_st, ops_st, ops_st))
    def run(ops3):
        a, b, c = _build(cls, apply_fn, ops3)
        # commutativity: a ⊔ b == b ⊔ a
        ab = copy.deepcopy(a); ab.merge(b)
        ba = copy.deepcopy(b); ba.merge(a)
        assert ab.value() == ba.value()
        # associativity: (a ⊔ b) ⊔ c == a ⊔ (b ⊔ c)
        abc1 = copy.deepcopy(a); abc1.merge(b); abc1.merge(c)
        bc = copy.deepcopy(b); bc.merge(c)
        abc2 = copy.deepcopy(a); abc2.merge(bc)
        assert abc1.value() == abc2.value()
        # idempotence: a ⊔ a == a
        aa = copy.deepcopy(a)
        changed = aa.merge(a)
        assert aa.value() == a.value() and not changed

    run()


@pytest.mark.parametrize("cls,apply_fn,ops_st", CASES,
                         ids=["gcounter", "pncounter", "orset"])
def test_convergence_any_delivery_order(cls, apply_fn, ops_st):
    """All replicas converge regardless of merge order/duplication."""
    @settings(max_examples=40, deadline=None)
    @given(st.tuples(ops_st, ops_st, ops_st),
           st.lists(st.tuples(st.integers(0, 2), st.integers(0, 2)),
                    min_size=6, max_size=20))
    def run(ops3, gossip):
        replicas = _build(cls, apply_fn, ops3)
        # arbitrary pairwise gossip (with duplication)...
        for i, j in gossip:
            if i != j:
                replicas[i].merge(replicas[j])
        # ...then a full exchange round to close the gaps
        for i in range(3):
            for j in range(3):
                if i != j:
                    replicas[i].merge(replicas[j])
        vals = [r.value() for r in replicas]
        assert vals[0] == vals[1] == vals[2]

    run()


def test_orset_add_wins():
    a, b = ORSet(), ORSet()
    a.add("x", "r0")
    b.merge(a)
    b.remove("x")          # b observed r0's add and removes it
    a.add("x", "r0")       # concurrent re-add with a NEW tag
    a.merge(b)
    b.merge(a)
    assert a.contains("x") and b.contains("x")


def test_lww_register_total_order():
    a, b = LWWRegister(), LWWRegister()
    a.set("first", 1.0, "r0")
    b.set("second", 2.0, "r1")
    a.merge(b)
    assert a.value() == "second"
    # tie on timestamp → replica id breaks it deterministically
    c, d = LWWRegister(), LWWRegister()
    c.set("cc", 5.0, "ra")
    d.set("dd", 5.0, "rb")
    c2 = copy.deepcopy(c); c2.merge(d)
    d2 = copy.deepcopy(d); d2.merge(c)
    assert c2.value() == d2.value() == "dd"


def test_mv_register_keeps_concurrent_siblings():
    a, b = MVRegister(), MVRegister()
    a.set("va", "r0")
    b.set("vb", "r1")
    a.merge(b)
    assert set(a.value()) == {"va", "vb"}
    # causal overwrite collapses siblings
    a.set("resolved", "r0")
    b.merge(a)
    assert b.value() == ("resolved",)


def test_replicated_store_digest_and_merge():
    s1 = ReplicatedStore("a")
    s2 = ReplicatedStore("b")
    s1.counter("steps").increment("a", 5)
    s1.orset("ckpts").add((1, b"x"), "a")
    s2.counter("steps").increment("b", 7)
    s2.register("latest").set((2, b"y"), 10.0, "b")
    assert s1.digest() != s2.digest()
    s1.merge(s2)
    s2.merge(s1)
    assert s1.digest() == s2.digest()
    assert s1.counter("steps").value() == 12
    # serialize roundtrip preserves digest
    s3 = ReplicatedStore.deserialize(s1.serialize(), "c")
    assert s3.digest() == s1.digest()


def test_deserialize_refuses_hostile_state():
    """Anti-entropy state arrives from arbitrary peers: the decoder must
    resolve only CRDT classes, never attacker-chosen globals."""
    import os
    import pickle

    class Exploit:
        def __reduce__(self):
            return (os.system, ("echo pwned",))

    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": Exploit()}))
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps(["not", "a", "dict"]))
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": "not-a-crdt"}))
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(b"\x80\x04 garbage")
    # allowlisted classes with type-confused internals are rejected up
    # front: merge()/digest() would otherwise raise mid-mutation and
    # poison the local store
    confused = GCounter()
    confused.counts = {"r": "not-an-int"}
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": confused}))
    bad_set = ORSet()
    bad_set.tombstones = {("r", "unsortable-seq")}
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": bad_set}))
    bad_mv = MVRegister()
    bad_mv.versions = {("not", "frozenset"): 1}
    with pytest.raises(ValueError):
        ReplicatedStore.deserialize(pickle.dumps({"k": bad_mv}))
    # every in-tree CRDT kind still round-trips through the allowlist
    s = ReplicatedStore("a")
    s.counter("steps").increment("a", 3)
    s.orset("ckpts").add((1, 0x70, b"\x01" * 32), "a")
    s.register("latest").set((1, 0x70, b"\x01" * 32), 1.0, "a")
    back = ReplicatedStore.deserialize(s.serialize(), "b")
    assert back.digest() == s.digest()
