"""Collaborative (DiLoCo-style) training rounds over the mesh.

Covers the coordinator-free round protocol end to end on a real simulated
fleet: bit-identical replicated outer state, top-k + int8 wire compression,
quorum close under mid-round worker loss, and crash/rejoin via CRDT merge
+ pinned contribution replay (the "membership under partition" property —
a dropped member must neither block the round nor fork outer state when
it comes back).
"""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.core.service import RpcStatus, ServiceError
from repro.data import make_batch_iterator
from repro.optim import cosine_schedule
from repro.train import train_state_init
from repro.train.collab import CollabConfig, CollabWorker
from repro.train.compress import (average_flat, compress_pseudograd,
                                  flat_digest, flat_from_entries,
                                  pseudo_gradient, tree_to_flat)


def _cfg():
    return get_config("minicpm-2b").reduced(n_layers=2, d_model=64, vocab=128)


def _make_workers(fleet, cfg, n, ccfg, fleet_name="fleetC"):
    sched = cosine_schedule(1e-3, 5, 400)
    workers = []
    for i in range(n):
        data = make_batch_iterator(cfg.vocab, 32, global_batch=4,
                                   n_shards=n, shard=i, seed=1)
        workers.append(CollabWorker(
            fleet.peers[i], cfg, train_state_init(cfg, jax.random.PRNGKey(0)),
            sched, data, fleet_name, collab=ccfg, step_seconds=0.2))
    return workers


# ---------------------------------------------------------------- compress
def test_compress_roundtrip_and_residual_identity():
    """sent == what receivers decode, so error feedback (grad - sent) is
    exactly the mass the fleet did NOT apply; wire bytes ≈ frac·(idx+val)."""
    rng = np.random.default_rng(3)
    grad = {"a/w": rng.normal(size=(200, 64)).astype(np.float32),
            "b/w": rng.normal(size=(4097,)).astype(np.float32),
            "tiny": rng.normal(size=(8,)).astype(np.float32)}
    parts, sent, stats = compress_pseudograd(grad, frac=0.05,
                                             quant="int8_block")
    decoded = flat_from_entries([(n, raw, meta) for n, raw, meta in parts])
    assert set(decoded) == set(grad)
    for k in grad:
        np.testing.assert_array_equal(decoded[k], sent[k])
    # sub-threshold leaves ship dense and exact
    np.testing.assert_array_equal(sent["tiny"], grad["tiny"])
    assert stats["wire_bytes"] < 0.10 * stats["dense_bytes"]
    # deterministic: same grad → same parts bytes → same CIDs mesh-wide
    parts2, _, _ = compress_pseudograd(grad, frac=0.05, quant="int8_block")
    assert [(n, r, m) for n, r, m in parts] == [(n, r, m)
                                               for n, r, m in parts2]


def test_pseudo_gradient_and_average_are_deterministic():
    rng = np.random.default_rng(4)
    a = {"w": rng.normal(size=(1000,)).astype(np.float32)}
    b = {"w": (a["w"] + rng.normal(size=(1000,)) * 1e-3).astype(np.float32)}
    g = pseudo_gradient(a, b)
    np.testing.assert_allclose(
        g["w"], (a["w"].astype(np.float64)
                 - b["w"].astype(np.float64)).astype(np.float32))
    avg = average_flat([g, g, g])
    np.testing.assert_array_equal(avg["w"], g["w"])
    assert flat_digest(avg) == flat_digest(g)


# ------------------------------------------------------------ round protocol
def test_collab_rounds_converge_bit_identical():
    """4 workers × 3 rounds, no coordinator: every worker lands on the
    same outer digest, zero aborted rounds, compressed wire ≤ 0.10× the
    fp32 full-exchange bytes, and no contribution pin outlives its
    replay window."""
    cfg = _cfg()
    fleet = make_fleet(6, seed=3, same_region="us")
    sim = fleet.sim
    ccfg = CollabConfig(inner_steps=8, settle=0.5, topk_frac=0.05)
    workers = _make_workers(fleet, cfg, 4, ccfg)

    procs = [sim.process(w.run(3, log=None)) for w in workers]
    sim.run(until=sim.now + 600)
    for p in procs:
        assert p.triggered, "worker process never finished"
        assert not p.failed, p.value

    assert all(w.outer_round == 3 for w in workers)
    assert all(w.stats["rounds_aborted"] == 0 for w in workers)
    digests = {w.outer_digest() for w in workers}
    assert len(digests) == 1, "outer state forked across the fleet"
    ratio = (workers[0].stats["wire_bytes"]
             / workers[0].stats["dense_bytes"])
    assert ratio <= 0.10, f"wire ratio {ratio:.3f} > 0.10"
    assert all(w.overdue_pins() == 0 for w in workers)


def test_collab_member_drop_quorum_close_and_rejoin():
    """Membership under partition: worker 3 dies mid-round-1; the quorum
    closes every round without it (zero aborts) and the survivors stay
    bit-identical.  On rejoin, catch_up merges the closed rounds from the
    CRDT record + pinned contribution DAGs instead of forking."""
    cfg = _cfg()
    fleet = make_fleet(6, seed=3, same_region="us")
    sim = fleet.sim
    ccfg = CollabConfig(inner_steps=8, settle=0.5, keep_rounds=4)
    workers = _make_workers(fleet, cfg, 4, ccfg)
    procs = [sim.process(w.run(3, log=None)) for w in workers]

    def killer():   # stop worker 3 mid-inner-phase of round 1
        while not any(h["round"] == 1 for h in workers[3].history):
            yield 0.25
        yield 0.3
        workers[3].stop()

    sim.process(killer(), daemon=True)
    sim.run(until=sim.now + 600)
    for p in procs[:3]:
        assert p.triggered and not p.failed, getattr(p, "value", None)

    assert all(w.outer_round == 3 for w in workers[:3])
    assert all(w.stats["rounds_aborted"] == 0 for w in workers[:3])
    d_surv = {w.outer_digest() for w in workers[:3]}
    assert len(d_surv) == 1
    # the dropout applied round 0 then died inside round 1: behind AND
    # diverged from the fleet until it merges
    assert workers[3].outer_round == 1
    assert workers[3].outer_digest() not in d_surv

    rejoin = sim.process(workers[3].run(1, log=None))
    more = [sim.process(w.run(1, log=None)) for w in workers[:3]]
    sim.run(until=sim.now + 600)
    assert rejoin.triggered and not rejoin.failed, rejoin.value
    for p in more:
        assert p.triggered and not p.failed, getattr(p, "value", None)

    assert workers[3].stats["catchup_rounds"] >= 1
    digests = {w.outer_digest() for w in workers}
    assert len(digests) == 1, "rejoiner forked outer state"
    assert all(w.overdue_pins() == 0 for w in workers)


def test_collab_status_rpc():
    """CollabService.status lets any peer verify replicated convergence
    (round + digest) without shipping parameters."""
    cfg = _cfg()
    fleet = make_fleet(6, seed=9, same_region="us")
    sim = fleet.sim
    ccfg = CollabConfig(inner_steps=4, settle=0.5)
    workers = _make_workers(fleet, cfg, 2, ccfg, fleet_name="fleetS")
    procs = [sim.process(w.run(1, log=None)) for w in workers]
    sim.run(until=sim.now + 300)
    for p in procs:
        assert p.triggered and not p.failed, getattr(p, "value", None)

    def probe():
        st = yield from workers[0].peer_status(fleet.peers[1].info())
        return st

    st = sim.run_process(probe(), until=sim.now + 60)
    assert st["round"] == 1
    assert st["digest"] == workers[0].outer_digest()
    assert st["closed"] == 1

    def probe_missing():
        from repro.train.collab import CollabService
        stub = workers[0].node.stub(CollabService, fleet.peers[1].info())
        try:
            yield from stub.status("no-such-fleet")
        except ServiceError as e:
            return e.status
        return None

    status = sim.run_process(probe_missing(), until=sim.now + 60)
    assert status == RpcStatus.NOT_FOUND
