"""Kademlia: routing tables, iterative lookup, provider records, scaling."""

import hashlib

import pytest

from repro.core.dht import RoutingTable, PeerInfo
from repro.core.fleet import make_fleet
from repro.core.peer import PeerId


def test_routing_table_buckets_and_eviction():
    me = PeerId.from_name("me")
    rt = RoutingTable(me, k=4)
    infos = [PeerInfo(PeerId.from_name(f"p{i}"), f"p{i}") for i in range(200)]
    for i in infos:
        rt.update(i)
    # k-bounded buckets
    assert all(len(b) <= 4 for b in rt.buckets)
    # closest() is sorted by xor distance
    key = hashlib.sha256(b"target").digest()
    closest = rt.closest(key, 10)
    dists = [c.peer_id.distance_to_key(key) for c in closest]
    assert dists == sorted(dists)
    rt.remove(infos[0].peer_id)
    assert infos[0].peer_id not in {i.peer_id for i in rt.closest(key, 200)}


def test_put_get_across_fleet():
    fleet = make_fleet(14, seed=11)
    sim = fleet.sim
    writer, reader = fleet.peers[0], fleet.peers[-1]

    def put():
        key = hashlib.sha256(b"model-meta").digest()
        n = yield from writer.dht.put(key, {"step": 42})
        return key, n

    key, n_stored = sim.run_process(put(), until=sim.now + 300)
    assert n_stored >= 1

    def get():
        val = yield from reader.dht.get(key)
        return val

    assert sim.run_process(get(), until=sim.now + 300) == {"step": 42}


def test_provider_records():
    fleet = make_fleet(12, seed=5)
    sim = fleet.sim
    provider, seeker = fleet.peers[2], fleet.peers[-1]
    key = hashlib.sha256(b"artifact").digest()

    def provide():
        n = yield from provider.dht.provide(key)
        return n

    assert sim.run_process(provide(), until=sim.now + 300) >= 1

    def find():
        provs = yield from seeker.dht.find_providers(key)
        return provs

    provs = sim.run_process(find(), until=sim.now + 300)
    assert provider.peer_id in {p.peer_id for p in provs}


def test_lookup_rounds_scale_sublinearly():
    """O(log N): rounds should grow far slower than N."""
    rounds = {}
    for n in (8, 32):
        fleet = make_fleet(n, seed=7, same_region="us")
        sim = fleet.sim
        node = fleet.peers[0]
        node.dht.stats["rounds"] = 0
        node.dht.stats["lookups"] = 0

        def lookup():
            key = hashlib.sha256(b"some-far-key").digest()
            yield from node.dht.find_node(key)

        sim.run_process(lookup(), until=sim.now + 300)
        rounds[n] = node.dht.stats["rounds"] / max(node.dht.stats["lookups"], 1)
    # 4x the peers must not cost 4x the rounds
    assert rounds[32] <= rounds[8] * 3 + 2
