"""Partition tolerance + monitoring: the paper's 'adversarial and
heterogeneous networks' claim under an actual partition."""

from repro.core.fleet import make_fleet
from repro.core.metrics import dashboard, node_snapshot
from repro.core.simnet import DialError


def test_crdt_converges_after_partition_heals():
    fleet = make_fleet(8, seed=19)
    sim = fleet.sim
    # relay reservations die with the partition; maintenance re-reserves
    for n in fleet.peers:
        sim.process(n.maintenance_loop(interval=5.0))
    us_nodes = [n for n in fleet.peers if n.host.region == "us"]
    eu_nodes = [n for n in fleet.peers if n.host.region == "eu"]
    assert us_nodes and eu_nodes
    a, b = us_nodes[0], eu_nodes[0]

    # partition the continents (existing cross-links die too)
    fleet.net.set_partition("us", "eu", blocked=True)

    # divergent writes on both sides
    a.store.counter("steps").increment(a.host.name, 3)
    b.store.counter("steps").increment(b.host.name, 5)

    def sync_attempt():
        try:
            yield from a.sync_crdt_with(b.info())
            return True
        except (DialError, Exception):
            return False

    # cross-partition sync must fail while partitioned
    ok = sim.run_process(sync_attempt(), until=sim.now + 120)
    assert not ok or a.store.digest() != b.store.digest()

    # heal; give maintenance a couple of ticks to re-reserve relays
    fleet.net.set_partition("us", "eu", blocked=False)
    sim.run(until=sim.now + 15)
    healed = sim.run_process(sync_attempt(), until=sim.now + 300)
    assert healed
    assert a.store.digest() == b.store.digest()
    assert a.store.counter("steps").value() == 8


def test_partition_blocks_new_dials():
    fleet = make_fleet(6, seed=23)
    sim = fleet.sim
    us = [n for n in fleet.peers if n.host.region == "us"][0]
    eu = [n for n in fleet.peers if n.host.region == "eu"][0]
    # drop any pre-existing cross-links, then partition every path from us:
    # the bootstraps live in us/eu/ap, so block all three pairs
    for r in ("eu", "ap"):
        fleet.net.set_partition("us", r, blocked=True)

    def dial():
        try:
            yield from us.connect_info(eu.info())
            return True
        except DialError:
            return False

    assert sim.run_process(dial(), until=sim.now + 300) is False
    fleet.net.set_partition("us", "eu", blocked=False)
    fleet.net.set_partition("us", "ap", blocked=False)
    # target re-reserves its relay slot after the heal (maintenance step)
    def re_reserve():
        if eu.relay_info is not None:
            yield from eu.reserve_relay(eu.relay_info)
    sim.run_process(re_reserve(), until=sim.now + 120)
    assert sim.run_process(dial(), until=sim.now + 300) is True


def test_metrics_snapshot_and_dashboard():
    fleet = make_fleet(5, seed=29)
    snap = node_snapshot(fleet.peers[0])
    assert snap["name"] == "peer0"
    assert "dht.queries" in snap and "bitswap.blocks_served" in snap
    assert snap["n_connections"] >= 1          # bootstrapped
    dash = dashboard(fleet.all_nodes)
    assert "fleet:" in dash
    assert len(dash.splitlines()) >= len(fleet.all_nodes) + 4
    # per-method RPC section (fed by the service-layer metrics interceptor)
    assert "per-method RPC" in dash
    assert "id.exchange" in dash            # bootstrap identity exchanges
    assert "kad.find_node" in dash          # DHT self-lookups
