"""Paged single-query decode attention: jnp path vs the dense oracle,
Pallas interpret vs jnp, and the int8-pool error bound."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import paged_decode_attention
from repro.kernels.paged_attention import (paged_attention_jnp,
                                           paged_attention_pallas)
from repro.kernels.ref import attention_ref

PAGE = 8
NP = 4          # pages per slot: up to NP*PAGE - 1 cached tokens
HK, REP, HD = 2, 2, 16
HQ = HK * REP

#: ragged slot lengths covering the edge cases: empty cache, one byte
#: short of a page boundary, exactly one full page, and mid-pool
LENGTHS = [0, PAGE - 1, PAGE, 2 * PAGE + 5]


def _problem(seed=0, lengths=LENGTHS, pool_pages=None):
    rng = np.random.default_rng(seed)
    M = len(lengths)
    P = pool_pages or (NP * M + 3)
    kp = rng.normal(size=(P, PAGE, HK, HD)).astype(np.float32)
    vp = rng.normal(size=(P, PAGE, HK, HD)).astype(np.float32)
    bt = rng.permutation(P)[: NP * M].reshape(M, NP).astype(np.int32)
    q = rng.normal(size=(M, HQ, HD)).astype(np.float32)
    kn = rng.normal(size=(M, HK, HD)).astype(np.float32)
    vn = rng.normal(size=(M, HK, HD)).astype(np.float32)
    return (q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(bt),
            jnp.asarray(np.asarray(lengths, np.int32)), jnp.asarray(kn),
            jnp.asarray(vn))


def _dense_oracle(q, kp, vp, bt, lengths, kn, vn):
    """Per-slot naive attention over the dense cache each slot *would*
    hold: its pool pages flattened up to ``length`` plus the new token."""
    kp, vp, bt = np.asarray(kp), np.asarray(vp), np.asarray(bt)
    out = np.zeros((len(lengths), HQ, HD), np.float32)
    for m, L in enumerate(np.asarray(lengths)):
        kd = np.concatenate(
            [kp[bt[m]].reshape(-1, HK, HD)[:L], np.asarray(kn)[m][None]], 0)
        vd = np.concatenate(
            [vp[bt[m]].reshape(-1, HK, HD)[:L], np.asarray(vn)[m][None]], 0)
        kd = np.repeat(kd, REP, axis=1)            # GQA share
        vd = np.repeat(vd, REP, axis=1)
        ref = attention_ref(
            jnp.asarray(np.asarray(q)[m][None, :, None, :]),  # (1, HQ, 1, HD)
            jnp.asarray(kd.transpose(1, 0, 2)[None]),
            jnp.asarray(vd.transpose(1, 0, 2)[None]), causal=True)
        out[m] = np.asarray(ref)[0, :, 0]
    return out


def test_jnp_matches_dense_oracle_at_ragged_lengths():
    args = _problem(seed=1)
    got = np.asarray(paged_attention_jnp(jnp.asarray(args[0]), *args[1:]))
    want = _dense_oracle(*args)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_pallas_interpret_matches_jnp():
    args = _problem(seed=2)
    jn = np.asarray(paged_attention_jnp(jnp.asarray(args[0]), *args[1:]))
    pa = np.asarray(paged_attention_pallas(jnp.asarray(args[0]), *args[1:],
                                           interpret=True))
    np.testing.assert_allclose(pa, jn, rtol=2e-5, atol=2e-6)


def test_dispatch_wrapper_runs_on_cpu():
    args = _problem(seed=3)
    got = np.asarray(paged_decode_attention(jnp.asarray(args[0]), *args[1:]))
    want = _dense_oracle(*args)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)


def test_stale_page_contents_never_leak():
    """Positions >= length — including the padded block-table pages and
    the slot's partially-filled last page — must not affect the output,
    no matter how large the garbage there is."""
    args = _problem(seed=4)
    q, kp, vp, bt, lengths, kn, vn = args
    kp, vp = np.asarray(kp).copy(), np.asarray(vp).copy()
    base = np.asarray(paged_attention_jnp(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), bt, lengths,
        kn, vn))
    # poison every pool position beyond each slot's length
    bt_np, ln = np.asarray(bt), np.asarray(lengths)
    for m in range(len(ln)):
        flat_k = kp[bt_np[m]].reshape(-1, HK, HD)
        flat_v = vp[bt_np[m]].reshape(-1, HK, HD)
        flat_k[ln[m]:] = 1e4
        flat_v[ln[m]:] = -1e4
        kp[bt_np[m]] = flat_k.reshape(NP, PAGE, HK, HD)
        vp[bt_np[m]] = flat_v.reshape(NP, PAGE, HK, HD)
    for fn in (paged_attention_jnp, paged_attention_pallas):
        poisoned = np.asarray(fn(jnp.asarray(q), jnp.asarray(kp),
                                 jnp.asarray(vp), bt, lengths, kn, vn))
        np.testing.assert_allclose(poisoned, base, rtol=2e-5, atol=2e-6)


def _quantize_pool(pool):
    """Per-(page, kv-head) maxabs int8, matching serving/batch.py."""
    amax = np.abs(pool).max(axis=(1, 3))
    scales = np.where(amax > 0, amax / 127.0, 1.0).astype(np.float32)
    q = np.rint(pool / scales[:, None, :, None]).astype(np.int8)
    return q, scales


def test_int8_pool_error_is_bounded():
    args = _problem(seed=5)
    q, kp, vp, bt, lengths, kn, vn = args
    kq, ks = _quantize_pool(np.asarray(kp))
    vq, vs = _quantize_pool(np.asarray(vp))
    # element-wise dequant bound: |x_hat - x| <= page_absmax / 254
    for pool, qz, sc in ((np.asarray(kp), kq, ks), (np.asarray(vp), vq, vs)):
        err = np.abs(qz.astype(np.float32) * sc[:, None, :, None] - pool)
        bound = np.abs(pool).max(axis=(1, 3)) / 254.0 + 1e-6
        assert (err <= bound[:, None, :, None]).all()
    fp = np.asarray(paged_attention_jnp(jnp.asarray(q), kp, vp, bt,
                                        lengths, kn, vn))
    for fn in (paged_attention_jnp, paged_attention_pallas):
        qa = np.asarray(fn(jnp.asarray(q), jnp.asarray(kq), jnp.asarray(vq),
                           bt, lengths, kn, vn,
                           k_scales=jnp.asarray(ks), v_scales=jnp.asarray(vs)))
        # unit-normal values, <=1% relative cache error: outputs stay close
        assert np.abs(qa - fp).max() < 0.08


def test_int8_quantized_pallas_matches_jnp():
    args = _problem(seed=6)
    q, kp, vp, bt, lengths, kn, vn = args
    kq, ks = _quantize_pool(np.asarray(kp))
    vq, vs = _quantize_pool(np.asarray(vp))
    common = (jnp.asarray(q), jnp.asarray(kq), jnp.asarray(vq), bt, lengths,
              kn, vn)
    jn = np.asarray(paged_attention_jnp(
        *common, k_scales=jnp.asarray(ks), v_scales=jnp.asarray(vs)))
    pa = np.asarray(paged_attention_pallas(
        *common, k_scales=jnp.asarray(ks), v_scales=jnp.asarray(vs)))
    np.testing.assert_allclose(pa, jn, rtol=2e-5, atol=2e-6)


def test_single_full_pool_exact_page_multiple():
    """A slot whose cache ends exactly on a page boundary (length == k*PAGE)
    must place the new token at the first slot of the next page."""
    lengths = [NP * PAGE - 1, PAGE, 2 * PAGE, 3 * PAGE]
    args = _problem(seed=7, lengths=lengths)
    got = np.asarray(paged_attention_jnp(jnp.asarray(args[0]), *args[1:]))
    want = _dense_oracle(*args)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-6)
