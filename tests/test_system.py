"""End-to-end behaviour of the full Lattica stack (the paper's Fig. 1)."""

import numpy as np

from repro.core import NATKind
from repro.core.fleet import DEFAULT_NAT_MIX, make_fleet


def test_full_mesh_connectivity_under_nat_mix():
    """Every peer can reach every other peer — directly or via relay."""
    fleet = make_fleet(10, seed=42)
    sim = fleet.sim
    reached = 0
    attempts = 0
    for a in fleet.peers[:5]:
        for b in fleet.peers[5:]:
            attempts += 1

            def connect(a=a, b=b):
                conn = yield from a.connect_info(b.info())
                return conn

            conn = sim.run_process(connect(), until=sim.now + 300)
            if conn is not None:
                reached += 1
    assert reached == attempts       # relays guarantee full connectivity


def test_direct_rate_roughly_matches_paper():
    """Paper §4: ~70% of dial attempts get a direct path (rest relay)."""
    fleet = make_fleet(24, seed=1)
    sim = fleet.sim
    direct = 0
    total = 0
    peers = fleet.peers
    for i in range(len(peers) - 1):
        a, b = peers[i], peers[(i + 7) % len(peers)]
        if a is b:
            continue

        def connect(a=a, b=b):
            conn = yield from a.connect_info(b.info())
            return conn

        conn = sim.run_process(connect(), until=sim.now + 300)
        total += 1
        if conn is not None and not conn.relayed:
            direct += 1
    rate = direct / total
    # the NAT mix yields a direct rate in the paper's ballpark
    assert 0.5 <= rate <= 0.95, rate


def test_state_converges_across_clusters():
    """CRDT registry written concurrently on two sides converges."""
    fleet = make_fleet(6, seed=33)
    sim = fleet.sim
    a, b = fleet.peers[0], fleet.peers[1]
    # concurrent writes
    a.store.orset("ckpt/f").add((1, b"aaa"), "a")
    a.store.counter("steps/f").increment("a", 10)
    b.store.orset("ckpt/f").add((2, b"bbb"), "b")
    b.store.counter("steps/f").increment("b", 5)

    def sync():
        yield from a.sync_crdt_with(b.info())

    sim.run_process(sync(), until=sim.now + 120)
    assert a.store.digest() == b.store.digest()
    assert a.store.counter("steps/f").value() == 15
    assert a.store.orset("ckpt/f").value() == {(1, b"aaa"), (2, b"bbb")}
