"""CRDT replication plane: v2 summary/delta protocol, v1 fallback + mixed
fleets, the crdt/<ns> delta push plane, watch_crdt, wait_converged."""

import pytest

from repro.core import LatticaNode, Network, ReplicatedStore, Sim
from repro.core.crdt import encode_entry
from repro.core.fleet import make_fleet, wait_converged


def _two(proto_a="v2", proto_b="v2", push=False, seed=5):
    sim = Sim(seed=seed)
    net = Network(sim)
    a = LatticaNode(net, "a", crdt_proto=proto_a, crdt_push=push)
    b = LatticaNode(net, "b", region="eu", crdt_proto=proto_b, crdt_push=push)
    sim.run_process(a.connect_info(b.info()))
    return sim, a, b


def test_v2_sync_moves_per_key_deltas():
    sim, a, b = _two()
    for i in range(50):
        a.store.orset(f"reg/k{i}").add((1, bytes([i]) * 32), "a")
    sim.run_process(a.sync_crdt_with(b.info()), until=sim.now + 300)
    assert a.store.digest() == b.store.digest()
    assert a.crdt_stats["delta_exchanges"] == 1
    assert a.crdt_stats["full_exchanges"] == 0

    # steady state: 1 key churns; the round must move far less than the
    # full store (summary + one fragment, not 50 keys of state)
    a.store.orset("reg/k0").add((2, b"\x02" * 32), "a")
    before = a.crdt_stats["tx_bytes"] + a.crdt_stats["rx_bytes"]
    sim.run_process(a.sync_crdt_with(b.info()), until=sim.now + 300)
    moved = a.crdt_stats["tx_bytes"] + a.crdt_stats["rx_bytes"] - before
    assert a.store.digest() == b.store.digest()
    assert moved < len(a.store.serialize())
    # a clean round stops at the digest probe: zero payload bytes
    before = a.crdt_stats["tx_bytes"] + a.crdt_stats["rx_bytes"]
    assert not sim.run_process(a.sync_crdt_with(b.info()),
                               until=sim.now + 300)
    assert a.crdt_stats["tx_bytes"] + a.crdt_stats["rx_bytes"] == before


def test_v2_falls_back_to_v1_peers_and_remembers():
    sim, a, v1 = _two(proto_b="v1")
    a.store.counter("steps").increment("a", 3)
    v1.store.counter("steps").increment("b", 4)
    sim.run_process(a.sync_crdt_with(v1.info()), until=sim.now + 300)
    assert a.store.digest() == v1.store.digest()
    assert a.store.counter("steps").value() == 7
    assert a.crdt_stats["full_exchanges"] == 1
    assert a._crdt_peer_proto[v1.peer_id] == "v1"

    # v1 node initiating against a v2 responder also converges (the v2
    # node still serves the full v1 surface)
    v1.store.counter("steps").increment("b", 2)
    sim.run_process(v1.sync_crdt_with(a.info()), until=sim.now + 300)
    assert a.store.digest() == v1.store.digest()
    assert v1.crdt_stats["full_exchanges"] == 1
    assert v1.crdt_stats["delta_exchanges"] == 0


def test_push_reaches_watchers_without_anti_entropy():
    fleet = make_fleet(6, seed=31, same_region="us")
    sim = fleet.sim
    writer, subs = fleet.peers[0], fleet.peers[1:]
    fired = {}
    for n in subs:
        n.watch_crdt(
            "reg/", lambda k, v, o, name=n.host.name:
            fired.setdefault(name, (k, o)))
    sim.run(until=sim.now + 5)          # subscription propagation
    writer.store.orset("reg/models").add((1, b"\x01" * 32), writer.host.name)
    sim.run(until=sim.now + 5)          # one gossip round, no anti-entropy
    assert len(fired) == len(subs), fired
    for key, origin in fired.values():
        assert key == "reg/models" and origin == "remote"
    for n in subs:
        assert (1, b"\x01" * 32) in n.store.orset("reg/models").value()
    assert writer.crdt_stats["push_published"] >= 1


def test_push_batches_same_instant_writes():
    fleet = make_fleet(3, seed=12, same_region="us")
    sim = fleet.sim
    w = fleet.peers[0]
    fleet.peers[1].watch_crdt("reg/", lambda *a: None)
    sim.run(until=sim.now + 5)
    w.store.orset("reg/a").add(1, w.host.name)
    w.store.orset("reg/b").add(2, w.host.name)
    w.store.counter("reg/c").increment(w.host.name)
    sim.run(until=sim.now + 5)
    # one namespace, one burst -> one delta document published
    assert w.crdt_stats["push_published"] == 1


def test_hostile_push_is_rejected_not_applied():
    fleet = make_fleet(2, seed=8, same_region="us")
    sim = fleet.sim
    a, b = fleet.peers
    b.watch_crdt("reg/", lambda *args: None)
    sim.run(until=sim.now + 5)
    digest = b.store.digest()
    # garbage, malformed docs, and kind-conflicting fragments all bounce
    b._on_crdt_push_msg("crdt/reg", b"\x80\x04 garbage", a.peer_id)
    b._on_crdt_push_msg("crdt/reg", b"CRD2{\"v\":2,\"d\":{\"k\":3}}",
                        a.peer_id)
    b.store.counter("reg/x").increment(b.host.name)
    digest = b.store.digest()
    conflict = ReplicatedStore("x")
    conflict.orset("reg/x").add(1, "x")     # reg/x is a counter at b
    b._on_crdt_push_msg("crdt/reg",
                        ReplicatedStore.encode_delta(
                            {"reg/x": conflict.entries["reg/x"]}),
                        a.peer_id)
    assert b.store.digest() == digest
    assert b.crdt_stats["push_rejected"] == 3
    assert b.crdt_stats["push_applied"] == 0


def test_anti_entropy_loop_survives_v2_and_converges():
    fleet = make_fleet(4, seed=21, same_region="us")
    sim = fleet.sim
    for i, n in enumerate(fleet.peers):
        n.store.counter("steps").increment(n.host.name, i + 1)
        sim.process(n.anti_entropy_loop(interval=2.0))
    assert wait_converged(sim, fleet.peers, timeout=600)
    assert fleet.peers[0].store.counter("steps").value() == 10


def test_wait_converged_times_out_when_partitioned():
    sim = Sim(seed=2)
    a, b = ReplicatedStore("a"), ReplicatedStore("b")
    a.counter("x").increment("a", 1)
    assert not wait_converged(sim, [a, b], timeout=5.0)
    # converge mid-wait: a process merges after 1 s, the watch wakes the
    # waiter immediately (no polling interval to round up to)
    def later():
        yield 1.0
        b.merge(a)
    sim.process(later())
    t0 = sim.now
    assert wait_converged(sim, [a, b], timeout=60.0)
    assert sim.now - t0 < 2.0


def test_v2_wire_docs_are_json_not_pickle():
    """The canonical path never hands peer bytes to pickle: v2 snapshots
    and delta docs are magic-prefixed JSON."""
    s = ReplicatedStore("a")
    s.counter("x").increment("a", 1)
    assert s.serialize()[:4] == b"CRD2"
    blob = ReplicatedStore.encode_delta(s.delta_since({}))
    assert blob[:4] == b"CRD2"
    import json
    doc = json.loads(blob[4:])
    assert doc["v"] == 2 and "x" in doc["d"]
    assert doc["d"]["x"] == encode_entry(s.entries["x"])


def test_v1_node_rejects_nothing_it_served_before():
    """A v1-proto node keeps accepting the legacy pickled exchange payloads
    (regression: the redesign must not strand old-format state)."""
    import pickle
    sim, a, b = _two(proto_a="v1", proto_b="v1")
    a.store.counter("steps").increment("a", 2)
    legacy = pickle.dumps(a.store.entries)
    restored = ReplicatedStore.deserialize(legacy)
    assert restored.digest() == a.store.digest()
    sim.run_process(a.sync_crdt_with(b.info()), until=sim.now + 300)
    assert a.store.digest() == b.store.digest()


def test_steady_state_skips_summary_after_digest_match():
    """Once a pair has converged, a round with local-only churn rides a
    blind delta push keyed off the cached (digest, vv) snapshot — no
    per-key summary exchange."""
    sim, a, b = _two()
    for i in range(20):
        a.store.orset(f"reg/k{i}").add((1, bytes([i]) * 32), "a")
    sim.run_process(a.sync_crdt_with(b.info()), until=sim.now + 300)
    assert a.store.digest() == b.store.digest()
    # clean round: digest probe matches, snapshot cached, nothing skipped
    assert not sim.run_process(a.sync_crdt_with(b.info()),
                               until=sim.now + 300)
    assert a.crdt_stats["summary_skipped"] == 0
    skipped_before = a.crdt_stats["delta_exchanges"]

    # steady state: only A churns → summary round elided entirely
    a.store.orset("reg/k0").add((2, b"\x02" * 32), "a")
    sim.run_process(a.sync_crdt_with(b.info()), until=sim.now + 300)
    assert a.store.digest() == b.store.digest()
    assert a.crdt_stats["summary_skipped"] == 1
    assert a.crdt_stats["delta_exchanges"] == skipped_before + 1
    assert (2, b"\x02" * 32) in b.store.orset("reg/k0").value()

    # both sides churned: the peer's digest no longer matches the cached
    # snapshot, so the full summary path runs — and still converges
    a.store.orset("reg/k1").add((3, b"\x03" * 32), "a")
    b.store.orset("reg/k2").add((3, b"\x04" * 32), "b")
    sim.run_process(a.sync_crdt_with(b.info()), until=sim.now + 300)
    assert a.store.digest() == b.store.digest()
    assert a.crdt_stats["summary_skipped"] == 1      # no bogus skip


# -- Merkle summary forest: probe equivalence with the flat summary ----------


def _rand_ops(rng, store, n, replica, clock):
    """Apply ``n`` random mutations across three namespaces."""
    for _ in range(n):
        ns = rng.choice(("reg", "models", "gate"))
        i = rng.randrange(40)
        kind = rng.randrange(3)
        if kind == 0:
            store.counter(f"{ns}/c{i}").increment(replica, rng.randrange(1, 4))
        elif kind == 1:
            store.orset(f"{ns}/s{i}").add(rng.randrange(8), replica)
        else:
            clock[0] += 1.0
            store.register(f"{ns}/r{i}").set(rng.randrange(100), clock[0],
                                             replica)


def _mst_localized(a, b):
    """Keys a summary-forest walk localizes as differing between two
    stores — the local-model mirror of the ``crdt.mst`` probe."""
    fa, fb = a.summary_forest(), b.summary_forest()
    diff = set()
    for ns in set(fa) | set(fb):
        ta, tb = fa.get(ns), fb.get(ns)
        if ta is None or tb is None:
            diff.update((ta or tb).keys_under(""))
            continue
        stack = [""]
        while stack:
            path = stack.pop()
            if ta.node_hash(path) == tb.node_hash(path):
                continue
            if ta.is_leaf(path) or tb.is_leaf(path):
                ka, kb = ta.leaf_digests(path), tb.leaf_digests(path)
                diff.update(k for k in set(ka) | set(kb)
                            if ka.get(k) != kb.get(k))
                continue
            ca, cb = ta.children(path), tb.children(path)
            stack.extend(path + nib for nib in set(ca) | set(cb)
                         if ca.get(nib) != cb.get(nib))
    return diff


def test_mst_walk_localizes_exactly_the_flat_diff():
    """Property (seeded randomized sweep — hypothesis is not in the
    image): on randomized divergent stores, walking the two summary
    forests localizes exactly the keys whose per-key digests differ in
    the flat ``key_digests()`` summary — no misses, no false positives.
    That equivalence is what lets the mst probe replace the O(keys)
    summary round wholesale."""
    import random

    for seed in range(8):
        a, b = ReplicatedStore("a"), ReplicatedStore("b")
        shared = [random.Random(seed), random.Random(seed)]
        _rand_ops(shared[0], a, 60, "s", [0.0])
        _rand_ops(shared[1], b, 60, "s", [0.0])
        assert a.digest() == b.digest()
        assert _mst_localized(a, b) == set()
        _rand_ops(random.Random(1000 + seed), a, 15, "a", [100.0])
        _rand_ops(random.Random(2000 + seed), b, 15, "b", [200.0])
        da, db = a.key_digests(), b.key_digests()
        flat = {k for k in set(da) | set(db) if da.get(k) != db.get(k)}
        assert _mst_localized(a, b) == flat
        assert flat        # the sweep actually diverged something


def test_mst_sync_converges_randomized_stores():
    """End-to-end check of the wire walk on the same randomized shapes:
    one mst sync round reconciles every divergent key both ways."""
    import random

    for seed in (0, 1):
        sim, a, b = _two(proto_a="mst", proto_b="mst", seed=40 + seed)
        shared = [random.Random(seed), random.Random(seed)]
        _rand_ops(shared[0], a.store, 60, "s", [0.0])
        _rand_ops(shared[1], b.store, 60, "s", [0.0])
        _rand_ops(random.Random(1000 + seed), a.store, 15, "a", [100.0])
        _rand_ops(random.Random(2000 + seed), b.store, 15, "b", [200.0])
        assert a.store.digest() != b.store.digest()
        sim.run_process(a.sync_crdt_with(b.info()), until=sim.now + 300)
        assert a.store.digest() == b.store.digest()
        assert a.crdt_stats["mst_exchanges"] == 1


def test_push_apply_advances_baseline_without_rebroadcast():
    """Regression: applying a pushed delta used to leave the receiver's
    push baseline behind, so the receiver's next local write re-published
    the entire namespace it had just received — at fleet scale every
    subscriber re-broadcasting every push turned one write into an
    overlay-wide echo storm.  A push-applied key (with no unflushed local
    edits) must advance the baseline; the next flush carries only the
    local delta."""
    fleet = make_fleet(4, seed=9, same_region="us", nat_kinds=[None] * 4)
    sim = fleet.sim
    for n in fleet.peers:
        n.join_crdt_push("reg")
    sim.run(until=sim.now + 5)
    a, b = fleet.peers[0], fleet.peers[1]
    for i in range(8):
        a.store.orset(f"reg/bulk{i}").add((i, bytes([i]) * 32), a.host.name)
    assert wait_converged(sim, fleet.peers, timeout=300.0)
    assert b.crdt_stats["push_applied"] >= 1
    # b's baseline covers the pushed keys: nothing pending to re-publish
    assert not b.store.delta_since(b._push_vv)

    sent = []
    publish = b.pubsub.publish
    def spy(topic, data, size=256):
        sent.append(data)
        return publish(topic, data, size)
    b.pubsub.publish = spy
    b.store.counter("reg/steps").increment(b.host.name, 1)
    assert wait_converged(sim, fleet.peers, timeout=300.0)
    keys = set()
    for blob in sent:
        keys |= set(ReplicatedStore.decode_delta(blob))
    assert keys == {"reg/steps"}
