"""Gossip pub/sub: delivery, dedup, topic scoping, scored-mesh dynamics
(graft/prune under score decay, UNSUBSCRIBE propagation, IHAVE/IWANT
repair, last-resort forwarding hygiene)."""

from repro.core import LatticaNode
from repro.core.fleet import make_fleet
from repro.core.pubsub import (HEARTBEAT, MESH_DEGREE,
                               SCORE_PRUNE_THRESHOLD)


def test_publish_reaches_subscribers():
    fleet = make_fleet(10, seed=8, same_region="us")
    sim = fleet.sim
    got = {n.host.name: [] for n in fleet.peers}
    for n in fleet.peers[1:]:
        n.pubsub.subscribe(
            "models", lambda t, d, f, name=n.host.name: got[name].append(d))

    def announce_and_publish():
        # subscriptions propagate lazily; re-announce after subscribing
        for n in fleet.peers:
            for pid in list(n.peers):
                yield from n.pubsub.announce_subscriptions(pid)
        yield from fleet.peers[0].pubsub.publish("models", ("v", 1))
        yield 5.0

    sim.run_process(announce_and_publish(), until=sim.now + 300)
    sim.run(until=sim.now + 30)
    reached = sum(1 for n in fleet.peers[1:] if got[n.host.name])
    assert reached >= len(fleet.peers) - 2      # gossip mesh coverage
    # no duplicate deliveries anywhere
    for msgs in got.values():
        assert len(msgs) <= 1


def test_late_subscription_propagates_without_manual_announce():
    """Regression: ``subscribe`` after connections exist used to stay
    invisible (topics were only exchanged lazily at announce time), so a
    fresh subscriber missed the next publish.  Subscribing must now push
    the update to known peers by itself."""
    fleet = make_fleet(6, seed=13, same_region="us")
    sim = fleet.sim
    got = []
    # subscribe AFTER the mesh is joined — no announce_subscriptions calls
    fleet.peers[3].pubsub.subscribe("late", lambda t, d, f: got.append(d))
    sim.run(until=sim.now + 5)          # the proactive update lands

    sim.run_process(fleet.peers[0].pubsub.publish("late", ("v", 7)),
                    until=sim.now + 60)
    sim.run(until=sim.now + 10)
    assert got == [("v", 7)]


def test_unsubscribed_topic_not_delivered():
    fleet = make_fleet(6, seed=3, same_region="us")
    sim = fleet.sim
    got = []
    fleet.peers[1].pubsub.subscribe("a", lambda t, d, f: got.append(d))

    def run():
        for n in fleet.peers:
            for pid in list(n.peers):
                yield from n.pubsub.announce_subscriptions(pid)
        yield from fleet.peers[0].pubsub.publish("b", "wrong-topic")
        yield 5.0

    sim.run_process(run(), until=sim.now + 120)
    sim.run(until=sim.now + 30)
    assert got == []


# -- scored-mesh dynamics ----------------------------------------------------


def test_prune_on_score_collapse_then_regraft_after_decay():
    """A mesh member whose deliveries start failing accumulates penalties,
    crosses SCORE_PRUNE_THRESHOLD at the next heartbeat and is dropped;
    once the decay drifts its score back to zero it becomes graft-eligible
    and rejoins an under-degree mesh."""
    fleet = make_fleet(5, seed=5, same_region="us")
    sim = fleet.sim
    for n in fleet.peers:
        n.pubsub.subscribe("scored", lambda t, d, f: None)
    sim.run(until=sim.now + 12)         # heartbeats graft the mesh up
    a = fleet.peers[0].pubsub
    assert len(a.mesh["scored"]) == len(fleet.peers) - 1
    victim = sorted(a.mesh["scored"], key=lambda p: p.digest)[0]

    # simulate a churned-out member: its eager pushes started failing
    a._perf_of(victim)["fail"] = 5.0
    prunes = a.stats["prunes"]
    sim.run(until=sim.now + 2 * HEARTBEAT + 0.5)
    assert victim not in a.mesh["scored"]
    assert a.scores[victim] < SCORE_PRUNE_THRESHOLD
    assert a.stats["prunes"] > prunes

    # only the penalized peer can refill the under-degree mesh, but graft
    # requires a non-negative score — the decay has to run its course
    sim.run(until=sim.now + 30 * HEARTBEAT)
    assert a.scores[victim] == 0.0      # snapped, graft-eligible again
    assert victim in a.mesh["scored"]


def test_unsubscribe_propagates_and_late_joiner_sees_current_set():
    """UNSUBSCRIBE reaches current peers eagerly (pushed topic-set update
    dissolves their mesh edges) and late joiners lazily: the full-set
    announce a fresh contact triggers returns the current topics, never
    the stale subscription."""
    fleet = make_fleet(6, seed=11, same_region="us")
    sim = fleet.sim
    a = fleet.peers[0]
    a.pubsub.subscribe("models", lambda t, d, f: None)
    sim.run(until=sim.now + 5)
    others = fleet.peers[1:]
    assert all("models" in n.pubsub.peer_topics.get(a.peer_id, set())
               for n in others)

    a.pubsub.unsubscribe("models")
    sim.run(until=sim.now + 5)
    for n in others:
        assert "models" not in n.pubsub.peer_topics.get(a.peer_id, set())
        assert a.peer_id not in n.pubsub.mesh.get("models", set())

    # a genuinely late joiner: connects after the unsubscribe, learns the
    # topic set through the contact-time announce exchange
    late = LatticaNode(fleet.net, "late-joiner", region="us")
    sim.run_process(late.connect_info(a.info()), until=sim.now + 60)
    sim.run_process(late.pubsub.announce_subscriptions(a.peer_id),
                    until=sim.now + 60)
    assert "models" not in late.pubsub.peer_topics.get(a.peer_id, set())


def test_ihave_iwant_repairs_partitioned_subscriber():
    """A subscriber severed from every mesh edge misses the eager push but
    must still converge: off-mesh IHAVE gossip advertises the message id,
    the IWANT pull fetches it from the advertiser's cache."""
    fleet = make_fleet(12, seed=3, same_region="us")
    sim = fleet.sim
    got = {n.host.name: [] for n in fleet.peers}
    for n in fleet.peers:
        n.pubsub.subscribe(
            "repair", lambda t, d, f, nm=n.host.name: got[nm].append(d))
    sim.run(until=sim.now + 12)         # mesh forms
    c = fleet.peers[3]
    # partition: sever every mesh edge touching c, blind c to who
    # subscribes (its own heartbeat cannot regraft mid-wave), and erase
    # c's subscription from every view except one meshed advertiser —
    # relays and off-mesh publishers would otherwise still push to c
    # from their interested pool.  Only the advertiser's lazy IHAVE
    # gossip is left knowing c wants the topic.
    advertiser = fleet.peers[2]
    for n in fleet.all_nodes:
        n.pubsub.mesh.get("repair", set()).discard(c.peer_id)
        if n is not c and n is not advertiser:
            n.pubsub.peer_topics.get(c.peer_id, set()).discard("repair")
    c.pubsub.mesh["repair"].clear()
    # c loses its peer table outright: it cannot graft back or dial out,
    # so eager delivery is impossible — only inbound IHAVE (advertiser
    # dials c, c's ctl response carries the IWANT) can repair it
    c.peers.clear()
    # the advertiser must stay at-degree without c, else its heartbeat
    # grafts c back into the mesh instead of lazily gossiping to it
    assert len(advertiser.pubsub.mesh["repair"]) >= 4

    sim.run_process(fleet.peers[0].pubsub.publish("repair", ("w", 9)),
                    until=sim.now + 60)
    sim.run(until=sim.now + 4 * HEARTBEAT + 1)
    assert got[c.host.name] == [("w", 9)]
    assert c.pubsub.stats["iwant_sent"] >= 1
    assert c.pubsub.stats["repaired"] >= 1
    assert sum(n.pubsub.stats["ihave_sent"] for n in fleet.peers) >= 1


def test_blind_relays_do_not_flood_watcherless_topics():
    """Regression: a publish on a topic with no subscribers anywhere used
    to cascade — every receiver re-forwarded to MESH_DEGREE more peers
    through the last-resort pools, an overlay-wide flood at fleet scale.
    Blind relays (neither subscribed nor meshed for the topic) may forward
    only to peers they know are interested, so the wave dies after the
    publisher's own hop."""
    fleet = make_fleet(12, seed=7, same_region="us")
    sim = fleet.sim
    sim.run(until=sim.now + 5)
    sim.run_process(fleet.peers[0].pubsub.publish("nobody/watches", "x"),
                    until=sim.now + 60)
    sim.run(until=sim.now + 10)
    total = sum(n.pubsub.stats["forwarded"] for n in fleet.all_nodes)
    assert total <= MESH_DEGREE
