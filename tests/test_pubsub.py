"""Gossip pub/sub: delivery, dedup, topic scoping."""

from repro.core.fleet import make_fleet


def test_publish_reaches_subscribers():
    fleet = make_fleet(10, seed=8, same_region="us")
    sim = fleet.sim
    got = {n.host.name: [] for n in fleet.peers}
    for n in fleet.peers[1:]:
        n.pubsub.subscribe(
            "models", lambda t, d, f, name=n.host.name: got[name].append(d))

    def announce_and_publish():
        # subscriptions propagate lazily; re-announce after subscribing
        for n in fleet.peers:
            for pid in list(n.peers):
                yield from n.pubsub.announce_subscriptions(pid)
        yield from fleet.peers[0].pubsub.publish("models", ("v", 1))
        yield 5.0

    sim.run_process(announce_and_publish(), until=sim.now + 300)
    sim.run(until=sim.now + 30)
    reached = sum(1 for n in fleet.peers[1:] if got[n.host.name])
    assert reached >= len(fleet.peers) - 2      # gossip mesh coverage
    # no duplicate deliveries anywhere
    for msgs in got.values():
        assert len(msgs) <= 1


def test_late_subscription_propagates_without_manual_announce():
    """Regression: ``subscribe`` after connections exist used to stay
    invisible (topics were only exchanged lazily at announce time), so a
    fresh subscriber missed the next publish.  Subscribing must now push
    the update to known peers by itself."""
    fleet = make_fleet(6, seed=13, same_region="us")
    sim = fleet.sim
    got = []
    # subscribe AFTER the mesh is joined — no announce_subscriptions calls
    fleet.peers[3].pubsub.subscribe("late", lambda t, d, f: got.append(d))
    sim.run(until=sim.now + 5)          # the proactive update lands

    sim.run_process(fleet.peers[0].pubsub.publish("late", ("v", 7)),
                    until=sim.now + 60)
    sim.run(until=sim.now + 10)
    assert got == [("v", 7)]


def test_unsubscribed_topic_not_delivered():
    fleet = make_fleet(6, seed=3, same_region="us")
    sim = fleet.sim
    got = []
    fleet.peers[1].pubsub.subscribe("a", lambda t, d, f: got.append(d))

    def run():
        for n in fleet.peers:
            for pid in list(n.peers):
                yield from n.pubsub.announce_subscriptions(pid)
        yield from fleet.peers[0].pubsub.publish("b", "wrong-topic")
        yield 5.0

    sim.run_process(run(), until=sim.now + 120)
    sim.run(until=sim.now + 30)
    assert got == []
