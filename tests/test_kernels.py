"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.mlstm_scan import mlstm_scan_bhsd
from repro.kernels.moe_gating import moe_gating_tokens
from repro.kernels.ref import attention_ref, mlstm_chunk_ref, moe_gating_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ------------------------------------------------------------ flash attention

@pytest.mark.parametrize("B,H,Sq,Sk,hd", [
    (1, 1, 128, 128, 64),
    (2, 3, 256, 256, 64),
    (1, 2, 256, 512, 128),     # cross: more keys than queries (cached-ish)
    (2, 2, 512, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [0, 128])
def test_flash_attention_sweep(B, H, Sq, Sk, hd, dtype, window):
    ks = jax.random.split(jax.random.PRNGKey(B * 7 + Sq), 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, Sk, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, Sk, hd), jnp.float32).astype(dtype)
    out = flash_attention_bhsd(q, k, v, causal=True, window=window,
                               bq=128, bk=128)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_flash_attention_noncausal():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = flash_attention_bhsd(q, k, v, causal=False, bq=128, bk=128)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------- MoE gating

@pytest.mark.parametrize("T,E,K", [(256, 16, 4), (512, 60, 4), (256, 8, 2),
                                   (1024, 64, 8)])
def test_moe_gating_sweep(T, E, K):
    logits = jax.random.normal(jax.random.PRNGKey(T + E), (T, E)) * 2
    w, idx, p = moe_gating_tokens(logits, K, bt=256)
    wr, ir, pr = moe_gating_ref(logits, K)
    np.testing.assert_allclose(np.asarray(p), np.asarray(pr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(w), np.asarray(wr), atol=1e-6)
    assert (np.asarray(idx) == np.asarray(ir)).all()
    # weights sum to 1 and indices are distinct per token
    np.testing.assert_allclose(np.asarray(w).sum(1), 1.0, atol=1e-5)
    assert all(len(set(row)) == K for row in np.asarray(idx))


# ---------------------------------------------------------------- mLSTM scan

@pytest.mark.parametrize("B,H,S,hd,chunk", [
    (1, 1, 128, 64, 64),
    (2, 2, 256, 64, 64),
    (1, 2, 256, 128, 128),
    (2, 1, 512, 256, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlstm_scan_sweep(B, H, S, hd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(S + hd), 5)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32) / np.sqrt(hd)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)
    li = jax.random.normal(ks[3], (B, H, S))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2.0)
    C0 = jnp.zeros((B, H, hd, hd))
    n0 = jnp.zeros((B, H, hd))
    m0 = jnp.full((B, H), -1e30)
    h, C, n, m = mlstm_scan_bhsd(q.astype(dtype), k.astype(dtype),
                                 v.astype(dtype), li, lf, C0, n0, m0,
                                 chunk=chunk)
    hr, Cr, nr, mr = mlstm_chunk_ref(q, k, v, li, lf, C0, n0, m0)
    tol = _tol(dtype) * 8
    np.testing.assert_allclose(np.asarray(h, np.float32),
                               np.asarray(hr, np.float32), atol=tol, rtol=tol)
    # states match in TRUE scale (C·exp(m)) — per-impl m may differ slightly
    np.testing.assert_allclose(
        np.asarray(C * jnp.exp(m)[..., None, None]),
        np.asarray(Cr * jnp.exp(mr)[..., None, None]), atol=tol, rtol=tol)


def test_mlstm_scan_nonzero_initial_state():
    """Chunked scan continuing from a warm state == one long oracle run."""
    B, H, S, hd = 1, 2, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd)) / np.sqrt(hd)
    v = jax.random.normal(ks[2], (B, H, S, hd))
    li = jax.random.normal(ks[3], (B, H, S))
    lf = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, H, S)) + 2.0)
    zero = jnp.zeros((B, H, hd, hd)), jnp.zeros((B, H, hd)), jnp.full((B, H), -1e30)
    # oracle over the full sequence
    hr, *_ = mlstm_chunk_ref(q, k, v, li, lf, *zero)
    # kernel: first half, then second half from the carried state
    h1, C1, n1, m1 = mlstm_scan_bhsd(
        q[:, :, :128], k[:, :, :128], v[:, :, :128],
        li[:, :, :128], lf[:, :, :128], *zero, chunk=64)
    h2, *_ = mlstm_scan_bhsd(
        q[:, :, 128:], k[:, :, 128:], v[:, :, 128:],
        li[:, :, 128:], lf[:, :, 128:], C1, n1, m1, chunk=64)
    got = jnp.concatenate([h1, h2], axis=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(hr),
                               atol=1e-4, rtol=1e-4)
