"""Per-arch smoke tests (reduced configs, CPU): shapes, finiteness,
prefill/decode self-consistency, one train step."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import ops_for
from repro.optim import constant_schedule
from repro.train import make_train_step, train_state_init


def _batch(cfg, key, B=2, S=32, labels=True):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if labels:
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    if cfg.arch == "vlm":
        P = cfg.n_patches
        batch["vision_embeds"] = jax.random.normal(key, (B, P, cfg.d_model))
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S + P, dtype=jnp.int32)[None, None], (3, B, S + P))
    if cfg.arch == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_source))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    ops = ops_for(cfg)
    key = jax.random.PRNGKey(0)
    params = ops.init(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S)
    logits, aux = ops.forward(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    loss, metrics = ops.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss))
    assert float(loss) < 2 * np.log(cfg.vocab)   # sane init scale


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    ops = ops_for(cfg)
    key = jax.random.PRNGKey(1)
    params = ops.init(cfg, key)
    B, S = 2, 32
    batch = _batch(cfg, key, B, S, labels=False)
    logits, _ = ops.forward(params, cfg, batch)
    extra = cfg.n_patches if cfg.arch == "vlm" else 0
    cache = ops.init_cache(cfg, B, S + extra)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - 3]
    if cfg.arch == "vlm":
        pre["positions3"] = batch["positions3"][:, :, :extra + S - 3]
    _, cache = ops.prefill(params, cfg, pre, cache)
    for t in range(S - 3, S - 1):
        step_logits, cache = ops.decode_step(
            params, cfg, batch["tokens"][:, t], cache)
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(logits[:, t]),
            atol=5e-4, rtol=1e-3)


@pytest.mark.parametrize("arch", ["granite-8b", "qwen2-moe-a2.7b",
                                  "xlstm-1.3b", "hymba-1.5b",
                                  "whisper-small"])
def test_one_train_step(arch):
    """One optimizer step runs and produces finite params/metrics."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    state = train_state_init(cfg, key)
    step = jax.jit(make_train_step(cfg, constant_schedule(1e-3)))
    batch = _batch(cfg, key, B=2, S=32)
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(state2.opt.step) == 1
    # params actually moved
    moved = jax.tree.reduce(
        lambda a, b: a or b,
        jax.tree.map(lambda p, q: bool(jnp.any(p != q)),
                     state.params, state2.params))
    assert moved


def test_microbatch_accumulation_matches_full_batch():
    cfg = get_config("minicpm-2b").reduced()
    key = jax.random.PRNGKey(3)
    state = train_state_init(cfg, key)
    batch = _batch(cfg, key, B=4, S=16)
    s1, m1 = jax.jit(make_train_step(cfg, constant_schedule(1e-3)))(state, batch)
    s4, m4 = jax.jit(make_train_step(cfg, constant_schedule(1e-3),
                                     microbatches=4))(state, batch)
    # losses are means over the same tokens; grads accumulate to the same
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5, atol=1e-5)
    flat1 = jax.tree.leaves(s1.params)
    flat4 = jax.tree.leaves(s4.params)
    for a, b in zip(flat1, flat4):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=6e-5, rtol=2e-3)


def test_sliding_window_variant_limits_attention():
    """A windowed model's decode must ignore tokens older than the window."""
    import dataclasses

    cfg = get_config("granite-8b").reduced(window=8)
    ops = ops_for(cfg)
    key = jax.random.PRNGKey(4)
    params = ops.init(cfg, key)
    B, S = 1, 24
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    # ring cache of size window
    cache = ops.init_cache(cfg, B, S)
    assert cache["layers"]["k"].shape[2] == 8      # ring buffer, not S
    _, cache = ops.prefill(params, cfg, {"tokens": toks[:, :16]}, cache)
    lg, cache = ops.decode_step(params, cfg, toks[:, 16], cache)
    # same suffix, different ancient prefix -> identical logits
    toks2 = toks.at[:, :8].set((toks[:, :8] + 7) % cfg.vocab)
    cache2 = ops.init_cache(cfg, B, S)
    _, cache2 = ops.prefill(params, cfg, {"tokens": toks2[:, :16]}, cache2)
    lg2, cache2 = ops.decode_step(params, cfg, toks2[:, 16], cache2)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(lg2),
                               atol=1e-5, rtol=1e-5)
