"""Dual-plane RPC: unary semantics, streaming backpressure, concurrency."""

import pytest

from repro.core import LatticaNode, Network, RpcError, Sim, call_unary, open_channel
from repro.core.rpc import INIT_CREDIT


def _pair(seed=0):
    sim = Sim(seed=seed)
    net = Network(sim)
    a = LatticaNode(net, "a", region="us", zone="a")
    b = LatticaNode(net, "b", region="us", zone="a")

    def conn():
        c = yield from a.connect_info(b.info())
        return c

    return sim, a, b, sim.run_process(conn())


def test_unary_roundtrip_and_error():
    sim, a, b, conn = _pair()

    def echo(payload, ctx):
        yield ctx.cpu(1e-6)
        return ("echo", payload), 64

    def boom(payload, ctx):
        yield ctx.cpu(1e-6)
        raise RuntimeError("kaboom")

    b.router.register_unary("t.echo", echo)
    b.router.register_unary("t.boom", boom)

    def run():
        r = yield from call_unary(a.host, conn, "t.echo", {"x": 1})
        try:
            yield from call_unary(a.host, conn, "t.boom", None)
            raised = False
        except RpcError as e:
            raised = "kaboom" in str(e)
        try:
            yield from call_unary(a.host, conn, "t.missing", None)
            missing = False
        except RpcError:
            missing = True
        return r, raised, missing

    r, raised, missing = sim.run_process(run())
    assert r == ("echo", {"x": 1}) and raised and missing


def test_streaming_backpressure_blocks_writer():
    """Writer must stall once in-flight bytes exceed the credit window."""
    sim, a, b, conn = _pair()
    progress = {"sent": 0, "consumed": 0, "max_outstanding": 0}
    MSG = 256 * 1024                       # 256 KiB messages, 1 MiB window

    def slow_reader(chan, ctx):
        for _ in range(12):
            yield 0.05                     # slow consumer
            yield from chan.recv()
            progress["consumed"] += 1
        chan.end()

    b.router.register_streaming("t.stream", slow_reader)

    def writer():
        chan = yield from open_channel(a.host, conn, "t.stream")
        for i in range(12):
            yield from chan.send(("blob", i), MSG)
            progress["sent"] += 1
            outstanding = progress["sent"] - progress["consumed"]
            progress["max_outstanding"] = max(
                progress["max_outstanding"], outstanding)
        return progress

    res = sim.run_process(writer(), until=sim.now + 60)
    assert res["sent"] == 12
    # window = 1MiB = 4 messages; writer can never be more than ~window+1
    # ahead of the consumer
    assert res["max_outstanding"] <= (INIT_CREDIT // MSG) + 2


def test_graceful_end_wakes_blocked_sender():
    """Regression: a graceful "end" frame must wake credit-blocked senders.

    The reader consumes one message (not enough to trigger a credit grant)
    and ends the channel; a writer blocked in send() waiting for credit must
    raise RpcError instead of deadlocking the simulation forever.
    """
    sim, a, b, conn = _pair()
    MSG = 300 * 1024          # window = 1 MiB -> 4th send blocks on credit

    def lazy_reader(chan, ctx):
        yield from chan.recv()            # 300 KiB < grant threshold: no credit
        chan.end()                        # graceful close, inbox not drained

    b.router.register_streaming("t.lazy", lazy_reader)

    def writer():
        chan = yield from open_channel(a.host, conn, "t.lazy")
        for i in range(8):
            yield from chan.send(("blob", i), MSG)
        return "all sent"

    with pytest.raises(RpcError):
        sim.run_process(writer(), until=sim.now + 60)


def test_concurrent_unary_calls():
    sim, a, b, conn = _pair()
    served = []

    def handler(payload, ctx):
        yield ctx.cpu(100e-6)
        served.append(payload)
        return payload * 2, 64

    b.router.register_unary("t.mul", handler)

    def run():
        procs = [sim.process(call_unary(a.host, conn, "t.mul", i))
                 for i in range(50)]
        results = yield sim.all_of(procs)
        return results

    results = sim.run_process(run())
    assert sorted(results) == [2 * i for i in range(50)]
    assert len(served) == 50


def test_rpc_latency_scales_with_region():
    """Same-host RPC must be much faster than inter-continental."""
    def roundtrip_time(region_b):
        sim = Sim(seed=1)
        net = Network(sim)
        a = LatticaNode(net, "a", region="us", zone="a")
        b = LatticaNode(net, "b", region=region_b,
                        zone="a" if region_b == "us" else "x")
        b.router.register_unary("t.ping", _pong)

        def run():
            conn = yield from a.connect_info(b.info())
            t0 = sim.now
            yield from call_unary(a.host, conn, "t.ping", None)
            return sim.now - t0

        return sim.run_process(run())

    def _pong(payload, ctx):
        yield ctx.cpu(1e-6)
        return "pong", 64

    t_local = roundtrip_time("us")
    t_inter = roundtrip_time("ap")
    assert t_inter > 10 * t_local
