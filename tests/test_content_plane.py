"""Delta-aware content plane: hierarchical manifests, pin/evict blockstore,
scored swarm fetch, and two-version delta sync."""

import numpy as np
import pytest

from repro.core.blockstore import BlockStore
from repro.core.cid import (CID, CODEC_DAG, CODEC_RAW, ManifestEntry,
                            build_dag, build_tree_dag, dag_reachable,
                            decode_manifest, decode_manifest_v2,
                            encode_manifest, encode_manifest_v2,
                            manifest_children, manifest_version, read_dag)
from repro.core.bitswap import ProviderScore
from repro.core.fleet import make_fleet


def _blob(n: int, seed: int) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, n, dtype=np.uint8).tobytes()


# ------------------------------------------------------- v2 manifest codec

def test_manifest_v2_roundtrip():
    entries = [
        ManifestEntry("layer0/w", CID.for_data(b"a", CODEC_DAG), 7, b"meta0"),
        ManifestEntry("layer0/b", CID.for_data(b"b", CODEC_RAW), 3, b""),
        ManifestEntry("émbed/♣", CID.for_data(b"c", CODEC_DAG), 0, b"\x00\xff"),
    ]
    enc = encode_manifest_v2(entries, 10, meta=b"root-meta")
    assert manifest_version(enc) == 2
    got, total, meta = decode_manifest_v2(enc)
    assert got == entries and total == 10 and meta == b"root-meta"
    assert manifest_children(enc) == [e.cid for e in entries]


def test_manifest_version_dispatch_keeps_v1_decodable():
    enc1 = encode_manifest([CID.for_data(b"x")], 1, meta=b"m")
    assert manifest_version(enc1) == 1
    children, total, meta = decode_manifest(enc1)
    assert total == 1 and meta == b"m" and len(children) == 1
    assert manifest_children(enc1) == children
    with pytest.raises(ValueError):
        manifest_version(b"NOPE....")


def test_tree_dag_structural_sharing_and_read():
    a, b, c = _blob(700, 1), _blob(900, 2), _blob(300, 3)
    v1 = build_tree_dag([("t0", a, b"ma"), ("t1", b, b"mb")], chunk_size=256)
    # v2 mutates one part, keeps the other byte-identical
    v2 = build_tree_dag([("t0", a, b"ma"), ("t1", c, b"mc")], chunk_size=256)
    assert v1.root != v2.root
    by_name1 = {e.name: e.cid for e in v1.entries}
    by_name2 = {e.name: e.cid for e in v2.entries}
    assert by_name1["t0"] == by_name2["t0"]          # unchanged sub-root reused
    assert by_name1["t1"] != by_name2["t1"]
    # reassembly is concatenation in entry order
    assert read_dag(v1.root, v1.blocks.get) == a + b
    assert read_dag(v2.root, v2.blocks.get) == a + c
    # shared blocks are literally the same CIDs
    shared = set(v1.blocks) & set(v2.blocks)
    sub0 = set(dag_reachable(by_name1["t0"], v1.blocks.get))
    assert sub0 <= shared


def test_read_dag_flat_v1_and_verification():
    data = _blob(1000, 4)
    dag = build_dag(data, chunk_size=256)
    assert read_dag(dag.root, dag.blocks.get) == data
    # a corrupted leaf is caught
    leaf = next(c for c in dag.blocks if c.codec == CODEC_RAW)
    bad = dict(dag.blocks)
    bad[leaf] = b"x" * len(bad[leaf])
    with pytest.raises(ValueError):
        read_dag(dag.root, bad.get)
    # a missing leaf is a KeyError, not silent truncation
    del bad[leaf]
    with pytest.raises(KeyError):
        read_dag(dag.root, bad.get)


# ---------------------------------------------------- blockstore pin/evict

def test_blockstore_budget_evicts_lru_unpinned():
    bs = BlockStore(capacity=1000)
    blocks = [_blob(300, i + 10) for i in range(4)]
    cids = [CID.for_data(b) for b in blocks]
    for c, b in zip(cids[:3], blocks[:3]):
        bs.put(c, b)
    assert bs.bytes_stored == 900
    bs.get(cids[0])                         # touch 0 -> LRU victim is 1
    bs.put(cids[3], blocks[3])
    assert bs.bytes_stored <= 1000
    assert not bs.has(cids[1]) and bs.has(cids[0]) and bs.has(cids[3])
    assert bs.stats["evictions"] == 1 and bs.stats["bytes_evicted"] == 300


def test_blockstore_pinned_roots_never_evicted():
    data = _blob(2048, 20)
    dag = build_tree_dag([("a", data[:1024], b""), ("b", data[1024:], b"")],
                         chunk_size=512)
    bs = BlockStore(capacity=None)
    bs.put_many(dag.blocks)
    bs.pin(dag.root)
    # budget far below the DAG size: nothing evictable, store overflows
    bs.set_capacity(512)
    for c in dag.blocks:
        assert bs.has(c), f"pinned block {c} evicted"
    assert bs.stats["evictions"] == 0
    with pytest.raises(ValueError):
        bs.delete(dag.root)
    # unpinned filler survives its own put (incoming blocks are exempt from
    # their own sweep) but is the LRU victim of the next one
    filler, filler2 = _blob(600, 21), _blob(600, 22)
    bs.put(CID.for_data(filler), filler)
    assert bs.has(CID.for_data(filler))
    bs.put(CID.for_data(filler2), filler2)
    assert not bs.has(CID.for_data(filler))
    for c in dag.blocks:
        assert bs.has(c)
    # after unpin the DAG becomes evictable
    bs.unpin(dag.root)
    bs.put(CID.for_data(filler), filler)
    assert all(not bs.has(c) for c in dag.blocks)


def test_blockstore_pin_refcounts_shared_subdags():
    a, b, c = _blob(400, 30), _blob(400, 31), _blob(400, 32)
    v1 = build_tree_dag([("t0", a, b""), ("t1", b, b"")], chunk_size=256)
    v2 = build_tree_dag([("t0", a, b""), ("t1", c, b"")], chunk_size=256)
    bs = BlockStore()
    bs.put_many(v1.blocks)
    bs.put_many(v2.blocks)
    bs.pin(v1.root)
    bs.pin(v2.root)
    shared = set(v1.blocks) & set(v2.blocks)
    assert shared, "versions should share t0's sub-DAG"
    bs.unpin(v1.root)
    # shared blocks still pinned through v2
    for cid in shared:
        assert bs.pinned(cid), f"{cid} lost its pin while v2 still holds it"
    # v1-only blocks are now unpinned
    for cid in set(v1.blocks) - shared:
        assert not bs.pinned(cid)


def test_blockstore_hit_miss_counters():
    bs = BlockStore()
    cid = CID.for_data(b"payload")
    assert bs.get(cid) is None
    bs.put(cid, b"payload")
    assert bs.get(cid) == b"payload"
    assert bs.stats == {"hits": 1, "misses": 1, "evictions": 0,
                        "bytes_evicted": 0}
    # peek doesn't skew the counters
    assert bs.peek(cid) == b"payload"
    assert bs.stats["hits"] == 1


# ------------------------------------------------------- provider scoring

def test_provider_score_ewma_and_failures():
    s = ProviderScore()
    start = s.value()
    for _ in range(10):
        s.record(1 << 20, 0.01)          # 100 MB/s provider
    assert s.value() > start
    fast = s.value()
    s.fail()
    s.fail()
    assert s.value() == pytest.approx(fast / 4)
    s.record(1 << 20, 0.01)              # success decays the failure penalty
    assert s.value() > fast / 4


def test_stripe_assignment_biases_toward_fast_provider():
    fleet = make_fleet(4, seed=3, same_region="us")
    node = fleet.peers[0]
    bs = node.bitswap
    fast, slow = fleet.peers[1].info(), fleet.peers[2].info()
    for _ in range(8):
        bs.score(fast).record(1 << 22, 0.01)     # ~400 MB/s
        bs.score(slow).record(1 << 18, 0.1)      # ~2.6 MB/s
    wanted = [CID.for_data(bytes([i]) * 8) for i in range(40)]
    stripes = bs._stripe(wanted, [fast, slow])
    assert len(stripes[0]) > 3 * len(stripes[1])
    assert sorted(sum(stripes, []), key=lambda c: c.digest) == \
        sorted(wanted, key=lambda c: c.digest)


def test_scoring_failover_prefers_healthy_provider():
    """A provider that dropped its blocks accumulates failures; the fetch
    still completes from the healthy seed and the dead one scores lower."""
    fleet = make_fleet(8, seed=9, same_region="us")
    sim = fleet.sim
    data = _blob(2 << 20, 9)
    good, flaky = fleet.peers[0], fleet.peers[1]

    def seed_all():
        dag = build_dag(data)
        yield from good.bitswap.publish_dag(dict(dag.blocks), dag.root)
        yield from flaky.bitswap.publish_dag(dict(dag.blocks), dag.root)
        return dag.root

    root = sim.run_process(seed_all(), until=sim.now + 600)
    for cid in list(flaky.blockstore.cids()):
        flaky.blockstore.delete(cid)

    leecher = fleet.peers[-1]

    def fetch():
        got = yield from leecher.fetch_artifact(root, reprovide=False)
        return got

    assert sim.run_process(fetch(), until=sim.now + 900) == data
    lb = leecher.bitswap
    assert lb.score(flaky.info()).failures > 0
    assert lb.score(good.info()).value() > lb.score(flaky.info()).value()


# -------------------------------------------------- two-version delta sync

def _params(n_tensors: int, size: int, seed: int, mutate=()):
    rng = np.random.default_rng(seed)
    tree = {f"layer{i}/w": rng.integers(0, 256, size, dtype=np.uint8)
            for i in range(n_tensors)}
    rng2 = np.random.default_rng(seed + 999)
    for i in mutate:
        tree[f"layer{i}/w"] = rng2.integers(0, 256, size, dtype=np.uint8)
    return tree


def test_delta_sync_skips_unchanged_tensors():
    from repro.checkpoint.lattica_ckpt import (fetch_checkpoint,
                                               publish_checkpoint)
    fleet = make_fleet(6, seed=23, same_region="us")
    sim = fleet.sim
    trainer, edge = fleet.peers[0], fleet.peers[-1]
    # 10 tensors x 128 KiB; v2 mutates exactly one
    p1 = _params(10, 128 * 1024, seed=1)
    p2 = _params(10, 128 * 1024, seed=1, mutate=[4])

    def publish(params, step, base=None):
        root = yield from publish_checkpoint(trainer, params, step, "df",
                                             base=base)
        return root

    r1 = sim.run_process(publish(p1, 1), until=sim.now + 600)

    def fetch(root):
        got = yield from fetch_checkpoint(edge, root, like=p1, fleet="df")
        return got

    got1 = sim.run_process(fetch(r1), until=sim.now + 900)
    for k in p1:
        np.testing.assert_array_equal(p1[k], got1[k])
    full_bytes = edge.bitswap.stats["bytes_fetched"]
    blocks_after_v1 = set(edge.blockstore.cids())

    r2 = sim.run_process(publish(p2, 2, base=r1), until=sim.now + 600)
    got2 = sim.run_process(fetch(r2), until=sim.now + 900)
    for k in p2:
        np.testing.assert_array_equal(p2[k], got2[k])
    delta_bytes = edge.bitswap.stats["bytes_fetched"] - full_bytes
    # acceptance: 10% of tensors mutated -> v2 fetch < 30% of a full fetch
    assert delta_bytes < 0.3 * full_bytes, (delta_bytes, full_bytes)
    # unchanged-tensor blocks were never re-fetched: everything fetched for
    # v2 is new (changed tensor or manifests), not blocks we already had
    v1_manifest = trainer.blockstore.peek(r1)
    e1 = {e.name: e.cid for e in decode_manifest_v2(v1_manifest)[0]}
    e2 = {e.name: e.cid
          for e in decode_manifest_v2(trainer.blockstore.peek(r2))[0]}
    unchanged = [n for n in e1 if e1[n] == e2[n]]
    assert len(unchanged) == 9
    refetched = [c for c in blocks_after_v1
                 if c in set(edge.blockstore.cids())]
    assert len(refetched) == len(blocks_after_v1)   # old blocks still held
    # publisher-side delta stats match: ~1/10 of bytes are new
    import pickle
    meta = pickle.loads(decode_manifest_v2(
        trainer.blockstore.peek(r2))[2])
    d = meta["delta"]
    assert d["reused_blocks"] > 0
    assert d["new_bytes"] < 0.3 * (d["new_bytes"] + d["reused_bytes"])
    # post-hoc accounting over the store agrees on the byte split (the root
    # manifest differs between the two: meta vs meta-less build)
    from repro.checkpoint.lattica_ckpt import checkpoint_delta
    d2 = checkpoint_delta(trainer, r2, r1)
    assert d2["reused_bytes"] == d["reused_bytes"]


def test_pinned_latest_survives_eviction_under_budget():
    """Blockstore budget < two checkpoints: after fetching v2, v1's blocks
    may be evicted but v2 (pinned latest) stays fully resident."""
    from repro.checkpoint.lattica_ckpt import (fetch_checkpoint,
                                               publish_checkpoint)
    fleet = make_fleet(6, seed=29, same_region="us")
    sim = fleet.sim
    trainer, edge = fleet.peers[0], fleet.peers[-1]
    p1 = _params(8, 128 * 1024, seed=2)
    p2 = _params(8, 128 * 1024, seed=3)          # fully different version
    ckpt_bytes = sum(v.nbytes for v in p1.values())
    edge.blockstore.set_capacity(int(1.5 * ckpt_bytes))

    def publish(params, step, base=None):
        root = yield from publish_checkpoint(trainer, params, step, "ev",
                                             base=base)
        return root

    def fetch(root):
        got = yield from fetch_checkpoint(edge, root, like=p1, fleet="ev")
        return got

    r1 = sim.run_process(publish(p1, 1), until=sim.now + 600)
    sim.run_process(fetch(r1), until=sim.now + 900)
    r2 = sim.run_process(publish(p2, 2, base=r1), until=sim.now + 600)
    got2 = sim.run_process(fetch(r2), until=sim.now + 900)
    for k in p2:
        np.testing.assert_array_equal(p2[k], got2[k])
    # v2 is pinned-latest: fully resident despite the budget
    for c in dag_reachable(r2, edge.blockstore.peek):
        assert edge.blockstore.has(c), f"latest-version block {c} evicted"
    assert edge.blockstore.stats["evictions"] > 0, \
        "budget < 2 checkpoints must have forced evictions of v1"
    assert edge.blockstore.bytes_stored <= int(1.5 * ckpt_bytes)


def test_flat_artifact_roundtrip_unchanged():
    """v1 flat-blob publish/fetch semantics are untouched by the refactor."""
    fleet = make_fleet(6, seed=31)
    sim = fleet.sim
    a, b = fleet.peers[0], fleet.peers[-1]
    blob = _blob(768 * 1024, 44)

    def run():
        root = yield from a.publish_artifact(blob)
        assert manifest_version(a.blockstore.peek(root)) == 1
        got = yield from b.fetch_artifact(root)
        return got

    assert sim.run_process(run(), until=sim.now + 900) == blob
