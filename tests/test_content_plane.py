"""Delta-aware content plane: hierarchical manifests, pin/evict blockstore,
scored swarm fetch, content-defined chunking, and two-version delta sync."""

import pickle

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.blockstore import BlockStore
from repro.core.cid import (CID, CODEC_DAG, CODEC_RAW, ChunkSpec,
                            ManifestEntry, build_dag, build_tree_dag,
                            cdc_cut_points, dag_reachable, decode_manifest,
                            decode_manifest_v2, encode_manifest,
                            encode_manifest_v2, manifest_children,
                            manifest_version, read_dag)
from repro.core.bitswap import FetchError, ProviderScore
from repro.core.fleet import make_fleet


def _blob(n: int, seed: int) -> bytes:
    return np.random.default_rng(seed).integers(
        0, 256, n, dtype=np.uint8).tobytes()


# ------------------------------------------------------- v2 manifest codec

def test_manifest_v2_roundtrip():
    entries = [
        ManifestEntry("layer0/w", CID.for_data(b"a", CODEC_DAG), 7, b"meta0"),
        ManifestEntry("layer0/b", CID.for_data(b"b", CODEC_RAW), 3, b""),
        ManifestEntry("émbed/♣", CID.for_data(b"c", CODEC_DAG), 0, b"\x00\xff"),
    ]
    enc = encode_manifest_v2(entries, 10, meta=b"root-meta")
    assert manifest_version(enc) == 2
    got, total, meta = decode_manifest_v2(enc)
    assert got == entries and total == 10 and meta == b"root-meta"
    assert manifest_children(enc) == [e.cid for e in entries]


def test_manifest_version_dispatch_keeps_v1_decodable():
    enc1 = encode_manifest([CID.for_data(b"x")], 1, meta=b"m")
    assert manifest_version(enc1) == 1
    children, total, meta = decode_manifest(enc1)
    assert total == 1 and meta == b"m" and len(children) == 1
    assert manifest_children(enc1) == children
    with pytest.raises(ValueError):
        manifest_version(b"NOPE....")


def test_tree_dag_structural_sharing_and_read():
    a, b, c = _blob(700, 1), _blob(900, 2), _blob(300, 3)
    v1 = build_tree_dag([("t0", a, b"ma"), ("t1", b, b"mb")], chunk_size=256)
    # v2 mutates one part, keeps the other byte-identical
    v2 = build_tree_dag([("t0", a, b"ma"), ("t1", c, b"mc")], chunk_size=256)
    assert v1.root != v2.root
    by_name1 = {e.name: e.cid for e in v1.entries}
    by_name2 = {e.name: e.cid for e in v2.entries}
    assert by_name1["t0"] == by_name2["t0"]          # unchanged sub-root reused
    assert by_name1["t1"] != by_name2["t1"]
    # reassembly is concatenation in entry order
    assert read_dag(v1.root, v1.blocks.get) == a + b
    assert read_dag(v2.root, v2.blocks.get) == a + c
    # shared blocks are literally the same CIDs
    shared = set(v1.blocks) & set(v2.blocks)
    sub0 = set(dag_reachable(by_name1["t0"], v1.blocks.get))
    assert sub0 <= shared


def test_read_dag_flat_v1_and_verification():
    data = _blob(1000, 4)
    dag = build_dag(data, chunk_size=256)
    assert read_dag(dag.root, dag.blocks.get) == data
    # a corrupted leaf is caught
    leaf = next(c for c in dag.blocks if c.codec == CODEC_RAW)
    bad = dict(dag.blocks)
    bad[leaf] = b"x" * len(bad[leaf])
    with pytest.raises(ValueError):
        read_dag(dag.root, bad.get)
    # a missing leaf is a KeyError, not silent truncation
    del bad[leaf]
    with pytest.raises(KeyError):
        read_dag(dag.root, bad.get)


# ---------------------------------------------------- content-defined chunking

def test_chunkspec_codec_roundtrip_and_validation():
    for spec in (ChunkSpec(), ChunkSpec(strategy="fixed", chunk_size=4096),
                 ChunkSpec.cdc(), ChunkSpec.cdc(avg_size=32 * 1024),
                 ChunkSpec.cdc(avg_size=8192, min_size=1024, max_size=65536)):
        assert ChunkSpec.decode(spec.encode()) == spec
    # constructor-built cdc specs normalize the (unused) chunk_size field,
    # so equality never diverges on derivable state
    assert ChunkSpec(strategy="cdc", min_size=16384, avg_size=65536,
                     max_size=262144) == ChunkSpec.cdc(avg_size=65536,
                                                       min_size=16384,
                                                       max_size=262144)
    with pytest.raises(ValueError):
        ChunkSpec(strategy="rolling")
    with pytest.raises(ValueError):
        ChunkSpec(strategy="fixed", chunk_size=0)
    with pytest.raises(ValueError):
        ChunkSpec.cdc(avg_size=1024, min_size=2048)
    for bad in (b"", b"cdc", b"cdc:1:2", b"fixed:many", b"fixed:1:2",
                b"cdc:0:0:0", b"\xff\xfe"):
        with pytest.raises(ValueError):
            ChunkSpec.decode(bad)


def test_cdc_bounds_determinism_and_reassembly():
    data = _blob(768 * 1024, seed=50)
    spec = ChunkSpec.cdc(avg_size=16 * 1024)
    chunks = spec.split(data)
    assert b"".join(chunks) == data
    assert len(chunks) > 10
    for piece in chunks[:-1]:
        assert spec.min_size <= len(piece) <= spec.max_size
    assert len(chunks[-1]) <= spec.max_size
    # boundaries are a pure function of (content, spec)
    assert spec.split(data) == chunks
    cuts = cdc_cut_points(data, spec.min_size, spec.avg_size, spec.max_size)
    assert cuts[-1] == len(data) and sorted(cuts) == cuts
    # degenerate inputs
    assert spec.split(b"") == [b""]
    assert b"".join(spec.split(b"xyz")) == b"xyz"


def test_cdc_slabbed_scan_matches_unslabbed(monkeypatch):
    """The slabbed (memory-bounded) candidate scan must place boundaries
    byte-for-byte where a whole-buffer scan would — slab size is an
    implementation knob, never an input to the content hash."""
    import repro.core.cid as cid_mod
    data = _blob(300 * 1024, 57)
    spec = ChunkSpec.cdc(avg_size=8 * 1024)
    full = spec.split(data)
    monkeypatch.setattr(cid_mod, "_CDC_SLAB", 64 * 1024)
    assert spec.split(data) == full
    monkeypatch.setattr(cid_mod, "_CDC_SLAB", 17)      # pathological slab
    assert spec.split(data) == full


def test_cdc_boundaries_shift_stable_where_fixed_cascades():
    data = _blob(512 * 1024, seed=51)
    edited = data[:8192] + b"\x00" * 333 + data[8192:]    # insert mid-part
    cdc = ChunkSpec.cdc(avg_size=16 * 1024)
    fixed = ChunkSpec(strategy="fixed", chunk_size=16 * 1024)

    def reuse(spec):
        before, after = set(spec.split(data)), spec.split(edited)
        return sum(len(c) for c in after if c in before) / len(edited)

    assert reuse(cdc) > 0.60        # unchanged tail keeps its chunks
    assert reuse(fixed) < 0.10      # every downstream boundary shifted


def _naive_gear_candidates(data: bytes, bits: int) -> list:
    """Byte-at-a-time gear rolling hash — the trusted oracle for the
    vectorized window-doubling scan."""
    from repro.core.cid import _gear_table
    table = _gear_table()
    mask = (1 << bits) - 1
    h = 0
    out = []
    for i, b in enumerate(data):
        h = ((h << 1) + int(table[b])) & 0xFFFFFFFF
        if (h & mask) == mask:
            out.append(i)
    return out


def test_windowed_hash_doubling_matches_naive():
    """The log-passes window-doubling construction must be bitwise
    identical to the naive width-term accumulation, truncation at the
    array start included."""
    from repro.core.cid import _gear_table, _windowed_hash
    rng = np.random.default_rng(60)
    g = _gear_table()[rng.integers(0, 256, 300)].astype(np.uint32)
    for width in (1, 2, 3, 7, 8, 13, 30, 64, 299, 300, 512):
        naive = np.zeros(len(g), dtype=np.uint32)
        for k in range(min(width, len(g))):
            naive[k:] += g[:len(g) - k] << np.uint32(k)
        np.testing.assert_array_equal(_windowed_hash(g, width), naive)


def test_cdc_candidates_match_naive_rolling_hash():
    """Strict and loose candidate sets both fall out of one wide scan;
    each must equal an independent byte-at-a-time scan at its own mask
    width (the gear-table-compatibility property)."""
    from repro.core.cid import _cdc_candidates
    data = _blob(64 * 1024, 61)
    bits, norm = 10, 2
    strict, loose = _cdc_candidates(data, bits, norm)
    assert strict.tolist() == _naive_gear_candidates(data, bits + norm)
    assert loose.tolist() == _naive_gear_candidates(data, bits - norm)
    s0, l0 = _cdc_candidates(data, bits, 0)
    assert s0.tolist() == l0.tolist() == _naive_gear_candidates(data, bits)


def test_norm_zero_is_exactly_the_legacy_chunking():
    """norm=0 must reproduce the single-mask boundaries byte-for-byte
    (published CIDs depend on it), for both the default spec field and an
    explicit norm=0."""
    data = _blob(512 * 1024, 62)
    legacy = ChunkSpec.cdc(avg_size=16 * 1024).split(data)
    assert ChunkSpec.cdc(avg_size=16 * 1024, norm=0).split(data) == legacy
    # and the greedy cut loop over naive candidates agrees end to end
    spec = ChunkSpec.cdc(avg_size=16 * 1024)
    bits = spec.avg_size.bit_length() - 1
    cands = [c + 1 for c in _naive_gear_candidates(data, bits)]
    cuts, last = [], 0
    while last < len(data):
        if len(data) - last <= spec.min_size:
            cuts.append(len(data))
            break
        hi = min(last + spec.max_size, len(data))
        nxt = [c for c in cands if last + spec.min_size <= c <= hi]
        cuts.append(nxt[0] if nxt else hi)
        last = cuts[-1]
    assert cdc_cut_points(data, spec.min_size, spec.avg_size,
                          spec.max_size) == cuts


def test_normalized_chunking_tightens_size_spread():
    """FastCDC normalization: chunk sizes concentrate around avg_size —
    lower coefficient of variation, fewer min-size runts — while staying
    deterministic and respecting the same [min, max] bounds."""
    data = _blob(2 * 2**20, 63)
    sizes = {}
    for norm in (0, 2):
        spec = ChunkSpec.cdc(avg_size=16 * 1024, norm=norm)
        chunks = spec.split(data)
        assert b"".join(chunks) == data
        for piece in chunks[:-1]:
            assert spec.min_size <= len(piece) <= spec.max_size
        assert spec.split(data) == chunks          # deterministic
        sizes[norm] = np.asarray([len(c) for c in chunks[:-1]], np.float64)
    cv = {n: s.std() / s.mean() for n, s in sizes.items()}
    assert cv[2] < 0.75 * cv[0]
    # the tiny-chunk overhead tail shrinks too
    small = {n: np.mean(s < 8 * 1024) for n, s in sizes.items()}
    assert small[2] <= small[0]


def test_normalized_chunking_still_shift_stable():
    data = _blob(512 * 1024, 64)
    edited = data[:9000] + b"\x7f" * 200 + data[9000:]
    spec = ChunkSpec.cdc(avg_size=16 * 1024, norm=2)
    before, after = set(spec.split(data)), spec.split(edited)
    reuse = sum(len(c) for c in after if c in before) / len(edited)
    assert reuse > 0.60


def test_chunkspec_norm_codec_and_validation():
    spec = ChunkSpec.cdc(avg_size=32 * 1024, norm=2)
    assert spec.encode() == b"cdc:8192:32768:131072:2"
    assert ChunkSpec.decode(spec.encode()) == spec
    # norm=0 keeps the legacy 4-field form (old readers must keep working)
    assert ChunkSpec.cdc(avg_size=32 * 1024, norm=0).encode() == \
        b"cdc:8192:32768:131072"
    assert ChunkSpec.decode(b"cdc:8192:32768:131072") == \
        ChunkSpec.cdc(avg_size=32 * 1024)
    with pytest.raises(ValueError):
        ChunkSpec(strategy="fixed", norm=1)        # norm is cdc-only
    with pytest.raises(ValueError):
        ChunkSpec.cdc(norm=-1)
    with pytest.raises(ValueError):
        ChunkSpec.cdc(norm=1.5)
    with pytest.raises(ValueError):
        ChunkSpec.decode(b"cdc:1:2:4:x")
    with pytest.raises(ValueError):
        ChunkSpec.decode(b"cdc:1:2:4:1:9")


def test_build_dag_default_keeps_fixed_layout():
    """No-spec builds must keep the historical fixed-chunk layout, so roots
    published before ChunkSpec existed stay reproducible."""
    from repro.core.cid import chunk
    data = _blob(3000, seed=52)
    legacy = build_dag(data, chunk_size=1024)
    explicit = build_dag(data, chunk_size=1024,
                         spec=ChunkSpec(strategy="fixed", chunk_size=1024))
    assert legacy.root == explicit.root
    leaves = decode_manifest(legacy.blocks[legacy.root])[0]
    assert [legacy.blocks[c] for c in leaves] == chunk(data, 1024)


def test_fixed_and_cdc_interop_same_bytes_either_way():
    parts = [("a", _blob(200 * 1024, 53), b"ma"), ("b", _blob(90 * 1024, 54), b"mb")]
    fx = build_tree_dag(parts, spec=ChunkSpec(strategy="fixed", chunk_size=32 * 1024))
    cd = build_tree_dag(parts, spec=ChunkSpec.cdc(avg_size=32 * 1024))
    assert fx.root != cd.root           # different leaf layout, different CIDs
    assert read_dag(fx.root, fx.blocks.get) == read_dag(cd.root, cd.blocks.get)
    # entry names/meta/sizes are layout-independent
    assert [(e.name, e.size, e.meta) for e in fx.entries] == \
        [(e.name, e.size, e.meta) for e in cd.entries]


def test_cdc_artifact_fetches_over_mesh():
    """A cdc-chunked v2 artifact is decodable/fetchable by peers that never
    saw the spec — the manifest lists leaf CIDs, whatever their boundaries."""
    fleet = make_fleet(4, seed=37, same_region="us")
    sim = fleet.sim
    a, b = fleet.peers[0], fleet.peers[-1]
    parts = [("t0", _blob(300 * 1024, 55), b""), ("t1", _blob(100 * 1024, 56), b"")]

    def run():
        root = yield from a.publish_tree_artifact(
            parts, spec=ChunkSpec.cdc(avg_size=64 * 1024))
        got = yield from b.fetch_artifact(root)
        return got

    assert sim.run_process(run(), until=sim.now + 900) == \
        b"".join(p[1] for p in parts)


# ---------------------------------------------------- blockstore pin/evict

def test_blockstore_budget_evicts_lru_unpinned():
    bs = BlockStore(capacity=1000)
    blocks = [_blob(300, i + 10) for i in range(4)]
    cids = [CID.for_data(b) for b in blocks]
    for c, b in zip(cids[:3], blocks[:3]):
        bs.put(c, b)
    assert bs.bytes_stored == 900
    bs.get(cids[0])                         # touch 0 -> LRU victim is 1
    bs.put(cids[3], blocks[3])
    assert bs.bytes_stored <= 1000
    assert not bs.has(cids[1]) and bs.has(cids[0]) and bs.has(cids[3])
    assert bs.stats["evictions"] == 1 and bs.stats["bytes_evicted"] == 300


def test_blockstore_pinned_roots_never_evicted():
    data = _blob(2048, 20)
    dag = build_tree_dag([("a", data[:1024], b""), ("b", data[1024:], b"")],
                         chunk_size=512)
    bs = BlockStore(capacity=None)
    bs.put_many(dag.blocks)
    bs.pin(dag.root)
    # budget far below the DAG size: nothing evictable, store overflows
    bs.set_capacity(512)
    for c in dag.blocks:
        assert bs.has(c), f"pinned block {c} evicted"
    assert bs.stats["evictions"] == 0
    with pytest.raises(ValueError):
        bs.delete(dag.root)
    # unpinned filler survives its own put (incoming blocks are exempt from
    # their own sweep) but is the LRU victim of the next one
    filler, filler2 = _blob(600, 21), _blob(600, 22)
    bs.put(CID.for_data(filler), filler)
    assert bs.has(CID.for_data(filler))
    bs.put(CID.for_data(filler2), filler2)
    assert not bs.has(CID.for_data(filler))
    for c in dag.blocks:
        assert bs.has(c)
    # after unpin the DAG becomes evictable
    bs.unpin(dag.root)
    bs.put(CID.for_data(filler), filler)
    assert all(not bs.has(c) for c in dag.blocks)


def test_blockstore_pin_refcounts_shared_subdags():
    a, b, c = _blob(400, 30), _blob(400, 31), _blob(400, 32)
    v1 = build_tree_dag([("t0", a, b""), ("t1", b, b"")], chunk_size=256)
    v2 = build_tree_dag([("t0", a, b""), ("t1", c, b"")], chunk_size=256)
    bs = BlockStore()
    bs.put_many(v1.blocks)
    bs.put_many(v2.blocks)
    bs.pin(v1.root)
    bs.pin(v2.root)
    shared = set(v1.blocks) & set(v2.blocks)
    assert shared, "versions should share t0's sub-DAG"
    bs.unpin(v1.root)
    # shared blocks still pinned through v2
    for cid in shared:
        assert bs.pinned(cid), f"{cid} lost its pin while v2 still holds it"
    # v1-only blocks are now unpinned
    for cid in set(v1.blocks) - shared:
        assert not bs.pinned(cid)


def test_unpin_releases_only_what_pin_counted():
    """pin() records its reachable set; blocks that arrive *afterwards* under
    that root were never refcounted for it, so unpin() must not decrement
    them — doing so silently strips another root's pin (the old bug)."""
    a, b, c = _blob(400, 60), _blob(400, 61), _blob(400, 62)
    v1 = build_tree_dag([("t0", a, b""), ("t1", b, b"")], chunk_size=256)
    v2 = build_tree_dag([("t0", a, b""), ("t1", c, b"")], chunk_size=256)
    bs = BlockStore()
    # v1: only the root manifest is resident at pin time, so the pin covers
    # just {root, sub-roots} — the sub-DAG interiors are unknown
    bs.put(v1.root, v1.blocks[v1.root])
    bs.pin(v1.root)
    # v2 arrives fully and is pinned: its leaves (incl. the shared t0
    # sub-DAG, which v1 also references) are refcounted exactly once
    bs.put_many(v2.blocks)
    bs.pin(v2.root)
    # late arrival: the rest of v1 (t1's sub-DAG) shows up after the pin
    bs.put_many({k: v for k, v in v1.blocks.items() if k != v1.root})
    shared_leaves = set(dag_reachable(v1.entries[0].cid, v2.blocks.get)) \
        - {v1.entries[0].cid}
    assert shared_leaves
    bs.unpin(v1.root)
    # v2 still pins the shared sub-DAG: a re-walking unpin would have
    # decremented these leaves to zero and made pinned data evictable
    for cid in shared_leaves:
        assert bs.pinned(cid), f"shared leaf {cid} lost v2's pin"
    assert v1.root not in bs.pinned_roots and v2.root in bs.pinned_roots
    # and the pinned version survives an over-budget squeeze
    bs.set_capacity(sum(len(blk) for blk in v2.blocks.values()))
    filler = _blob(700, 63)
    bs.put(CID.for_data(filler), filler)
    for cid in dag_reachable(v2.root, bs.peek):
        assert bs.has(cid), f"pinned v2 block {cid} evicted"


def test_unpin_unknown_root_is_noop():
    bs = BlockStore()
    data = _blob(64, 64)
    cid = CID.for_data(data)
    bs.put(cid, data)
    assert bs.unpin(cid) == 0
    assert bs.pin(cid) == 1 and bs.pin(cid) == 0     # idempotent
    assert bs.unpin(cid) == 1 and bs.unpin(cid) == 0


def test_blockstore_hit_miss_counters():
    bs = BlockStore()
    cid = CID.for_data(b"payload")
    assert bs.get(cid) is None
    bs.put(cid, b"payload")
    assert bs.get(cid) == b"payload"
    assert bs.stats == {"hits": 1, "misses": 1, "evictions": 0,
                        "bytes_evicted": 0}
    # peek doesn't skew the counters
    assert bs.peek(cid) == b"payload"
    assert bs.stats["hits"] == 1


# ------------------------------------------------------- provider scoring

def test_provider_score_ewma_and_failures():
    s = ProviderScore()
    start = s.value()
    for _ in range(10):
        s.record(1 << 20, 0.01)          # 100 MB/s provider
    assert s.value() > start
    fast = s.value()
    s.fail()
    s.fail()
    assert s.value() == pytest.approx(fast / 4)
    s.record(1 << 20, 0.01)              # success decays the failure penalty
    assert s.value() > fast / 4


def test_stripe_assignment_biases_toward_fast_provider():
    fleet = make_fleet(4, seed=3, same_region="us")
    node = fleet.peers[0]
    bs = node.bitswap
    fast, slow = fleet.peers[1].info(), fleet.peers[2].info()
    for _ in range(8):
        bs.score(fast).record(1 << 22, 0.01)     # ~400 MB/s
        bs.score(slow).record(1 << 18, 0.1)      # ~2.6 MB/s
    wanted = [CID.for_data(bytes([i]) * 8) for i in range(40)]
    stripes = bs._stripe(wanted, [fast, slow])
    assert len(stripes[0]) > 3 * len(stripes[1])
    assert sorted(sum(stripes, []), key=lambda c: c.digest) == \
        sorted(wanted, key=lambda c: c.digest)


def test_scoring_failover_prefers_healthy_provider():
    """A provider that dropped its blocks accumulates failures; the fetch
    still completes from the healthy seed and the dead one scores lower."""
    fleet = make_fleet(8, seed=9, same_region="us")
    sim = fleet.sim
    data = _blob(2 << 20, 9)
    good, flaky = fleet.peers[0], fleet.peers[1]

    def seed_all():
        dag = build_dag(data)
        yield from good.bitswap.publish_dag(dict(dag.blocks), dag.root)
        yield from flaky.bitswap.publish_dag(dict(dag.blocks), dag.root)
        return dag.root

    root = sim.run_process(seed_all(), until=sim.now + 600)
    for cid in list(flaky.blockstore.cids()):
        flaky.blockstore.delete(cid)

    leecher = fleet.peers[-1]

    def fetch():
        got = yield from leecher.fetch_artifact(root, reprovide=False)
        return got

    assert sim.run_process(fetch(), until=sim.now + 900) == data
    lb = leecher.bitswap
    assert lb.score(flaky.info()).failures > 0
    assert lb.score(good.info()).value() > lb.score(flaky.info()).value()


# ------------------------------------------- misbehaving peers / bad blocks

def test_stream_fetch_rejects_unsolicited_blocks():
    """A provider that streams self-verifying blocks nobody asked for must
    not get them stored (store-stuffing) nor credited to its throughput
    score; the fetch still completes via the honest retry path."""
    from repro.core.bitswap import BitswapService, streaming
    from repro.core.rpc import RpcError

    junk = b"unsolicited stuffing " * 64
    junk_cid = CID.for_data(junk)

    class StuffingBitswapService(BitswapService):
        @streaming("bs.fetch")
        def fetch(self, chan, ctx):
            bs = self.bitswap
            try:
                wants = yield from chan.recv(timeout=60.0)
            except RpcError:
                return
            try:
                # pad the stream with a verifiable block off the wantlist
                yield from chan.send((junk_cid, junk), len(junk))
                for cid in wants:
                    block = bs.node.blockstore.get(cid)
                    yield ctx.cpu(8e-6)
                    yield from chan.send((cid, block),
                                         len(block) if block else 64)
            except RpcError:
                return
            chan.end()

    fleet = make_fleet(3, seed=41, same_region="us")
    sim = fleet.sim
    provider, leecher = fleet.peers[0], fleet.peers[-1]
    provider.serve(StuffingBitswapService(provider.bitswap))
    data = _blob(8 * 256 * 1024, 65)      # 8 leaves: streaming plane engages

    def run():
        root = yield from provider.publish_artifact(data)
        got = yield from leecher.fetch_artifact(root, reprovide=False)
        return got

    assert sim.run_process(run(), until=sim.now + 900) == data
    assert leecher.bitswap.stats["unsolicited_rejected"] >= 1
    assert not leecher.blockstore.has(junk_cid)


def test_corrupt_manifest_surfaces_as_fetch_error():
    """A hash-valid but truncated/garbage manifest is a protocol error: the
    fetch raises FetchError instead of leaking struct.error/IndexError."""
    fleet = make_fleet(3, seed=43, same_region="us")
    sim = fleet.sim
    provider, leecher = fleet.peers[0], fleet.peers[-1]
    good = encode_manifest_v2(
        [ManifestEntry("t", CID.for_data(b"x"), 1, b"")], 1, b"meta")
    for bad in (good[:len(good) - 6], good[:9], b"LDG2" + b"\xff" * 40):
        cid = CID.for_data(bad, CODEC_DAG)

        def run(cid=cid, bad=bad):
            yield from provider.bitswap.publish_dag({cid: bad}, cid)
            yield from leecher.fetch_artifact(cid, reprovide=False)

        with pytest.raises(FetchError):
            sim.run_process(run(), until=sim.now + 900)


# ----------------------------------------------- manifest decoder hardening

def test_manifest_decoders_reject_truncation_with_valueerror():
    v1 = encode_manifest([CID.for_data(b"a"), CID.for_data(b"b")], 2, b"meta")
    v2 = encode_manifest_v2(
        [ManifestEntry("name", CID.for_data(b"a"), 7, b"entry-meta")], 7, b"m")
    for full, decode in ((v1, decode_manifest), (v2, decode_manifest_v2)):
        decode(full)                            # sanity: intact decodes
        for k in range(len(full)):
            with pytest.raises(ValueError):
                decode(full[:k])
    with pytest.raises(ValueError):
        decode_manifest(v2)                     # wrong magic, right length
    with pytest.raises(ValueError):
        decode_manifest_v2(v1)


@settings(max_examples=150, deadline=None)
@given(st.binary(max_size=160))
def test_manifest_decoders_raise_only_valueerror_on_garbage(blob):
    for prefix in (b"", b"LDAG", b"LDG2"):
        data = prefix + blob
        for fn in (manifest_version, decode_manifest, decode_manifest_v2,
                   manifest_children):
            try:
                fn(data)
            except ValueError:
                pass        # the one contract error callers translate


# -------------------------------------------- safe checkpoint meta encoding

def test_leaf_meta_roundtrip_is_pickle_free():
    from repro.checkpoint.serial import leaf_from_part, params_to_parts
    tree = {"emb/w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "bias": np.array(2.5, dtype=np.float16)}
    parts = {name: (raw, meta) for name, raw, meta in params_to_parts(tree)}
    for name, arr in tree.items():
        raw, meta = parts[name]
        assert not meta.startswith(b"\x80"), "meta must not be pickled"
        np.testing.assert_array_equal(leaf_from_part(raw, meta), arr)


def test_leaf_meta_legacy_pickle_shim_and_exploit_rejection():
    import os
    from repro.checkpoint.serial import leaf_from_part

    raw = np.arange(6, dtype=np.float32).tobytes()
    # primitives-only legacy meta (what old publishers wrote) still decodes
    legacy = pickle.dumps(("float32", (2, 3)))
    assert leaf_from_part(raw, legacy).shape == (2, 3)

    # a pickle that resolves any global — the ACE vector — is refused
    class Exploit:
        def __reduce__(self):
            return (os.system, ("echo pwned",))

    with pytest.raises(ValueError):
        leaf_from_part(raw, pickle.dumps(Exploit()))
    # unsafe dtypes can't smuggle object pointers through frombuffer
    with pytest.raises(ValueError):
        leaf_from_part(raw, b'{"dtype":"object","shape":[6]}')
    with pytest.raises(ValueError):
        leaf_from_part(raw, b'{"dtype":"float32","shape":[-1]}')
    with pytest.raises(ValueError):
        leaf_from_part(raw, b"not json, not pickle")


def test_safe_meta_loads_allowlists_peerinfo_only():
    import os
    from repro.checkpoint.lattica_ckpt import safe_meta_loads
    from repro.core.dht import PeerInfo
    from repro.core.peer import PeerId

    info = PeerInfo(PeerId(b"\x07" * 32), "peer0")
    meta = {"step": 3, "chunking": "cdc:1:2:4", "publisher": info}
    back = safe_meta_loads(pickle.dumps(meta))
    assert back["step"] == 3 and back["publisher"] == info

    class Exploit:
        def __reduce__(self):
            return (os.system, ("echo pwned",))

    with pytest.raises(ValueError):
        safe_meta_loads(pickle.dumps({"step": 1, "publisher": Exploit()}))
    with pytest.raises(ValueError):
        safe_meta_loads(b"\x80\x04 garbage")


def test_params_from_bytes_legacy_and_hostile_blobs():
    import struct as struct_mod
    from repro.checkpoint.serial import params_from_bytes, params_to_bytes

    tree = {"a": np.arange(4, dtype=np.float32),
            "b": np.arange(6, dtype=np.int32).reshape(2, 3)}
    blob = params_to_bytes(tree)
    assert blob[:4] == b"LCK2"
    back = params_from_bytes(blob, like=tree)
    for k in tree:
        np.testing.assert_array_equal(tree[k], back[k])

    # hand-built legacy (LCK1, pickled-index) blob from an old release
    payload = tree["a"].tobytes() + tree["b"].tobytes()
    index = [("a", "float32", (4,), 0), ("b", "int32", (2, 3), 16)]
    head = pickle.dumps(index)
    legacy = b"LCK1" + struct_mod.pack(">I", len(head)) + head + payload
    back = params_from_bytes(legacy, like=tree)
    for k in tree:
        np.testing.assert_array_equal(tree[k], back[k])

    # a legacy blob whose index pickle resolves globals is refused
    import os

    class Exploit:
        def __reduce__(self):
            return (os.system, ("echo pwned",))

    head = pickle.dumps([Exploit()])
    hostile = b"LCK1" + struct_mod.pack(">I", len(head)) + head + payload
    with pytest.raises(ValueError):
        params_from_bytes(hostile)
    for garbage in (b"", b"LCK2", b"LCK2" + struct_mod.pack(">I", 99),
                    b"LCK9" + blob[4:], blob[:20]):
        with pytest.raises(ValueError):
            params_from_bytes(garbage)


# -------------------------------------------------- two-version delta sync

def _params(n_tensors: int, size: int, seed: int, mutate=()):
    rng = np.random.default_rng(seed)
    tree = {f"layer{i}/w": rng.integers(0, 256, size, dtype=np.uint8)
            for i in range(n_tensors)}
    rng2 = np.random.default_rng(seed + 999)
    for i in mutate:
        tree[f"layer{i}/w"] = rng2.integers(0, 256, size, dtype=np.uint8)
    return tree


def test_delta_sync_skips_unchanged_tensors():
    from repro.checkpoint.lattica_ckpt import (fetch_checkpoint,
                                               publish_checkpoint)
    fleet = make_fleet(6, seed=23, same_region="us")
    sim = fleet.sim
    trainer, edge = fleet.peers[0], fleet.peers[-1]
    # 10 tensors x 128 KiB; v2 mutates exactly one
    p1 = _params(10, 128 * 1024, seed=1)
    p2 = _params(10, 128 * 1024, seed=1, mutate=[4])

    def publish(params, step, base=None):
        root = yield from publish_checkpoint(trainer, params, step, "df",
                                             base=base)
        return root

    r1 = sim.run_process(publish(p1, 1), until=sim.now + 600)

    def fetch(root):
        got = yield from fetch_checkpoint(edge, root, like=p1, fleet="df")
        return got

    got1 = sim.run_process(fetch(r1), until=sim.now + 900)
    for k in p1:
        np.testing.assert_array_equal(p1[k], got1[k])
    full_bytes = edge.bitswap.stats["bytes_fetched"]
    blocks_after_v1 = set(edge.blockstore.cids())

    r2 = sim.run_process(publish(p2, 2, base=r1), until=sim.now + 600)
    got2 = sim.run_process(fetch(r2), until=sim.now + 900)
    for k in p2:
        np.testing.assert_array_equal(p2[k], got2[k])
    delta_bytes = edge.bitswap.stats["bytes_fetched"] - full_bytes
    # acceptance: 10% of tensors mutated -> v2 fetch < 30% of a full fetch
    assert delta_bytes < 0.3 * full_bytes, (delta_bytes, full_bytes)
    # unchanged-tensor blocks were never re-fetched: everything fetched for
    # v2 is new (changed tensor or manifests), not blocks we already had
    v1_manifest = trainer.blockstore.peek(r1)
    e1 = {e.name: e.cid for e in decode_manifest_v2(v1_manifest)[0]}
    e2 = {e.name: e.cid
          for e in decode_manifest_v2(trainer.blockstore.peek(r2))[0]}
    unchanged = [n for n in e1 if e1[n] == e2[n]]
    assert len(unchanged) == 9
    refetched = [c for c in blocks_after_v1
                 if c in set(edge.blockstore.cids())]
    assert len(refetched) == len(blocks_after_v1)   # old blocks still held
    # publisher-side delta stats match: ~1/10 of bytes are new
    import pickle
    meta = pickle.loads(decode_manifest_v2(
        trainer.blockstore.peek(r2))[2])
    d = meta["delta"]
    assert d["reused_blocks"] > 0
    assert d["new_bytes"] < 0.3 * (d["new_bytes"] + d["reused_bytes"])
    # post-hoc accounting over the store agrees on the byte split (the root
    # manifest differs between the two: meta vs meta-less build)
    from repro.checkpoint.lattica_ckpt import checkpoint_delta
    d2 = checkpoint_delta(trainer, r2, r1)
    assert d2["reused_bytes"] == d["reused_bytes"]


def test_publish_checkpoint_cdc_deterministic_and_spec_recorded():
    """Same params + same ChunkSpec => identical root CID on re-publish
    (boundary determinism), and the spec travels in the manifest meta so a
    delta publish against ``base`` reuses it automatically."""
    from repro.checkpoint.lattica_ckpt import chunk_spec_of, publish_checkpoint
    fleet = make_fleet(4, seed=47, same_region="us")
    sim = fleet.sim
    trainer = fleet.peers[0]
    spec = ChunkSpec.cdc(avg_size=32 * 1024)
    params = _params(4, 96 * 1024, seed=5)

    def publish(params, step, base=None, spec=None):
        root = yield from publish_checkpoint(trainer, params, step, "cdc",
                                             base=base, spec=spec)
        return root

    r1 = sim.run_process(publish(params, 1, spec=spec), until=sim.now + 600)
    r1_again = sim.run_process(publish(params, 1, spec=spec),
                               until=sim.now + 600)
    assert r1 == r1_again
    assert chunk_spec_of(trainer, r1) == spec
    # spec=None + base: the base's recorded spec is reused, so the unchanged
    # tensors' sub-root CIDs — cdc boundaries and all — reproduce verbatim
    p2 = dict(params)
    p2["layer0/w"] = _params(1, 96 * 1024, seed=6)["layer0/w"]
    r2 = sim.run_process(publish(p2, 2, base=r1), until=sim.now + 600)
    assert chunk_spec_of(trainer, r2) == spec
    e1 = {e.name: e.cid
          for e in decode_manifest_v2(trainer.blockstore.peek(r1))[0]}
    e2 = {e.name: e.cid
          for e in decode_manifest_v2(trainer.blockstore.peek(r2))[0]}
    assert e1["layer1/w"] == e2["layer1/w"]     # unchanged sub-root reused
    assert e1["layer0/w"] != e2["layer0/w"]


def test_cdc_checkpoint_reuses_leaves_across_grown_tensor():
    """The shift-stability payoff end-to-end: v2 *grows* a tensor (new rows
    prepended, every byte after them shifted); a cdc follower re-fetches only
    around the edit while fixed chunking re-fetches nearly everything."""
    from repro.checkpoint.lattica_ckpt import (fetch_checkpoint,
                                               publish_checkpoint)

    def run_one(spec):
        fleet = make_fleet(4, seed=53, same_region="us")
        sim = fleet.sim
        trainer, edge = fleet.peers[0], fleet.peers[-1]
        rng = np.random.default_rng(70)
        vocab = rng.integers(0, 256, 512 * 1024, dtype=np.uint8)
        grown = np.concatenate(
            [rng.integers(0, 256, 2048, dtype=np.uint8), vocab])
        p1 = {"embed/vocab": vocab}
        p2 = {"embed/vocab": grown}

        def publish(params, step, base=None):
            root = yield from publish_checkpoint(trainer, params, step, "gr",
                                                 base=base, spec=spec)
            return root

        def fetch(root, like):
            got = yield from fetch_checkpoint(edge, root, like=like,
                                              fleet="gr")
            return got

        r1 = sim.run_process(publish(p1, 1), until=sim.now + 600)
        got1 = sim.run_process(fetch(r1, p1), until=sim.now + 900)
        np.testing.assert_array_equal(got1["embed/vocab"], vocab)
        base_bytes = edge.bitswap.stats["bytes_fetched"]
        r2 = sim.run_process(publish(p2, 2, base=r1), until=sim.now + 600)
        # like=None: the grown tensor changes shape, so restore as a dict
        got2 = sim.run_process(fetch(r2, None), until=sim.now + 900)
        np.testing.assert_array_equal(got2["embed/vocab"], grown)
        return ((edge.bitswap.stats["bytes_fetched"] - base_bytes)
                / grown.nbytes)

    cdc_frac = run_one(ChunkSpec.cdc(avg_size=32 * 1024))
    fixed_frac = run_one(ChunkSpec(strategy="fixed", chunk_size=32 * 1024))
    assert cdc_frac < 0.40, f"cdc refetched {cdc_frac:.0%} after a grow"
    assert fixed_frac > 0.90, f"fixed refetched only {fixed_frac:.0%}"


def test_pinned_latest_survives_eviction_under_budget():
    """Blockstore budget < two checkpoints: after fetching v2, v1's blocks
    may be evicted but v2 (pinned latest) stays fully resident."""
    from repro.checkpoint.lattica_ckpt import (fetch_checkpoint,
                                               publish_checkpoint)
    fleet = make_fleet(6, seed=29, same_region="us")
    sim = fleet.sim
    trainer, edge = fleet.peers[0], fleet.peers[-1]
    p1 = _params(8, 128 * 1024, seed=2)
    p2 = _params(8, 128 * 1024, seed=3)          # fully different version
    ckpt_bytes = sum(v.nbytes for v in p1.values())
    edge.blockstore.set_capacity(int(1.5 * ckpt_bytes))

    def publish(params, step, base=None):
        root = yield from publish_checkpoint(trainer, params, step, "ev",
                                             base=base)
        return root

    def fetch(root):
        got = yield from fetch_checkpoint(edge, root, like=p1, fleet="ev")
        return got

    r1 = sim.run_process(publish(p1, 1), until=sim.now + 600)
    sim.run_process(fetch(r1), until=sim.now + 900)
    r2 = sim.run_process(publish(p2, 2, base=r1), until=sim.now + 600)
    got2 = sim.run_process(fetch(r2), until=sim.now + 900)
    for k in p2:
        np.testing.assert_array_equal(p2[k], got2[k])
    # v2 is pinned-latest: fully resident despite the budget
    for c in dag_reachable(r2, edge.blockstore.peek):
        assert edge.blockstore.has(c), f"latest-version block {c} evicted"
    assert edge.blockstore.stats["evictions"] > 0, \
        "budget < 2 checkpoints must have forced evictions of v1"
    assert edge.blockstore.bytes_stored <= int(1.5 * ckpt_bytes)


def test_flat_artifact_roundtrip_unchanged():
    """v1 flat-blob publish/fetch semantics are untouched by the refactor."""
    fleet = make_fleet(6, seed=31)
    sim = fleet.sim
    a, b = fleet.peers[0], fleet.peers[-1]
    blob = _blob(768 * 1024, 44)

    def run():
        root = yield from a.publish_artifact(blob)
        assert manifest_version(a.blockstore.peek(root)) == 1
        got = yield from b.fetch_artifact(root)
        return got

    assert sim.run_process(run(), until=sim.now + 900) == blob
