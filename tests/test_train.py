"""Training loop + schedules + end-to-end mesh model-sync."""

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.data import make_batch_iterator
from repro.models import ops_for
from repro.optim import cosine_schedule, wsd_schedule
from repro.train import Trainer, train_state_init
from repro.train.trainer import LatticaSyncTrainer, ModelSubscriber


def test_loss_decreases_on_synthetic_data():
    cfg = get_config("minicpm-2b").reduced(n_layers=2, d_model=128, vocab=256)
    data = make_batch_iterator(cfg.vocab, seq_len=64, global_batch=8, seed=0)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    trainer = Trainer(cfg, state, cosine_schedule(3e-3, 10, 200), data)
    hist = trainer.run(60, log=None)
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first - 0.2, (first, last)


def test_wsd_schedule_phases():
    sched = wsd_schedule(1e-3, warmup=10, stable=50, decay=40)
    assert float(sched(0)) == 0.0
    assert float(sched(5)) == pytest.approx(5e-4)
    assert float(sched(30)) == pytest.approx(1e-3)
    assert float(sched(59)) == pytest.approx(1e-3)
    assert float(sched(100)) == pytest.approx(1e-5, rel=0.05)
    # monotone decay inside the decay phase
    assert float(sched(70)) > float(sched(90))


def test_sharded_loader_deterministic_and_disjoint():
    it0 = make_batch_iterator(128, 32, global_batch=8, n_shards=2, shard=0)
    it0b = make_batch_iterator(128, 32, global_batch=8, n_shards=2, shard=0)
    it1 = make_batch_iterator(128, 32, global_batch=8, n_shards=2, shard=1)
    b0, b0b, b1 = next(it0), next(it0b), next(it1)
    np.testing.assert_array_equal(b0["tokens"], b0b["tokens"])
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    assert b0["tokens"].shape == (4, 32)
    # labels are next-token shifted with -1 tail padding
    np.testing.assert_array_equal(b0["labels"][:, :-1], b0["tokens"][:, 1:])
    assert (b0["labels"][:, -1] == -1).all()


def test_mesh_train_publish_subscribe():
    """Scenario 3 end-to-end: trainer publishes versions into the mesh;
    a subscriber cluster converges on the latest and fetches the params."""
    cfg = get_config("minicpm-2b").reduced(n_layers=2, d_model=64, vocab=128)
    fleet = make_fleet(8, seed=17)
    sim = fleet.sim
    trainer_node = fleet.peers[0]
    edge_node = fleet.peers[-1]

    data = make_batch_iterator(cfg.vocab, 32, global_batch=4, seed=1)
    state = train_state_init(cfg, jax.random.PRNGKey(0))
    trainer = LatticaSyncTrainer(
        cfg, state, cosine_schedule(1e-3, 5, 100), data,
        node=trainer_node, fleet="fleetX", publish_every=10,
        step_seconds=0.2)
    sub = ModelSubscriber(edge_node, cfg, "fleetX",
                          like=state.params)

    t_proc = sim.process(trainer.run_mesh(20, log=None))
    s_proc = sim.process(sub.follow(interval=2.0, until_step=19))
    sim.run(until=sim.now + 600)
    assert t_proc.triggered and not t_proc.failed
    assert sub.current_step == 20
    assert sub.params is not None
    # fetched params == trainer's final params
    for a, b in zip(jax.tree.leaves(trainer.state.params),
                    jax.tree.leaves(sub.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # version registry is consistent on both sides
    from repro.checkpoint.lattica_ckpt import CheckpointRegistry
    assert (CheckpointRegistry(edge_node, "fleetX").latest()
            == CheckpointRegistry(trainer_node, "fleetX").latest())
