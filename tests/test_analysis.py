"""latlint rule fixtures (each L001–L006 firing exactly once), waiver
parsing, and the simsan sanitizer: determinism digests, perturbation,
double-settle/orphan detection, leak audits, and regressions for the
stream-hygiene fixes."""

import textwrap

import pytest

from repro.analysis import run_lint
from repro.core import LatticaNode, Network, Sim, call_unary
from repro.core.fleet import make_fleet
from repro.core.nat import NATKind
from repro.serving.batch import KVPool

# ---------------------------------------------------------------------------
# latlint fixtures — one rule, one violation
# ---------------------------------------------------------------------------


def lint_src(tmp_path, src, name="fixture.py"):
    f = tmp_path / name
    f.parent.mkdir(parents=True, exist_ok=True)
    f.write_text(textwrap.dedent(src))
    return run_lint([f])


def only_active(report, rule):
    assert [v.rule for v in report.active] == [rule], report.format_text()
    return report.active[0]


def test_l001_wall_clock_fires_once(tmp_path):
    rep = lint_src(tmp_path, """\
        import time

        def handler(payload):
            return {"at": time.time(), "payload": payload}
        """)
    v = only_active(rep, "L001")
    assert "time.time()" in v.message


def test_l001_from_import_and_global_random(tmp_path):
    rep = lint_src(tmp_path, """\
        from time import monotonic as mono
        import random

        def jitter():
            return mono() + random.random()
        """)
    assert sorted(v.rule for v in rep.active) == ["L001", "L001"]


def test_l001_sim_rng_is_fine(tmp_path):
    rep = lint_src(tmp_path, """\
        def jitter(sim):
            return sim.now + sim.rng.random()
        """)
    assert rep.active == []


def test_l002_raw_rpc_fires_once(tmp_path):
    rep = lint_src(tmp_path, """\
        def wire(node, handler):
            node.router.register_unary("x.op", handler)
        """)
    v = only_active(rep, "L002")
    assert "typed service plane" in v.message


def test_l002_exempt_in_service_module(tmp_path):
    rep = lint_src(tmp_path, """\
        def wire(node, handler):
            node.router.register_unary("x.op", handler)
        """, name="repro/core/service.py")
    assert rep.active == []


def test_l003_pickle_fires_once(tmp_path):
    rep = lint_src(tmp_path, """\
        import pickle

        def decode(blob):
            return pickle.loads(blob)
        """)
    v = only_active(rep, "L003")
    assert "safepickle" in v.message


def test_l004_hedging_non_idempotent_fires_once(tmp_path):
    rep = lint_src(tmp_path, """\
        from repro.core.service import unary

        class Svc:
            @unary("infer", timeout=30.0)
            def infer(self, payload, ctx):
                yield 0
                return payload

        def caller(sim, stub, payload):
            def attempt():
                resp = yield from stub.infer(payload)
                return resp
            return hedged_call(sim, [attempt, attempt])
        """)
    v = only_active(rep, "L004")
    assert "'infer'" in v.message


def test_l004_declared_idempotent_is_fine(tmp_path):
    rep = lint_src(tmp_path, """\
        from repro.core.service import unary

        class Svc:
            @unary("score", timeout=30.0, idempotent=True)
            def score(self, payload, ctx):
                yield 0
                return payload

        def caller(sim, stub, payload):
            def attempt():
                resp = yield from stub.score(payload)
                return resp
            return hedged_call(sim, [attempt, attempt])
        """)
    assert rep.active == []


def test_l005_bare_generator_call_fires_once(tmp_path):
    rep = lint_src(tmp_path, """\
        def pump(chan):
            while True:
                yield chan.recv()

        def serve(chan):
            pump(chan)
            return True
        """)
    v = only_active(rep, "L005")
    assert "pump" in v.message


def test_l005_ambiguous_name_is_skipped(tmp_path):
    rep = lint_src(tmp_path, """\
        def send(x):
            yield x

        class Plain:
            def send(self, x):
                return x

        def use(obj, x):
            obj.send(x)
        """)
    assert rep.active == []


def test_l007_flat_summary_fires_once(tmp_path):
    rep = lint_src(tmp_path, """\
        def probe(store, stub):
            summary = encode_summary(store.key_digests())
            yield from stub.summary(summary)
        """)
    v = only_active(rep, "L007")
    assert "O(keys)" in v.message and "summary_forest" in v.message


def test_l007_waiver_and_crdt_module_exempt(tmp_path):
    waived = lint_src(tmp_path, """\
        def probe(store):
            # latlint: disable=L007 serves the flat-v2 wire for old peers
            return store.key_digests()
        """)
    assert waived.active == [] and [v.rule for v in waived.waived] == ["L007"]
    defining = lint_src(tmp_path, """\
        def summary_of(store):
            return store.key_digests()
        """, name="repro/core/crdt.py")
    assert defining.active == []


def test_l007_mst_walk_is_fine(tmp_path):
    rep = lint_src(tmp_path, """\
        def probe(store):
            forest = store.summary_forest()
            return store.summary_roots(), forest
        """)
    assert rep.active == []


def test_l006_vmem_budget_fires_once(tmp_path):
    rep = lint_src(tmp_path, """\
        import jax.experimental.pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def launch(x):
            return pl.pallas_call(
                kernel,
                grid=(4,),
                in_specs=[pl.BlockSpec((2048, 4096), lambda i: (0, i))],
                out_specs=pl.BlockSpec((2048, 4096), lambda i: (0, i)),
            )(x)
        """)
    v = only_active(rep, "L006")
    assert "VMEM" in v.message


def test_l006_index_map_arity_and_rank(tmp_path):
    rep = lint_src(tmp_path, """\
        import jax.experimental.pallas as pl

        def kernel(x_ref, o_ref):
            o_ref[...] = x_ref[...]

        def launch(x):
            return pl.pallas_call(
                kernel,
                grid=(4, 8),
                in_specs=[pl.BlockSpec((8, 16), lambda i: (0, i))],
                out_specs=pl.BlockSpec((8, 16), lambda i, j: (i, j)),
            )(x)
        """)
    assert [v.rule for v in rep.active] == ["L006"]
    assert "2 grid dims" in rep.active[0].message


def test_l006_grid_divisibility_guard(tmp_path):
    bad = """\
        import jax.experimental.pallas as pl

        def launch(x, S, bq=128):
            {guard}
            return pl.pallas_call(
                lambda x_ref, o_ref: None,
                grid=(S // bq,),
                in_specs=[pl.BlockSpec((8, 16), lambda i: (0, i))],
                out_specs=pl.BlockSpec((8, 16), lambda i: (0, i)),
            )(x)
        """
    rep = lint_src(tmp_path, bad.format(guard="pass"))
    assert [v.rule for v in rep.active] == ["L006"]
    assert "assert S % bq == 0" in rep.active[0].message
    rep = lint_src(tmp_path, bad.format(guard="assert S % bq == 0"))
    assert rep.active == []


# ---------------------------------------------------------------------------
# waivers
# ---------------------------------------------------------------------------


def test_waiver_trailing_with_reason(tmp_path):
    rep = lint_src(tmp_path, """\
        import time

        def banner():
            return time.time()  # latlint: disable=L001 CLI banner timing
        """)
    assert rep.active == []
    assert len(rep.waived) == 1
    assert rep.waived[0].waive_reason == "CLI banner timing"


def test_waiver_without_reason_does_not_waive(tmp_path):
    rep = lint_src(tmp_path, """\
        import time

        def banner():
            return time.time()  # latlint: disable=L001
        """)
    v = only_active(rep, "L001")
    assert "missing a reason" in v.message


def test_waiver_line_above_and_file_level(tmp_path):
    rep = lint_src(tmp_path, """\
        import time

        def banner():
            # latlint: disable=L001 standalone waiver above the call
            return time.time()
        """)
    assert rep.active == [] and len(rep.waived) == 1
    rep = lint_src(tmp_path, """\
        # latlint: disable-file=L001 whole module is host-side CLI code
        import time

        def a():
            return time.time()

        def b():
            return time.time()
        """)
    assert rep.active == [] and len(rep.waived) == 2


# ---------------------------------------------------------------------------
# simsan: determinism digests + perturbation
# ---------------------------------------------------------------------------


def _digest_scenario(seed, perturb=None):
    import random as stdlib_random
    sim = Sim(seed=seed, sanitize=True, perturb=perturb)
    order = []

    def worker(name):
        # per-worker seeded delays: drawing from the shared sim.rng here
        # would make the delays depend on same-time scheduling order (the
        # exact order-dependence the perturbation mode exists to surface)
        rng = stdlib_random.Random(f"{seed}:{name}")
        for _ in range(3):
            yield sim.timeout(rng.random())
            order.append((name, sim.now))

    for name in "abcd":
        sim.process(worker(name))
    sim.run()
    return sim.trace_digest(), order


def test_trace_digest_double_run_identical():
    d1, o1 = _digest_scenario(seed=11)
    d2, o2 = _digest_scenario(seed=11)
    assert d1 == d2 and o1 == o2


def test_trace_digest_differs_across_seeds():
    d1, _ = _digest_scenario(seed=11)
    d2, _ = _digest_scenario(seed=12)
    assert d1 != d2


def test_perturbation_keeps_functional_result():
    _, base = _digest_scenario(seed=11)
    for p in (1, 2, 3):
        _, got = _digest_scenario(seed=11, perturb=p)
        # distinct event times: dispatch order — and thus the functional
        # result — must be independent of the tie-break key
        assert got == base


def test_perturbation_reorders_simultaneous_events():
    def ties(perturb=None):
        sim = Sim(seed=0, sanitize=True, perturb=perturb)
        order = []

        def worker(name):
            for _ in range(3):
                yield sim.timeout(1.0)     # every worker wakes at t=1,2,3
                order.append(name)

        for name in "abcdef":
            sim.process(worker(name))
        sim.run()
        return order

    base = ties()
    assert base[:6] == list("abcdef")      # FIFO tie-break without perturb
    assert any(ties(perturb=p) != base for p in (1, 2, 3))


def test_trace_digest_requires_sanitize():
    sim = Sim(seed=0)
    with pytest.raises(Exception):
        sim.trace_digest()


# ---------------------------------------------------------------------------
# simsan: double-settle + orphan detection
# ---------------------------------------------------------------------------


def test_double_settle_benign_and_conflicting():
    sim = Sim(seed=0, sanitize=True)
    evt = sim.event()
    evt.succeed(5)
    evt.succeed(5)                         # idempotent re-settle: benign
    assert sim.san_report()["double_settles"] == []
    evt.succeed(6)                         # same kind, different value
    evt.fail(RuntimeError("late loser"))   # conflicting kind
    settles = sim.san_report()["double_settles"]
    assert len(settles) == 2
    assert settles[0]["first"] == "succeed" and settles[0]["second"] == "succeed"
    assert settles[1]["second"] == "fail"


def test_orphaned_process_reported_daemon_exempt():
    sim = Sim(seed=0, sanitize=True)

    def stuck():
        yield sim.event()

    def service_loop():
        while True:
            yield sim.timeout(1.0)

    def finishes():
        yield sim.timeout(0.5)

    sim.process(stuck())
    sim.process(service_loop(), daemon=True)
    sim.process(finishes())
    sim.run(until=10.0)
    orphans = sim.san_report()["orphans"]
    assert len(orphans) == 1 and "stuck" in orphans[0]


# ---------------------------------------------------------------------------
# simsan: leak audit
# ---------------------------------------------------------------------------


def _pair(seed=0, sanitize=True):
    sim = Sim(seed=seed, sanitize=sanitize)
    net = Network(sim)
    a = LatticaNode(net, "a", region="us", zone="a")
    b = LatticaNode(net, "b", region="us", zone="a")

    def conn():
        c = yield from a.connect_info(b.info())
        return c

    return sim, a, b, sim.run_process(conn())


def test_leak_fixture_half_open_stream_and_kv_page():
    sim, a, b, conn = _pair()
    pool = KVPool(n_layers=2, n_kv_heads=2, head_dim=16, page_size=8)
    sim.register_leak_check("kv.pages:test", pool.pages_in_use)
    sim.run(until=sim.now + 5)
    sim.leak_baseline()

    # leak 1: initiator opens a stream and walks away without closing it
    stream = conn.open_stream("fixture.unhandled", a.host)
    sim.run(until=sim.now + 5)
    # leak 2: KV pages allocated for a session and never freed
    pages = pool.alloc(3)

    audit = sim.leak_audit()
    assert audit["net.half_open_streams"] == 1
    assert audit["kv.pages:test"] == 3

    stream.close()
    pool.free(pages)
    assert sim.leak_audit() == {}


def test_unary_rpc_leaves_no_half_open_streams():
    sim, a, b, conn = _pair()

    def echo(payload, ctx):
        yield ctx.cpu(1e-6)
        return ("echo", payload), 64

    b.router.register_unary("t.echo", echo)
    sim.run(until=sim.now + 5)
    sim.leak_baseline()

    def run():
        for i in range(3):
            yield from call_unary(a.host, conn, "t.echo", {"i": i})

    sim.run_process(run(), until=sim.now + 60)
    sim.run(until=sim.now + 10)
    assert sim.leak_audit() == {}


def test_traversal_protocols_return_streams_to_baseline():
    """Regression for the handler-side stream hygiene fixes: a full
    NAT-traversal connect (AutoNAT, relay, DCUtR/ping as needed) must not
    strand stream endpoints or relay reservations."""
    sim = Sim(seed=7, sanitize=True)
    fleet = make_fleet(
        3, sim=sim, same_region="us",
        nat_kinds=[NATKind.PORT_RESTRICTED, NATKind.PORT_RESTRICTED, None])
    sim.run(until=sim.now + 30)
    sim.leak_baseline()

    conn = sim.run_process(
        fleet.peers[0].connect_info(fleet.peers[1].info()),
        until=sim.now + 300)
    assert conn is not None
    sim.run(until=sim.now + 30)
    audit = sim.leak_audit()
    assert "net.half_open_streams" not in audit, audit
    assert not any(k.startswith("relay.reservations") for k in audit), audit
    assert sim.san_report()["double_settles"] == []
