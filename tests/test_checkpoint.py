"""Checkpoint serialization + the Lattica publish/fetch path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (load_local, params_from_bytes, params_to_bytes,
                              save_local)
from repro.checkpoint.lattica_ckpt import (CheckpointRegistry,
                                           fetch_latest, publish_checkpoint)
from repro.configs import get_config
from repro.core.cid import build_dag
from repro.core.fleet import make_fleet
from repro.models import ops_for


def _params():
    cfg = get_config("minicpm-2b").reduced(n_layers=2, d_model=64, vocab=128)
    ops = ops_for(cfg)
    return cfg, ops.init(cfg, jax.random.PRNGKey(0))


def test_roundtrip_restores_structure_and_values():
    cfg, params = _params()
    blob = params_to_bytes(params)
    restored = params_from_bytes(blob, like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_canonical_bytes_are_deterministic():
    _, params = _params()
    assert params_to_bytes(params) == params_to_bytes(params)
    # identical params -> identical root CID (dedup across the mesh)
    r1 = build_dag(params_to_bytes(params)).root
    r2 = build_dag(params_to_bytes(jax.tree.map(jnp.copy, params))).root
    assert r1 == r2


@settings(max_examples=20, deadline=None)
@given(st.dictionaries(
    st.text(alphabet="abcdef", min_size=1, max_size=6),
    st.tuples(st.integers(1, 5), st.integers(1, 5)),
    min_size=1, max_size=5))
def test_roundtrip_arbitrary_trees(spec):
    tree = {k: np.arange(r * c, dtype=np.float32).reshape(r, c) * 1.5
            for k, (r, c) in spec.items()}
    blob = params_to_bytes(tree)
    back = params_from_bytes(blob, like=tree)
    for k in tree:
        np.testing.assert_array_equal(tree[k], back[k])


def test_local_save_load(tmp_path):
    _, params = _params()
    path = str(tmp_path / "ckpt" / "step10.lck")
    n = save_local(path, params)
    assert n > 0
    back = load_local(path, like=params)
    np.testing.assert_array_equal(np.asarray(params["embed"]),
                                  np.asarray(back["embed"]))


def test_publish_fetch_over_mesh():
    """The paper's RL-pipeline: trainer publishes, edge node swarm-fetches,
    CRDT registry carries the version pointer."""
    fleet = make_fleet(8, seed=13)
    sim = fleet.sim
    trainer, edge = fleet.peers[0], fleet.peers[-1]
    _, params = _params()

    def publish():
        root = yield from publish_checkpoint(trainer, params, 100, "fleetA")
        return root

    root = sim.run_process(publish(), until=sim.now + 600)
    assert CheckpointRegistry(trainer, "fleetA").latest()[0] == 100

    def fetch():
        # edge learns the registry via anti-entropy with the trainer
        yield from edge.sync_crdt_with(trainer.info())
        step, got = yield from fetch_latest(edge, "fleetA", like=params)
        return step, got

    step, got = sim.run_process(fetch(), until=sim.now + 900)
    assert step == 100
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
