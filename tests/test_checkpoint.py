"""Checkpoint serialization + the Lattica publish/fetch path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (leaf_from_part, load_local, params_from_bytes,
                              params_to_bytes, params_to_parts, save_local)
from repro.checkpoint.lattica_ckpt import (CheckpointRegistry,
                                           fetch_checkpoint, fetch_latest,
                                           negotiate_chunk_spec,
                                           publish_checkpoint)
from repro.core.cid import ChunkSpec
from repro.configs import get_config
from repro.core.cid import build_dag
from repro.core.fleet import make_fleet
from repro.models import ops_for


def _params():
    cfg = get_config("minicpm-2b").reduced(n_layers=2, d_model=64, vocab=128)
    ops = ops_for(cfg)
    return cfg, ops.init(cfg, jax.random.PRNGKey(0))


def test_roundtrip_restores_structure_and_values():
    cfg, params = _params()
    blob = params_to_bytes(params)
    restored = params_from_bytes(blob, like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_canonical_bytes_are_deterministic():
    _, params = _params()
    assert params_to_bytes(params) == params_to_bytes(params)
    # identical params -> identical root CID (dedup across the mesh)
    r1 = build_dag(params_to_bytes(params)).root
    r2 = build_dag(params_to_bytes(jax.tree.map(jnp.copy, params))).root
    assert r1 == r2


@settings(max_examples=20, deadline=None)
@given(st.dictionaries(
    st.text(alphabet="abcdef", min_size=1, max_size=6),
    st.tuples(st.integers(1, 5), st.integers(1, 5)),
    min_size=1, max_size=5))
def test_roundtrip_arbitrary_trees(spec):
    tree = {k: np.arange(r * c, dtype=np.float32).reshape(r, c) * 1.5
            for k, (r, c) in spec.items()}
    blob = params_to_bytes(tree)
    back = params_from_bytes(blob, like=tree)
    for k in tree:
        np.testing.assert_array_equal(tree[k], back[k])


def _block_bound(arr, block=4096):
    """Elementwise error bound of int8_block: per-block range / 508
    (zero-padding participates in the final block's min/max)."""
    flat = np.asarray(arr, np.float32).ravel()
    nb = -(-flat.size // block)
    padded = np.zeros(nb * block, np.float32)
    padded[:flat.size] = flat
    blocks = padded.reshape(nb, block)
    per_block = (blocks.max(axis=1) - blocks.min(axis=1)) / 508.0
    return (np.repeat(per_block, block)[:flat.size].reshape(arr.shape)
            + 1e-7)


def _mixed_tree():
    rng = np.random.default_rng(5)
    return {
        "big": (rng.normal(size=(3, 4096 + 123)) * 4.0).astype(np.float32),
        "odd": rng.normal(size=(4097,)).astype(np.float32),
        "small": rng.normal(size=(10,)).astype(np.float32),   # < min size
        "ints": np.arange(2048, dtype=np.int32),              # non-float
    }


def test_int8_block_roundtrip_within_bound():
    tree = _mixed_tree()
    blob = params_to_bytes(tree, quant="int8_block")
    assert blob[:4] == b"LCK3"
    back = params_from_bytes(blob, like=tree)
    for key in ("big", "odd"):
        err = np.abs(back[key] - tree[key])
        assert (err <= _block_bound(tree[key])).all(), key
        assert err.max() > 0                       # actually lossy
    # sub-threshold float and integer leaves ship raw: exact
    np.testing.assert_array_equal(back["small"], tree["small"])
    np.testing.assert_array_equal(back["ints"], tree["ints"])
    # float leaves drop to ~1/4; the raw int leaf keeps its full bytes
    assert len(blob) < 0.45 * len(params_to_bytes(tree))


def test_quant_blob_is_deterministic():
    tree = _mixed_tree()
    assert (params_to_bytes(tree, quant="int8_block")
            == params_to_bytes(tree, quant="int8_block"))


def test_unquantized_encoding_is_legacy_lck2():
    """quant=None must keep writing the exact LCK2 format older releases
    read (and published CIDs depend on), and old blobs must keep
    decoding."""
    tree = _mixed_tree()
    blob = params_to_bytes(tree)
    assert blob[:4] == b"LCK2"
    back = params_from_bytes(blob, like=tree)
    for k in tree:
        np.testing.assert_array_equal(back[k], tree[k])


def test_rejects_unknown_quant_mode():
    with pytest.raises(ValueError):
        params_to_bytes(_mixed_tree(), quant="int4_magic")


def test_quantized_parts_decode_per_leaf():
    """The per-tensor publish path: each part's meta carries the codec, so
    a fetcher dequantizes leaf-by-leaf without the whole blob."""
    tree = _mixed_tree()
    parts = {name: (raw, meta)
             for name, raw, meta in params_to_parts(tree, quant="int8_block")}
    assert set(parts) == {"big", "odd", "small", "ints"}
    for key in ("big", "odd"):
        got = leaf_from_part(*parts[key])
        assert (np.abs(got - tree[key]) <= _block_bound(tree[key])).all()
        assert len(parts[key][0]) < 0.30 * tree[key].nbytes
    np.testing.assert_array_equal(leaf_from_part(*parts["small"]),
                                  tree["small"])
    np.testing.assert_array_equal(leaf_from_part(*parts["ints"]), tree["ints"])
    # quant=None parts are byte-identical to the historical encoding
    raw_parts = params_to_parts(tree)
    for name, raw, meta in raw_parts:
        assert raw == np.ascontiguousarray(tree[name]).tobytes()
        assert b"int8_block" not in meta


def test_publish_fetch_quantized_over_mesh():
    """End-to-end RL push with wire quantization: the trainer's fp32
    master stays local, the edge fetches int8_block parts and dequantizes
    transparently via part meta."""
    fleet = make_fleet(6, seed=17)
    sim = fleet.sim
    trainer, edge = fleet.peers[0], fleet.peers[-1]
    _, params = _params()

    def publish():
        root = yield from publish_checkpoint(trainer, params, 7, "qfleet",
                                             quant="int8_block")
        return root

    sim.run_process(publish(), until=sim.now + 600)

    def fetch():
        yield from edge.sync_crdt_with(trainer.info())
        step, got = yield from fetch_latest(edge, "qfleet", like=params)
        return step, got

    step, got = sim.run_process(fetch(), until=sim.now + 900)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape
        if a.dtype.kind == "f" and a.size >= 1024:
            assert (np.abs(b - a) <= _block_bound(a)).all()
        else:
            np.testing.assert_array_equal(a, b)


def test_local_save_load(tmp_path):
    _, params = _params()
    path = str(tmp_path / "ckpt" / "step10.lck")
    n = save_local(path, params)
    assert n > 0
    back = load_local(path, like=params)
    np.testing.assert_array_equal(np.asarray(params["embed"]),
                                  np.asarray(back["embed"]))


def test_fetch_negotiates_publisher_chunk_spec():
    """A fetcher preferring cdc against a fixed-chunked checkpoint still
    fetches fine — the publisher's recorded spec wins (content addressing
    fixes the boundaries) and the mismatch is counted for operators."""
    fleet = make_fleet(6, seed=17)
    sim = fleet.sim
    trainer, edge = fleet.peers[0], fleet.peers[-1]
    _, params = _params()
    pub_spec = ChunkSpec(chunk_size=32 * 1024)

    def publish():
        return (yield from publish_checkpoint(trainer, params, 7, "fleetB",
                                              spec=pub_spec))

    root = sim.run_process(publish(), until=sim.now + 600)
    prefer = ChunkSpec.cdc(avg_size=64 * 1024)

    def fetch():
        yield from edge.sync_crdt_with(trainer.info())
        return (yield from fetch_checkpoint(
            edge, root, like=params, hint_providers=[trainer.info()],
            prefer_spec=prefer))

    got = sim.run_process(fetch(), until=sim.now + 900)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert edge.bitswap.stats["spec_mismatch"] == 1
    assert edge.bitswap.stats["spec_negotiated"] == 1
    # the negotiated spec is the publisher's: a delta re-publish from the
    # fetcher reproduces identical boundaries
    assert negotiate_chunk_spec(edge, root, prefer) == pub_spec
    # agreeing (or indifferent) fetchers never count a mismatch
    assert negotiate_chunk_spec(edge, root, pub_spec) == pub_spec
    assert negotiate_chunk_spec(edge, root, None) == pub_spec
    assert edge.bitswap.stats["spec_mismatch"] == 2    # only the retry above


def test_publish_fetch_over_mesh():
    """The paper's RL-pipeline: trainer publishes, edge node swarm-fetches,
    CRDT registry carries the version pointer."""
    fleet = make_fleet(8, seed=13)
    sim = fleet.sim
    trainer, edge = fleet.peers[0], fleet.peers[-1]
    _, params = _params()

    def publish():
        root = yield from publish_checkpoint(trainer, params, 100, "fleetA")
        return root

    root = sim.run_process(publish(), until=sim.now + 600)
    assert CheckpointRegistry(trainer, "fleetA").latest()[0] == 100

    def fetch():
        # edge learns the registry via anti-entropy with the trainer
        yield from edge.sync_crdt_with(trainer.info())
        step, got = yield from fetch_latest(edge, "fleetA", like=params)
        return step, got

    step, got = sim.run_process(fetch(), until=sim.now + 900)
    assert step == 100
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------- sparse parts
def _topk_view(arr, k):
    """Reference top-|x| selection: sorted uint32 flat indices + values."""
    flat = np.asarray(arr, np.float32).ravel()
    idx = np.sort(np.argpartition(-np.abs(flat), k - 1)[:k]).astype(np.uint32)
    return idx, flat[idx]


def test_sparse_topk_part_roundtrip_exact():
    """vals=None sparse parts carry raw f32 values: kept positions decode
    exactly, absent positions decode to zero."""
    from repro.checkpoint.serial import encode_leaf_meta, encode_sparse_leaf

    rng = np.random.default_rng(11)
    arr = rng.normal(size=(64, 33)).astype(np.float32)
    idx, val = _topk_view(arr, 100)
    raw, enc = encode_sparse_leaf(idx, val, arr.shape)
    assert enc == {"codec": "topk", "k": 100}
    got = leaf_from_part(raw, encode_leaf_meta("float32", arr.shape, enc))
    dense = np.zeros(arr.size, np.float32)
    dense[idx] = val
    np.testing.assert_array_equal(got, dense.reshape(arr.shape))
    # wire cost is 8 bytes/kept element (uint32 idx + f32 val)
    assert len(raw) == 8 * 100


def test_sparse_topk_int8_vals_within_bound():
    """vals="int8_block" quantizes the kept values through the same
    block codec dense parts use; error bound holds on kept positions and
    absent positions stay exactly zero."""
    from repro.checkpoint.serial import encode_leaf_meta, encode_sparse_leaf

    rng = np.random.default_rng(12)
    arr = (rng.normal(size=(9000,)) * 3.0).astype(np.float32)
    idx, val = _topk_view(arr, 4500)
    raw, enc = encode_sparse_leaf(idx, val, arr.shape, vals="int8_block")
    assert enc["vals"] == "int8_block"
    got = leaf_from_part(raw, encode_leaf_meta("float32", arr.shape, enc))
    mask = np.zeros(arr.size, bool)
    mask[idx] = True
    assert (got[~mask] == 0).all()
    assert (np.abs(got[idx] - val) <= _block_bound(val)).all()
    assert len(raw) < 0.70 * 8 * 4500        # int8 vals beat raw f32 vals


def test_sparse_topk_rejects_malformed():
    """Peer-supplied sparse payloads: every malformation is a ValueError,
    never a crash or silent mis-decode."""
    from repro.checkpoint.serial import encode_leaf_meta, encode_sparse_leaf

    arr = np.arange(50, dtype=np.float32)
    idx, val = _topk_view(arr, 10)
    raw, enc = encode_sparse_leaf(idx, val, arr.shape)
    meta = encode_leaf_meta("float32", arr.shape, enc)
    # encoder-side: index out of range / length mismatch / bad vals codec
    with pytest.raises(ValueError):
        encode_sparse_leaf(np.array([50], np.uint32),
                           np.array([1.0], np.float32), arr.shape)
    with pytest.raises(ValueError):
        encode_sparse_leaf(idx, val[:-1], arr.shape)
    with pytest.raises(ValueError):
        encode_sparse_leaf(idx, val, arr.shape, vals="fp4")
    # decoder-side: k out of range for the leaf
    bad = encode_leaf_meta("float32", arr.shape,
                           {"codec": "topk", "k": 51})
    with pytest.raises(ValueError):
        leaf_from_part(raw, bad)
    # truncated payload
    with pytest.raises(ValueError):
        leaf_from_part(raw[:-3], meta)
    # out-of-range index smuggled into a well-formed payload
    evil_idx = idx.copy()
    evil_idx[0] = 4_000_000_000
    evil = np.sort(evil_idx).astype(np.uint32).tobytes() + val.tobytes()
    with pytest.raises(ValueError):
        leaf_from_part(evil, meta)


def test_local_save_cdc_dedup(tmp_path):
    """Chunked local checkpoints: a near-duplicate save (one leaf nudged)
    rewrites only the CDC blocks that actually changed."""
    _, params = _params()
    spec = ChunkSpec.cdc(avg_size=16 * 1024)
    p1 = str(tmp_path / "step10.lck")
    n1 = save_local(p1, params, spec=spec)
    back = load_local(p1, like=params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # nudge one leaf and re-save to the SAME path: the shared block dir
    # already holds every unchanged CDC chunk, so only the chunks covering
    # the edit (plus the root manifest) hit the disk
    edited = jax.tree.map(jnp.copy, params)
    edited["embed"] = edited["embed"].at[0, 0].add(1.0)
    n2 = save_local(p1, edited, spec=spec)
    assert 0 < n2 < 0.3 * n1
    back2 = load_local(p1, like=edited)
    np.testing.assert_array_equal(np.asarray(edited["embed"]),
                                  np.asarray(back2["embed"]))
    # byte-identical re-save: every block present, only the root rewrites
    n3 = save_local(p1, edited, spec=spec)
    assert n3 < 0.01 * n1
