"""NAT traversal: the hole-punch outcome matrix emerges from NAT semantics.

DCUtR v2 changes the classic Ford et al. matrix: symmetric NATs with a
*predictable* port allocator (sequential / fixed-delta) now reach direct
connectivity via predicted-port punching, while random allocators still fall
back to the relay.  Relay reservations are TTL'd and capacity-bounded, so
their lifecycle is covered here too.  All asserts resolve through explicit
RPC/connect outcomes (never anti-entropy timing).
"""

import pytest

from repro.core import (DialError, LatticaNode, NATBox, NATKind, Network,
                        PortAlloc, Sim)
from repro.core.fleet import make_nat
from repro.core.service import stream_request
from repro.core.traversal import PROTO_RELAY_RESERVE

K = NATKind

# NatSpec (fleet.make_nat): bare kind (default sequential allocator) or
# (kind, alloc, delta)
SYM_SEQ = (K.SYMMETRIC, "sequential", 1)
SYM_DELTA = (K.SYMMETRIC, "fixed_delta", 3)
SYM_RAND = (K.SYMMETRIC, "random", 1)


def _kind(spec):
    return spec if isinstance(spec, (NATKind, type(None))) else spec[0]


def _spec_id(spec):
    if spec is None:
        return "public"
    if isinstance(spec, NATKind):
        return spec.value
    kind, alloc, _ = spec
    return f"{kind.value}({alloc})"


def _mesh(spec_a, spec_b, seed=3):
    sim = Sim(seed=seed)
    net = Network(sim)
    boot1 = LatticaNode(net, "boot1", region="us", zone="core")
    boot2 = LatticaNode(net, "boot2", region="eu", zone="core")
    boot1.transport.enable_relay()
    boot2.transport.enable_relay()
    sim.run_process(boot2.connect_info(boot1.info()))
    binfos = [boot1.info(), boot2.info()]
    a = LatticaNode(net, "a", region="us", nat=make_nat(net, spec_a))
    b = LatticaNode(net, "b", region="eu", nat=make_nat(net, spec_b))

    def join(n):
        yield from n.bootstrap(binfos)
    sim.run_process(join(a))
    sim.run_process(join(b))
    return sim, a, b, [boot1, boot2]


#: Ford et al. (2005) pairwise matrix, updated for DCUtR v2: a symmetric NAT
#: with a predictable allocator is punchable via the predicted-port spray.
PUNCH_MATRIX = [
    (K.FULL_CONE, K.FULL_CONE, True),
    (K.FULL_CONE, K.RESTRICTED_CONE, True),
    (K.FULL_CONE, K.PORT_RESTRICTED, True),
    (K.FULL_CONE, SYM_SEQ, True),
    (K.RESTRICTED_CONE, K.RESTRICTED_CONE, True),
    (K.RESTRICTED_CONE, K.PORT_RESTRICTED, True),
    # address-restricted filter only checks the IP: no prediction needed
    (K.RESTRICTED_CONE, SYM_RAND, True),
    (K.PORT_RESTRICTED, K.PORT_RESTRICTED, True),
    # the seed-failing pairs: succeed iff the symmetric allocator is regular
    (K.PORT_RESTRICTED, SYM_SEQ, True),
    (SYM_SEQ, K.PORT_RESTRICTED, True),
    (K.PORT_RESTRICTED, SYM_DELTA, True),
    (K.PORT_RESTRICTED, SYM_RAND, False),
    (SYM_RAND, K.PORT_RESTRICTED, False),
    # symmetric<->symmetric with random allocators can never line up: both
    # sides mint unpredictable fresh mappings while punching — relay
    # fallback.  (Two *predictable* symmetric NATs are not asserted either
    # way: their sprays occasionally produce a matching (dst, src) pair.)
    (SYM_RAND, SYM_RAND, False),
]


@pytest.mark.parametrize(
    "sa,sb,expect_direct", PUNCH_MATRIX,
    ids=[f"{_spec_id(a)}-{_spec_id(b)}" for a, b, _ in PUNCH_MATRIX])
def test_punch_matrix(sa, sb, expect_direct):
    sim, a, b, _boots = _mesh(sa, sb)

    def connect():
        conn = yield from a.connect_info(b.info())
        return conn

    conn = sim.run_process(connect(), until=sim.now + 120)
    assert conn is not None                       # relay guarantees a path
    ka, kb = _kind(sa), _kind(sb)
    if expect_direct:
        # direct path: dialable peer (full-cone advertises its mapping),
        # reuse of an inbound connection, or a DCUtR punch
        assert not conn.relayed, f"{sa} -> {sb} should get a direct path"
        if (ka not in (None, K.FULL_CONE)
                and kb not in (None, K.FULL_CONE)):
            assert a.transport.stats["punch_ok"] >= 1
    else:
        assert conn.relayed, f"{sa} -> {sb} should fall back to relay"
        assert a.transport.stats["punch_fail"] >= 1


def test_predicted_punch_is_attributed():
    """A PORT_RESTRICTED -> SYMMETRIC(sequential) upgrade goes through the
    spray window, and the stats say so."""
    sim, a, b, _ = _mesh(K.PORT_RESTRICTED, SYM_SEQ)

    def connect():
        conn = yield from a.connect_info(b.info())
        return conn

    conn = sim.run_process(connect(), until=sim.now + 120)
    assert not conn.relayed
    assert (a.transport.stats["predicted_punch_ok"]
            + b.transport.stats["predicted_punch_ok"]) >= 1
    # the symmetric side probed its allocator before advertising it
    assert b.transport.stats["fingerprint_probes"] >= 1


def test_stale_first_candidate_still_upgrades():
    """Regression (seed bug): DCUtR punched only candidate[0], so one stale
    advertised address sank the whole upgrade.  v2 punches every candidate."""
    sim, a, b, _ = _mesh(K.PORT_RESTRICTED, K.PORT_RESTRICTED)
    # inject a bogus observed address; most-recent-first ordering makes it
    # the FIRST candidate b advertises
    b.transport._observe(("1.2.3.4", 1111))
    assert b.transport.candidate_addrs()[0] == ("1.2.3.4", 1111)

    def connect():
        conn = yield from a.connect_info(b.info())
        return conn

    conn = sim.run_process(connect(), until=sim.now + 120)
    assert not conn.relayed, "a stale first candidate must not sink DCUtR"


def test_autonat_ignores_stale_observed_addr():
    """Regression (seed bug): AutoNAT probed only sorted(observed)[0], so a
    stale lexically-smallest address misclassified a reachable host."""
    sim, a, b, boots = _mesh(K.FULL_CONE, None)
    assert a.transport.reachability == "public"
    # poison the address book with an unreachable, lexically-smallest addr
    a.transport._observe(("0.0.0.1", 1))
    assert min(sorted(a.transport.observed_addrs)) == ("0.0.0.1", 1)

    def reprobe():
        conn = a.host.connection_to(boots[0].host)
        assert conn is not None
        verdict = yield from a.transport.autonat_probe(conn)
        return verdict

    assert sim.run_process(reprobe(), until=sim.now + 60) == "public"


def test_observed_addrs_pruned_by_age():
    sim, a, _b, _ = _mesh(SYM_SEQ, None)   # symmetric: several observed addrs
    t = a.transport
    assert len(t.observed_addrs) > 1
    newest = t.candidate_addrs()[0]
    # fast-forward past the TTL with no traffic re-confirming the addrs:
    # stale extras are dropped, but the freshest mapping is always kept
    # (a keepalive-less node must never become completely unadvertisable)
    sim.run(until=sim.now + 400)
    assert t.observed_addrs == {newest}
    t._observe(("5.6.7.8", 99))
    assert t.candidate_addrs()[0] == ("5.6.7.8", 99)
    sim.run(until=sim.now + 400)
    assert t.observed_addrs == {("5.6.7.8", 99)}


def test_autonat_classification():
    cases = [(None, "public"), (K.FULL_CONE, "public"),
             (K.RESTRICTED_CONE, "private"), (K.PORT_RESTRICTED, "private"),
             (SYM_SEQ, "private")]
    for spec, expected in cases:
        sim, a, b, _ = _mesh(spec, None)
        assert a.transport.reachability == expected, spec


def test_relayed_connection_carries_data():
    sim, a, b, _ = _mesh(SYM_RAND, SYM_RAND)

    def roundtrip():
        conn = yield from a.connect_info(b.info())
        assert conn.relayed
        rtt = yield from a.transport.ping(conn)
        return rtt

    rtt = sim.run_process(roundtrip(), until=sim.now + 60)
    # us <-> eu via relay: at least 2 inter-region one-way latencies
    assert rtt > 2 * 0.075


def test_direct_dial_public_peers():
    sim, a, b, _ = _mesh(None, None)

    def connect():
        conn = yield from a.connect_info(b.info())
        return conn

    conn = sim.run_process(connect())
    assert conn is not None and not conn.relayed
    assert a.transport.stats["punch_ok"] == 0     # no punch needed


# ---------------------------------------------------------------------------
# NATBox port-allocation models
# ---------------------------------------------------------------------------


def test_port_alloc_sequential_and_fixed_delta():
    sim = Sim(seed=1)
    net = Network(sim)
    seq = NATBox(net, K.SYMMETRIC, alloc="sequential")
    host = net.host("h1", nat=seq)
    ports = [seq.map_outbound(host, 4001, ("9.9.9.9", p))[1]
             for p in range(1, 5)]
    assert [q - p for p, q in zip(ports, ports[1:])] == [1, 1, 1]
    # same destination reuses the mapping (endpoint-dependent, not per-packet)
    assert seq.map_outbound(host, 4001, ("9.9.9.9", 1))[1] == ports[0]

    fd = NATBox(net, K.SYMMETRIC, alloc=PortAlloc.FIXED_DELTA, delta=5)
    h2 = net.host("h2", nat=fd)
    ports = [fd.map_outbound(h2, 4001, ("9.9.9.9", p))[1]
             for p in range(1, 5)]
    assert [q - p for p, q in zip(ports, ports[1:])] == [5, 5, 5]


def test_port_alloc_random_is_irregular_but_deterministic():
    def draw(seed):
        sim = Sim(seed=seed)
        net = Network(sim)
        box = NATBox(net, K.SYMMETRIC, alloc="random")
        host = net.host("h", nat=box)
        return [box.map_outbound(host, 4001, ("9.9.9.9", p))[1]
                for p in range(1, 9)]

    ports = draw(7)
    deltas = {q - p for p, q in zip(ports, ports[1:])}
    assert len(deltas) > 1, "random allocator must not produce one stride"
    assert len(set(ports)) == len(ports)
    assert ports == draw(7)                 # seeded rng => reproducible


def test_mapping_expires_after_idle_ttl():
    sim = Sim(seed=2)
    net = Network(sim)
    box = NATBox(net, K.PORT_RESTRICTED, ttl=60.0)
    host = net.host("h", nat=box)
    ip, ext = box.map_outbound(host, 4001, ("9.9.9.9", 1))
    # inside the ttl: inbound from the contacted remote routes through
    assert box.filter_inbound(ext, ("9.9.9.9", 1)) == (host, 4001)
    sim.run(until=sim.now + 59.0)
    assert box.filter_inbound(ext, ("9.9.9.9", 1)) == (host, 4001)
    # the inbound datagram refreshed the idle timer (RFC 4787 REQ-6)
    sim.run(until=sim.now + 59.0)
    assert box.filter_inbound(ext, ("9.9.9.9", 1)) == (host, 4001)
    # idle past the ttl: the mapping is reclaimed, inbound goes unmapped
    sim.run(until=sim.now + 61.0)
    assert box.filter_inbound(ext, ("9.9.9.9", 1)) is None
    assert box.stats["expired"] == 1
    assert box.stats["inbound_unmapped"] == 1


def test_expired_mapping_reminted_with_fresh_port_and_filter():
    sim = Sim(seed=2)
    net = Network(sim)
    box = NATBox(net, K.PORT_RESTRICTED, ttl=30.0)
    host = net.host("h", nat=box)
    _, ext1 = box.map_outbound(host, 4001, ("9.9.9.9", 1))
    sim.run(until=sim.now + 31.0)
    _, ext2 = box.map_outbound(host, 4001, ("8.8.8.8", 2))
    assert ext2 != ext1, "post-expiry outbound must mint a fresh mapping"
    assert box.stats["expired"] == 1
    # the old filter state died with the mapping: the previously contacted
    # remote cannot reach the new external port
    assert box.filter_inbound(ext2, ("9.9.9.9", 1)) is None
    assert box.stats["inbound_filtered"] == 1
    assert box.filter_inbound(ext2, ("8.8.8.8", 2)) == (host, 4001)


def test_outbound_traffic_keeps_mapping_alive():
    sim = Sim(seed=2)
    net = Network(sim)
    box = NATBox(net, K.FULL_CONE, ttl=40.0)
    host = net.host("h", nat=box)
    _, ext = box.map_outbound(host, 4001, ("9.9.9.9", 1))
    for _ in range(4):                 # regular keepalives inside the ttl
        sim.run(until=sim.now + 35.0)
        assert box.map_outbound(host, 4001, ("9.9.9.9", 1))[1] == ext
    assert box.stats["expired"] == 0
    assert box.stats["mappings"] == 1


def test_ttl_none_keeps_mappings_forever():
    sim = Sim(seed=2)
    net = Network(sim)
    box = NATBox(net, K.PORT_RESTRICTED)          # the pre-expiry default
    host = net.host("h", nat=box)
    _, ext = box.map_outbound(host, 4001, ("9.9.9.9", 1))
    sim.run(until=sim.now + 10_000.0)
    assert box.filter_inbound(ext, ("9.9.9.9", 1)) == (host, 4001)
    assert box.stats["expired"] == 0


def test_natbox_stats_and_network_aggregate():
    sim, a, b, _ = _mesh(K.PORT_RESTRICTED, SYM_SEQ)

    def connect():
        conn = yield from a.connect_info(b.info())
        return conn

    sim.run_process(connect(), until=sim.now + 120)
    agg = a.net.nat_stats()
    assert "port_restricted" in agg
    assert "symmetric/sequential" in agg
    sym = agg["symmetric/sequential"]
    assert sym["boxes"] == 1 and sym["mappings"] > 1
    # punching a symmetric NAT necessarily bounces some datagrams off it
    assert sym["inbound_filtered"] + sym["inbound_unmapped"] > 0
    assert sym["inbound_ok"] > 0


# ---------------------------------------------------------------------------
# Relay reservation lifecycle
# ---------------------------------------------------------------------------


def _relay_of(node, boots):
    primary = node.relay_info
    assert primary is not None
    return next(bt for bt in boots if bt.peer_id == primary.peer_id)


def test_relay_reservation_expires_without_refresh():
    sim, a, b, boots = _mesh(SYM_RAND, SYM_RAND)
    relay = _relay_of(b, boots)
    assert b.peer_id.digest in relay.transport.relay_reservations
    sim.run(until=sim.now + relay.transport.relay_ttl + 1)

    def attempt():
        conn = yield from a.connect_info(relay.info())
        try:
            yield from a.transport.relay_connect(conn, b.peer_id)
            return "connected"
        except DialError as e:
            return str(e)

    outcome = sim.run_process(attempt(), until=sim.now + 60)
    assert "no reservation" in outcome
    assert b.peer_id.digest not in relay.transport.relay_reservations
    assert relay.transport.relay_stats["expired"] >= 1


def test_maintenance_loop_refreshes_reservation():
    sim, a, b, boots = _mesh(SYM_RAND, SYM_RAND)
    relay = _relay_of(b, boots)
    ttl = relay.transport.relay_ttl
    sim.process(b.maintenance_loop(interval=5.0))
    sim.run(until=sim.now + ttl + 30)        # past the unrefreshed expiry
    res = relay.transport.relay_reservations.get(b.peer_id.digest)
    assert res is not None and res.refreshes >= 1

    def attempt():
        conn = yield from a.connect_info(relay.info())
        circuit = yield from a.transport.relay_connect(conn, b.peer_id)
        return circuit

    assert sim.run_process(attempt(), until=sim.now + 60) is not None


def test_foreign_host_cannot_refresh_or_squat_reservation():
    """The reservation digest must match the peer on the authenticated
    connection: no refreshing someone else's slot, and no squatting a
    not-yet-joined peer's digest to capture its circuits."""
    from repro.core import PeerId

    sim, a, b, boots = _mesh(None, SYM_RAND)
    relay = _relay_of(b, boots)

    def forge(digest, claimed_name):
        conn = yield from a.connect_info(relay.info())
        stream = conn.open_stream(PROTO_RELAY_RESERVE, a.host)
        msg = yield from stream_request(
            stream, ("reserve", digest, claimed_name), 96, timeout=5.0)
        return msg

    # refresh of an existing slot, with the victim's own claimed name
    msg = sim.run_process(forge(b.peer_id.digest, "b"), until=sim.now + 60)
    assert msg[1] is False
    res = relay.transport.relay_reservations[b.peer_id.digest]
    assert res.host_name == "b"              # slot not hijacked
    # squat of a digest whose owner has not joined yet
    victim = PeerId.from_name("not-joined-yet")
    msg = sim.run_process(forge(victim.digest, a.host.name),
                          until=sim.now + 60)
    assert msg[1] is False
    assert victim.digest not in relay.transport.relay_reservations
    assert relay.transport.relay_stats["rejected_foreign"] >= 2


def test_relay_capacity_limit():
    sim = Sim(seed=11)
    net = Network(sim)
    boot = LatticaNode(net, "boot1", region="us", zone="core")
    boot.transport.enable_relay(capacity=1)
    binfos = [boot.info()]
    b = LatticaNode(net, "b", region="us", nat=NATBox(net, K.PORT_RESTRICTED))
    c = LatticaNode(net, "c", region="us", nat=NATBox(net, K.PORT_RESTRICTED))

    def join(n):
        yield from n.bootstrap(binfos)
    sim.run_process(join(b))
    sim.run_process(join(c))
    assert len(boot.transport.relay_reservations) == 1
    assert boot.transport.relay_stats["rejected_capacity"] >= 1
    assert b.relay_infos and not c.relay_infos
    # the holder can still refresh its own slot at capacity
    assert sim.run_process(b.reserve_relay(boot.info()), until=sim.now + 60)
    assert boot.transport.relay_stats["refreshed"] >= 1


def test_relay_drops_reservation_on_lost_target():
    sim, a, b, boots = _mesh(SYM_RAND, SYM_RAND)
    relay = _relay_of(b, boots)
    # the relay loses its connection to b (crash / link flap)
    conn = relay.host.connection_to(b.host)
    assert conn is not None
    conn.close()

    def attempt():
        c2r = yield from a.connect_info(relay.info())
        try:
            yield from a.transport.relay_connect(c2r, b.peer_id)
            return "connected"
        except DialError as e:
            return str(e)

    outcome = sim.run_process(attempt(), until=sim.now + 60)
    assert "relay lost target" in outcome
    assert b.peer_id.digest not in relay.transport.relay_reservations
    assert relay.transport.relay_stats["dropped_lost_target"] >= 1


def test_private_node_holds_failover_relays():
    """Relay selection reserves on the best-RTT relays, primary first, and
    advertises every held relay so dialers can fail over."""
    sim, a, b, boots = _mesh(SYM_RAND, SYM_RAND)
    assert len(b.relay_infos) == 2
    relay_addrs = [ad for ad in b.info().addrs if ad.is_relay]
    assert len(relay_addrs) == 2
    # primary is the lower-RTT relay: b sits in eu, boot2 is the eu relay
    assert b.relay_info.host_name == "boot2"
    meta = [b._relay_meta[i.peer_id.digest] for i in b.relay_infos]
    assert meta[0]["rtt"] <= meta[1]["rtt"]
