"""NAT traversal: the hole-punch outcome matrix emerges from NAT semantics."""

import pytest

from repro.core import DialError, LatticaNode, NATBox, NATKind, Network, Sim

K = NATKind


def _mesh(kind_a, kind_b, seed=3):
    sim = Sim(seed=seed)
    net = Network(sim)
    boot1 = LatticaNode(net, "boot1", region="us", zone="core")
    boot2 = LatticaNode(net, "boot2", region="eu", zone="core")
    boot1.transport.enable_relay()
    boot2.transport.enable_relay()
    sim.run_process(boot2.connect_info(boot1.info()))
    binfos = [boot1.info(), boot2.info()]
    nat_a = NATBox(net, kind_a) if kind_a else None
    nat_b = NATBox(net, kind_b) if kind_b else None
    a = LatticaNode(net, "a", region="us", nat=nat_a)
    b = LatticaNode(net, "b", region="eu", nat=nat_b)

    def join(n):
        yield from n.bootstrap(binfos)
    sim.run_process(join(a))
    sim.run_process(join(b))
    return sim, a, b


#: Ford et al. (2005) pairwise matrix: can a direct path be established?
PUNCH_MATRIX = [
    (K.FULL_CONE, K.FULL_CONE, True),
    (K.FULL_CONE, K.RESTRICTED_CONE, True),
    (K.FULL_CONE, K.PORT_RESTRICTED, True),
    (K.FULL_CONE, K.SYMMETRIC, True),
    (K.RESTRICTED_CONE, K.RESTRICTED_CONE, True),
    (K.RESTRICTED_CONE, K.PORT_RESTRICTED, True),
    (K.RESTRICTED_CONE, K.SYMMETRIC, True),
    (K.PORT_RESTRICTED, K.PORT_RESTRICTED, True),
    (K.PORT_RESTRICTED, K.SYMMETRIC, False),
    (K.SYMMETRIC, K.SYMMETRIC, False),
]


@pytest.mark.parametrize("ka,kb,expect_direct", PUNCH_MATRIX,
                         ids=[f"{a.value}-{b.value}" for a, b, _ in PUNCH_MATRIX])
def test_punch_matrix(ka, kb, expect_direct):
    sim, a, b = _mesh(ka, kb)

    def connect():
        conn = yield from a.connect_info(b.info())
        return conn

    conn = sim.run_process(connect(), until=sim.now + 120)
    assert conn is not None                       # relay guarantees a path
    if expect_direct:
        # direct path: dialable peer (full-cone advertises its mapping),
        # reuse of an inbound connection, or a DCUtR punch
        assert not conn.relayed, f"{ka} -> {kb} should get a direct path"
        if (ka not in (None, K.FULL_CONE)
                and kb not in (None, K.FULL_CONE)):
            assert a.transport.stats["punch_ok"] >= 1
    else:
        assert conn.relayed, f"{ka} -> {kb} should fall back to relay"
        assert a.transport.stats["punch_fail"] >= 1


def test_autonat_classification():
    cases = [(None, "public"), (K.FULL_CONE, "public"),
             (K.RESTRICTED_CONE, "private"), (K.PORT_RESTRICTED, "private"),
             (K.SYMMETRIC, "private")]
    for kind, expected in cases:
        sim, a, b = _mesh(kind, None)
        assert a.transport.reachability == expected, kind


def test_relayed_connection_carries_data():
    sim, a, b = _mesh(K.SYMMETRIC, K.SYMMETRIC)

    def roundtrip():
        conn = yield from a.connect_info(b.info())
        assert conn.relayed
        rtt = yield from a.transport.ping(conn)
        return rtt

    rtt = sim.run_process(roundtrip(), until=sim.now + 60)
    # us <-> eu via relay: at least 2 inter-region one-way latencies
    assert rtt > 2 * 0.075


def test_direct_dial_public_peers():
    sim, a, b = _mesh(None, None)

    def connect():
        conn = yield from a.connect_info(b.info())
        return conn

    conn = sim.run_process(connect())
    assert conn is not None and not conn.relayed
    assert a.transport.stats["punch_ok"] == 0     # no punch needed
