"""Sharded inference over the mesh: pipeline correctness + DHT failover."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.fleet import make_fleet
from repro.models import ops_for
from repro.serving.sharded import ShardClient, deploy_sharded


@pytest.fixture(scope="module")
def served():
    cfg = get_config("granite-8b").reduced(n_layers=4, d_model=64, vocab=256)
    ops = ops_for(cfg)
    params = ops.init(cfg, jax.random.PRNGKey(0))
    fleet = make_fleet(9, seed=21, same_region="us")
    sim = fleet.sim
    # 2 shards × 2 replicas on the first 4 peers
    servers = deploy_sharded(fleet.peers[:4], cfg, params, "svc", replicas=2)

    def announce():
        for s in servers:
            yield from s.announce()

    sim.run_process(announce(), until=sim.now + 600)
    return cfg, ops, params, fleet, servers


def test_pipeline_score_matches_local(served):
    cfg, ops, params, fleet, servers = served
    sim = fleet.sim
    client = ShardClient(fleet.peers[-1], cfg, "svc", n_shards=2)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                         0, cfg.vocab), np.int32)

    def run():
        out = yield from client.score(toks)
        return out

    remote = sim.run_process(run(), until=sim.now + 600)
    local, _ = ops.forward(params, cfg, {"tokens": jnp.asarray(toks)})
    np.testing.assert_allclose(remote, np.asarray(local), atol=1e-4, rtol=1e-4)


def test_generation_matches_local_engine(served):
    cfg, ops, params, fleet, servers = served
    sim = fleet.sim
    client = ShardClient(fleet.peers[-2], cfg, "svc", n_shards=2)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (1, 8),
                                         0, cfg.vocab), np.int32)

    def run():
        out = yield from client.generate(toks, 4)
        return out

    remote = sim.run_process(run(), until=sim.now + 600)
    from repro.serving.engine import GenerationEngine
    eng = GenerationEngine(cfg, params, max_len=32)
    local, _ = eng.generate({"tokens": jnp.asarray(toks)}, 4)
    np.testing.assert_array_equal(remote, local)


def test_failover_to_replica_shard(served):
    cfg, ops, params, fleet, servers = served
    sim = fleet.sim
    # kill the first replica of shard 0
    dead = [s for s in servers if s.shard_idx == 0][0]
    dead.stop()
    client = ShardClient(fleet.peers[-1], cfg, "svc", n_shards=2)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (1, 8),
                                         0, cfg.vocab), np.int32)

    def run():
        out = yield from client.score(toks)
        return out

    remote = sim.run_process(run(), until=sim.now + 900)
    local, _ = ops.forward(params, cfg, {"tokens": jnp.asarray(toks)})
    np.testing.assert_allclose(remote, np.asarray(local), atol=1e-4, rtol=1e-4)
    assert client.stats["failovers"] >= 1
