"""Bitswap: swarm fetch, verification, provider failover, re-providing."""

import numpy as np
import pytest

from repro.core.bitswap import FetchError
from repro.core.cid import CID, build_dag
from repro.core.fleet import make_fleet


def _blob(n: int, seed: int) -> bytes:
    """Incompressible bytes: every 256 KiB chunk gets a distinct CID."""
    return np.random.default_rng(seed).integers(
        0, 256, n, dtype=np.uint8).tobytes()


def test_fetch_from_single_seed():
    fleet = make_fleet(8, seed=2)
    sim = fleet.sim
    seed_node, leecher = fleet.peers[0], fleet.peers[-1]
    data = _blob(512 * 1024, 2)              # 512 KiB -> 2 distinct chunks

    def publish():
        root = yield from seed_node.publish_artifact(data)
        return root

    root = sim.run_process(publish(), until=sim.now + 300)

    def fetch():
        got = yield from leecher.fetch_artifact(root)
        return got

    assert sim.run_process(fetch(), until=sim.now + 600) == data
    # leecher re-provides after fetch
    assert leecher.blockstore.has(root)


def test_swarm_fetch_uses_multiple_providers():
    fleet = make_fleet(10, seed=4, same_region="us")
    sim = fleet.sim
    data = _blob(1 << 20, 4)                 # 1 MiB -> 4 distinct chunks
    seeds = fleet.peers[:3]

    def seed_all():
        dag = build_dag(data)
        for s in seeds:
            yield from s.bitswap.publish_dag(dict(dag.blocks), dag.root)
        return dag.root

    root = sim.run_process(seed_all(), until=sim.now + 600)
    leecher = fleet.peers[-1]

    def fetch():
        got = yield from leecher.fetch_artifact(root, reprovide=False)
        return got

    assert sim.run_process(fetch(), until=sim.now + 600) == data
    # at least two seeds actually served blocks
    serving = [s for s in seeds if s.bitswap.stats["blocks_served"] > 0]
    assert len(serving) >= 2


def test_failover_when_provider_dies_midfetch():
    fleet = make_fleet(8, seed=9, same_region="us")
    sim = fleet.sim
    data = _blob(2 << 20, 9)                 # 2 MiB -> 8 distinct chunks
    good, flaky = fleet.peers[0], fleet.peers[1]

    def seed_all():
        dag = build_dag(data)
        yield from good.bitswap.publish_dag(dict(dag.blocks), dag.root)
        yield from flaky.bitswap.publish_dag(dict(dag.blocks), dag.root)
        return dag.root

    root = sim.run_process(seed_all(), until=sim.now + 600)
    # flaky provider drops all its blocks after announcing
    for cid in list(flaky.blockstore.cids()):
        flaky.blockstore.delete(cid)

    leecher = fleet.peers[-1]

    def fetch():
        got = yield from leecher.fetch_artifact(root, reprovide=False)
        return got

    assert sim.run_process(fetch(), until=sim.now + 900) == data
    assert leecher.bitswap.stats["retries"] >= 1


def test_no_providers_raises():
    fleet = make_fleet(6, seed=6)
    sim = fleet.sim
    bogus = CID.for_data(b"never published")

    def fetch():
        yield from fleet.peers[0].fetch_artifact(bogus)

    with pytest.raises(FetchError):
        sim.run_process(fetch(), until=sim.now + 300)
