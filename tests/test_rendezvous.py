"""Rendezvous namespaces: expedited discovery before DHT records propagate."""

from repro.core.fleet import make_fleet
from repro.core.rendezvous import discover, register


def test_register_and_discover():
    fleet = make_fleet(6, seed=41)
    sim = fleet.sim
    rdv = fleet.bootstrap[0].info()          # boot0 serves rendezvous
    a, b, c = fleet.peers[0], fleet.peers[1], fleet.peers[2]

    def run():
        ok1 = yield from register(a, rdv, "fleet/llm", ttl=100.0)
        ok2 = yield from register(b, rdv, "fleet/llm", ttl=100.0)
        yield from register(c, rdv, "fleet/other", ttl=100.0)
        found = yield from discover(c, rdv, "fleet/llm")
        return ok1, ok2, found

    ok1, ok2, found = sim.run_process(run(), until=sim.now + 300)
    assert ok1 and ok2
    ids = {i.peer_id for i in found}
    assert a.peer_id in ids and b.peer_id in ids
    assert c.peer_id not in ids              # different namespace
    # discovery seeded c's peerstore with dialable records
    assert a.peer_id in c.peers


def test_ttl_expiry():
    fleet = make_fleet(4, seed=43)
    sim = fleet.sim
    rdv = fleet.bootstrap[0].info()
    a, b = fleet.peers[0], fleet.peers[1]

    def run():
        yield from register(a, rdv, "ns", ttl=5.0)
        yield 60.0                            # let the registration lapse
        found = yield from discover(b, rdv, "ns")
        return found

    found = sim.run_process(run(), until=sim.now + 300)
    assert a.peer_id not in {i.peer_id for i in found}
