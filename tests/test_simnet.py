"""The discrete-event substrate itself: processes, events, CPU, paths."""

import pytest

from repro.core.simnet import (CPU, DialError, Network, Sim, scenario_for)


def test_timeout_ordering_deterministic():
    sim = Sim(seed=0)
    log = []

    def proc(name, delay):
        yield delay
        log.append((name, sim.now))

    sim.process(proc("b", 2.0))
    sim.process(proc("a", 1.0))
    sim.process(proc("c", 1.0))       # same time as 'a': FIFO tie-break
    sim.run()
    assert log == [("a", 1.0), ("c", 1.0), ("b", 2.0)]


def test_process_return_value_and_chaining():
    sim = Sim()

    def child():
        yield 0.5
        return 42

    def parent():
        v = yield sim.process(child())
        return v * 2

    assert sim.run_process(parent()) == 84
    assert sim.now == 0.5


def test_exception_propagates_to_waiter():
    sim = Sim()

    def bad():
        yield 0.1
        raise DialError("nope")

    def parent():
        try:
            yield sim.process(bad())
        except DialError as e:
            return f"caught {e}"

    assert sim.run_process(parent()) == "caught nope"


def test_any_of_and_all_of():
    sim = Sim()

    def waiter():
        idx, val = yield sim.any_of([sim.timeout(2.0, "slow"),
                                     sim.timeout(1.0, "fast")])
        vals = yield sim.all_of([sim.timeout(0.5, "x"), sim.timeout(0.2, "y")])
        return idx, val, vals

    idx, val, vals = sim.run_process(waiter())
    assert (idx, val) == (1, "fast")
    assert vals == ["x", "y"]


def test_deadlock_detection():
    sim = Sim()

    def stuck():
        yield sim.event()             # never fires

    with pytest.raises(Exception, match="deadlock"):
        sim.run_process(stuck())


def test_cpu_serializes_across_cores():
    sim = Sim()
    cpu = CPU(sim, cores=2)
    done = []

    def work(i):
        yield cpu.consume(1.0)
        done.append((i, sim.now))

    for i in range(4):
        sim.process(work(i))
    sim.run()
    # 4 × 1s of work on 2 cores = 2s; two finish at 1s, two at 2s
    times = sorted(t for _, t in done)
    assert times == [1.0, 1.0, 2.0, 2.0]


def test_scenario_classification():
    sim = Sim()
    net = Network(sim)
    a = net.host("a", region="us", zone="a")
    b = net.host("b", region="us", zone="a")
    c = net.host("c", region="us", zone="b")
    d = net.host("d", region="eu", zone="a")
    e = net.host("e", region="us", zone="a", machine="m1")
    f = net.host("f", region="us", zone="a", machine="m1")
    assert scenario_for(a, b) == "lan"
    assert scenario_for(a, c) == "wan"
    assert scenario_for(a, d) == "inter"
    assert scenario_for(e, f) == "loopback"
    # inter has strictly higher latency than lan
    assert net.path(a, d)[0] > net.path(a, b)[0]
