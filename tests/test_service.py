"""Typed service layer: error mapping, deadlines, retries, interceptors,
codec-computed wire sizes, per-method metrics."""

import pytest

from repro.core import LatticaNode, Network, RpcStatus, ServiceError, Sim
from repro.core.dht import PEERINFO_WIRE_SIZE, PeerInfo
from repro.core.metrics import dashboard, rpc_method_stats
from repro.core.peer import Multiaddr, PeerId
from repro.core.service import (ByteLength, ClientInterceptor, CONTROL,
                                DeclaredSizeCodec, Fixed, PEER_INFO,
                                PEER_INFO_LIST, Service, ServerInterceptor,
                                TensorDictCodec, pickled, streaming, unary)


def _pair(seed=0):
    sim = Sim(seed=seed)
    net = Network(sim)
    a = LatticaNode(net, "a", region="us", zone="a")
    b = LatticaNode(net, "b", region="us", zone="a")
    sim.run_process(a.connect_info(b.info()))
    return sim, a, b


class EchoService(Service):
    name = "t"

    def __init__(self):
        self.calls = 0
        self.fail_first = 0          # raise UNAVAILABLE for the first N calls
        self.delay = 0.0

    @unary("t.echo", request=Fixed(96), response=pickled(floor=64),
           idempotent=True, timeout=5.0, backoff=0.01)
    def echo(self, payload, ctx):
        self.calls += 1
        if self.calls <= self.fail_first:
            raise ServiceError(RpcStatus.UNAVAILABLE, "induced flake")
        if self.delay:
            yield self.delay
        yield ctx.cpu(1e-6)
        return ("echo", payload)

    @unary("t.write", request=Fixed(96), response=Fixed(64),
           idempotent=False, timeout=5.0, backoff=0.01)
    def write(self, payload, ctx):
        self.calls += 1
        if self.calls <= self.fail_first:
            raise ServiceError(RpcStatus.UNAVAILABLE, "induced flake")
        yield ctx.cpu(1e-6)
        return True

    @unary("t.boom", request=Fixed(96), response=Fixed(64), timeout=5.0)
    def boom(self, payload, ctx):
        yield ctx.cpu(1e-6)
        raise RuntimeError("kaboom")

    @unary("t.slow", request=Fixed(96), response=Fixed(64),
           idempotent=False, timeout=0.5)
    def slow(self, payload, ctx):
        yield 10.0
        return True

    @streaming("t.squares")
    def squares(self, chan, ctx):
        for i in range(4):
            yield from chan.send(i * i, 64)
        chan.end()


# ---------------------------------------------------------------- basics


def test_unary_roundtrip_and_streaming():
    sim, a, b = _pair()
    b.serve(EchoService())
    stub = a.stub(EchoService, b.info())

    def run():
        r = yield from stub.echo({"x": 1})
        chan = yield from stub.squares()
        got = []
        try:
            while True:
                got.append((yield from chan.recv(timeout=5.0)))
        except Exception:
            pass
        return r, got

    r, got = sim.run_process(run())
    assert r == ("echo", {"x": 1})
    assert got == [0, 1, 4, 9]


# ---------------------------------------------------------- error mapping


def test_internal_error_is_typed():
    sim, a, b = _pair()
    b.serve(EchoService())
    stub = a.stub(EchoService, b.info())

    def run():
        yield from stub.boom(None)

    with pytest.raises(ServiceError) as ei:
        sim.run_process(run())
    assert ei.value.status is RpcStatus.INTERNAL
    assert "kaboom" in ei.value.detail


def test_unknown_method_maps_to_not_found():
    sim, a, b = _pair()
    # b does NOT serve EchoService
    stub = a.stub(EchoService, b.info())

    def run():
        yield from stub.write(None)

    with pytest.raises(ServiceError) as ei:
        sim.run_process(run())
    assert ei.value.status is RpcStatus.NOT_FOUND


def test_unreachable_peer_maps_to_unavailable():
    sim = Sim(seed=3)
    net = Network(sim)
    a = LatticaNode(net, "a")
    ghost = PeerInfo(PeerId.from_name("ghost"), "ghost",
                     (Multiaddr("203.0.250.1", 4001),))

    def run():
        stub = a.stub(EchoService, ghost)
        yield from stub.write(None)

    with pytest.raises(ServiceError) as ei:
        sim.run_process(run(), until=sim.now + 600)
    assert ei.value.status is RpcStatus.UNAVAILABLE


def test_deadline_expiry():
    sim, a, b = _pair()
    b.serve(EchoService())
    stub = a.stub(EchoService, b.info())

    def run():
        t0 = sim.now
        try:
            yield from stub.slow(None)
            return None
        except ServiceError as e:
            return e.status, sim.now - t0

    status, elapsed = sim.run_process(run())
    assert status is RpcStatus.DEADLINE_EXCEEDED
    assert 0.5 <= elapsed < 2.0              # spec timeout, not handler time


# ------------------------------------------------------------------ retries


def test_idempotent_retry_succeeds_on_second_attempt():
    sim, a, b = _pair()
    svc = b.serve(EchoService())
    svc.fail_first = 1
    stub = a.stub(EchoService, b.info())

    def run():
        r = yield from stub.echo("hi")
        return r

    assert sim.run_process(run()) == ("echo", "hi")
    assert svc.calls == 2                    # first attempt flaked, retry won


def test_non_idempotent_never_retries():
    sim, a, b = _pair()
    svc = b.serve(EchoService())
    svc.fail_first = 1
    stub = a.stub(EchoService, b.info())

    def run():
        yield from stub.write("hi")

    with pytest.raises(ServiceError) as ei:
        sim.run_process(run())
    assert ei.value.status is RpcStatus.UNAVAILABLE
    assert svc.calls == 1                    # exactly one attempt, no retry


# -------------------------------------------------------------- interceptors


def test_interceptor_ordering():
    sim, a, b = _pair()
    order = []

    class Tracer(ClientInterceptor):
        def __init__(self, tag):
            self.tag = tag

        def intercept(self, call, proceed):
            order.append(f"{self.tag}>")
            resp = yield from proceed(call)
            order.append(f"<{self.tag}")
            return resp

    class ServerTracer(ServerInterceptor):
        def __init__(self, tag):
            self.tag = tag

        def intercept(self, info, payload, ctx, proceed):
            order.append(f"{self.tag}>")
            resp = yield from proceed(payload, ctx)
            order.append(f"<{self.tag}")
            return resp

    b.serve(EchoService(), interceptors=[ServerTracer("s1"),
                                         ServerTracer("s2")])
    stub = a.stub(EchoService, b.info(),
                  interceptors=[Tracer("c1"), Tracer("c2")])

    def run():
        yield from stub.echo(1)

    sim.run_process(run())
    assert order == ["c1>", "c2>", "s1>", "s2>", "<s2", "<s1", "<c2", "<c1"]


# ------------------------------------------------------------------- codecs


def test_codec_sizes_match_historical_constants():
    """Codec-computed sizes must stay within 2x of the hand-tuned wire-size
    constants the call sites used to pass."""
    info = PeerInfo(PeerId.from_name("x"), "x", (Multiaddr("1.2.3.4", 4001),))

    def within_2x(computed, historical):
        return historical / 2 <= computed <= historical * 2

    assert PEER_INFO.size_of(info) == PEERINFO_WIRE_SIZE
    assert PEER_INFO_LIST.size_of([info] * 5) == 5 * PEERINFO_WIRE_SIZE
    assert PEER_INFO_LIST.size_of([]) == PEERINFO_WIRE_SIZE
    assert CONTROL.size_of(None) == 64
    # crdt.exchange used max(len(blob), 64)
    blob = b"z" * 5000
    assert ByteLength().size_of(blob) == 5000
    assert ByteLength().size_of(b"") == 64
    # ps.msg used a caller-declared size as the wire size
    assert DeclaredSizeCodec().size_of(("t", "data", b"m", None, 192)) == 192
    # id.exchange used size=96 for one PeerInfo
    assert within_2x(pickled(floor=64).size_of((1, "small")), 64)
    # infer.* used activation nbytes
    import numpy as np
    x = np.zeros((2, 8), dtype=np.float32)
    assert TensorDictCodec().size_of({"op": "decode", "x": x}) == x.nbytes
    assert Fixed(96).size_of("anything") == 96


# ------------------------------------------------------------------ metrics


def test_per_method_metrics_and_dashboard():
    sim, a, b = _pair()
    svc = b.serve(EchoService())
    stub = a.stub(EchoService, b.info())
    served_before = b.router.stats["unary_served"]   # identify from _pair

    def run():
        for i in range(5):
            yield from stub.echo(i)
        try:
            yield from stub.boom(None)
        except ServiceError:
            pass

    sim.run_process(run())
    client = a.rpc_metrics.client
    assert client["t.echo"].calls == 5 and client["t.echo"].errors == 0
    assert client["t.boom"].calls == 1 and client["t.boom"].errors == 1
    # router counters keep pre-service-layer semantics even though failures
    # now travel in-band: errors = handler failures, unary_served = successes
    assert b.router.stats["errors"] == 1
    assert b.router.stats["unary_served"] == served_before + 5
    assert client["t.echo"].percentile(0.50) > 0
    assert client["t.echo"].percentile(0.95) >= client["t.echo"].percentile(0.50)
    assert b.rpc_metrics.server["t.echo"].calls == 5
    merged = rpc_method_stats([a, b])
    assert merged["t.echo"].calls == 5
    dash = dashboard([a, b])
    assert "t.echo" in dash and "per-method RPC" in dash


def test_conn_pinned_stub_fails_typed_after_close():
    """A stub pinned to an explicit Connection (no PeerInfo) must raise a
    typed UNAVAILABLE — not crash — when the connection dies, including on
    the retry path of idempotent methods."""
    sim, a, b = _pair()
    b.serve(EchoService())
    conn = a.host.connection_to(b.host)
    stub = a.stub(EchoService, conn=conn)

    def run():
        r = yield from stub.echo("up")       # works while conn is live
        conn.close()
        try:
            yield from stub.echo("down")     # idempotent: exercises retries
            return r, None
        except ServiceError as e:
            return r, e.status

    r, status = sim.run_process(run())
    assert r == ("echo", "up")
    assert status is RpcStatus.UNAVAILABLE


def test_scoped_services_are_disambiguated():
    sim, a, b = _pair()

    class ShardLike(Service):
        name = "sh"

        def __init__(self, tag=None):
            self.tag = tag
            self.scope = tag

        @unary("sh.op", request=Fixed(96), response=Fixed(64), timeout=5.0)
        def op(self, payload, ctx):
            yield ctx.cpu(1e-6)
            return self.tag

    b.serve(ShardLike("f.0"))
    b.serve(ShardLike("f.1"))

    def run():
        r0 = yield from a.stub(ShardLike, b.info(), scope="f.0").op(None)
        r1 = yield from a.stub(ShardLike, b.info(), scope="f.1").op(None)
        return r0, r1

    assert sim.run_process(run()) == ("f.0", "f.1")
