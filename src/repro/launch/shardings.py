"""Sharding rules: map every param/state/input leaf to a PartitionSpec.

Two modes:

* ``train`` — 2-D sharding (FSDP × TP): large matrices shard one dim on
  the ``model`` axis (tensor parallelism) and the other on the data axes
  (ZeRO-style), so params + AdamW moments fit per-device for the 32B/132B
  configs.  XLA inserts the corresponding all-gathers/reduce-scatters.
* ``serve`` — tensor parallelism only (weights replicated across data
  groups), except MoE experts which stay expert/data-sharded (a 132B MoE
  doesn't fit one data group otherwise).

Every axis assignment passes through ``_fits`` — a dim that doesn't divide
the axis size is replicated instead (e.g. whisper's 51865 vocab, 28-head
VLM attention), keeping GSPMD away from degenerate paddings.
"""

from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

from .mesh import mesh_axes


def _axis_size(mesh: Mesh, axis: Any) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _fits(mesh: Mesh, axis: Any, dim: int) -> Optional[Any]:
    n = _axis_size(mesh, axis)
    return axis if (n > 1 and dim % n == 0) else None


def _path_str(path: Tuple) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path: str, shape: Sequence[int], mesh: Mesh, cfg: ModelConfig,
               mode: str) -> P:
    """PartitionSpec for one parameter (or optimizer-moment) leaf."""
    data_axes, model = mesh_axes(mesh)
    fsdp: Any = data_axes if len(data_axes) == 1 else data_axes
    if isinstance(fsdp, tuple) and len(fsdp) == 1:
        fsdp = fsdp[0]
    if mode == "serve":
        fsdp = None
    name = path.split("/")[-1]
    nd = len(shape)

    # §Perf iteration (xlstm × prefill_32k): tensor-parallel sharding of the
    # xLSTM cell forces a reshard of q/k/v and the (hd×hd) matrix state on
    # EVERY chunk step (1238 collectives, 105 GiB/dev moved).  Under the
    # sequence-parallel schedule the model axis carries segments instead,
    # so weights replicate.  Decode (no seq-par) keeps TP sharding.
    if mode == "serve" and cfg.arch == "ssm" and cfg.seq_segments > 1:
        return P(*([None] * nd))

    def spec_trailing(*trailing: Any) -> P:
        lead = (None,) * (nd - len(trailing))
        fixed = tuple(_fits(mesh, ax, shape[len(lead) + i])
                      for i, ax in enumerate(trailing))
        return P(*(lead + fixed))

    # ---- embeddings / heads -------------------------------------------------
    if name in ("embed", "embed_out"):
        return spec_trailing(model, fsdp)
    if name in ("lm_head", "enc_proj"):
        return spec_trailing(fsdp, model)

    # ---- MoE ---------------------------------------------------------------
    if "moe" in path and name in ("w_gate", "w_up", "w_down"):
        # experts (E, D, F) / (E, F, D): expert-parallel over the data axes
        # when E divides (dbrx: 16), else FSDP the middle dim (qwen2-moe: 60)
        E = shape[-3]
        ep = _fits(mesh, fsdp if mode == "train" else
                   (fsdp or _first_data_axis(mesh)), E)
        if name == "w_down":
            inner = spec_trailing(None, model, None if ep else fsdp)
        else:
            inner = spec_trailing(None, None if ep else fsdp, model)
        parts = list(inner)
        parts[-3] = ep
        return P(*parts)
    if name == "router":
        return spec_trailing(fsdp, None)

    # ---- attention / MLP / generic matrices ---------------------------------
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "ff_gate", "w_in",
                "w_dt2", "w_z", "w_o"):
        return spec_trailing(fsdp, model)
    if name in ("wo", "w_down", "ff_down", "w_out", "w_bc", "w_dt1", "A_log"):
        return spec_trailing(model, fsdp)
    if "slstm" in path and name in ("w_i", "w_f"):
        return spec_trailing(fsdp, model)
    if "mlstm" in path and name in ("w_i", "w_f"):
        return spec_trailing(model, None)
    if name.startswith("r_"):                    # sLSTM recurrent (H, hd, hd)
        return spec_trailing(None, None, model)
    if name == "conv_w":
        return spec_trailing(None, model)

    # ---- everything else (norms, biases, gates) — replicate ----------------
    return P(*([None] * nd))


def _first_data_axis(mesh: Mesh) -> Any:
    data_axes, _ = mesh_axes(mesh)
    return data_axes if len(data_axes) > 1 else data_axes[0]


def batch_spec(name: str, shape: Sequence[int], mesh: Mesh) -> P:
    data_axes, _model = mesh_axes(mesh)
    batch_ax: Any = data_axes if len(data_axes) > 1 else data_axes[0]
    nd = len(shape)
    if name == "positions3":                     # (3, B, S)
        b = _fits(mesh, batch_ax, shape[1])
        return P(None, b, *([None] * (nd - 2)))
    b = _fits(mesh, batch_ax, shape[0])
    return P(b, *([None] * (nd - 1)))


def cache_spec(path: str, shape: Sequence[int], mesh: Mesh,
               cfg: ModelConfig) -> P:
    """Decode caches: (L, B, ...) — batch on data axes, head-ish dims on
    model where they divide."""
    data_axes, model = mesh_axes(mesh)
    batch_ax: Any = data_axes if len(data_axes) > 1 else data_axes[0]
    name = path.split("/")[-1]
    nd = len(shape)
    if nd == 0 or name == "len":
        return P()
    # leading L dim for stacked caches; ssm list caches have no L dim
    has_L = cfg.arch != "ssm"
    bdim = 1 if has_L else 0
    parts: list = [None] * nd
    if bdim < nd:
        parts[bdim] = _fits(mesh, batch_ax, shape[bdim])
    if name in ("k", "v", "xk", "xv"):           # (L,B,T,Hk,hd)
        parts[-2] = _fits(mesh, model, shape[-2])
        if parts[-2] is None:
            # KV heads don't divide the model axis (e.g. Hk=8 on 16):
            # shard the sequence dim instead — attention over a T-sharded
            # cache lowers to partial-softmax + small stat all-reduces,
            # vastly cheaper than replicating a 32k-token cache
            parts[-3] = _fits(mesh, model, shape[-3])
    elif name in ("C",):                         # (B,H,hd,hd) [+L via list]
        parts[-1] = _fits(mesh, model, shape[-1])
    elif name in ("n", "sc", "sn", "sh", "sm"):  # (B,H,hd)
        parts[-1] = _fits(mesh, model, shape[-1])
    elif name == "h":                            # mamba (L,B,d_in,N)
        parts[-2] = _fits(mesh, model, shape[-2])
    elif name == "conv":                         # (L,B,K-1,d_in)
        parts[-1] = _fits(mesh, model, shape[-1])
    return P(*parts)


# ---------------------------------------------------------------------------
# tree builders
# ---------------------------------------------------------------------------

def tree_shardings(tree: Any, mesh: Mesh, cfg: ModelConfig, kind: str,
                   mode: str = "train") -> Any:
    """Build a NamedSharding pytree for ``tree`` (a ShapeDtypeStruct tree).

    kind: "params" | "batch" | "cache" | "replicated"
    """
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        pstr = _path_str(path)
        shape = leaf.shape
        if kind == "params":
            spec = param_spec(pstr, shape, mesh, cfg, mode)
        elif kind == "batch":
            spec = batch_spec(pstr.split("/")[-1], shape, mesh)
        elif kind == "cache":
            spec = cache_spec(pstr, shape, mesh, cfg)
        else:
            spec = P(*([None] * len(shape)))
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)
