"""Production mesh construction.

Functions only — importing this module never touches jax device state, so
``dryrun.py`` can set ``XLA_FLAGS`` first.

Mesh semantics (DESIGN.md §6): ``model`` carries tensor/expert parallelism
(XLA collectives over ICI); ``data`` carries data parallelism / FSDP; the
``pod`` axis stands for the paper's *clusters* — in a real Lattica
deployment the gradient/model sync across it rides the CRDT + Bitswap
substrate instead of ICI, and the multi-pod dry-run proves the sharded
program is coherent with that axis present.
"""

from __future__ import annotations

from typing import Tuple


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_axes(mesh) -> Tuple[Tuple[str, ...], str]:
    """Returns (batch/data axes, model axis) for a mesh from
    ``make_production_mesh``."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
