import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

Proves the distribution config is coherent without hardware:
``jax.jit(step, in_shardings, out_shardings).lower(**specs).compile()``
on the 16×16 single-pod mesh and the 2×16×16 multi-pod mesh, then records
memory_analysis / cost_analysis / collective traffic for §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
    python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, cfg_for_shape, get_config,
                           input_specs, shape_supported)
from repro.models import ops_for
from repro.models.config import ModelConfig
from repro.launch.mesh import make_production_mesh, mesh_axes
from repro.launch.hlo_stats import op_histogram, parse_collectives
from repro.launch.shardings import tree_shardings
from repro.optim import cosine_schedule
from repro.train.step import make_train_step, train_state_init


def _replicated_like(tree: Any, mesh) -> Any:
    return jax.tree.map(
        lambda l: NamedSharding(mesh, P(*([None] * len(l.shape)))), tree)


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool = False,
                  dtype: Any = jnp.bfloat16,
                  overrides: Optional[Dict[str, Any]] = None,
                  sharding_overrides: Optional[Dict[str, Any]] = None):
    """Lower one (arch × shape × mesh) step.  Returns (lowered, meta)."""
    import dataclasses

    shape = SHAPES[shape_name]
    cfg = cfg_for_shape(get_config(arch), shape)
    auto: Dict[str, Any] = {}
    if shape.kind == "train":
        auto["remat"] = True              # activation checkpoint each block
    if cfg.n_experts:
        # dispatch groups = data-axis size, so expert buffers stay local
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        if tokens % 16 == 0:
            auto["moe_groups"] = 16
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    n_batch_shards = 32 if multi_pod else 16
    if shape.global_batch % n_batch_shards == 0:
        auto["act_batch_axes"] = batch_axes
        auto["act_model_axis"] = "model"
    if cfg.arch == "ssm" and shape.kind == "prefill":
        # §Perf: sequence-parallel mLSTM over the (otherwise idle) model
        # axis — weights replicated, segments concurrent, causality
        # restored by an associative state scan.  (Train keeps the
        # sequential chunk path: seq-par × microbatch × remat × grad
        # blows up XLA:CPU compile time — noted in EXPERIMENTS §4.1.)
        auto["seq_segments"] = 16
        auto["act_seq_axis"] = "model"
    auto.update(overrides or {})
    cfg = dataclasses.replace(cfg, **auto)
    ok, why = shape_supported(cfg, shape)
    if not ok:
        raise ValueError(f"skipped: {why}")
    ops = ops_for(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind = shape.kind
    batch_shapes = input_specs(cfg, shape, dtype)

    with mesh:
        if kind == "train":
            state_shapes = jax.eval_shape(
                lambda: train_state_init(cfg, jax.random.PRNGKey(0), dtype))
            micro = 1
            for cand in (8, 4, 2):
                if shape.global_batch % (n_batch_shards * cand) == 0:
                    micro = cand
                    break
            step = make_train_step(cfg, cosine_schedule(3e-4, 100, 10_000),
                                   microbatches=micro)
            state_sh = tree_shardings(state_shapes, mesh, cfg, "params", "train")
            batch_sh = tree_shardings(batch_shapes, mesh, cfg, "batch")
            out_shapes = jax.eval_shape(step, state_shapes, batch_shapes)
            out_sh = (state_sh, _replicated_like(out_shapes[1], mesh))
            if sharding_overrides:
                state_sh, batch_sh, out_sh = sharding_overrides["train"](
                    mesh, cfg, state_sh, batch_sh, out_sh)
            jfn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                          out_shardings=out_sh)
            lowered = jfn.lower(state_shapes, batch_shapes)
        else:
            params_shapes = jax.eval_shape(
                lambda: ops.init(cfg, jax.random.PRNGKey(0), dtype))
            params_sh = tree_shardings(params_shapes, mesh, cfg, "params", "serve")
            B = shape.global_batch
            data_axes, _ = mesh_axes(mesh)
            batch_ax = data_axes if len(data_axes) > 1 else data_axes[0]
            baxis = batch_ax if B % _axsize(mesh, batch_ax) == 0 else None
            if kind == "prefill":
                cache_shapes = jax.eval_shape(
                    lambda: ops.init_cache(cfg, B, shape.seq_len, dtype))
                cache_sh = tree_shardings(cache_shapes, mesh, cfg, "cache")
                batch_sh = tree_shardings(batch_shapes, mesh, cfg, "batch")

                def prefill_step(params, batch, cache):
                    return ops.prefill(params, cfg, batch, cache)

                out_sh = (NamedSharding(mesh, P(baxis, None)), cache_sh)
                jfn = jax.jit(prefill_step,
                              in_shardings=(params_sh, batch_sh, cache_sh),
                              out_shardings=out_sh)
                lowered = jfn.lower(params_shapes, batch_shapes, cache_shapes)
            else:  # decode
                cache_shapes = jax.eval_shape(
                    lambda: ops.init_cache(cfg, B, shape.seq_len, dtype))
                cache_sh = tree_shardings(cache_shapes, mesh, cfg, "cache")
                token_shape = batch_shapes["token"]
                token_sh = NamedSharding(mesh, P(baxis))

                def serve_step(params, token, cache):
                    return ops.decode_step(params, cfg, token, cache)

                out_sh = (NamedSharding(mesh, P(baxis, None)), cache_sh)
                jfn = jax.jit(serve_step,
                              in_shardings=(params_sh, token_sh, cache_sh),
                              out_shardings=out_sh)
                lowered = jfn.lower(params_shapes, token_shape, cache_shapes)

    meta = {"arch": arch, "shape": shape_name, "kind": kind,
            "multi_pod": multi_pod, "n_devices": mesh.devices.size,
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "window": cfg.window}
    return lowered, meta


def _axsize(mesh, axis) -> int:
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            dtype: Any = jnp.bfloat16, verbose: bool = True,
            overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    t0 = time.time()  # latlint: disable=L001 host-side compile timing, not sim code
    lowered, meta = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                  dtype=dtype, overrides=overrides)
    t_lower = time.time() - t0  # latlint: disable=L001 host-side compile timing, not sim code
    t0 = time.time()  # latlint: disable=L001 host-side compile timing, not sim code
    compiled = lowered.compile()
    t_compile = time.time() - t0  # latlint: disable=L001 host-side compile timing, not sim code

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    txt = compiled.as_text()
    colls = parse_collectives(txt)

    rec = dict(meta)
    rec.update({
        "t_lower_s": round(t_lower, 2),
        "t_compile_s": round(t_compile, 2),
        "bytes_args_per_dev": int(mem.argument_size_in_bytes),
        "bytes_temp_per_dev": int(mem.temp_size_in_bytes),
        "bytes_out_per_dev": int(mem.output_size_in_bytes),
        "hlo_flops_per_dev": float(cost.get("flops", 0.0)),
        "hlo_bytes_per_dev": float(cost.get("bytes accessed", 0.0)),
        "collective_counts": colls.counts,
        "collective_bytes_per_dev": colls.total_bytes,
        "top_ops": op_histogram(txt, 8),
    })
    if verbose:
        peak = (rec["bytes_args_per_dev"] + rec["bytes_temp_per_dev"]
                + rec["bytes_out_per_dev"]) / 2**30
        print(f"[dryrun] {arch} × {shape_name} × "
              f"{'2x16x16' if multi_pod else '16x16'}: OK  "
              f"compile={t_compile:.1f}s  mem/dev={peak:.2f}GiB  "
              f"flops/dev={rec['hlo_flops_per_dev']:.3g}  "
              f"coll={ {k: f'{v/2**20:.1f}MiB' for k, v in colls.bytes_by_op.items()} }",
              flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) for the chosen mesh")
    ap.add_argument("--out", default=None, help="write JSON records here")
    ap.add_argument("--dtype", default="bfloat16")
    args = ap.parse_args()

    dtype = jnp.dtype(args.dtype)
    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                combos.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        combos = [(args.arch, args.shape)]

    records, failures = [], []
    for arch, shape_name in combos:
        shape = SHAPES[shape_name]
        cfg = cfg_for_shape(get_config(arch), shape)
        ok, why = shape_supported(cfg, shape)
        if not ok:
            print(f"[dryrun] {arch} × {shape_name}: SKIP ({why})", flush=True)
            records.append({"arch": arch, "shape": shape_name,
                            "skipped": why})
            continue
        try:
            records.append(run_one(arch, shape_name,
                                   multi_pod=args.multi_pod, dtype=dtype))
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            failures.append((arch, shape_name, repr(e)))
            records.append({"arch": arch, "shape": shape_name,
                            "error": repr(e)})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=2)
    if failures:
        print(f"[dryrun] FAILURES: {failures}", flush=True)
        return 1
    print(f"[dryrun] all {len(combos)} combos OK "
          f"({'multi-pod 2x16x16' if args.multi_pod else 'single-pod 16x16'})",
          flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
