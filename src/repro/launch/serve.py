"""Serving launcher: batched generation with the KV-cache decode path.

    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --reduced \
        --batch 4 --prompt-len 64 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--load", default=None, help="checkpoint to serve")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models import ops_for
    from repro.serving import GenerationEngine

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = cfg.reduced()
    ops = ops_for(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = ops.init(cfg, key)
    if args.load:
        from repro.checkpoint import load_local
        params = load_local(args.load, like=params)

    B, S = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.arch == "vlm":
        batch["vision_embeds"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model))
        batch["positions3"] = jnp.broadcast_to(
            jnp.arange(S + cfg.n_patches, dtype=jnp.int32)[None, None],
            (3, B, S + cfg.n_patches))
    if cfg.arch == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_source))

    eng = GenerationEngine(cfg, params,
                           max_len=S + args.gen + cfg.n_patches + 1)
    t0 = time.time()  # latlint: disable=L001 CLI wall-clock throughput banner
    out, stats = eng.generate(batch, args.gen,
                              temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0  # latlint: disable=L001 CLI wall-clock throughput banner
    print(f"[serve] arch={cfg.name} batch={B} prompt={S} generated={args.gen}")
    print(f"[serve] {stats['generated']} tokens in {dt:.2f}s "
          f"({stats['generated']/dt:.1f} tok/s incl. prefill+compile)")
    print(f"[serve] sample continuation: {out[0][:16].tolist()}")


if __name__ == "__main__":
    main()
