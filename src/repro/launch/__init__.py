# NOTE: deliberately empty — repro.launch.dryrun must be able to set
# XLA_FLAGS before *any* jax import, so this package must not import jax
# (or anything that does) at import time.
