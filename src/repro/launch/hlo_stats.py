"""Parse collective traffic out of post-SPMD HLO text.

``compiled.as_text()`` is the per-device module after partitioning: every
collective instruction's result shape is the per-device shard, and
``replica_groups=[G,g]`` gives the group size.  Per-device bytes moved over
the interconnect, by op type (ring algorithms):

    all-reduce       2 · size · (g-1)/g
    all-gather       size · (g-1)/g          (size = gathered result)
    reduce-scatter   size · (g-1)            (size = scattered result)
    all-to-all       size · (g-1)/g
    collective-permute   size
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\(?[a-z0-9]+\[[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


@dataclass
class CollectiveStats:
    counts: Dict[str, int] = field(default_factory=dict)
    bytes_by_op: Dict[str, float] = field(default_factory=dict)

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())


def _shape_bytes(shapes_str: str) -> float:
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        op = m.group("op")
        if "-done(" in line:
            continue  # async pair: count the -start only
        size = _shape_bytes(m.group("shapes"))
        g = None
        gm = _GROUPS_RE.search(line)
        if gm is not None:
            g = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl is not None:
                g = len(gl.group(1).split(","))
        g = g or 2
        if op == "all-reduce":
            moved = 2.0 * size * (g - 1) / g
        elif op == "all-gather":
            moved = size * (g - 1) / g
        elif op == "reduce-scatter":
            moved = size * (g - 1)
        elif op == "all-to-all":
            moved = size * (g - 1) / g
        else:  # collective-permute
            moved = size
        stats.counts[op] = stats.counts.get(op, 0) + 1
        stats.bytes_by_op[op] = stats.bytes_by_op.get(op, 0.0) + moved
    return stats


def op_histogram(hlo_text: str, top: int = 12) -> List[Tuple[str, int]]:
    """Instruction-name histogram (remat/duplication smell test)."""
    ops: Dict[str, int] = {}
    for m in re.finditer(r"^\s*(?:ROOT )?%?([a-z0-9_.-]+) = ", hlo_text,
                         re.MULTILINE):
        base = m.group(1).split(".")[0]
        ops[base] = ops.get(base, 0) + 1
    return sorted(ops.items(), key=lambda kv: -kv[1])[:top]
