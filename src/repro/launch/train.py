"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-8b \
        --reduced --steps 100 --batch 8 --seq 256

On this CPU container ``--reduced`` trains a smoke-scale variant of the
chosen family.  On a real TPU slice, drop ``--reduced`` and the same entry
point builds the production mesh and pjit-shards the full config with the
dry-run's shardings (the step function and sharding rules are exactly the
ones ``repro.launch.dryrun`` proves out).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--reduced", action="store_true",
                    help="train the reduced (smoke) variant on CPU")
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--schedule", choices=["cosine", "wsd"], default="cosine")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--save", default=None, help="checkpoint path")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import make_batch_iterator
    from repro.optim import cosine_schedule, wsd_schedule
    from repro.train import Trainer, make_train_step, train_state_init

    cfg = get_config(args.arch)
    if args.reduced or jax.default_backend() == "cpu":
        cfg = cfg.reduced(n_layers=args.layers, d_model=args.d_model,
                          vocab=args.vocab)
    n_params = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(
        jax.eval_shape(lambda: train_state_init(
            cfg, jax.random.PRNGKey(0)).params)))
    print(f"[train] arch={cfg.name} family={cfg.arch} params={n_params/1e6:.1f}M "
          f"backend={jax.default_backend()} devices={jax.device_count()}")

    if args.schedule == "wsd":
        sched = wsd_schedule(args.lr, args.steps // 10, 7 * args.steps // 10,
                             2 * args.steps // 10)
    else:
        sched = cosine_schedule(args.lr, args.steps // 10, args.steps)

    data = make_batch_iterator(cfg.vocab, args.seq, args.batch,
                               seed=args.seed)
    state = train_state_init(cfg, jax.random.PRNGKey(args.seed))
    trainer = Trainer(cfg, state, sched, data)
    t0 = time.time()  # latlint: disable=L001 CLI wall-clock throughput banner
    hist = trainer.run(args.steps, log_every=max(args.steps // 20, 1))
    dt = time.time() - t0  # latlint: disable=L001 CLI wall-clock throughput banner
    toks = args.steps * args.batch * args.seq
    print(f"[train] {args.steps} steps in {dt:.1f}s "
          f"({toks/dt:.0f} tok/s) loss {hist[0]['loss']:.3f} -> "
          f"{hist[-1]['loss']:.3f}")
    if args.save:
        from repro.checkpoint import save_local
        n = save_local(args.save, trainer.state.params)
        print(f"[train] saved {n/1e6:.1f} MB checkpoint to {args.save}")


if __name__ == "__main__":
    main()
