"""latlint rules L001–L005 and L007 (AST checks; L006 lives in kernel_lint).

Each rule encodes a convention the repo's determinism or safety story
depends on; see the module docstring of :mod:`repro.analysis` for the
one-line summaries and ROADMAP "Conventions" for the rationale.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from .latlint import LintContext, Rule, SourceFile, Violation

# ---------------------------------------------------------------------------
# shared AST helpers
# ---------------------------------------------------------------------------


def import_maps(tree: ast.AST) -> Tuple[Dict[str, str], Dict[str, Tuple[str, str]]]:
    """``(module_aliases, from_imports)``: ``import time as t`` yields
    ``{"t": "time"}``; ``from time import time as now`` yields
    ``{"now": ("time", "time")}``."""
    mod_alias: Dict[str, str] = {}
    from_imports: Dict[str, Tuple[str, str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                mod_alias[a.asname or a.name.split(".")[0]] = a.name
        elif isinstance(node, ast.ImportFrom):
            for a in node.names:
                from_imports[a.asname or a.name] = (node.module or "", a.name)
    return mod_alias, from_imports


def terminal_name(func: ast.AST) -> Optional[str]:
    if isinstance(func, ast.Name):
        return func.id
    if isinstance(func, ast.Attribute):
        return func.attr
    return None


def _own_body_walk(fn: ast.AST) -> Iterable[ast.AST]:
    """Walk a function body without descending into nested function scopes."""
    stack = list(getattr(fn, "body", []))
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def is_generator_fn(fn: ast.AST) -> bool:
    return any(isinstance(n, (ast.Yield, ast.YieldFrom))
               for n in _own_body_walk(fn))


def enclosing_function(tree: ast.AST, target: ast.AST) -> Optional[ast.AST]:
    """Innermost FunctionDef whose subtree contains ``target``."""
    best: Optional[ast.AST] = None

    def visit(node: ast.AST, current: Optional[ast.AST]) -> bool:
        nonlocal best
        if node is target:
            best = current
            return True
        nxt = node if isinstance(
            node, (ast.FunctionDef, ast.AsyncFunctionDef)) else current
        return any(visit(child, nxt) for child in ast.iter_child_nodes(node))

    visit(tree, None)
    return best


# ---------------------------------------------------------------------------
# L001 — wall-clock / global random
# ---------------------------------------------------------------------------

_TIME_FNS = {"time", "monotonic", "monotonic_ns", "time_ns",
             "perf_counter", "perf_counter_ns", "process_time"}
_RANDOM_FNS = {"random", "randint", "uniform", "choice", "choices", "shuffle",
               "sample", "randrange", "getrandbits", "gauss", "expovariate",
               "betavariate", "normalvariate", "triangular", "seed",
               "randbytes", "vonmisesvariate", "paretovariate"}
_DATETIME_NOW = {"now", "utcnow", "today"}


class WallClockRule(Rule):
    id = "L001"
    title = "no wall-clock or module-global random in sim-executing code"

    def check(self, sf: SourceFile, ctx: LintContext) -> Iterable[Violation]:
        mod_alias, from_imports = import_maps(sf.tree)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Attribute) and isinstance(func.value, ast.Name):
                base = mod_alias.get(func.value.id)
                if base == "time" and func.attr in _TIME_FNS:
                    yield self.violation(
                        sf, node, f"wall-clock time.{func.attr}() — "
                        "sim-executing code must use sim.now")
                elif base == "random" and func.attr in _RANDOM_FNS:
                    yield self.violation(
                        sf, node, f"module-global random.{func.attr}() — "
                        "use the Sim's seeded Random (sim.rng)")
                elif (func.attr in _DATETIME_NOW and not node.args
                      and not node.keywords
                      and self._is_datetime(func.value, mod_alias,
                                            from_imports)):
                    yield self.violation(
                        sf, node, f"argless datetime.{func.attr}() reads the "
                        "wall clock — derive timestamps from sim.now")
            elif (isinstance(func, ast.Attribute)
                  and isinstance(func.value, ast.Attribute)
                  and isinstance(func.value.value, ast.Name)
                  and func.attr in _DATETIME_NOW
                  and not node.args and not node.keywords
                  and mod_alias.get(func.value.value.id) == "datetime"
                  and func.value.attr in ("datetime", "date")):
                yield self.violation(
                    sf, node, f"argless datetime.{func.attr}() reads the "
                    "wall clock — derive timestamps from sim.now")
            elif isinstance(func, ast.Name):
                origin = from_imports.get(func.id)
                if origin is None:
                    continue
                module, name = origin
                if module == "time" and name in _TIME_FNS:
                    yield self.violation(
                        sf, node, f"wall-clock {name}() (from time) — "
                        "sim-executing code must use sim.now")
                elif module == "random" and name in _RANDOM_FNS:
                    yield self.violation(
                        sf, node, f"module-global {name}() (from random) — "
                        "use the Sim's seeded Random (sim.rng)")

    @staticmethod
    def _is_datetime(value: ast.Name, mod_alias: Dict[str, str],
                     from_imports: Dict[str, Tuple[str, str]]) -> bool:
        if from_imports.get(value.id, ("", ""))[0] == "datetime":
            return True
        return mod_alias.get(value.id) == "datetime"


# ---------------------------------------------------------------------------
# L002 — raw RPC plane
# ---------------------------------------------------------------------------

_RAW_RPC = {"register_unary", "call_unary"}
_L002_EXEMPT = ("core/service.py", "core/rpc.py")


class RawRpcRule(Rule):
    id = "L002"
    title = "no raw register_unary/call_unary outside core/service.py"

    def applies(self, rel: str) -> bool:
        return not rel.endswith(_L002_EXEMPT)

    def check(self, sf: SourceFile, ctx: LintContext) -> Iterable[Violation]:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                name = terminal_name(node.func)
                if name in _RAW_RPC:
                    yield self.violation(
                        sf, node, f"raw {name}() bypasses the typed service "
                        "plane — declare a Service with @unary/@streaming "
                        "MethodSpecs instead")


# ---------------------------------------------------------------------------
# L003 — unsafe deserialization
# ---------------------------------------------------------------------------

_PICKLE_LOADERS = {"load", "loads", "Unpickler"}


class PickleRule(Rule):
    id = "L003"
    title = "no pickle.load(s) outside core/safepickle.py"

    def applies(self, rel: str) -> bool:
        return not rel.endswith("core/safepickle.py")

    def check(self, sf: SourceFile, ctx: LintContext) -> Iterable[Violation]:
        mod_alias, from_imports = import_maps(sf.tree)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            name: Optional[str] = None
            if (isinstance(func, ast.Attribute)
                    and isinstance(func.value, ast.Name)
                    and mod_alias.get(func.value.id) == "pickle"
                    and func.attr in _PICKLE_LOADERS):
                name = f"pickle.{func.attr}"
            elif isinstance(func, ast.Name):
                origin = from_imports.get(func.id)
                if (origin is not None and origin[0] == "pickle"
                        and origin[1] in _PICKLE_LOADERS):
                    name = f"pickle.{origin[1]}"
            if name is not None:
                yield self.violation(
                    sf, node, f"{name} deserializes arbitrary objects — "
                    "peer-supplied bytes must go through "
                    "core/safepickle.restricted_loads")


# ---------------------------------------------------------------------------
# L004 — hedging requires idempotency (cross-file)
# ---------------------------------------------------------------------------

_SPEC_DECORATORS = {"unary", "streaming"}
_HEDGE_WRAPPERS = {"hedged_call"}


def index_method_specs(ctx: LintContext) -> None:
    """Record every ``@unary``/``@streaming`` declaration: both the python
    method name and the wire name map to the declared ``idempotent`` flag.
    Conflicting duplicate declarations collapse to False (conservative)."""
    for sf in ctx.files:
        for node in ast.walk(sf.tree):
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            for dec in node.decorator_list:
                if not (isinstance(dec, ast.Call)
                        and terminal_name(dec.func) in _SPEC_DECORATORS):
                    continue
                idem = False
                for kw in dec.keywords:
                    if (kw.arg == "idempotent"
                            and isinstance(kw.value, ast.Constant)):
                        idem = bool(kw.value.value)
                names = [node.name]
                if dec.args and isinstance(dec.args[0], ast.Constant) \
                        and isinstance(dec.args[0].value, str):
                    names.append(dec.args[0].value)
                for n in names:
                    if n in ctx.method_idempotency:
                        ctx.method_idempotency[n] = (
                            ctx.method_idempotency[n] and idem)
                    else:
                        ctx.method_idempotency[n] = idem


class HedgedIdempotentRule(Rule):
    id = "L004"
    title = "hedged_call only over idempotent MethodSpecs"

    def check(self, sf: SourceFile, ctx: LintContext) -> Iterable[Violation]:
        hedge_sites = [n for n in ast.walk(sf.tree)
                       if isinstance(n, ast.Call)
                       and terminal_name(n.func) in _HEDGE_WRAPPERS]
        for site in hedge_sites:
            scope = enclosing_function(sf.tree, site) or sf.tree
            flagged: Set[str] = set()
            for node in ast.walk(scope):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)):
                    continue
                method = node.func.attr
                if method in flagged:
                    continue
                idem = ctx.method_idempotency.get(method)
                if idem is False:
                    flagged.add(method)
                    yield self.violation(
                        sf, site, f"hedged_call in a scope invoking "
                        f"'{method}', whose MethodSpec does not declare "
                        "idempotent=True — hedging can execute it twice")


# ---------------------------------------------------------------------------
# L005 — generator-process hygiene (cross-file)
# ---------------------------------------------------------------------------


def index_generators(ctx: LintContext) -> None:
    """Names that are *unambiguously* generator functions: every definition
    with that name in the scanned set contains a yield.  Ambiguous names
    (e.g. ``send`` — generator on RpcChannel, plain method on Stream) are
    excluded so the rule cannot misfire on plain calls."""
    defs: Dict[str, List[bool]] = {}
    for sf in ctx.files:
        for node in ast.walk(sf.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(node.name, []).append(is_generator_fn(node))
    ctx.generator_only_names = {name for name, flags in defs.items()
                                if all(flags)}


class OrphanGeneratorRule(Rule):
    id = "L005"
    title = "bare call of a yield-protocol function is never driven"

    def check(self, sf: SourceFile, ctx: LintContext) -> Iterable[Violation]:
        for node in ast.walk(sf.tree):
            if not (isinstance(node, ast.Expr)
                    and isinstance(node.value, ast.Call)):
                continue
            name = terminal_name(node.value.func)
            if name in ctx.generator_only_names:
                yield self.violation(
                    sf, node, f"bare call of generator function '{name}' "
                    "creates a generator nothing will drive — use "
                    "`yield from {0}(...)` or `sim.process({0}(...))`"
                    .format(name))


# ---------------------------------------------------------------------------
# L007 — O(keys) flat summary construction outside the Merkle path
# ---------------------------------------------------------------------------

_L007_EXEMPT = ("core/crdt.py",)


class FlatSummaryRule(Rule):
    id = "L007"
    title = "no flat O(keys) key_digests() summary outside core/crdt.py"

    def applies(self, rel: str) -> bool:
        return not rel.endswith(_L007_EXEMPT)

    def check(self, sf: SourceFile, ctx: LintContext) -> Iterable[Violation]:
        for node in ast.walk(sf.tree):
            if isinstance(node, ast.Call):
                name = terminal_name(node.func)
                if name == "key_digests":
                    yield self.violation(
                        sf, node, "key_digests() builds an O(keys) flat "
                        "summary every call — sync probes should walk "
                        "summary_forest()/summary_roots() (O(log n) MST "
                        "localization); waive only where the flat v2/v1 "
                        "wire surface for old peers is the point")
