"""Analysis plane: repo-specific static analysis (latlint) + simulator
sanitizer gates (simsan).

``latlint`` is an AST-based lint framework with rules that encode this
repo's correctness conventions — the ones that keep the discrete-event
simulator deterministic and the protocol planes well-behaved:

* **L001** no wall-clock (``time.time``/``time.monotonic``/argless
  ``datetime.now``) or module-global ``random.*`` in sim-executing code
* **L002** no raw ``register_unary``/``call_unary`` outside the typed
  service plane (``core/service.py``)
* **L003** no ``pickle.load(s)`` outside ``core/safepickle.py``
* **L004** ``hedged_call`` only over methods whose ``MethodSpec`` declares
  ``idempotent=True`` (resolved cross-file against service declarations)
* **L005** generator-process hygiene: a bare call of a yield-protocol
  function silently creates a never-driven generator
* **L006** Pallas kernel sanity: BlockSpec/grid divisibility and a static
  VMEM footprint estimate against the per-core budget
* **L007** no flat O(keys) ``key_digests()`` summary construction outside
  ``core/crdt.py`` — sync probes walk the Merkle summary forest; the flat
  form is a waivered wire-compat surface for pre-MST peers only

Rules support inline waivers (``# latlint: disable=L00x <reason>``) and a
machine-readable JSON report.  The simsan side lives in
:mod:`repro.core.simnet` (``Sim(sanitize=True)``); :mod:`repro.analysis.gates`
drives the determinism double-run and leak-audit gates over the serving,
CRDT-sync, and churned scale-fleet smokes.  CLI:
``python -m repro.analysis --strict``.
"""

from .latlint import Report, Violation, run_lint  # noqa: F401

__all__ = ["Report", "Violation", "run_lint"]
