"""L006 — Pallas kernel sanity: static shape/grid/VMEM checks.

For every ``pl.pallas_call`` in a scanned file this rule verifies, without
executing anything:

* **index_map arity** — each BlockSpec's index_map lambda takes exactly
  ``len(grid) + num_scalar_prefetch`` arguments (PrefetchScalarGridSpec
  prepends its scalar operands to every index_map's signature);
* **index_map rank** — the index tuple it returns has one entry per
  block-shape dimension;
* **grid divisibility** — a grid extent computed as ``a // b`` must be
  guarded by an ``assert a % b == 0`` in the same function, otherwise the
  launch silently drops the remainder rows;
* **VMEM budget** — the static footprint estimate (every BlockSpec block
  + every ``pltpu.VMEM`` scratch buffer) must fit the per-core budget.

Symbolic dimensions resolve through a small constant propagator (parameter
defaults, module constants, ``min``/``//``/tuple assignments); anything
still unresolved falls back to :data:`DIM_BOUNDS` (conservative per-name
upper bounds for this repo's conventional dimension names) or
:data:`DEFAULT_DIM_BOUND`.  The estimate is deliberately an upper bound:
a kernel that passes here can still be tuned, but one that fails cannot
fit in VMEM under this repo's shape conventions.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from .latlint import LintContext, Rule, SourceFile, Violation
from .rules import terminal_name

#: TPU VMEM per core (pallas guide: ~16 MiB usable per TensorCore).
VMEM_BUDGET = 16 * 1024 * 1024

#: Conservative upper bounds for this repo's conventional dim names, used
#: when constant propagation cannot resolve a dimension (e.g. it comes from
#: a runtime ``x.shape`` unpack).  Keyed by variable name.
DIM_BOUNDS: Dict[str, int] = {
    "hd": 256, "head_dim": 256,      # head dim (largest config: 256)
    "H": 64, "Hk": 32,               # query / kv heads per shard
    "page": 128,                     # KV page size (serving uses 32)
    "E": 512,                        # MoE experts
    "k": 16, "K": 16,                # top-k
    "W": 512, "bt": 512,             # chunk/token-block tiles
    "bq": 512, "bk": 1024,           # attention tiles
    "rep": 8,                        # H // Hk replication factor
}

#: Fallback bound for dimensions with no entry above.
DEFAULT_DIM_BOUND = 128

_DTYPE_BYTES = {"float32": 4, "int32": 4, "uint32": 4, "bfloat16": 2,
                "float16": 2, "int16": 2, "int8": 1, "uint8": 1,
                "bool_": 1, "float64": 8, "int64": 8}


# ---------------------------------------------------------------------------
# constant propagation
# ---------------------------------------------------------------------------


class Env:
    """Name -> AST expression bindings: module constants, enclosing-function
    parameter defaults, and (tuple-)assignments, innermost binding winning."""

    def __init__(self) -> None:
        self._bind: Dict[str, ast.AST] = {}
        self._defaults: Dict[str, ast.AST] = {}

    def bind(self, name: str, expr: ast.AST) -> None:
        self._bind[name] = expr

    def bind_default(self, name: str, expr: ast.AST) -> None:
        self._defaults[name] = expr

    def load_scope(self, scope: ast.AST) -> None:
        for node in ast.walk(scope):
            if isinstance(node, ast.Assign) and len(node.targets) == 1:
                tgt = node.targets[0]
                if isinstance(tgt, ast.Name):
                    self.bind(tgt.id, node.value)
                elif (isinstance(tgt, ast.Tuple)
                      and isinstance(node.value, ast.Tuple)
                      and len(tgt.elts) == len(node.value.elts)):
                    for t, v in zip(tgt.elts, node.value.elts):
                        if isinstance(t, ast.Name):
                            self.bind(t.id, v)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                args = node.args
                pos = args.args + args.kwonlyargs
                defaults = ([None] * (len(args.args) - len(args.defaults))
                            + list(args.defaults) + list(args.kw_defaults))
                for a, d in zip(pos, defaults):
                    if d is not None:
                        self.bind_default(a.arg, d)

    def resolve_expr(self, name: str) -> Optional[ast.AST]:
        return self._bind.get(name)

    def resolve_int(self, expr: Optional[ast.AST],
                    active: Optional[Set[str]] = None) -> Optional[int]:
        """Best-effort integer value of an expression; None if unresolvable.
        ``active`` breaks self-referential chains like ``bq = min(bq, Sq)``
        by falling back to the parameter default for the inner reference."""
        if expr is None:
            return None
        active = set() if active is None else active
        if isinstance(expr, ast.Constant):
            return expr.value if isinstance(expr.value, int) else None
        if isinstance(expr, ast.Name):
            name = expr.id
            if name not in active and name in self._bind:
                return self.resolve_int(self._bind[name], active | {name})
            if name in self._defaults:
                return self.resolve_int(self._defaults[name], active | {name})
            return None
        if isinstance(expr, ast.BinOp):
            lhs = self.resolve_int(expr.left, active)
            rhs = self.resolve_int(expr.right, active)
            if lhs is None or rhs is None:
                return None
            if isinstance(expr.op, ast.Add):
                return lhs + rhs
            if isinstance(expr.op, ast.Sub):
                return lhs - rhs
            if isinstance(expr.op, ast.Mult):
                return lhs * rhs
            if isinstance(expr.op, ast.FloorDiv) and rhs != 0:
                return lhs // rhs
            if isinstance(expr.op, ast.Mod) and rhs != 0:
                return lhs % rhs
            return None
        if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name):
            vals = [self.resolve_int(a, active) for a in expr.args]
            known = [v for v in vals if v is not None]
            if expr.func.id == "min" and known:
                # min() over partially-known args: the known minimum is a
                # sound upper bound for footprint purposes
                return min(known)
            if expr.func.id == "max" and len(known) == len(vals) and known:
                return max(known)
        return None

    def dim_bound(self, expr: Optional[ast.AST]) -> int:
        """Integer upper bound for a block dimension: exact value when
        resolvable, else the per-name table, else the default bound."""
        val = self.resolve_int(expr)
        if val is not None:
            return val
        if isinstance(expr, ast.Name):
            return DIM_BOUNDS.get(expr.id, DEFAULT_DIM_BOUND)
        return DEFAULT_DIM_BOUND


# ---------------------------------------------------------------------------
# pallas_call model
# ---------------------------------------------------------------------------


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _as_list(expr: Optional[ast.AST], env: Env) -> List[ast.AST]:
    """Flatten an in_specs/out_specs expression into element expressions,
    resolving a Name to its assignment and following ``+=`` style
    concatenation of list literals one level deep."""
    if expr is None:
        return []
    if isinstance(expr, ast.Name):
        expr = env.resolve_expr(expr.id)
        if expr is None:
            return []
    if isinstance(expr, (ast.List, ast.Tuple)):
        return list(expr.elts)
    if isinstance(expr, ast.BinOp) and isinstance(expr.op, ast.Add):
        return _as_list(expr.left, env) + _as_list(expr.right, env)
    return [expr]


class _SpecInfo:
    def __init__(self, call: ast.Call, env: Env):
        self.node = call
        shape = _kw(call, "block_shape")
        if shape is None and call.args:
            shape = call.args[0]
        self.shape_elts: Optional[List[ast.AST]] = (
            list(shape.elts) if isinstance(shape, (ast.Tuple, ast.List))
            else None)
        imap = _kw(call, "index_map")
        if imap is None and len(call.args) > 1:
            imap = call.args[1]
        if isinstance(imap, ast.Name):
            imap = env.resolve_expr(imap.id)
        self.index_map: Optional[ast.Lambda] = (
            imap if isinstance(imap, ast.Lambda) else None)

    def nbytes(self, env: Env, itemsize: int = 4) -> int:
        if self.shape_elts is None:
            return 0
        total = itemsize
        for d in self.shape_elts:
            total *= max(1, env.dim_bound(d))
        return total


def _block_specs(expr: Optional[ast.AST], env: Env) -> List[_SpecInfo]:
    out = []
    for elt in _as_list(expr, env):
        if isinstance(elt, ast.Name):
            elt = env.resolve_expr(elt.id)
        if isinstance(elt, ast.Call) and terminal_name(elt.func) == "BlockSpec":
            out.append(_SpecInfo(elt, env))
    return out


def _vmem_scratch_bytes(expr: Optional[ast.AST], env: Env) -> int:
    total = 0
    for elt in _as_list(expr, env):
        if not (isinstance(elt, ast.Call)
                and terminal_name(elt.func) == "VMEM"):
            continue
        itemsize = 4
        if len(elt.args) > 1:
            dtype = terminal_name(elt.args[1]) or ""
            itemsize = _DTYPE_BYTES.get(dtype, 4)
        shape = elt.args[0] if elt.args else None
        if isinstance(shape, (ast.Tuple, ast.List)):
            n = itemsize
            for d in shape.elts:
                n *= max(1, env.dim_bound(d))
            total += n
    return total


def _assert_guards(scope: ast.AST) -> Set[Tuple[str, str]]:
    """(numerator, denominator) name pairs proven divisible by an
    ``assert a % b == 0`` (BoolOp conjunctions are flattened)."""
    guards: Set[Tuple[str, str]] = set()

    def harvest(test: ast.AST) -> None:
        if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
            for v in test.values:
                harvest(v)
            return
        if not (isinstance(test, ast.Compare) and len(test.ops) == 1
                and isinstance(test.ops[0], ast.Eq)):
            return
        lhs, rhs = test.left, test.comparators[0]
        for a, b in ((lhs, rhs), (rhs, lhs)):
            if (isinstance(a, ast.BinOp) and isinstance(a.op, ast.Mod)
                    and isinstance(a.left, ast.Name)
                    and isinstance(a.right, ast.Name)
                    and isinstance(b, ast.Constant) and b.value == 0):
                guards.add((a.left.id, a.right.id))

    for node in ast.walk(scope):
        if isinstance(node, ast.Assert):
            harvest(node.test)
    return guards


def _floordiv_pairs(expr: ast.AST, env: Env) -> List[Tuple[str, str]]:
    """Name-pair floor divisions in a grid extent, following one level of
    assignment (``nq = Sq // bq`` referenced as ``nq`` in the grid)."""
    if isinstance(expr, ast.Name):
        resolved = env.resolve_expr(expr.id)
        if resolved is not None:
            expr = resolved
    pairs = []
    for node in ast.walk(expr):
        if (isinstance(node, ast.BinOp) and isinstance(node.op, ast.FloorDiv)
                and isinstance(node.left, ast.Name)
                and isinstance(node.right, ast.Name)):
            pairs.append((node.left.id, node.right.id))
    return pairs


class KernelSanityRule(Rule):
    id = "L006"
    title = "Pallas BlockSpec/grid divisibility + static VMEM budget"

    def check(self, sf: SourceFile, ctx: LintContext) -> Iterable[Violation]:
        calls = [n for n in ast.walk(sf.tree)
                 if isinstance(n, ast.Call)
                 and terminal_name(n.func) == "pallas_call"]
        if not calls:
            return
        for call in calls:
            scope = self._enclosing(sf.tree, call)
            env = Env()
            env.load_scope(sf.tree)   # module constants + all param defaults
            if scope is not None:
                env.load_scope(scope)  # innermost bindings win
            yield from self._check_call(sf, call, scope or sf.tree, env)

    @staticmethod
    def _enclosing(tree: ast.AST, target: ast.AST) -> Optional[ast.AST]:
        from .rules import enclosing_function
        return enclosing_function(tree, target)

    def _check_call(self, sf: SourceFile, call: ast.Call, scope: ast.AST,
                    env: Env) -> Iterable[Violation]:
        grid_expr = _kw(call, "grid")
        in_specs = _kw(call, "in_specs")
        out_specs = _kw(call, "out_specs")
        scratch = _kw(call, "scratch_shapes")
        n_prefetch = 0
        grid_spec = _kw(call, "grid_spec")
        if isinstance(grid_spec, ast.Name):
            grid_spec = env.resolve_expr(grid_spec.id)
        if isinstance(grid_spec, ast.Call):
            grid_expr = _kw(grid_spec, "grid") or grid_expr
            in_specs = _kw(grid_spec, "in_specs") or in_specs
            out_specs = _kw(grid_spec, "out_specs") or out_specs
            scratch = _kw(grid_spec, "scratch_shapes") or scratch
            npf = env.resolve_int(_kw(grid_spec, "num_scalar_prefetch"))
            n_prefetch = npf or 0

        grid_elts = self._grid_elts(grid_expr, env)
        specs = (_block_specs(in_specs, env)
                 + _block_specs(out_specs, env))

        # 1. index_map arity / rank
        if grid_elts is not None:
            want = len(grid_elts) + n_prefetch
            for spec in specs:
                lam = spec.index_map
                if lam is None:
                    continue
                got = len(lam.args.args)
                if got != want:
                    yield self.violation(
                        sf, lam, f"index_map takes {got} args but the launch "
                        f"has {len(grid_elts)} grid dims + {n_prefetch} "
                        f"scalar-prefetch operands (= {want})")
                rank = (len(lam.body.elts)
                        if isinstance(lam.body, ast.Tuple) else 1)
                if spec.shape_elts is not None and rank != len(spec.shape_elts):
                    yield self.violation(
                        sf, lam, f"index_map returns {rank} indices for a "
                        f"rank-{len(spec.shape_elts)} block_shape")

        # 2. grid divisibility
        if grid_elts is not None:
            guards = _assert_guards(scope)
            for elt in grid_elts:
                for num, den in _floordiv_pairs(elt, env):
                    if (num, den) not in guards:
                        yield self.violation(
                            sf, call, f"grid extent {num} // {den} has no "
                            f"`assert {num} % {den} == 0` guard — a "
                            "non-divisible shape silently drops the "
                            "remainder block")

        # 3. static VMEM footprint
        total = sum(s.nbytes(env) for s in specs)
        total += _vmem_scratch_bytes(scratch, env)
        if total > VMEM_BUDGET:
            yield self.violation(
                sf, call, f"static VMEM footprint estimate {total} B "
                f"({total / 2**20:.1f} MiB) exceeds the {VMEM_BUDGET // 2**20}"
                " MiB per-core budget — shrink block shapes or scratch")

    @staticmethod
    def _grid_elts(expr: Optional[ast.AST], env: Env) -> Optional[List[ast.AST]]:
        if expr is None:
            return None
        if isinstance(expr, ast.Name):
            expr = env.resolve_expr(expr.id)
        if isinstance(expr, (ast.Tuple, ast.List)):
            return list(expr.elts)
        return None
