"""CLI driver: ``python -m repro.analysis [--strict] [--json PATH]
[--determinism] [paths...]``.

Without ``paths`` the whole ``repro`` package is linted.  ``--strict``
exits non-zero on any active (unwaived) violation.  ``--determinism``
additionally runs the simsan gates (double-run digest equality,
perturbation robustness, leak audit) and fails on any gate breach.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import List

from .latlint import run_lint


def main(argv: List[str] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="latlint static analysis + simsan determinism gates")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: the repro package)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on active violations")
    ap.add_argument("--json", metavar="PATH",
                    help="write the JSON report here ('-' for stdout)")
    ap.add_argument("--determinism", action="store_true",
                    help="also run the simsan determinism/leak gates")
    ap.add_argument("--gate", action="append", metavar="NAME", default=None,
                    help="restrict --determinism to this gate (repeatable)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base seed for the determinism gates")
    ap.add_argument("--perturbations", type=int, default=1,
                    help="number of seeded tie-break perturbation runs")
    args = ap.parse_args(argv)

    paths = args.paths or [str(Path(__file__).resolve().parent.parent)]
    report = run_lint(paths)
    print(report.format_text())
    if args.json:
        if args.json == "-":
            print(report.to_json())
        else:
            Path(args.json).write_text(report.to_json())
            print(f"latlint: JSON report -> {args.json}")

    rc = 0
    if args.strict and report.active:
        rc = 1

    if args.determinism:
        from .gates import run_all_gates
        results = run_all_gates(seed=args.seed,
                                perturbations=args.perturbations,
                                names=args.gate)
        for res in results:
            print(res.format())
        if any(not res.ok for res in results):
            rc = max(rc, 2)

    return rc


if __name__ == "__main__":
    sys.exit(main())
