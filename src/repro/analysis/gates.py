"""simsan gates: end-to-end determinism / race / leak scenarios for CI.

Each gate builds a realistic workload on a ``Sim(sanitize=True)`` and
returns a :class:`GateRun` — the event-trace digest plus a *functional
fingerprint* (model output, converged store digest) and the sanitizer
findings.  :func:`run_gates` then enforces the contract:

* **determinism** — two runs of the same scenario under the same seed
  produce bit-identical event-trace digests;
* **schedule robustness** — perturbation runs (seeded tie-break shuffle
  of same-timestamp events) reproduce the same functional fingerprint
  even though the event order differs;
* **hygiene** — every run finishes with zero double-settles, zero
  orphaned (non-daemon) processes, and a leak audit at baseline.

Scenarios deliberately reuse the public builders (``make_fleet``,
``deploy_sharded``, the CRDT push plane) so the gate exercises the same
code paths the tests and examples do.  Each scenario runs a *warm-up*
request before snapshotting the leak baseline: connection pools and push
subscriptions are long-lived by design, so the audit only charges the
measured workload for resources it failed to return.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, List, Optional

from ..core.fleet import make_fleet, wait_converged
from ..core.simnet import Sim

GateFn = Callable[[int, Optional[int]], "GateRun"]


@dataclass
class GateRun:
    """One execution of a gate scenario on a sanitizing Sim."""
    digest: str                      #: event-trace digest (order-sensitive)
    fingerprint: Any                 #: functional result (order-insensitive)
    double_settles: List[Dict[str, Any]]
    orphans: List[str]
    leaks: Dict[str, float]
    events: int

    @property
    def clean(self) -> bool:
        return not (self.double_settles or self.orphans or self.leaks)


@dataclass
class GateResult:
    name: str
    ok: bool
    failures: List[str] = field(default_factory=list)
    runs: List[GateRun] = field(default_factory=list)

    def format(self) -> str:
        head = f"gate {self.name}: {'ok' if self.ok else 'FAIL'}"
        if self.runs:
            head += (f" ({len(self.runs)} runs, "
                     f"{self.runs[0].events} events/run)")
        return "\n".join([head] + [f"  - {f}" for f in self.failures])


def _finish(sim: Sim, fingerprint: Any) -> GateRun:
    rep = sim.san_report()
    return GateRun(digest=rep["trace_digest"], fingerprint=fingerprint,
                   double_settles=rep["double_settles"],
                   orphans=rep["orphans"], leaks=rep["leaks"],
                   events=rep["events"])


# ---------------------------------------------------------------------------
# serving gate: sharded inference fleet, score + generate round-trips
# ---------------------------------------------------------------------------


def serving_gate(seed: int = 0, perturb: Optional[int] = None) -> GateRun:
    """Deploy a 2-shard pipeline on a public fleet and drive one generate
    round-trip.  Fingerprint: the generated token ids."""
    import numpy as np

    from ..configs import get_config
    from ..models import ops_for
    from ..serving.sharded import ShardClient, deploy_sharded

    cfg = get_config("granite-8b").reduced(n_layers=2, d_model=32, vocab=128)
    ops = ops_for(cfg)
    import jax
    params = ops.init(cfg, jax.random.PRNGKey(seed))

    sim = Sim(seed=seed, sanitize=True, perturb=perturb)
    # public-only peers: no relay reservations, so the leak audit sees the
    # serving plane alone
    fleet = make_fleet(4, sim=sim, same_region="us", nat_kinds=[None] * 4)
    servers = deploy_sharded(fleet.peers[:2], cfg, params, "gate-svc")

    def announce() -> Generator:
        for s in servers:
            yield from s.announce()

    sim.run_process(announce(), until=sim.now + 600)
    client = ShardClient(fleet.peers[-1], cfg, "gate-svc", n_shards=2)
    toks = np.arange(8, dtype=np.int32)[None, :] % cfg.vocab

    def ask() -> Generator:
        out = yield from client.generate(toks, 3)
        return out

    # warm-up dials every shard and populates the connection pool; only
    # then is the baseline meaningful for the audited request
    sim.run_process(ask(), until=sim.now + 900)
    sim.run(until=sim.now + 30)      # quiesce in-flight teardown first
    sim.leak_baseline()
    out = sim.run_process(ask(), until=sim.now + 900)
    sim.run(until=sim.now + 30)      # let in-flight teardown settle
    return _finish(sim, np.asarray(out).tolist())


# ---------------------------------------------------------------------------
# CRDT gate: replicated-store convergence over the push plane
# ---------------------------------------------------------------------------


def crdt_gate(seed: int = 0, perturb: Optional[int] = None) -> GateRun:
    """Fan a write out across a replicated fleet and wait for convergence.
    Fingerprint: (converged?, final store digest)."""
    sim = Sim(seed=seed, sanitize=True, perturb=perturb)
    fleet = make_fleet(5, sim=sim, same_region="us", nat_kinds=[None] * 5)
    writer = fleet.peers[0]
    # convergence rides the push plane (no periodic anti-entropy in the
    # fleet), so every replica must join the written namespaces' topics
    for n in fleet.peers:
        n.join_crdt_push("reg")
        n.join_crdt_push("gate")
    sim.run(until=sim.now + 5)       # pubsub subscription propagation

    def write_and_wait(tag: int) -> bool:
        for i in range(4):
            writer.store.orset(f"reg/gate{tag}").add(
                (tag, bytes([tag, i]) * 16), writer.host.name)
        writer.store.counter("gate/steps").increment(writer.host.name, tag)
        return wait_converged(sim, fleet.peers, timeout=300.0)

    write_and_wait(1)                # warm-up: push subscriptions + dials
    sim.run(until=sim.now + 30)      # quiesce in-flight teardown first
    sim.leak_baseline()
    ok = write_and_wait(2)
    sim.run(until=sim.now + 30)
    digest = writer.store.digest().hex()
    return _finish(sim, (ok, digest))


# ---------------------------------------------------------------------------
# fleet gate: scale-fleet churn — scored mesh + MST anti-entropy hygiene
# ---------------------------------------------------------------------------


def fleet_gate(seed: int = 0, perturb: Optional[int] = None) -> GateRun:
    """NAT-mixed scale fleet under a churn wave: a registry write rides the
    push plane while restarts tear mesh links down, then star-pattern MST
    anti-entropy repairs the restarted replicas.  Exercises graft/prune,
    subscription re-announce and the bounded mesh caches — the audit
    charges any mesh state (mcache, seen-set, pending IWANTs) a restart or
    repair fails to return.  Fingerprint: (converged?, store digest)."""
    from ..core.fleet import make_scale_fleet

    sim = Sim(seed=seed, sanitize=True, perturb=perturb)
    fleet = make_scale_fleet(48, sim=sim)
    writer = fleet.publics[0]
    hub = fleet.publics[1]
    for n in fleet.nodes:
        n.join_crdt_push("reg")
    sim.run(until=sim.now + 10)      # subscription propagation + mesh graft

    def sync_round() -> None:
        # every node anti-entropies with the hub concurrently; delta2 sync
        # is bidirectional, so one gather round + one distribute round
        # spreads the union even to replicas that missed every push
        for _ in range(2):
            procs = [sim.process(n.sync_crdt_with(hub.info()))
                     for n in fleet.nodes if n is not hub]
            deadline = sim.now + 60.0
            while sim.now < deadline and not all(p.triggered for p in procs):
                sim.run(until=min(deadline, sim.now + 0.5))

    def write_churn_converge(tag: int) -> bool:
        for i in range(4):
            writer.store.orset(f"reg/gate{tag}").add(
                (tag, bytes([tag, i]) * 16), writer.host.name)
        writer.store.counter("reg/steps").increment(writer.host.name, tag)
        sim.run(until=sim.now + 2)   # let the push wave land first
        fleet.churn_wave(0.05)       # restart NAT'd members mid-flight
        sim.run(until=sim.now + 5)   # restarted nodes re-announce + regraft
        sync_round()
        return wait_converged(sim, fleet.nodes, timeout=300.0)

    # warm-up: dials, push meshes, relay paths, first churn's re-wiring
    write_churn_converge(1)
    sim.run(until=sim.now + 30)      # heartbeats expire transient IWANTs
    sim.leak_baseline()
    ok = write_churn_converge(2)
    sim.run(until=sim.now + 30)
    digest = writer.store.digest().hex()
    return _finish(sim, (ok, digest))


GATES: Dict[str, GateFn] = {
    "serving": serving_gate,
    "crdt-sync": crdt_gate,
    "fleet": fleet_gate,
}


def run_gate(name: str, gate: GateFn, seed: int = 0,
             perturbations: int = 1) -> GateResult:
    """Double-run + perturbation-run one gate and check the contract."""
    failures: List[str] = []
    runs = [gate(seed, None), gate(seed, None)]
    if runs[0].digest != runs[1].digest:
        failures.append(
            f"non-deterministic: digests {runs[0].digest[:12]} != "
            f"{runs[1].digest[:12]} across identical runs")
    for p in range(perturbations):
        runs.append(gate(seed, p + 1))
        if runs[-1].fingerprint != runs[0].fingerprint:
            failures.append(
                f"perturbation {p + 1} changed the functional result — "
                "an outcome depends on same-timestamp event ordering")
    for i, r in enumerate(runs):
        label = f"run {i}" + (" (perturbed)" if i >= 2 else "")
        if r.double_settles:
            failures.append(f"{label}: {len(r.double_settles)} conflicting "
                            f"double-settle(s): {r.double_settles[0]}")
        if r.orphans:
            failures.append(f"{label}: orphaned processes: {r.orphans}")
        if r.leaks:
            failures.append(f"{label}: leak audit above baseline: {r.leaks}")
    return GateResult(name=name, ok=not failures, failures=failures,
                      runs=runs)


def run_all_gates(seed: int = 0, perturbations: int = 1,
                  names: Optional[List[str]] = None) -> List[GateResult]:
    selected = names if names is not None else list(GATES)
    out = []
    for name in selected:
        if name not in GATES:
            raise KeyError(f"unknown gate '{name}' (have: {list(GATES)})")
        out.append(run_gate(name, GATES[name], seed=seed,
                            perturbations=perturbations))
    return out
