"""latlint framework: source loading, waiver parsing, rule driving, reports.

A :class:`Rule` sees one parsed :class:`SourceFile` at a time plus a
:class:`LintContext` holding cross-file indexes (service method
declarations, generator-function names) built in a first pass — that is
what lets L004 resolve ``hedged_call`` sites against ``MethodSpec``
declarations living in other modules.

Waivers::

    x = time.time()          # latlint: disable=L001 CLI wall-clock banner
    # latlint: disable=L001 applies to the next line too
    # latlint: disable-file=L005 whole-file waiver

A waiver with no reason does not waive — the violation stays active with a
note, so ``--strict`` still fails.  Reports serialize to JSON
(``Report.to_json``) for machine consumption.
"""

from __future__ import annotations

import ast
import json
import re
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

WAIVER_RE = re.compile(
    r"#\s*latlint:\s*disable(?P<scope>-file)?="
    r"(?P<rules>[A-Z]\d{3}(?:\s*,\s*[A-Z]\d{3})*)\s*(?P<reason>.*)$")


@dataclass
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str
    waived: bool = False
    waive_reason: str = ""

    def to_dict(self) -> Dict[str, object]:
        return asdict(self)

    def format(self) -> str:
        tag = f" [waived: {self.waive_reason}]" if self.waived else ""
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}{tag}"


@dataclass
class Waiver:
    rules: Tuple[str, ...]
    reason: str
    line: int
    file_level: bool


class SourceFile:
    """One parsed file: AST + the waiver comments found in its text."""

    def __init__(self, path: Path, rel: str):
        self.path = path
        self.rel = rel
        self.text = path.read_text()
        self.tree = ast.parse(self.text, filename=str(path))
        self.line_waivers: Dict[int, Waiver] = {}
        self.file_waivers: Dict[str, Waiver] = {}
        for lineno, raw in enumerate(self.text.splitlines(), start=1):
            m = WAIVER_RE.search(raw)
            if m is None:
                continue
            rules = tuple(r.strip() for r in m.group("rules").split(","))
            w = Waiver(rules, m.group("reason").strip(), lineno,
                       m.group("scope") is not None)
            if w.file_level:
                for r in rules:
                    self.file_waivers[r] = w
            else:
                self.line_waivers[lineno] = w

    def waiver_for(self, rule: str, line: int) -> Optional[Waiver]:
        """A waiver covers a violation if it is file-level, trails the
        violating line, or sits alone on the line directly above it."""
        if rule in self.file_waivers:
            return self.file_waivers[rule]
        for ln in (line, line - 1):
            w = self.line_waivers.get(ln)
            if w is not None and rule in w.rules:
                return w
        return None


class LintContext:
    """Cross-file indexes shared by all rules (filled by ``build_context``)."""

    def __init__(self, files: Sequence[SourceFile]):
        self.files = list(files)
        #: service method name (python attr AND wire name) -> idempotent flag
        self.method_idempotency: Dict[str, bool] = {}
        #: names whose every definition in the scanned set is a generator fn
        self.generator_only_names: set = set()


class Rule:
    id = "L000"
    title = ""

    def applies(self, rel: str) -> bool:
        return True

    def check(self, sf: SourceFile, ctx: LintContext) -> Iterable[Violation]:
        raise NotImplementedError

    def violation(self, sf: SourceFile, node: ast.AST, message: str) -> Violation:
        return Violation(self.id, sf.rel, getattr(node, "lineno", 0),
                         getattr(node, "col_offset", 0), message)


@dataclass
class Report:
    violations: List[Violation]
    files_scanned: int

    @property
    def active(self) -> List[Violation]:
        return [v for v in self.violations if not v.waived]

    @property
    def waived(self) -> List[Violation]:
        return [v for v in self.violations if v.waived]

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for v in self.active:
            out[v.rule] = out.get(v.rule, 0) + 1
        return out

    def to_json(self) -> str:
        return json.dumps({
            "files_scanned": self.files_scanned,
            "active": [v.to_dict() for v in self.active],
            "waived": [v.to_dict() for v in self.waived],
            "counts": self.counts(),
        }, indent=2, sort_keys=True)

    def format_text(self) -> str:
        lines = [v.format() for v in self.active]
        lines += [v.format() for v in self.waived]
        status = "clean" if not self.active else f"{len(self.active)} active"
        lines.append(f"latlint: {self.files_scanned} files, {status}, "
                     f"{len(self.waived)} waived")
        return "\n".join(lines)


def default_rules() -> List[Rule]:
    from . import kernel_lint, rules
    return [rules.WallClockRule(), rules.RawRpcRule(), rules.PickleRule(),
            rules.HedgedIdempotentRule(), rules.OrphanGeneratorRule(),
            kernel_lint.KernelSanityRule(), rules.FlatSummaryRule()]


def _collect_files(paths: Sequence[Path]) -> List[SourceFile]:
    seen: Dict[Path, None] = {}
    for p in paths:
        p = Path(p)
        if p.is_dir():
            for f in sorted(p.rglob("*.py")):
                seen.setdefault(f.resolve())
        else:
            seen.setdefault(p.resolve())
    files = []
    for f in seen:
        files.append(SourceFile(f, _logical_rel(f)))
    return files


def _logical_rel(path: Path) -> str:
    """Stable logical path for rule scoping: from the ``repro`` package root
    when the file lives inside it, else the bare file name."""
    parts = path.parts
    if "repro" in parts:
        i = len(parts) - 1 - parts[::-1].index("repro")
        return "/".join(parts[i:])
    return path.name


def build_context(files: Sequence[SourceFile]) -> LintContext:
    from .rules import index_generators, index_method_specs
    ctx = LintContext(files)
    index_method_specs(ctx)
    index_generators(ctx)
    return ctx


def run_lint(paths: Sequence[Path],
             rules: Optional[Sequence[Rule]] = None) -> Report:
    files = _collect_files([Path(p) for p in paths])
    rules = list(rules) if rules is not None else default_rules()
    ctx = build_context(files)
    violations: List[Violation] = []
    for sf in files:
        for rule in rules:
            if not rule.applies(sf.rel):
                continue
            for v in rule.check(sf, ctx):
                w = sf.waiver_for(v.rule, v.line)
                if w is not None:
                    if w.reason:
                        v.waived = True
                        v.waive_reason = w.reason
                    else:
                        v.message += " (waiver present but missing a reason)"
                violations.append(v)
    violations.sort(key=lambda v: (v.path, v.line, v.col, v.rule))
    return Report(violations=violations, files_scanned=len(files))
