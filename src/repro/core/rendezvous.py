"""Rendezvous service: namespace registration for expedited peer discovery.

The paper uses a rendezvous point to orchestrate NAT traversal and to
shortcut provider discovery before DHT records propagate.  Any public node
can serve the rendezvous RPCs; clients register under a namespace (e.g. a
model-fleet name) and discover other registrants.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Tuple, TYPE_CHECKING

from .dht import PeerInfo
from .rpc import RpcContext, RpcError, call_unary
from .simnet import DialError

if TYPE_CHECKING:  # pragma: no cover
    from .node import LatticaNode

DEFAULT_TTL = 7200.0


class RendezvousServer:
    def __init__(self, node: "LatticaNode"):
        self.node = node
        self.registrations: Dict[str, Dict[bytes, Tuple[PeerInfo, float]]] = {}
        node.router.register_unary("rdv.register", self._h_register)
        node.router.register_unary("rdv.discover", self._h_discover)

    def _h_register(self, payload: Any, ctx: RpcContext) -> Generator:
        ns, info, ttl = payload
        self.registrations.setdefault(ns, {})[info.peer_id.digest] = (
            info, self.node.sim.now + ttl)
        yield ctx.cpu(3e-6)
        return True, 64

    def _h_discover(self, payload: Any, ctx: RpcContext) -> Generator:
        ns = payload
        now = self.node.sim.now
        entries = self.registrations.get(ns, {})
        live = [i for i, (info, exp) in entries.items() if exp > now]
        infos = [entries[k][0] for k in live]
        yield ctx.cpu(3e-6)
        return infos, 96 * max(len(infos), 1)


def register(node: "LatticaNode", rdv: PeerInfo, namespace: str,
             ttl: float = DEFAULT_TTL) -> Generator:
    conn = yield from node.connect_info(rdv)
    ok = yield from call_unary(node.host, conn, "rdv.register",
                               (namespace, node.info(), ttl), size=128)
    return ok


def discover(node: "LatticaNode", rdv: PeerInfo, namespace: str) -> Generator:
    conn = yield from node.connect_info(rdv)
    infos = yield from call_unary(node.host, conn, "rdv.discover", namespace,
                                  size=96)
    for i in infos:
        node.remember(i)
    return infos
