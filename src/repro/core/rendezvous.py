"""Rendezvous service: namespace registration for expedited peer discovery.

The paper uses a rendezvous point to orchestrate NAT traversal and to
shortcut provider discovery before DHT records propagate.  Any public node
can serve the rendezvous RPCs; clients register under a namespace (e.g. a
model-fleet name) and discover other registrants.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Tuple, TYPE_CHECKING

from .dht import PeerInfo
from .rpc import RpcContext
from .service import Fixed, PEER_INFO_LIST, Service, unary

if TYPE_CHECKING:  # pragma: no cover
    from .node import LatticaNode

DEFAULT_TTL = 7200.0


class RendezvousService(Service):
    """Namespace registry: register under a fleet name, discover registrants.
    Both methods are idempotent (re-register just refreshes the TTL)."""

    name = "rdv"

    def __init__(self, server: "RendezvousServer"):
        self.server = server

    @unary("rdv.register", request=Fixed(128), response=Fixed(64),
           idempotent=True, timeout=15.0)
    def register(self, payload: Any, ctx: RpcContext) -> Generator:
        ns, info, ttl = payload
        self.server.registrations.setdefault(ns, {})[info.peer_id.digest] = (
            info, self.server.node.sim.now + ttl)
        yield ctx.cpu(3e-6)
        return True

    @unary("rdv.discover", request=Fixed(96), response=PEER_INFO_LIST,
           idempotent=True, timeout=15.0)
    def discover(self, payload: Any, ctx: RpcContext) -> Generator:
        ns = payload
        now = self.server.node.sim.now
        entries = self.server.registrations.get(ns, {})
        live = [i for i, (info, exp) in entries.items() if exp > now]
        yield ctx.cpu(3e-6)
        return [entries[k][0] for k in live]


class RendezvousServer:
    def __init__(self, node: "LatticaNode"):
        self.node = node
        self.registrations: Dict[str, Dict[bytes, Tuple[PeerInfo, float]]] = {}
        node.serve(RendezvousService(self))


def register(node: "LatticaNode", rdv: PeerInfo, namespace: str,
             ttl: float = DEFAULT_TTL) -> Generator:
    stub = node.stub(RendezvousService, rdv)
    ok = yield from stub.register((namespace, node.info(), ttl))
    return ok


def discover(node: "LatticaNode", rdv: PeerInfo, namespace: str) -> Generator:
    stub = node.stub(RendezvousService, rdv)
    infos = yield from stub.discover(namespace)
    for i in infos:
        node.remember(i)
    return infos
