"""Dual-plane RPC over Lattica streams (the paper's §2 RPC subsystem).

* **Unary plane** — request/response for control operations (health probes,
  shard placement, DHT queries, model-version lookups).  One stream per call,
  idempotent, cheap to retry.
* **Streaming plane** — long-lived, multiplexed, credit-based backpressured
  channels for tensor traffic.  Writers block when the receiver's byte-credit
  window is exhausted; receivers grant window updates as they drain, i.e.
  reactive-streams semantics over the simulated wire.

Handlers are generator functions so they can do real simulated work
(CPU, nested RPC, block fetches) while serving.
"""

from __future__ import annotations

import itertools
from collections import deque
from typing import Any, Callable, Dict, Generator, Optional, Tuple

from .simnet import Connection, DialError, Event, Host, Sim, Stream

PROTO_UNARY = "/lattica/rpc/1.0"
PROTO_STREAM = "/lattica/rpc-stream/1.0"

INIT_CREDIT = 1 << 20           # 1 MiB receive window per channel
CREDIT_GRANT_THRESHOLD = INIT_CREDIT // 2
CONTROL_MSG_SIZE = 64

UnaryHandler = Callable[[Any, "RpcContext"], Generator]       # -> (resp, size)
StreamHandler = Callable[["RpcChannel", "RpcContext"], Generator]


class RpcError(Exception):
    pass


class RpcContext:
    def __init__(self, host: Host, remote_host: Host):
        self.host = host
        self.remote_host = remote_host

    def cpu(self, seconds: float) -> Event:
        return self.host.cpu.consume(seconds)


class RpcRouter:
    """Per-node method registry; attach to a host to serve RPCs."""

    def __init__(self, host: Host):
        self.host = host
        self.sim: Sim = host.net.sim
        self.unary: Dict[str, UnaryHandler] = {}
        self.streaming: Dict[str, StreamHandler] = {}
        self.stats = {"unary_served": 0, "stream_served": 0, "errors": 0}
        host.handle(PROTO_UNARY, self._serve_unary)
        host.handle(PROTO_STREAM, self._serve_stream)

    def register_unary(self, method: str, handler: UnaryHandler) -> None:
        self.unary[method] = handler

    def register_streaming(self, method: str, handler: StreamHandler) -> None:
        self.streaming[method] = handler

    # -- server side ---------------------------------------------------------
    def _serve_unary(self, stream: Stream) -> Generator:
        # close our endpoint on every exit path: the client closes its side
        # after the response, and leaving ours open is a stream leak the
        # simsan audit flags (half-open pair on a live connection).
        try:
            try:
                method, payload, remote_name = yield from stream.recv(timeout=60.0)
            except DialError:
                return
            handler = self.unary.get(method)
            ctx = RpcContext(self.host, self.host.net.hosts[remote_name])
            if handler is None:
                self.stats["errors"] += 1
                stream.send(("err", f"no such method {method}"), CONTROL_MSG_SIZE)
                return
            try:
                resp, size = yield from handler(payload, ctx)
                self.stats["unary_served"] += 1
                stream.send(("ok", resp), max(size, CONTROL_MSG_SIZE))
            except Exception as exc:  # noqa: BLE001 — surfaced to the caller
                self.stats["errors"] += 1
                try:
                    stream.send(("err", repr(exc)), CONTROL_MSG_SIZE)
                except DialError:
                    pass
        finally:
            stream.close()

    def _serve_stream(self, stream: Stream) -> Generator:
        try:
            method, remote_name = yield from stream.recv(timeout=60.0)
        except DialError:
            stream.close()
            return
        handler = self.streaming.get(method)
        if handler is None:
            stream.send(("err", f"no such stream method {method}"), CONTROL_MSG_SIZE)
            stream.close()
            return
        stream.send(("hello",), CONTROL_MSG_SIZE)
        chan = RpcChannel(stream, self.sim)
        ctx = RpcContext(self.host, self.host.net.hosts[remote_name])
        self.stats["stream_served"] += 1
        try:
            yield from handler(chan, ctx)
        finally:
            # idempotent if the handler already ended the channel; otherwise
            # this is the server-side half-close that keeps streams balanced.
            chan.end()


# -- client side --------------------------------------------------------------


def call_unary(host: Host, conn: Connection, method: str, payload: Any,
               size: int = 128, timeout: float = 60.0) -> Generator:
    """Unary call over an existing connection.  Raises RpcError on failure."""
    stream = conn.open_stream(PROTO_UNARY, host)
    stream.send((method, payload, host.name), max(size, CONTROL_MSG_SIZE))
    try:
        msg = yield from stream.recv(timeout=timeout)
    except DialError as e:
        raise RpcError(f"{method}: {e}") from e
    finally:
        stream.close()
    if msg[0] != "ok":
        raise RpcError(f"{method}: remote error: {msg[1]}")
    return msg[1]


def open_channel(host: Host, conn: Connection, method: str,
                 timeout: float = 30.0) -> Generator:
    """Open a backpressured streaming channel; returns RpcChannel."""
    stream = conn.open_stream(PROTO_STREAM, host)
    stream.send((method, host.name), CONTROL_MSG_SIZE)
    msg = yield from stream.recv(timeout=timeout)
    if msg[0] != "hello":
        raise RpcError(f"{method}: channel rejected: {msg}")
    return RpcChannel(stream, host.net.sim)


class RpcChannel:
    """Bidirectional message channel with byte-credit flow control.

    Both endpoints hold an ``RpcChannel`` around their end of the stream.
    ``send`` blocks (yields) when the peer's window is exhausted; the peer
    grants credit back as its application code consumes messages.
    """

    def __init__(self, stream: Stream, sim: Sim):
        self.stream = stream
        self.sim = sim
        self.send_credit = INIT_CREDIT
        self._credit_waiters: deque = deque()
        self._pending_grant = 0
        self._inbox: deque = deque()
        self._inbox_waiter: Optional[Event] = None
        self._remote_ended = False
        self.bytes_sent = 0
        self.bytes_received = 0
        self._pump = sim.process(self._pump_loop(), daemon=True)

    # -- receive pump: demultiplexes data vs credit frames -------------------
    def _pump_loop(self) -> Generator:
        while True:
            try:
                msg = yield from self.stream.recv()
            except DialError:
                self._remote_ended = True
                self._wake_inbox()
                for w in self._credit_waiters:
                    if not w.triggered:
                        w.succeed()
                return
            kind = msg[0]
            if kind == "data":
                self._inbox.append((msg[1], msg[2]))
                self._wake_inbox()
            elif kind == "credit":
                self.send_credit += msg[1]
                while self._credit_waiters and self.send_credit > 0:
                    w = self._credit_waiters.popleft()
                    if not w.triggered:
                        w.succeed()
            elif kind == "end":
                self._remote_ended = True
                self._wake_inbox()
                # A graceful end must also wake senders blocked on credit,
                # exactly like the DialError path: they re-check
                # _remote_ended and raise instead of hanging forever.
                for w in self._credit_waiters:
                    if not w.triggered:
                        w.succeed()
                return  # "end" is the peer's final frame; park the pump

    def _wake_inbox(self) -> None:
        if self._inbox_waiter is not None and not self._inbox_waiter.triggered:
            self._inbox_waiter.succeed()

    # -- api ------------------------------------------------------------------
    def send(self, payload: Any, size: int) -> Generator:
        """Send one message, honoring the receive window (may yield)."""
        while self.send_credit < size:
            if self._remote_ended:
                raise RpcError("channel closed by peer")
            waiter = self.sim.event()
            self._credit_waiters.append(waiter)
            yield waiter
        self.send_credit -= size
        self.bytes_sent += size
        self.stream.send(("data", payload, size), size)
        return None

    def recv(self, timeout: Optional[float] = None) -> Generator:
        """Receive one message; returns payload or raises RpcError at end."""
        while not self._inbox:
            if self._remote_ended:
                raise RpcError("channel ended")
            self._inbox_waiter = self.sim.event()
            if timeout is not None:
                idx, _ = yield self.sim.any_of(
                    [self._inbox_waiter, self.sim.timeout(timeout)])
                if idx == 1 and not self._inbox:
                    raise RpcError("channel recv timeout")
            else:
                yield self._inbox_waiter
        payload, size = self._inbox.popleft()
        self.bytes_received += size
        self._pending_grant += size
        if self._pending_grant >= CREDIT_GRANT_THRESHOLD:
            self.stream.send(("credit", self._pending_grant), CONTROL_MSG_SIZE)
            self._pending_grant = 0
        return payload

    def end(self) -> None:
        try:
            self.stream.send(("end",), CONTROL_MSG_SIZE)
        except DialError:
            pass
        self.stream.close()
