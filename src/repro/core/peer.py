"""Peer identity & multiaddresses.

Peer IDs are the sha256 of an (abstract) public key, matching libp2p's
hash-of-pubkey scheme; the 256-bit digest doubles as the Kademlia key space.
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Optional


class PeerId:
    __slots__ = ("digest",)

    def __init__(self, digest: bytes):
        assert len(digest) == 32
        self.digest = digest

    @classmethod
    def from_pubkey(cls, pubkey: bytes) -> "PeerId":
        return cls(hashlib.sha256(pubkey).digest())

    @classmethod
    def from_name(cls, name: str) -> "PeerId":
        return cls.from_pubkey(name.encode())

    def xor_distance(self, other: "PeerId") -> int:
        return int.from_bytes(self.digest, "big") ^ int.from_bytes(other.digest, "big")

    def distance_to_key(self, key: bytes) -> int:
        return int.from_bytes(self.digest, "big") ^ int.from_bytes(key, "big")

    def __eq__(self, other: object) -> bool:
        return isinstance(other, PeerId) and other.digest == self.digest

    def __hash__(self) -> int:
        return hash(self.digest)

    def __lt__(self, other: "PeerId") -> bool:
        return self.digest < other.digest

    def short(self) -> str:
        return self.digest.hex()[:12]

    def __repr__(self) -> str:
        return f"PeerId({self.short()})"


@dataclass(frozen=True)
class Multiaddr:
    """A dialable address: either a direct (ip, port) or a relay circuit."""

    ip: str
    port: int
    transport: str = "quic"           # "tcp" | "quic"
    relay_peer: Optional["PeerId"] = None   # set => /p2p-circuit via that relay

    @property
    def is_relay(self) -> bool:
        return self.relay_peer is not None

    def __repr__(self) -> str:
        base = f"/ip4/{self.ip}/{self.transport}/{self.port}"
        if self.relay_peer is not None:
            return f"/p2p/{self.relay_peer.short()}/p2p-circuit{base}"
        return base
