"""Typed service layer over the dual-plane RPC router (the paper's §2 API).

The raw plane (:mod:`repro.core.rpc`) moves framed messages; this module is
the declarative surface every in-tree protocol is defined against.  A
*service* is a class whose RPC methods are declared with :class:`MethodSpec`
metadata — wire name, plane (unary/streaming), request/response codecs,
idempotency, deadline and retry policy — so call sites stop hand-rolling
method-name strings, wire-size constants and ``repr(exc)`` error matching.

## Defining a service

Declare handler methods with the :func:`unary` / :func:`streaming`
decorators.  Handlers are simulation generators: ``yield ctx.cpu(...)`` to
model work, then ``return`` the response payload (the response codec computes
its wire size — no more ``return resp, 96``)::

    from repro.core.service import (Service, unary, streaming, pickled,
                                    Fixed, ServiceError, RpcStatus)

    class KvService(Service):
        name = "kv"

        def __init__(self):
            self.data = {}

        @unary("kv.get", request=Fixed(96), response=pickled(floor=64),
               idempotent=True, timeout=10.0)
        def get(self, key, ctx):
            yield ctx.cpu(2e-6)
            if key not in self.data:
                raise ServiceError(RpcStatus.NOT_FOUND, f"no key {key!r}")
            return self.data[key]

        @unary("kv.put", request=pickled(floor=96), response=Fixed(64),
               timeout=10.0)
        def put(self, payload, ctx):
            key, value = payload
            yield ctx.cpu(2e-6)
            self.data[key] = value
            return True

        @streaming("kv.scan")
        def scan(self, chan, ctx):
            for key, value in sorted(self.data.items()):
                yield from chan.send((key, value), 128)
            chan.end()

Serve it on a node, call it through a generated stub::

    server.serve(KvService())
    stub = client.stub(KvService, server.info())
    value = yield from stub.get("model/latest")     # typed unary call
    chan  = yield from stub.scan()                  # opens an RpcChannel

Stubs transparently reuse ``connect_info`` connections, enforce per-call
deadlines, retry *idempotent* unary calls with jittered backoff on
``UNAVAILABLE``/``DEADLINE_EXCEEDED``, and raise :class:`ServiceError`
carrying an :class:`RpcStatus` instead of stringly-typed failures.  Client
and server middleware is supported via interceptors; a built-in metrics
interceptor feeds per-method counters/latency into ``core/metrics.py``.

Multiple instances of one service (e.g. one pipeline shard per peer) are
disambiguated with ``scope``: wire names become ``"<name>.<scope>"`` on both
the serving and stub side.
"""

from __future__ import annotations

import enum
import pickle
from collections import deque
from dataclasses import dataclass, field
from typing import (Any, Callable, Dict, Generator, Iterable, List, Optional,
                    Tuple)

from .rpc import (CONTROL_MSG_SIZE, RpcChannel, RpcContext, RpcError,
                  RpcRouter, call_unary, open_channel)
from .simnet import Connection, DialError, Host, Sim

__all__ = [
    "RpcStatus", "ServiceError", "Codec", "Fixed", "ByteLength", "pickled",
    "PeerInfoCodec", "PeerInfoListCodec", "DeclaredSizeCodec",
    "TensorDictCodec", "CodecFn", "CONTROL", "PEER_INFO", "PEER_INFO_LIST",
    "MethodSpec", "unary", "streaming", "Service", "serve_service", "Stub",
    "ClientCall", "MetricsClientInterceptor", "MetricsServerInterceptor",
    "RpcMetrics", "MethodStats", "stream_request",
]


# ---------------------------------------------------------------------------
# Status codes & typed errors
# ---------------------------------------------------------------------------


class RpcStatus(enum.Enum):
    """gRPC-style terminal status of an RPC."""

    OK = 0
    UNAVAILABLE = 1          # dial/transport failure, peer down — retryable
    NOT_FOUND = 2            # unknown method or missing resource
    DEADLINE_EXCEEDED = 3    # the per-call deadline elapsed
    INTERNAL = 4             # handler raised an unexpected exception

    @property
    def retryable(self) -> bool:
        return self in (RpcStatus.UNAVAILABLE, RpcStatus.DEADLINE_EXCEEDED)


class ServiceError(RpcError):
    """Typed RPC failure: carries an :class:`RpcStatus` plus detail text.

    Subclasses :class:`RpcError` so pre-existing ``except (DialError,
    RpcError)`` best-effort paths keep working unchanged.
    """

    def __init__(self, status: RpcStatus, detail: str = "", method: str = ""):
        super().__init__(f"{method or 'rpc'}: {status.name}: {detail}")
        self.status = status
        self.detail = detail
        self.method = method


# ---------------------------------------------------------------------------
# Codecs: simulated wire size from the payload
# ---------------------------------------------------------------------------


class Codec:
    """Computes the simulated wire size of a payload.

    The simulator charges bandwidth/CPU per byte, so the codec is what keeps
    call sites honest about payload size without hand-passed constants.
    """

    name = "codec"

    def size_of(self, payload: Any) -> int:  # pragma: no cover - interface
        raise NotImplementedError


class Fixed(Codec):
    """Constant wire size — control messages, digests, keys."""

    def __init__(self, size: int):
        self.name = f"fixed({size})"
        self.size = size

    def size_of(self, payload: Any) -> int:
        return self.size


class ByteLength(Codec):
    """``len(payload)`` for bytes-like payloads, with a framing floor."""

    def __init__(self, floor: int = CONTROL_MSG_SIZE):
        self.name = f"bytes(floor={floor})"
        self.floor = floor

    def size_of(self, payload: Any) -> int:
        return max(len(payload) if payload is not None else 0, self.floor)


class _Pickled(Codec):
    """Serialized-size codec for small structured payloads."""

    def __init__(self, floor: int = CONTROL_MSG_SIZE):
        self.name = f"pickled(floor={floor})"
        self.floor = floor

    def size_of(self, payload: Any) -> int:
        try:
            return max(len(pickle.dumps(payload, protocol=4)), self.floor)
        except Exception:  # unpicklable sim object — fall back to the floor
            return self.floor


def pickled(floor: int = CONTROL_MSG_SIZE) -> Codec:
    return _Pickled(floor)


#: Wire size of one serialized PeerInfo record (kept equal to the historical
#: hand-tuned constant so calibrated benchmarks are unchanged).
PEER_INFO_WIRE = 96


class PeerInfoCodec(Codec):
    name = "peer_info"

    def size_of(self, payload: Any) -> int:
        return PEER_INFO_WIRE


class PeerInfoListCodec(Codec):
    name = "peer_info_list"

    def size_of(self, payload: Any) -> int:
        return PEER_INFO_WIRE * max(len(payload), 1)


class DeclaredSizeCodec(Codec):
    """Payload tuples whose last element declares the application size
    (pub/sub messages, where the simulated body is caller-declared)."""

    name = "declared"

    def size_of(self, payload: Any) -> int:
        return max(int(payload[-1]), CONTROL_MSG_SIZE)


class TensorDictCodec(Codec):
    """``{"x": ndarray}`` activation payloads: size = array nbytes."""

    name = "tensor_dict"

    def __init__(self, key: str = "x"):
        self.key = key

    def size_of(self, payload: Any) -> int:
        x = payload.get(self.key) if isinstance(payload, dict) else payload
        nbytes = getattr(x, "nbytes", None)
        return max(int(nbytes), CONTROL_MSG_SIZE) if nbytes else CONTROL_MSG_SIZE


class CodecFn(Codec):
    """Adapter for one-off size functions (tagged-union responses)."""

    def __init__(self, name: str, fn: Callable[[Any], int]):
        self.name = name
        self._fn = fn

    def size_of(self, payload: Any) -> int:
        return max(int(self._fn(payload)), CONTROL_MSG_SIZE)


CONTROL = Fixed(CONTROL_MSG_SIZE)
PEER_INFO = PeerInfoCodec()
PEER_INFO_LIST = PeerInfoListCodec()


# ---------------------------------------------------------------------------
# Method specs & service definition
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MethodSpec:
    """Declarative description of one RPC method."""

    name: str                          # wire name, e.g. "kad.find_node"
    kind: str = "unary"                # "unary" | "streaming"
    request: Codec = CONTROL
    response: Codec = CONTROL
    idempotent: bool = False
    timeout: float = 15.0              # per-attempt deadline (seconds)
    retries: int = 2                   # extra attempts (idempotent only)
    backoff: float = 0.05              # base for jittered exponential backoff


def unary(name: str, *, request: Codec = CONTROL, response: Codec = CONTROL,
          idempotent: bool = False, timeout: float = 15.0, retries: int = 2,
          backoff: float = 0.05) -> Callable:
    """Declare a unary handler ``def m(self, payload, ctx) -> resp``."""

    spec = MethodSpec(name=name, kind="unary", request=request,
                      response=response, idempotent=idempotent,
                      timeout=timeout, retries=retries, backoff=backoff)

    def deco(fn: Callable) -> Callable:
        fn.__rpc_spec__ = spec
        return fn

    return deco


def streaming(name: str, *, timeout: float = 30.0) -> Callable:
    """Declare a streaming handler ``def m(self, chan, ctx)``."""

    spec = MethodSpec(name=name, kind="streaming", timeout=timeout)

    def deco(fn: Callable) -> Callable:
        fn.__rpc_spec__ = spec
        return fn

    return deco


class Service:
    """Base class: collects decorated methods into a spec table."""

    #: short service name, used for diagnostics
    name = "svc"
    #: per-instance disambiguator; wire names become "<name>.<scope>"
    scope: Optional[str] = None

    @classmethod
    def rpc_specs(cls) -> Dict[str, MethodSpec]:
        """attr name -> MethodSpec, in definition order (MRO-resolved).
        Cached per class: hot paths build stubs per call."""
        cached = cls.__dict__.get("_rpc_specs_cache")
        if cached is not None:
            return cached
        specs: Dict[str, MethodSpec] = {}
        for klass in reversed(cls.__mro__):
            for attr, val in vars(klass).items():
                spec = getattr(val, "__rpc_spec__", None)
                if spec is not None:
                    specs[attr] = spec
        cls._rpc_specs_cache = specs
        return specs

    def wire_name(self, spec: MethodSpec) -> str:
        return spec.name if self.scope is None else f"{spec.name}.{self.scope}"


# ---------------------------------------------------------------------------
# Per-method metrics
# ---------------------------------------------------------------------------


class MethodStats:
    """Counters + bounded latency reservoir for one method."""

    __slots__ = ("calls", "errors", "latencies")

    def __init__(self, maxlen: Optional[int] = 512):
        self.calls = 0
        self.errors = 0
        self.latencies: deque = deque(maxlen=maxlen)

    def record(self, ok: bool, latency: float) -> None:
        self.calls += 1
        if not ok:
            self.errors += 1
        self.latencies.append(latency)

    def percentile(self, q: float) -> float:
        if not self.latencies:
            return 0.0
        ordered = sorted(self.latencies)
        idx = min(int(q * len(ordered)), len(ordered) - 1)
        return ordered[idx]


class RpcMetrics:
    """Per-node registry the metrics interceptors feed; read by
    ``core/metrics.py`` for the fleet dashboard."""

    def __init__(self):
        self.client: Dict[str, MethodStats] = {}
        self.server: Dict[str, MethodStats] = {}

    def _table(self, role: str) -> Dict[str, MethodStats]:
        return self.client if role == "client" else self.server

    def record(self, role: str, method: str, ok: bool, latency: float) -> None:
        table = self._table(role)
        stats = table.get(method)
        if stats is None:
            stats = table[method] = MethodStats()
        stats.record(ok, latency)


# ---------------------------------------------------------------------------
# Server side
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HandlerInfo:
    service: Service
    attr: str
    wire: str
    spec: MethodSpec


class ServerInterceptor:
    """Server middleware; override :meth:`intercept`.

    ``proceed(payload, ctx)`` is a generator function running the rest of the
    chain (ultimately the handler).  Raise :class:`ServiceError` to fail the
    call with a typed status.
    """

    def intercept(self, info: HandlerInfo, payload: Any, ctx: RpcContext,
                  proceed: Callable) -> Generator:
        resp = yield from proceed(payload, ctx)
        return resp


class MetricsServerInterceptor(ServerInterceptor):
    def __init__(self, metrics: RpcMetrics, sim: Sim):
        self.metrics = metrics
        self.sim = sim

    def intercept(self, info: HandlerInfo, payload: Any, ctx: RpcContext,
                  proceed: Callable) -> Generator:
        t0 = self.sim.now
        try:
            resp = yield from proceed(payload, ctx)
        except BaseException:
            self.metrics.record("server", info.wire, False, self.sim.now - t0)
            raise
        self.metrics.record("server", info.wire, True, self.sim.now - t0)
        return resp


def _server_chain(info: HandlerInfo,
                  interceptors: Tuple[ServerInterceptor, ...]) -> Callable:
    handler = getattr(info.service, info.attr)

    def base(payload: Any, ctx: RpcContext) -> Generator:
        resp = yield from handler(payload, ctx)
        return resp

    chain = base
    for icpt in reversed(interceptors):
        def wrap(payload, ctx, _i=icpt, _next=chain):
            resp = yield from _i.intercept(info, payload, ctx, _next)
            return resp
        chain = wrap
    return chain


def _wrap_unary(info: HandlerInfo, chain: Callable,
                router: RpcRouter) -> Callable:
    """Adapt a service handler to the raw router plane: run the interceptor
    chain, map exceptions to in-band ``("e", status, detail)`` frames, and
    compute the response wire size from the codec."""

    def _count_error() -> None:
        # Failures travel in-band, so the router's success path runs next and
        # bumps unary_served; pre-compensate to keep the pre-refactor
        # semantics (errors = handler failures, unary_served = successes).
        router.stats["errors"] += 1
        router.stats["unary_served"] -= 1

    def router_handler(payload: Any, ctx: RpcContext) -> Generator:
        try:
            resp = yield from chain(payload, ctx)
        except ServiceError as exc:
            _count_error()
            return (("e", exc.status.value, exc.detail or str(exc)),
                    CONTROL_MSG_SIZE)
        except DialError:
            raise                      # transport died mid-call; nothing to send
        except Exception as exc:  # noqa: BLE001 — typed as INTERNAL for the caller
            _count_error()
            return ("e", RpcStatus.INTERNAL.value, repr(exc)), CONTROL_MSG_SIZE
        return ("r", resp), max(info.spec.response.size_of(resp),
                                CONTROL_MSG_SIZE)

    return router_handler


def _wrap_streaming(info: HandlerInfo, metrics: Optional[RpcMetrics]) -> Callable:
    handler = getattr(info.service, info.attr)

    def router_handler(chan: RpcChannel, ctx: RpcContext) -> Generator:
        if metrics is not None:
            metrics.record("server", info.wire, True, 0.0)
        yield from handler(chan, ctx)

    return router_handler


def serve_service(router: RpcRouter, service: Service,
                  interceptors: Iterable[ServerInterceptor] = (),
                  metrics: Optional[RpcMetrics] = None) -> Service:
    """Register every declared method of ``service`` with the router."""
    sim = router.sim
    chain_interceptors: Tuple[ServerInterceptor, ...] = tuple(interceptors)
    if metrics is not None:
        chain_interceptors = (MetricsServerInterceptor(metrics, sim),
                              ) + chain_interceptors
    for attr, spec in service.rpc_specs().items():
        info = HandlerInfo(service, attr, service.wire_name(spec), spec)
        if spec.kind == "unary":
            chain = _server_chain(info, chain_interceptors)
            router.register_unary(info.wire, _wrap_unary(info, chain, router))
        else:
            router.register_streaming(info.wire,
                                      _wrap_streaming(info, metrics))
    return service


# ---------------------------------------------------------------------------
# Client side: generated stubs
# ---------------------------------------------------------------------------


@dataclass
class ClientCall:
    """Mutable invocation record threaded through client interceptors."""

    wire: str
    spec: MethodSpec
    payload: Any
    timeout: float
    status: RpcStatus = RpcStatus.OK
    attempts: int = 0


class ClientInterceptor:
    """Client middleware; ``proceed(call)`` runs the rest of the chain."""

    def intercept(self, call: ClientCall, proceed: Callable) -> Generator:
        resp = yield from proceed(call)
        return resp


class MetricsClientInterceptor(ClientInterceptor):
    def __init__(self, metrics: RpcMetrics, sim: Sim):
        self.metrics = metrics
        self.sim = sim

    def intercept(self, call: ClientCall, proceed: Callable) -> Generator:
        t0 = self.sim.now
        try:
            resp = yield from proceed(call)
        except BaseException:
            self.metrics.record("client", call.wire, False, self.sim.now - t0)
            raise
        self.metrics.record("client", call.wire, True, self.sim.now - t0)
        return resp


class Stub:
    """Generated client for a service: one generator method per MethodSpec.

    Target is either a ``PeerInfo`` (connections acquired — and reused — via
    ``node.connect_info``) or an explicit ``Connection`` (``conn=...``), for
    callers sitting inside connection establishment itself.
    """

    def __init__(self, node: Any, service_cls: type, target: Any = None, *,
                 conn: Optional[Connection] = None,
                 scope: Optional[str] = None,
                 interceptors: Iterable[ClientInterceptor] = ()):
        if target is None and conn is None:
            raise ValueError("stub needs a PeerInfo target or conn=")
        self._node = node
        self._host: Host = node.host
        self._sim: Sim = node.sim
        self._target = target
        self._conn = conn
        self._scope = scope
        chain: Tuple[ClientInterceptor, ...] = tuple(interceptors)
        metrics = getattr(node, "rpc_metrics", None)
        if metrics is not None:
            chain = (MetricsClientInterceptor(metrics, self._sim),) + chain
        self._interceptors = chain
        # the interceptor chain only depends on the interceptor tuple
        # (per-call state travels in the ClientCall), so build it once
        self._chain = self._transport_call
        for icpt in reversed(chain):
            def wrap(c, _i=icpt, _next=self._chain):
                resp = yield from _i.intercept(c, _next)
                return resp
            self._chain = wrap
        effective_scope = scope if scope is not None else service_cls.scope
        for attr, spec in service_cls.rpc_specs().items():
            wire = (spec.name if effective_scope is None
                    else f"{spec.name}.{effective_scope}")
            setattr(self, attr, self._bind(wire, spec))

    # -- wiring --------------------------------------------------------------
    def _bind(self, wire: str, spec: MethodSpec) -> Callable:
        if spec.kind == "streaming":
            def open_method(timeout: Optional[float] = None) -> Generator:
                chan = yield from self._open(wire, spec,
                                             timeout or spec.timeout)
                return chan
            open_method.__name__ = wire
            return open_method

        def call_method(payload: Any = None, *,
                        timeout: Optional[float] = None) -> Generator:
            resp = yield from self._invoke(wire, spec, payload,
                                           timeout or spec.timeout)
            return resp
        call_method.__name__ = wire
        return call_method

    def _acquire(self) -> Generator:
        if self._conn is not None:
            if not self._conn.closed:
                return self._conn
            if self._target is None:
                # pinned-connection stub: nothing to re-dial against
                raise DialError("stub connection closed")
        conn = yield from self._node.connect_info(self._target)
        return conn

    # -- unary ---------------------------------------------------------------
    def _invoke(self, wire: str, spec: MethodSpec, payload: Any,
                timeout: float) -> Generator:
        call = ClientCall(wire=wire, spec=spec, payload=payload,
                          timeout=timeout)
        resp = yield from self._chain(call)
        return resp

    def _transport_call(self, call: ClientCall) -> Generator:
        spec = call.spec
        attempts = 1 + (spec.retries if spec.idempotent else 0)
        last: Optional[ServiceError] = None
        for attempt in range(attempts):
            call.attempts = attempt + 1
            if attempt:
                # jittered exponential backoff before each retry
                base = spec.backoff * (2 ** (attempt - 1))
                yield self._sim.timeout(base * (0.5 + self._sim.rng.random()))
            try:
                conn = yield from self._acquire()
            except DialError as exc:
                last = ServiceError(RpcStatus.UNAVAILABLE, str(exc),
                                    call.wire)
                continue
            try:
                resp = yield from self._attempt(conn, call)
                return resp
            except ServiceError as exc:
                last = exc
                if exc.status.retryable and attempt + 1 < attempts:
                    continue
                call.status = exc.status
                raise
        call.status = last.status if last else RpcStatus.UNAVAILABLE
        raise last or ServiceError(RpcStatus.UNAVAILABLE, "no attempt ran",
                                   call.wire)

    def _attempt(self, conn: Connection, call: ClientCall) -> Generator:
        spec = call.spec
        size = spec.request.size_of(call.payload)
        # Race the raw call against the deadline.  The inner rpc timeout is
        # kept far beyond ours so transport failures surface as DialError and
        # deadline expiry is decided here, in exactly one place.
        proc = self._sim.process(call_unary(
            self._host, conn, call.wire, call.payload, size=size,
            timeout=call.timeout * 2 + 60.0))
        try:
            idx, val = yield self._sim.any_of(
                [proc, self._sim.timeout(call.timeout)])
        except ServiceError:
            raise
        except RpcError as exc:
            # call_unary chains DialError causes; an uncaused RpcError is the
            # router's "no such method" err frame.
            if isinstance(exc.__cause__, DialError):
                raise ServiceError(RpcStatus.UNAVAILABLE, str(exc),
                                   call.wire) from exc
            raise ServiceError(RpcStatus.NOT_FOUND, str(exc),
                               call.wire) from exc
        except DialError as exc:
            raise ServiceError(RpcStatus.UNAVAILABLE, str(exc),
                               call.wire) from exc
        if idx == 1:
            raise ServiceError(RpcStatus.DEADLINE_EXCEEDED,
                               f"deadline {call.timeout}s elapsed", call.wire)
        return _unwrap(val, call.wire)

    # -- streaming -----------------------------------------------------------
    def _open(self, wire: str, spec: MethodSpec, timeout: float) -> Generator:
        try:
            conn = yield from self._acquire()
            chan = yield from open_channel(self._host, conn, wire,
                                           timeout=timeout)
        except ServiceError:
            raise
        except DialError as exc:
            raise ServiceError(RpcStatus.UNAVAILABLE, str(exc), wire) from exc
        except RpcError as exc:
            status = (RpcStatus.UNAVAILABLE
                      if isinstance(exc.__cause__, DialError)
                      else RpcStatus.NOT_FOUND)
            raise ServiceError(status, str(exc), wire) from exc
        metrics = getattr(self._node, "rpc_metrics", None)
        if metrics is not None:
            metrics.record("client", wire, True, 0.0)
        return chan


def _unwrap(envelope: Any, wire: str) -> Any:
    """Decode the service-plane response envelope into resp-or-raise."""
    if isinstance(envelope, tuple) and envelope and envelope[0] == "r":
        return envelope[1]
    if isinstance(envelope, tuple) and len(envelope) == 3 and envelope[0] == "e":
        try:
            status = RpcStatus(envelope[1])
        except ValueError:
            status = RpcStatus.INTERNAL
        raise ServiceError(status, str(envelope[2]), wire)
    raise ServiceError(RpcStatus.INTERNAL,
                       f"malformed response envelope: {envelope!r}", wire)


# ---------------------------------------------------------------------------
# Raw-stream control helper (pre-connection protocols)
# ---------------------------------------------------------------------------


def stream_request(stream: Any, payload: Any, size: int = CONTROL_MSG_SIZE,
                   timeout: float = 10.0, close: bool = True) -> Generator:
    """One request/response over a raw stream, for control exchanges that run
    *below* the typed RPC plane (relay signalling, AutoNAT dial-backs): the
    connection is still being established, so no router is reachable yet.
    Centralizes the send/recv/close boilerplate those paths hand-rolled."""
    stream.send(payload, size)
    try:
        msg = yield from stream.recv(timeout=timeout)
    finally:
        if close:
            stream.close()
    return msg
