"""Deterministic discrete-event network simulator.

This is the substrate every Lattica protocol in this repo actually runs on:
packets traverse NAT boxes, streams are bandwidth/latency/CPU constrained, and
all protocol logic (Kademlia, Bitswap, DCUtR, RPC, gossip) executes as
generator-based processes against this event loop.  Determinism: a single
seeded ``random.Random`` drives jitter/loss/choices, and the heap breaks ties
with a monotone sequence number.

Process framework (SimPy-like, minimal):
    * ``yield <float>``          sleep for that many seconds
    * ``yield Event``            wait until the event succeeds (or re-raises)
    * ``yield Process``          wait for a child process to finish
    * ``return value``           completes the process; parents receive value
"""

from __future__ import annotations

import hashlib
import heapq
import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Generator, List, Optional, Tuple

# --------------------------------------------------------------------------
# Core event loop
# --------------------------------------------------------------------------


class SimError(Exception):
    pass


class DialError(SimError):
    """Raised when a dial / traversal attempt fails."""


def _values_differ(a: Any, b: Any) -> bool:
    """Conservative inequality: identity first, then ``==`` where it yields a
    plain bool (ndarrays and other broadcasting types count as different)."""
    if a is b:
        return False
    try:
        return not bool(a == b)
    except Exception:
        return True


class Sanitizer:
    """simsan evidence collector for one :class:`Sim` run.

    Activated via ``Sim(sanitize=True)``; records an event-trace digest
    (every dispatched callback, in order), double-settled events, processes
    that never ran to completion, and — together with the
    ``register_leak_check`` hooks subsystems install on the :class:`Sim` —
    an end-of-run resource leak audit.
    """

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self._hash = hashlib.sha256()
        self.events_traced = 0
        self.double_settles: List[Dict[str, Any]] = []
        self._processes: List[Tuple["Process", str, bool]] = []

    # -- event trace ---------------------------------------------------------
    def trace(self, t: float, fn: Callable) -> None:
        name = getattr(fn, "__qualname__", None) or type(fn).__name__
        self._hash.update(f"{t!r}|{name}\n".encode())
        self.events_traced += 1

    def digest(self) -> str:
        return self._hash.hexdigest()

    # -- double-settle -------------------------------------------------------
    def note_settle(self, evt: "Event", kind: str, value: Any) -> None:
        """Called on succeed()/fail() of an already-triggered event; records a
        violation when the second settle disagrees with the first."""
        first = "fail" if evt.failed else "succeed"
        if kind == first and not _values_differ(value, evt.value):
            return  # benign idempotent re-settle with the same outcome
        self.double_settles.append({
            "t": self.sim.now,
            "event": type(evt).__name__,
            "first": first,
            "second": kind,
            "first_value": repr(evt.value)[:120],
            "second_value": repr(value)[:120],
        })

    # -- orphaned processes --------------------------------------------------
    def note_process(self, proc: "Process", daemon: bool) -> None:
        gen = proc._gen
        label = getattr(gen, "__qualname__", None) or repr(gen)
        self._processes.append((proc, label, daemon))

    def orphans(self) -> List[str]:
        """Non-daemon processes that never ran to completion.  Daemon
        processes (service loops marked ``sim.process(gen, daemon=True)``)
        are expected to outlive the run and are exempt."""
        return [label for proc, label, daemon in self._processes
                if not daemon and not proc.triggered]


class Event:
    """One-shot event; processes can wait on it."""

    __slots__ = ("sim", "triggered", "failed", "value", "_waiters")

    def __init__(self, sim: "Sim"):
        self.sim = sim
        self.triggered = False
        self.failed = False
        self.value: Any = None
        self._waiters: List[Callable[["Event"], None]] = []

    def succeed(self, value: Any = None) -> "Event":
        if self.triggered:
            if self.sim._san is not None:
                self.sim._san.note_settle(self, "succeed", value)
            return self
        self.triggered = True
        self.value = value
        for w in self._waiters:
            self.sim._schedule(0.0, w, self)
        self._waiters.clear()
        return self

    def fail(self, exc: BaseException) -> "Event":
        if self.triggered:
            if self.sim._san is not None:
                self.sim._san.note_settle(self, "fail", exc)
            return self
        self.triggered = True
        self.failed = True
        self.value = exc
        for w in self._waiters:
            self.sim._schedule(0.0, w, self)
        self._waiters.clear()
        return self

    def _add_waiter(self, cb: Callable[["Event"], None]) -> None:
        if self.triggered:
            self.sim._schedule(0.0, cb, self)
        else:
            self._waiters.append(cb)


class Process(Event):
    """Drives a generator; completion is an Event carrying the return value."""

    __slots__ = ("_gen",)

    def __init__(self, sim: "Sim", gen: Generator):
        super().__init__(sim)
        self._gen = gen
        sim._schedule(0.0, self._resume, None)

    # -- stepping ----------------------------------------------------------
    def _resume(self, evt: Optional[Event]) -> None:
        if self.triggered:
            return
        try:
            if isinstance(evt, Event) and evt.failed:
                item = self._gen.throw(evt.value)
            else:
                item = self._gen.send(evt.value if isinstance(evt, Event) else evt)
        except StopIteration as stop:
            self.succeed(stop.value)
            return
        except BaseException as exc:  # noqa: BLE001 - propagate to waiters
            if self._waiters:
                self.fail(exc)
            else:
                self.fail(exc)
                # Unobserved failure: keep silent (protocol best-effort paths).
            return
        self._dispatch(item)

    def _dispatch(self, item: Any) -> None:
        if isinstance(item, Event):
            item._add_waiter(self._resume)
        elif isinstance(item, (int, float)):
            self.sim._schedule(float(item), self._resume, None)
        else:  # pragma: no cover - programming error
            raise TypeError(f"process yielded unsupported item {item!r}")


class Sim:
    def __init__(self, seed: int = 0, sanitize: bool = False,
                 perturb: Optional[int] = None):
        import random

        self.now: float = 0.0
        self.rng = random.Random(seed)
        self._heap: List[Tuple[float, Any, Callable, Any]] = []
        self._seq = itertools.count()
        #: simsan: ``sanitize=True`` records an event-trace digest, flags
        #: conflicting double-settles, and tracks processes for the orphan
        #: report.  ``perturb=<seed>`` additionally randomizes same-time
        #: tie-breaks (from a *separate* seeded Random, so ``rng`` draws are
        #: unchanged) to surface latent event-order dependence.
        self._san: Optional[Sanitizer] = Sanitizer(self) if sanitize else None
        self._perturb = (random.Random(f"simsan-perturb:{perturb}")
                         if perturb is not None else None)
        self._leak_checks: Dict[str, Callable[[], float]] = {}
        self._leak_baseline: Dict[str, float] = {}

    # -- scheduling --------------------------------------------------------
    def _schedule(self, delay: float, fn: Callable, arg: Any) -> None:
        if self._perturb is None:
            key: Any = next(self._seq)
        else:
            # Random primary key shuffles equal-time events; the sequence
            # number stays as a deterministic final tie-break.
            key = (self._perturb.random(), next(self._seq))
        heapq.heappush(self._heap, (self.now + delay, key, fn, arg))

    def event(self) -> Event:
        return Event(self)

    def timeout(self, delay: float, value: Any = None) -> Event:
        ev = Event(self)
        self._schedule(delay, lambda _: ev.succeed(value), None)
        return ev

    def process(self, gen: Generator, daemon: bool = False) -> Process:
        """Spawn a process.  ``daemon=True`` marks service loops expected to
        outlive the run (listeners, pumps, maintenance) so the simsan orphan
        detector does not report them."""
        proc = Process(self, gen)
        if self._san is not None:
            self._san.note_process(proc, daemon)
        return proc

    def any_of(self, events: List[Event]) -> Event:
        """Succeeds with (index, value) of the first event that fires."""
        out = Event(self)

        def make_cb(i: int):
            def cb(evt: Event) -> None:
                if out.triggered:
                    return
                if evt.failed:
                    out.fail(evt.value)
                else:
                    out.succeed((i, evt.value))

            return cb

        for i, e in enumerate(events):
            e._add_waiter(make_cb(i))
        return out

    def all_of(self, events: List[Event]) -> Event:
        out = Event(self)
        remaining = [len(events)]
        results: List[Any] = [None] * len(events)
        if not events:
            out.succeed([])
            return out

        def make_cb(i: int):
            def cb(evt: Event) -> None:
                if out.triggered:
                    return
                if evt.failed:
                    out.fail(evt.value)
                    return
                results[i] = evt.value
                remaining[0] -= 1
                if remaining[0] == 0:
                    out.succeed(results)

            return cb

        for i, e in enumerate(events):
            e._add_waiter(make_cb(i))
        return out

    # -- running -----------------------------------------------------------
    def run(self, until: Optional[float] = None) -> None:
        san = self._san
        while self._heap:
            t, _, fn, arg = self._heap[0]
            if until is not None and t > until:
                self.now = until
                return
            heapq.heappop(self._heap)
            self.now = t
            if san is not None:
                san.trace(t, fn)
            fn(arg)
        if until is not None:
            self.now = max(self.now, until)

    def run_process(self, gen: Generator, until: float = 1e9) -> Any:
        """Run the loop until ``gen`` completes; returns its value or raises."""
        proc = self.process(gen)
        san = self._san
        while self._heap and not proc.triggered:
            t, _, fn, arg = heapq.heappop(self._heap)
            if t > until:
                raise SimError(f"process did not complete before t={until}")
            self.now = t
            if san is not None:
                san.trace(t, fn)
            fn(arg)
        if not proc.triggered:
            raise SimError("deadlock: process blocked with empty event queue")
        if proc.failed:
            raise proc.value
        return proc.value

    # -- simsan surface ------------------------------------------------------
    def trace_digest(self) -> str:
        """sha256 over every dispatched ``(time, callback)`` so far.  Two runs
        of the same scenario under the same seed must agree bit-for-bit."""
        if self._san is None:
            raise SimError("trace_digest requires Sim(sanitize=True)")
        return self._san.digest()

    def register_leak_check(self, name: str, fn: Callable[[], float]) -> None:
        """Install a named resource gauge (count of currently-held resources).
        Subsystems register these at construction; the audit compares gauges
        against the baseline snapshot.  Re-registering a name replaces it."""
        self._leak_checks[name] = fn

    def leak_report(self) -> Dict[str, float]:
        return {name: fn() for name, fn in sorted(self._leak_checks.items())}

    def leak_baseline(self) -> Dict[str, float]:
        """Snapshot current gauges as the audit baseline (call after setup so
        long-lived resources — listen sockets, live relay reservations —
        don't read as leaks)."""
        self._leak_baseline = self.leak_report()
        return dict(self._leak_baseline)

    def leak_audit(self) -> Dict[str, float]:
        """Gauges that moved above the baseline: ``{name: excess}``.  Empty
        means every audited resource returned to baseline."""
        base = self._leak_baseline
        return {name: v - base.get(name, 0)
                for name, v in self.leak_report().items()
                if v - base.get(name, 0) != 0}

    def san_report(self) -> Dict[str, Any]:
        """Full simsan report: trace digest, double-settles, orphans, leaks."""
        if self._san is None:
            raise SimError("san_report requires Sim(sanitize=True)")
        return {
            "trace_digest": self._san.digest(),
            "events": self._san.events_traced,
            "double_settles": list(self._san.double_settles),
            "orphans": self._san.orphans(),
            "leaks": self.leak_audit(),
        }


# --------------------------------------------------------------------------
# Network model: regions, links, CPU
# --------------------------------------------------------------------------

#: One-way latency in seconds between region classes.  ``local`` means the
#: same physical host (loopback); keys are frozensets of region labels.
DEFAULT_LATENCY = {
    "loopback": 20e-6,
    "lan": 0.25e-3,
    "wan": 10e-3,
    "inter": 75e-3,
}

#: Link bandwidth in bytes/second for each scenario class.
DEFAULT_BANDWIDTH = {
    "loopback": 4.0e9,   # memory-speed loopback
    "lan": 1.25e9,       # 10 Gbps
    "wan": 1.5e8,        # ~1.2 Gbps shared WAN path
    "inter": 3.0e7,      # ~240 Mbps transcontinental path
}

#: Packet loss probability (datagrams only; streams are reliable).
DEFAULT_LOSS = {"loopback": 0.0, "lan": 0.0, "wan": 0.005, "inter": 0.02}


def scenario_for(a: "Host", b: "Host") -> str:
    if a is b or (a.machine is not None and a.machine == b.machine):
        return "loopback"
    if a.region == b.region:
        return "lan" if a.zone == b.zone else "wan"
    return "inter"


class CPU:
    """A small multi-core CPU model: work items serialize across cores."""

    def __init__(self, sim: Sim, cores: int = 4):
        self.sim = sim
        self.cores = [0.0] * cores

    def consume(self, seconds: float) -> Event:
        """Occupy the earliest-free core for ``seconds``; event fires at end."""
        i = min(range(len(self.cores)), key=lambda k: self.cores[k])
        start = max(self.sim.now, self.cores[i])
        finish = start + seconds
        self.cores[i] = finish
        return self.sim.timeout(finish - self.sim.now)


@dataclass
class Packet:
    src: Tuple[str, int]       # observed (ip, port) of the sender
    dst: Tuple[str, int]
    payload: Any
    size: int = 128


class Socket:
    """Datagram socket (UDP-like) used by the traversal machinery."""

    def __init__(self, host: "Host", port: int):
        self.host = host
        self.port = port
        self._inbox: deque = deque()
        self._waiter: Optional[Event] = None
        self.closed = False

    def sendto(self, dst: Tuple[str, int], payload: Any, size: int = 128) -> None:
        self.host.net.send_packet(self.host, self.port, dst, payload, size)

    def _deliver(self, pkt: Packet) -> None:
        if self.closed:
            return
        self._inbox.append(pkt)
        if self._waiter is not None and not self._waiter.triggered:
            self._waiter.succeed()

    def recv(self, timeout: Optional[float] = None) -> Generator:
        """Process helper: yields until a packet arrives (or raises DialError)."""
        while not self._inbox:
            self._waiter = self.host.net.sim.event()
            if timeout is not None:
                race = self.host.net.sim.any_of(
                    [self._waiter, self.host.net.sim.timeout(timeout)]
                )
                idx, _ = yield race
                if idx == 1 and not self._inbox:
                    raise DialError(f"recv timeout on {self.host.name}:{self.port}")
            else:
                yield self._waiter
        return self._inbox.popleft()

    def close(self) -> None:
        self.closed = True
        self.host._sockets.pop(self.port, None)


# --------------------------------------------------------------------------
# Streams & connections
# --------------------------------------------------------------------------


class Stream:
    """One half of a bidirectional protocol stream over a Connection."""

    def __init__(self, conn: "Connection", stream_id: int, protocol: str, initiator: bool):
        self.conn = conn
        self.stream_id = stream_id
        self.protocol = protocol
        self.initiator = initiator
        self._inbox: deque = deque()
        self._waiter: Optional[Event] = None
        self.closed = False
        self.reset = False

    # local endpoint index within the connection (0 or 1)
    @property
    def _side(self) -> int:
        return 0 if self.initiator else 1

    def send(self, payload: Any, size: int = 128) -> None:
        if self.closed or self.conn.closed:
            raise DialError("stream closed")
        self.conn._transmit(self._side, self.stream_id, payload, size)

    def recv(self, timeout: Optional[float] = None) -> Generator:
        sim = self.conn.net.sim
        while not self._inbox:
            if self.reset or self.conn.closed:
                raise DialError("stream reset by peer / connection closed")
            self._waiter = sim.event()
            if timeout is not None:
                idx, _ = yield sim.any_of([self._waiter, sim.timeout(timeout)])
                if idx == 1 and not self._inbox:
                    raise DialError(f"stream recv timeout ({self.protocol})")
            else:
                yield self._waiter
        return self._inbox.popleft()

    def _deliver(self, payload: Any) -> None:
        self._inbox.append(payload)
        if self._waiter is not None and not self._waiter.triggered:
            self._waiter.succeed()

    def close(self) -> None:
        self.closed = True

    def _do_reset(self) -> None:
        self.reset = True
        if self._waiter is not None and not self._waiter.triggered:
            self._waiter.succeed()


class Connection:
    """An established, secured, multiplexed connection between two hosts.

    Latency / bandwidth are fixed at establishment (possibly via a relay
    path).  Each direction serializes bytes at ``bandwidth``; each message
    additionally costs CPU time on both endpoints (serialization + crypto).
    """

    #: Calibrated to the paper's Table-1 testbed (4-core hosts): ~200 µs of
    #: core time per message (stream bookkeeping, protobuf, syscalls) plus
    #: ~17 ns/byte (Noise AEAD + copies ≈ 60 MB/s/core).  These two constants
    #: reproduce the CPU-bound rows of Table 1 (10k QPS @128 B, ~850 QPS
    #: @256 KB on one host); the WAN rows are bandwidth/latency-bound.
    CPU_PER_MSG = 200e-6          # fixed per-message CPU cost (seconds)
    CPU_PER_BYTE = 17e-9          # per-byte serialization+MAC cost

    def __init__(self, net: "Network", a: "Host", b: "Host",
                 latency: float, bandwidth: float, relayed: bool = False,
                 relay: Optional["Host"] = None):
        self.net = net
        self.hosts = (a, b)
        self.latency = latency
        self.bandwidth = bandwidth
        self.relayed = relayed
        self.relay = relay
        self.closed = False
        self._next_free = [0.0, 0.0]          # per-direction tx serialization
        self._stream_seq = itertools.count(1)
        self._streams: Dict[int, List[Optional[Stream]]] = {}
        a._connections.setdefault(b.name, []).append(self)
        b._connections.setdefault(a.name, []).append(self)

    # -- streams -----------------------------------------------------------
    def open_stream(self, protocol: str, opener: "Host") -> Stream:
        if self.closed:
            raise DialError("connection closed")
        side = self.hosts.index(opener)
        sid = next(self._stream_seq)
        local = Stream(self, sid, protocol, initiator=(side == 0))
        remote = Stream(self, sid, protocol, initiator=(side != 0))
        # store endpoints indexed by connection side
        pair: List[Optional[Stream]] = [None, None]
        pair[side] = local
        pair[1 - side] = remote
        self._streams[sid] = pair
        # hand the remote endpoint to the responder's protocol handler
        responder = self.hosts[1 - side]
        responder._spawn_handler(protocol, remote)
        return local

    # -- data movement -----------------------------------------------------
    def transmit(self, sender: Stream, payload: Any, size: int) -> None:
        pair = self._streams.get(sender.stream_id)
        if pair is None or self.closed:
            return
        side = pair.index(sender)
        receiver = pair[1 - side]
        src_host, dst_host = self.hosts[side], self.hosts[1 - side]
        sim = self.net.sim
        # CPU at the sender
        tx_cpu = self.CPU_PER_MSG + self.CPU_PER_BYTE * size
        cpu_done = src_host.cpu.consume(tx_cpu)

        def after_cpu(_evt: Event) -> None:
            # serialize on the wire
            start = max(sim.now, self._next_free[side])
            wire = size / self.bandwidth
            self._next_free[side] = start + wire
            arrive = start + wire + self.latency
            sim._schedule(arrive - sim.now, lambda _: at_dst(), None)

        def at_dst() -> None:
            if self.closed or receiver is None or receiver.closed:
                return
            rx_cpu = self.CPU_PER_MSG + self.CPU_PER_BYTE * size
            done = dst_host.cpu.consume(rx_cpu)
            done._add_waiter(lambda _e: receiver._deliver(payload))

        cpu_done._add_waiter(after_cpu)

    def close(self) -> None:
        self.closed = True
        for pair in self._streams.values():
            for s in pair:
                if s is not None:
                    s._do_reset()
        a, b = self.hosts
        if self in a._connections.get(b.name, []):
            a._connections[b.name].remove(self)
        if self in b._connections.get(a.name, []):
            b._connections[a.name].remove(self)


# Patch Stream.send to route via Connection.transmit with correct identity.
def _stream_send(self: Stream, payload: Any, size: int = 128) -> None:
    if self.closed or self.conn.closed:
        raise DialError("stream closed")
    self.conn.transmit(self, payload, size)


Stream.send = _stream_send  # type: ignore[method-assign]


# --------------------------------------------------------------------------
# Hosts & the network fabric
# --------------------------------------------------------------------------


class Host:
    """A machine: sockets, CPU, protocol handlers, connections."""

    _ip_seq = itertools.count(1)

    def __init__(self, net: "Network", name: str, region: str = "us",
                 zone: str = "a", nat: Optional[Any] = None, cores: int = 4,
                 machine: Optional[str] = None):
        self.net = net
        self.name = name
        self.region = region
        self.zone = zone              # same region+zone => LAN, else WAN
        self.machine = machine        # same machine => loopback path
        self.ip = f"10.0.{next(Host._ip_seq)}.1" if nat else f"203.0.{next(Host._ip_seq)}.1"
        self.nat = nat
        self.cpu = CPU(net.sim, cores)
        self._sockets: Dict[int, Socket] = {}
        self._port_seq = itertools.count(40000)
        self._handlers: Dict[str, Callable[[Stream], Generator]] = {}
        self._connections: Dict[str, List[Connection]] = {}
        net._register_host(self)
        if nat is not None:
            nat.attach(self)

    # -- addressing --------------------------------------------------------
    @property
    def public_ip(self) -> Optional[str]:
        if self.nat is None:
            return self.ip
        return None  # only reachable through the NAT's mapped ports

    def bind(self, port: Optional[int] = None) -> Socket:
        if port is None:
            port = next(self._port_seq)
        sock = Socket(self, port)
        self._sockets[port] = sock
        return sock

    # -- protocols ---------------------------------------------------------
    def handle(self, protocol: str, fn: Callable[[Stream], Generator]) -> None:
        self._handlers[protocol] = fn

    def _spawn_handler(self, protocol: str, stream: Stream) -> None:
        fn = self._handlers.get(protocol)
        if fn is None:
            stream._do_reset()
            return
        # daemon: inbound handlers are driven by the remote peer and may park
        # on recv() past the end of a scenario — not orphans.
        self.net.sim.process(fn(stream), daemon=True)

    def connection_to(self, other: "Host") -> Optional[Connection]:
        for c in self._connections.get(other.name, []):
            if not c.closed:
                return c
        return None


class Network:
    def __init__(self, sim: Sim,
                 latency: Optional[Dict[str, float]] = None,
                 bandwidth: Optional[Dict[str, float]] = None,
                 loss: Optional[Dict[str, float]] = None):
        self.sim = sim
        self.latency = dict(DEFAULT_LATENCY, **(latency or {}))
        self.bandwidth = dict(DEFAULT_BANDWIDTH, **(bandwidth or {}))
        self.loss = dict(DEFAULT_LOSS, **(loss or {}))
        self.hosts: Dict[str, Host] = {}
        self._by_ip: Dict[str, Any] = {}   # ip -> Host | NATBox
        self.nats: List[Any] = []          # every NATBox on this fabric
        self._partitions: set = set()     # frozenset({region_a, region_b})
        sim.register_leak_check("net.sockets", self._open_socket_count)
        sim.register_leak_check("net.half_open_streams",
                                self._half_open_stream_count)

    # -- simsan gauges -------------------------------------------------------
    def _open_socket_count(self) -> int:
        return sum(len(h._sockets) for h in self.hosts.values())

    def _half_open_stream_count(self) -> int:
        """Streams on live connections where exactly one endpoint closed —
        the signature of a handler or caller that forgot to close its side.
        (Both-open pairs are in-flight exchanges; both-closed are done.)"""
        n = 0
        seen: set = set()
        for h in self.hosts.values():
            for conns in h._connections.values():
                for c in conns:
                    if id(c) in seen or c.closed:
                        continue
                    seen.add(id(c))
                    for pair in c._streams.values():
                        open_ends = sum(
                            1 for s in pair
                            if s is not None and not s.closed and not s.reset)
                        if open_ends == 1:
                            n += 1
        return n

    # -- registry ----------------------------------------------------------
    def _register_host(self, host: Host) -> None:
        self.hosts[host.name] = host
        if host.nat is None:
            self._by_ip[host.ip] = host

    def register_nat(self, nat: Any) -> None:
        self._by_ip[nat.public_ip] = nat
        self.nats.append(nat)

    def nat_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-NAT-kind aggregate of every box's traversal counters."""
        from .nat import aggregate_nat_stats
        return aggregate_nat_stats(self.nats)

    def host(self, name: str, **kw: Any) -> Host:
        return Host(self, name, **kw)

    # -- partitions ----------------------------------------------------------
    def set_partition(self, region_a: str, region_b: str,
                      blocked: bool = True) -> None:
        """Cut (or heal) the path between two regions.  Cutting also tears
        down existing cross-partition connections (links die, sessions
        reset) — the failure mode CRDT anti-entropy must survive."""
        key = frozenset((region_a, region_b))
        if blocked:
            self._partitions.add(key)
            for host in list(self.hosts.values()):
                if host.region not in (region_a, region_b):
                    continue
                other_region = region_b if host.region == region_a else region_a
                for name, conns in list(host._connections.items()):
                    peer = self.hosts.get(name)
                    if peer is not None and peer.region == other_region:
                        for c in list(conns):
                            c.close()
        else:
            self._partitions.discard(key)

    def partitioned(self, a: Host, b: Host) -> bool:
        return frozenset((a.region, b.region)) in self._partitions

    # -- path properties ----------------------------------------------------
    def path(self, a: Host, b: Host) -> Tuple[float, float, float]:
        sc = scenario_for(a, b)
        return self.latency[sc], self.bandwidth[sc], self.loss[sc]

    # -- datagrams (NAT-aware) ----------------------------------------------
    def send_packet(self, src_host: Host, src_port: int,
                    dst: Tuple[str, int], payload: Any, size: int = 128) -> None:
        # outbound NAT translation
        if src_host.nat is not None:
            observed = src_host.nat.map_outbound(src_host, src_port, dst)
        else:
            observed = (src_host.ip, src_port)
        target = self._by_ip.get(dst[0])
        if target is None:
            return  # black hole
        # resolve the receiving host (possibly through its NAT filter)
        if isinstance(target, Host):
            dst_host, dst_port = target, dst[1]
        else:  # NAT box
            routed = target.filter_inbound(dst[1], observed)
            if routed is None:
                return  # dropped by NAT
            dst_host, dst_port = routed
        if self.partitioned(src_host, dst_host):
            return  # black-holed across the partition
        lat, _bw, loss = self.path(src_host, dst_host)
        if loss and self.sim.rng.random() < loss:
            return
        jitter = self.sim.rng.random() * lat * 0.05
        pkt = Packet(src=observed, dst=dst, payload=payload, size=size)

        def deliver(_: Any) -> None:
            sock = dst_host._sockets.get(dst_port)
            if sock is not None:
                sock._deliver(pkt)

        self.sim._schedule(lat + jitter + size / self.bandwidth[scenario_for(src_host, dst_host)],
                           deliver, None)

    # -- connections ---------------------------------------------------------
    def establish(self, a: Host, b: Host, relayed: bool = False,
                  relay: Optional[Host] = None) -> Connection:
        """Create a secured connection (path properties from the region model).

        Reachability must have been proven by the caller (direct dial packets
        or a completed hole punch) — this just instantiates the channel.
        """
        if relayed and relay is not None:
            lat = self.path(a, relay)[0] + self.path(relay, b)[0]
            bw = min(self.path(a, relay)[1], self.path(relay, b)[1],
                     RELAY_BANDWIDTH_CAP)
        else:
            lat, bw, _ = self.path(a, b)
        return Connection(self, a, b, lat, bw, relayed=relayed, relay=relay)


#: Circuit relays are a shared, rate-limited resource (libp2p caps relayed
#: connections hard; we model a generous but finite cap).
RELAY_BANDWIDTH_CAP = 2.0e6  # 16 Mbit/s per relayed connection
