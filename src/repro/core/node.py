"""LatticaNode: the composed stack — what the paper's SDK exposes.

identity + transport (dial/AutoNAT/relay/DCUtR) + RPC router + Kademlia DHT
+ pub/sub + CRDT replicated store + content-addressed blockstore + Bitswap.

``connect_info`` implements the paper's connection policy:
  1. reuse an existing connection;
  2. try direct dial on advertised direct addrs;
  3. fall back to a circuit relay;
  4. attempt a DCUtR hole-punch upgrade, keeping the circuit if it fails.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional, Tuple, TYPE_CHECKING

from .bitswap import Bitswap
from .blockstore import BlockStore
from .cid import CID, ChunkSpec, build_dag, build_tree_dag
from .crdt import (MST_LEAF_SIZE, ReplicatedStore, decode_delta2_request,
                   decode_delta2_response, decode_delta_request,
                   decode_mst_request, decode_mst_response, decode_summary,
                   decode_vv_map, encode_delta2_request,
                   encode_delta2_response, encode_delta_request,
                   encode_mst_request, encode_mst_response, encode_summary,
                   encode_vv_map, mst_wire_hash)
from .dht import KademliaDHT, PeerInfo
from .peer import Multiaddr, PeerId
from .pubsub import PubSub
from .rendezvous import RendezvousServer
from .rpc import RpcContext, RpcError, RpcRouter
from .service import (ByteLength, ClientInterceptor, Fixed, PEER_INFO,
                      RpcMetrics, RpcStatus, Service, ServerInterceptor,
                      ServiceError, Stub, serve_service, unary)
from .simnet import Connection, DialError, Host, Network, Sim
from .traversal import MAIN_PORT, Transport

#: How many relays a private node tries to hold reservations on (primary +
#: failover), ranked by measured RTT.
RELAY_TARGET = 2

#: A failed DCUtR upgrade is retried on the next connect after this long —
#: NAT state and address books evolve, so "relayed once" must not mean
#: "relayed forever" (libp2p retries hole punching the same way).
UPGRADE_RETRY_COOLDOWN = 30.0


class IdentityService(Service):
    """Push-pull identity exchange: each side learns the other's PeerInfo."""

    name = "id"

    def __init__(self, node: "LatticaNode"):
        self.node = node

    @unary("id.exchange", request=PEER_INFO, response=PEER_INFO,
           idempotent=True, timeout=10.0)
    def exchange(self, payload: Any, ctx: RpcContext) -> Generator:
        self.node.remember(payload)
        yield ctx.cpu(2e-6)
        return self.node.info()


class CrdtSyncService(Service):
    """v1 anti-entropy pair: digest probe, then full state exchange+merge.
    Both methods are idempotent — CRDT merge is, by definition.  Kept as
    the complete v1 surface so legacy peers are still served."""

    name = "crdt"

    def __init__(self, node: "LatticaNode"):
        self.node = node

    @unary("crdt.digest", request=Fixed(96), response=Fixed(96),
           idempotent=True, timeout=15.0)
    def digest(self, payload: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(10e-6)
        return self.node.store.digest()

    @unary("crdt.exchange", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=60.0)
    def exchange(self, payload: Any, ctx: RpcContext) -> Generator:
        incoming = ReplicatedStore.deserialize(payload)
        yield ctx.cpu(30e-6)
        self.node.store.merge(incoming)
        return self.node.store.serialize()


class CrdtSyncV2Service(CrdtSyncService):
    """v2 anti-entropy: summary exchange, then per-key delta transfer.

    ``summary`` takes the caller's per-key digest map and answers with our
    version vectors for exactly the keys that differ (or that one side is
    missing); ``delta`` then moves minimal per-key fragments both ways in a
    single RPC — the caller's fragments ride in the request, ours in the
    response.  Bytes moved are O(changed-state); the v1 methods remain
    served for peers that never learned the v2 surface."""

    @unary("crdt.summary", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=30.0)
    def summary(self, payload: Any, ctx: RpcContext) -> Generator:
        theirs = decode_summary(payload)
        yield ctx.cpu(20e-6)
        store = self.node.store
        # latlint: disable=L007 serves the flat-v2 wire surface for old peers
        mine = store.key_digests()
        diff: Dict[str, Any] = {}
        for key, dg in theirs.items():
            if mine.get(key) != dg:
                diff[key] = store.entry_vv(key)
        for key in mine:
            if key not in theirs:
                diff[key] = store.entry_vv(key)
        return encode_vv_map(diff)

    @unary("crdt.delta", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=60.0)
    def delta(self, payload: Any, ctx: RpcContext) -> Generator:
        vv_map, their_deltas = decode_delta_request(payload)
        yield ctx.cpu(30e-6)
        store = self.node.store
        if their_deltas and store.apply_delta(their_deltas):
            self.node._schedule_crdt_push()     # rumor-monger fresh state
        mine = store.delta_since(vv_map, keys=vv_map.keys())
        return ReplicatedStore.encode_delta(mine)


class CrdtSyncMstService(CrdtSyncV2Service):
    """Merkle-summarized anti-entropy: the caller walks our namespace-
    sharded summary forest (``crdt.mst``) to localize differing keys in
    O(log n) tree nodes, then runs the existing ``crdt.delta`` round on
    just those keys.  The flat v2 ``crdt.summary`` and the v1 full-state
    surface stay served, so mixed fleets negotiate downward per peer."""

    @unary("crdt.mst", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=30.0)
    def mst(self, payload: Any, ctx: RpcContext) -> Generator:
        want_roots, queries = decode_mst_request(payload)
        yield ctx.cpu(15e-6)
        store = self.node.store
        forest = store.summary_forest()
        nodes: List[Dict[str, Any]] = []
        for ns, path in queries:
            tree = forest.get(ns)
            if tree is None or not tree.keys_under(path):
                nodes.append({"ns": ns, "p": path, "t": "x"})
            elif tree.is_leaf(path):
                kd = {k: [dg, store.entry_vv(k)]
                      for k, dg in tree.leaf_digests(path).items()}
                nodes.append({"ns": ns, "p": path, "t": "l", "kd": kd})
            else:
                nodes.append({"ns": ns, "p": path, "t": "i",
                              "c": tree.children(path)})
        roots = store.summary_roots() if want_roots else None
        return encode_mst_response(nodes, roots)

    @unary("crdt.delta2", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=60.0)
    def delta2(self, payload: Any, ctx: RpcContext) -> Generator:
        """The MST walk's delta round.  Beyond ``crdt.delta`` it (a) ships
        full state for our keys under the caller's reconcile-bucket paths
        that its vv map does not name (the caller never fetched our per-key
        digests for those buckets), and (b) returns a ``want`` vv map for
        the keys where the caller's vv shows state we lack, so it can
        answer with one push-only ``crdt.delta``."""
        vv_map, their_deltas, buckets = decode_delta2_request(payload)
        yield ctx.cpu(30e-6)
        store = self.node.store
        if their_deltas and store.apply_delta(their_deltas):
            self.node._schedule_crdt_push()     # rumor-monger fresh state
        mine = store.delta_since(vv_map, keys=vv_map.keys())
        forest = store.summary_forest()
        for ns, path in buckets:
            tree = forest.get(ns)
            if tree is None:
                continue
            extra = [k for k in tree.keys_under(path) if k not in vv_map]
            if extra:
                mine.update(store.delta_since({}, keys=extra))
        want: Dict[str, Any] = {}
        for k, vv in vv_map.items():
            if vv and store.entry_vv(k) != vv:
                want[k] = store.entry_vv(k)
        return encode_delta2_response(mine, want)


def crdt_ns(key: str) -> str:
    """Namespace of a store key: its first path segment (``ckpt/f`` →
    ``ckpt``).  Delta pushes are published per-namespace on
    ``crdt/<ns>`` pubsub topics."""
    return key.split("/", 1)[0]


class LatticaNode:
    def __init__(self, net: Network, name: str, region: str = "us",
                 zone: str = "a", nat: Optional[Any] = None, cores: int = 4,
                 serve_rendezvous: bool = False,
                 machine: Optional[str] = None,
                 store_budget: Optional[int] = None,
                 crdt_proto: str = "mst",
                 crdt_push: bool = True,
                 crdt_push_window: float = 0.0):
        self.net = net
        self.sim: Sim = net.sim
        self.host: Host = net.host(name, region=region, zone=zone, nat=nat,
                                   cores=cores, machine=machine)
        self.peer_id = PeerId.from_name(name)
        self.transport = Transport(self.host, self.peer_id)
        self.router = RpcRouter(self.host)
        self.rpc_metrics = RpcMetrics()
        self._stub_cache: Dict[Any, Stub] = {}
        self.blockstore = BlockStore(capacity=store_budget)
        self.sim.register_leak_check(
            f"blockstore.holds:{name}", self.blockstore.outstanding_holds)
        self.sim.register_leak_check(
            f"blockstore.pins:{name}", self.blockstore.pinned_root_count)
        self._pinned_latest: Dict[str, CID] = {}
        self.store = ReplicatedStore(replica=name)
        self.peers: Dict[PeerId, PeerInfo] = {}
        self.infos_by_host: Dict[str, PeerInfo] = {}
        if crdt_proto not in ("v1", "v2", "mst"):
            raise ValueError(f"unknown crdt_proto {crdt_proto!r}")
        #: "mst" (default) localizes differing keys via the Merkle summary
        #: forest walk; "v2" uses the flat per-key digest summary; "v1"
        #: forces the legacy digest→full-swap protocol and serves only the
        #: v1 wire surface.  Each tier negotiates downward per peer
        #: (mst→v2→v1), so mixed-version fleets still converge.
        self.crdt_proto = crdt_proto
        #: eager convergence: local mutations publish deltas on crdt/<ns>
        #: pubsub topics so connected subscribers converge in one gossip
        #: round instead of waiting for an anti-entropy tick
        self.crdt_push = crdt_push and crdt_proto in ("v2", "mst")
        #: how long a scheduled push waits to coalesce further writes; 0.0
        #: batches only the same event instant (one-tick debounce), while a
        #: positive window lets high-churn namespaces ship one delta doc
        #: per window instead of per instant
        self.crdt_push_window = float(crdt_push_window)
        self.crdt_stats = {"rounds": 0, "delta_exchanges": 0,
                           "full_exchanges": 0, "tx_bytes": 0, "rx_bytes": 0,
                           "push_published": 0, "push_bytes": 0,
                           "push_applied": 0, "push_rejected": 0,
                           "summary_skipped": 0, "summary_bytes": 0,
                           "mst_exchanges": 0, "mst_probe_bytes": 0}
        self._crdt_peer_proto: Dict[PeerId, str] = {}
        #: per peer (our digest, our vv) snapshotted when both sides last
        #: held identical state — lets steady-state rounds skip the
        #: crdt.summary exchange entirely (see sync_crdt_with)
        self._crdt_sync_cache: Dict[PeerId, Tuple[bytes, Dict[str, Any]]] = {}
        self._push_vv: Dict[str, Any] = {}       # store.vv() at last push
        self._push_pending = False
        self._crdt_topics: set = set()
        self.identity = self.serve(IdentityService(self))
        self.crdt_sync = self.serve(
            CrdtSyncMstService(self) if crdt_proto == "mst"
            else CrdtSyncV2Service(self) if crdt_proto == "v2"
            else CrdtSyncService(self))
        if self.crdt_push:
            self.store.on_local_change(self._on_crdt_mutation)
        self.dht = KademliaDHT(self)
        self.pubsub = PubSub(self)
        self.bitswap = Bitswap(self)
        self.relay_infos: List[PeerInfo] = []          # primary first (by RTT)
        self._relay_meta: Dict[bytes, Dict[str, float]] = {}
        self._relay_candidates: List[PeerInfo] = []
        self.rendezvous: Optional[RendezvousServer] = (
            RendezvousServer(self) if serve_rendezvous else None)
        self._upgrade_attempted: Dict[PeerId, float] = {}  # peer -> last try

    # ----------------------------------------------------------- service API
    def serve(self, service: Service,
              interceptors: List[ServerInterceptor] = ()) -> Service:
        """Register every declared RPC method of ``service`` on this node."""
        return serve_service(self.router, service, interceptors=interceptors,
                             metrics=self.rpc_metrics)

    def stub(self, service_cls: type, target: Optional[PeerInfo] = None, *,
             conn: Optional[Connection] = None, scope: Optional[str] = None,
             interceptors: List[ClientInterceptor] = ()) -> Stub:
        """Typed client stub for ``service_cls`` at ``target`` (or over an
        explicit ``conn``).  Connections are acquired lazily per call via
        ``connect_info`` and reused.  Peer-targeted stubs without custom
        interceptors are cached — hot paths (DHT lookups, gossip fan-out)
        request one per RPC."""
        if conn is None and not interceptors and target is not None:
            key = (service_cls, target.peer_id, scope)
            cached = self._stub_cache.get(key)
            if cached is not None:
                cached._target = target      # refresh the PeerInfo snapshot
                return cached
            made = Stub(self, service_cls, target, scope=scope)
            self._stub_cache[key] = made
            return made
        return Stub(self, service_cls, target, conn=conn, scope=scope,
                    interceptors=interceptors)

    # ------------------------------------------------------------- identity
    @property
    def relay_info(self) -> Optional[PeerInfo]:
        """Primary (lowest-RTT) relay this node holds a reservation on."""
        return self.relay_infos[0] if self.relay_infos else None

    def info(self) -> PeerInfo:
        addrs: List[Multiaddr] = []
        if self.host.nat is None:
            addrs.append(Multiaddr(self.host.ip, MAIN_PORT))
        elif self.transport.reachability == "public":
            # e.g. full-cone NAT: our observed mapping is stranger-dialable
            for ip, port in sorted(self.transport.observed_addrs):
                addrs.append(Multiaddr(ip, port))
        for relay_info in self.relay_infos:     # primary first, then failover
            relay_ip = relay_info.addrs[0].ip
            addrs.append(Multiaddr(relay_ip, MAIN_PORT,
                                   relay_peer=relay_info.peer_id))
        return PeerInfo(self.peer_id, self.host.name, tuple(addrs))

    def remember(self, info: PeerInfo) -> None:
        if info.peer_id == self.peer_id:
            return
        old = self.peers.get(info.peer_id)
        if old is not None and not info.addrs:
            return  # don't clobber a dialable record with an empty one
        self.peers[info.peer_id] = info
        self.infos_by_host[info.host_name] = info
        self.dht.table.update(info)

    # ------------------------------------------------------------ connecting
    def connect_info(self, info: PeerInfo) -> Generator:
        """Connect to a peer, NAT-traversing as needed; returns Connection."""
        target_host = self.net.hosts.get(info.host_name)
        if target_host is not None:
            existing = self.host.connection_to(target_host)
            if existing is not None:
                if existing.relayed:
                    # a circuit is a fallback, not a fate: periodically
                    # retry the DCUtR upgrade (cooldown-limited)
                    upgraded = yield from self._maybe_upgrade(existing, info)
                    if upgraded is not None:
                        return upgraded
                return existing
        self.remember(info)
        direct = [a for a in info.addrs if not a.is_relay]
        relayed = [a for a in info.addrs if a.is_relay]
        last_err: Optional[Exception] = None
        for addr in direct:
            try:
                conn = yield from self.transport.dial_direct((addr.ip, addr.port))
                yield from self._identify(conn)
                return conn
            except DialError as e:
                last_err = e
        for addr in relayed:
            try:
                relay_host_conn = yield from self._conn_to_relay(addr)
                circuit = yield from self.transport.relay_connect(
                    relay_host_conn, info.peer_id)
                yield from self._identify(circuit)
                upgraded = yield from self._maybe_upgrade(circuit, info)
                return upgraded or circuit
            except DialError as e:
                last_err = e
        raise DialError(f"cannot connect to {info.peer_id}: {last_err}")

    def _conn_to_relay(self, addr: Multiaddr) -> Generator:
        relay_host = self.net._by_ip.get(addr.ip)
        if relay_host is not None:
            existing = self.host.connection_to(relay_host)
            if existing is not None and not existing.relayed:
                return existing
        conn = yield from self.transport.dial_direct((addr.ip, addr.port))
        return conn

    def _maybe_upgrade(self, circuit: Connection,
                       info: PeerInfo) -> Generator:
        """One DCUtR attempt per peer per cooldown window; returns a direct
        Connection or None (keep the circuit)."""
        last = self._upgrade_attempted.get(info.peer_id)
        if last is not None and self.sim.now - last < UPGRADE_RETRY_COOLDOWN:
            return None
        self._upgrade_attempted[info.peer_id] = self.sim.now
        direct = yield from self.transport.dcutr_upgrade(circuit)
        if direct is not None:
            circuit.close()
            return direct
        return None

    def _identify(self, conn: Connection) -> Generator:
        try:
            stub = self.stub(IdentityService, conn=conn)
            their = yield from stub.exchange(self.info())
            self.remember(their)
        except (RpcError, DialError):
            pass
        return None

    def connect_peer(self, peer_id: PeerId) -> Generator:
        info = self.peers.get(peer_id)
        if info is None:
            # resolve through the DHT
            closest = yield from self.dht.find_node(peer_id.digest)
            info = self.peers.get(peer_id)
            if info is None:
                for c in closest:
                    if c.peer_id == peer_id:
                        info = c
                        break
        if info is None:
            raise DialError(f"unknown peer {peer_id}")
        conn = yield from self.connect_info(info)
        return conn

    # ------------------------------------------------------------- bootstrap
    def bootstrap(self, bootstrap_infos: List[PeerInfo],
                  relay: Optional[PeerInfo] = None) -> Generator:
        """Join the mesh: dial bootstrappers, learn reachability, reserve a
        relay if private, then populate the DHT routing table."""
        conns = []
        probed = False
        for info in bootstrap_infos:
            try:
                conn = yield from self.connect_info(info)
                conns.append(conn)
            except DialError:
                continue
            if not probed:
                # AutoNAT immediately after the FIRST contact: the dial-back
                # is forwarded to a public peer we have never contacted, so
                # cone-NAT filters can't be satisfied by our own traffic.
                yield from self.transport.autonat_probe(conn)
                probed = True
        if not conns:
            raise DialError("all bootstrap nodes unreachable")
        self._relay_candidates = list(bootstrap_infos)
        if relay is not None and all(c.peer_id != relay.peer_id
                                     for c in self._relay_candidates):
            self._relay_candidates.append(relay)
        if self.transport.reachability != "public":
            candidates = [relay] if relay is not None else bootstrap_infos
            got = yield from self.acquire_relays(candidates)
            if not got and relay is not None:
                yield from self.acquire_relays(bootstrap_infos)
        yield from self.dht.bootstrap_lookup()
        for pid in list(self.peers):
            yield from self.pubsub.announce_subscriptions(pid)
        return self.transport.reachability

    # ---------------------------------------------------------------- relays
    def acquire_relays(self, candidates: List[PeerInfo],
                       want: int = RELAY_TARGET) -> Generator:
        """Score candidate relays by RTT and hold reservations on the best
        ``want`` of them (primary + failover).  Returns reservations held."""
        held = {i.peer_id for i in self.relay_infos}
        scored = []
        for info in candidates:
            if info.peer_id == self.peer_id or info.peer_id in held:
                continue
            try:
                conn = yield from self.connect_info(info)
                if conn.relayed:
                    continue        # a relay must be directly reachable
                rtt = yield from self.transport.ping(conn)
            except (DialError, RpcError):
                continue
            scored.append((rtt, info, conn))
        scored.sort(key=lambda s: s[0])
        for rtt, info, conn in scored:
            if len(self.relay_infos) >= want:
                break
            try:
                ok, ttl = yield from self.transport.relay_reserve(conn)
            except DialError:
                continue
            if ok:
                self._note_relay(info, ttl, rtt)
        return len(self.relay_infos)

    def reserve_relay(self, relay_info: PeerInfo) -> Generator:
        """Reserve (or refresh) a slot on one specific relay."""
        conn = yield from self.connect_info(relay_info)
        ok, ttl = yield from self.transport.relay_reserve(conn)
        if ok:
            self._note_relay(relay_info, ttl)
        return ok

    def _note_relay(self, info: PeerInfo, ttl: float,
                    rtt: Optional[float] = None) -> None:
        digest = info.peer_id.digest
        if all(i.peer_id != info.peer_id for i in self.relay_infos):
            self.relay_infos.append(info)
        meta = self._relay_meta.setdefault(digest, {})
        meta["expires_at"] = self.sim.now + ttl
        if rtt is not None:
            meta["rtt"] = rtt
        self.relay_infos.sort(
            key=lambda i: self._relay_meta.get(i.peer_id.digest, {})
                              .get("rtt", float("inf")))

    def _drop_relay(self, info: PeerInfo) -> None:
        self.relay_infos = [i for i in self.relay_infos
                            if i.peer_id != info.peer_id]
        self._relay_meta.pop(info.peer_id.digest, None)

    # ------------------------------------------------------------------ CRDT
    def sync_crdt_with(self, info: PeerInfo) -> Generator:
        """One anti-entropy round with one peer; returns True if state moved.

        mst (default): digest probe → Merkle summary-forest walk localizes
        differing keys in O(log n) tree nodes → per-key delta transfer.
        v2: digest probe → flat per-key digest summary → delta transfer.
        Peers that do not serve a tier's methods (``NOT_FOUND``) are
        remembered and get the next tier down (mst→v2→v1); a v1-configured
        node always speaks v1."""
        stats = self.crdt_stats
        stub = self.stub(CrdtSyncMstService, info)
        theirs = yield from stub.digest()
        stats["rounds"] += 1
        if theirs == self.store.digest():
            # identical state: snapshot (digest, vv) atomically so the next
            # divergent round can prove "peer == our old self" and skip the
            # summary exchange
            self._crdt_sync_cache[info.peer_id] = (theirs, self.store.vv())
            return False
        peer_proto = self._crdt_peer_proto.get(info.peer_id)
        if self.crdt_proto in ("v2", "mst") and peer_proto != "v1":
            cached = self._crdt_sync_cache.get(info.peer_id)
            if cached is not None and cached[0] == theirs:
                # the peer still holds exactly the state both sides shared
                # after the last round (content digests match), so what it
                # lacks is precisely delta_since(our vv back then): push it
                # without the crdt.summary round trip
                moved = yield from self._sync_crdt_skip(stub, info, cached[1])
                return moved
            if self.crdt_proto == "mst" and peer_proto != "v2":
                try:
                    moved = yield from self._sync_crdt_mst(stub)
                    stats["delta_exchanges"] += 1
                    stats["mst_exchanges"] += 1
                    self._crdt_sync_cache[info.peer_id] = (
                        self.store.digest(), self.store.vv())
                    return moved
                except ServiceError as e:
                    if e.status is not RpcStatus.NOT_FOUND:
                        raise
                    # peer predates the MST surface; remember and use flat v2
                    self._crdt_peer_proto[info.peer_id] = "v2"
            try:
                moved = yield from self._sync_crdt_v2(stub)
                stats["delta_exchanges"] += 1
                self._crdt_sync_cache[info.peer_id] = (
                    self.store.digest(), self.store.vv())
                return moved
            except ServiceError as e:
                if e.status is not RpcStatus.NOT_FOUND:
                    raise
                # peer only serves the v1 surface; remember and fall back
                self._crdt_peer_proto[info.peer_id] = "v1"
        stats["full_exchanges"] += 1
        mine = self.store.serialize()
        resp = yield from stub.exchange(mine)
        stats["tx_bytes"] += len(mine)
        stats["rx_bytes"] += len(resp)
        if self.store.merge(ReplicatedStore.deserialize(resp)):
            # rumor-monger state learned via anti-entropy: a peer the flood
            # could not reach re-publishes once it catches up, so the last
            # stragglers converge epidemically instead of pairwise-randomly
            self._schedule_crdt_push()
        return True

    def _sync_crdt_mst(self, stub: Stub) -> Generator:
        """Merkle walk + delta round of the mst protocol (digest already
        differed).  Round 0 fetches the peer's per-namespace roots; each
        following round batch-queries the differing subtrees one level
        deeper.  A differing subtree that is bucket-sized on *our* side
        stops descending there: its keys are reconciled through the
        ``crdt.delta2`` round's vv exchange (the responder ships its
        unnamed keys under the bucket path, and its ``want`` map pulls our
        surplus) — the probe never fetches per-key digest docs for buckets
        both sides hold.  Returns True if any state moved either way."""
        stats = self.crdt_stats
        store = self.store

        def track(req: bytes, resp: bytes) -> None:
            stats["tx_bytes"] += len(req)
            stats["rx_bytes"] += len(resp)
            stats["mst_probe_bytes"] += len(req) + len(resp)

        req = encode_mst_request([], want_roots=True)
        resp = yield from stub.mst(req)
        track(req, resp)
        their_roots, _ = decode_mst_response(resp)
        their_roots = their_roots or {}
        forest = store.summary_forest()
        my_roots = {ns: mst_wire_hash(t.root()) for ns, t in forest.items()}

        want_vv: Dict[str, Any] = {}    # remote-differing key -> their vv
        local_only: set = set()         # our keys the peer lacks entirely
        buckets: List[Tuple[str, str]] = []     # differing shared buckets
        frontier: List[Tuple[str, str]] = []
        for ns in sorted(set(my_roots) | set(their_roots)):
            if my_roots.get(ns) == their_roots.get(ns):
                continue
            if ns not in their_roots:
                local_only.update(forest[ns].keys_under(""))
            else:
                frontier.append((ns, ""))
        rounds = 0
        while frontier and rounds < 64:
            rounds += 1
            batch, frontier = frontier[:512], frontier[512:]
            req = encode_mst_request(batch)
            resp = yield from stub.mst(req)
            track(req, resp)
            _, docs = decode_mst_response(resp)
            for nd in docs:
                ns, path, t = nd["ns"], nd["p"], nd["t"]
                tree = forest.get(ns)
                local_keys = tree.keys_under(path) if tree is not None else []
                if t == "x":
                    # peer has nothing under this subtree
                    local_only.update(local_keys)
                elif t == "i":
                    their_children = nd["c"]        # wire-width hashes
                    mine_children = (
                        {nib: mst_wire_hash(h)
                         for nib, h in tree.children(path).items()}
                        if local_keys else {})
                    for nib in sorted(set(their_children) | set(mine_children)):
                        th = their_children.get(nib)
                        if th == mine_children.get(nib):
                            continue
                        if th is None:
                            local_only.update(tree.keys_under(path + nib))
                            continue
                        sub = path + nib
                        n_sub = (len(tree.keys_under(sub))
                                 if tree is not None else 0)
                        if 0 < n_sub <= MST_LEAF_SIZE:
                            buckets.append((ns, sub))
                        else:
                            frontier.append((ns, sub))
                else:   # leaf doc: our side was empty (or outsized) here
                    their_kd = nd["kd"]
                    mine_kd = (tree.leaf_digests(path)
                               if tree is not None else {})
                    for k, pair in their_kd.items():
                        if mine_kd.get(k) != pair[0]:
                            want_vv[k] = pair[1]
                    for k in local_keys:
                        if k not in their_kd:
                            local_only.add(k)
        diff: Dict[str, Any] = dict(want_vv)
        for k in local_only:
            diff.setdefault(k, None)    # peer knows nothing of these
        if not diff and not buckets:
            return False
        push = store.delta_since(diff, keys=diff.keys())
        my_vv = {k: store.entry_vv(k) for k in diff}
        for ns, path in buckets:
            for k in forest[ns].keys_under(path):
                my_vv[k] = store.entry_vv(k)
        req = encode_delta2_request(my_vv, push, buckets)
        dresp = yield from stub.delta2(req)
        stats["tx_bytes"] += len(req)
        stats["rx_bytes"] += len(dresp)
        their_deltas, want = decode_delta2_response(dresp)
        changed = store.apply_delta(their_deltas) if their_deltas else []
        push2 = store.delta_since(want, keys=want.keys()) if want else {}
        if push2:
            req2 = encode_delta_request({}, push2)
            dresp2 = yield from stub.delta(req2)
            stats["tx_bytes"] += len(req2)
            stats["rx_bytes"] += len(dresp2)
        if changed:
            self._schedule_crdt_push()      # rumor-monger what we learned
        return bool(changed) or bool(push) or bool(push2)

    def _sync_crdt_v2(self, stub: Stub) -> Generator:
        """Summary + delta rounds of the v2 protocol (digest already
        differed).  Returns True if any state moved in either direction."""
        stats = self.crdt_stats
        # latlint: disable=L007 negotiated flat-v2 fallback for pre-MST peers
        summary = encode_summary(self.store.key_digests())
        resp = yield from stub.summary(summary)
        stats["tx_bytes"] += len(summary)
        stats["rx_bytes"] += len(resp)
        stats["summary_bytes"] += len(summary) + len(resp)
        diff = decode_vv_map(resp)
        if not diff:
            return False
        # their vv per differing key -> what we have that they lack; our vv
        # rides along so the response carries what they have that we lack
        push = self.store.delta_since(diff, keys=diff.keys())
        my_vv = {k: self.store.entry_vv(k) for k in diff}
        req = encode_delta_request(my_vv, push)
        dresp = yield from stub.delta(req)
        stats["tx_bytes"] += len(req)
        stats["rx_bytes"] += len(dresp)
        their_deltas = ReplicatedStore.decode_delta(dresp)
        changed = self.store.apply_delta(their_deltas) if their_deltas else []
        if changed:
            self._schedule_crdt_push()      # rumor-monger what we learned
        return bool(changed) or bool(push)

    def _sync_crdt_skip(self, stub: Stub, info: PeerInfo,
                        since_vv: Dict[str, Any]) -> Generator:
        """Steady-state fast path: the peer's digest equals our snapshot
        from the last converged round, so it is missing exactly
        ``delta_since(since_vv)`` and has nothing we lack — one push-only
        ``crdt.delta``, no summary."""
        stats = self.crdt_stats
        push = self.store.delta_since(since_vv)
        # atomic (digest, vv) of the state the peer will hold post-merge;
        # verified by digest equality before the next skip, so a concurrent
        # local mutation mid-RPC only costs a fallback to the summary path
        snap = (self.store.digest(), self.store.vv())
        req = encode_delta_request({}, push)
        dresp = yield from stub.delta(req)
        stats["summary_skipped"] += 1
        stats["delta_exchanges"] += 1
        stats["tx_bytes"] += len(req)
        stats["rx_bytes"] += len(dresp)
        their_deltas = ReplicatedStore.decode_delta(dresp)
        changed = self.store.apply_delta(their_deltas) if their_deltas else []
        self._crdt_sync_cache[info.peer_id] = snap
        return bool(changed) or bool(push)

    # ------------------------------------------------------- CRDT delta push
    def watch_crdt(self, prefix: str, callback: Any) -> int:
        """Watch store keys under ``prefix`` *and* join the namespace's
        delta-push topic: ``callback(key, value, origin)`` fires on local
        mutations, merged-in anti-entropy state, and pushed deltas arriving
        via pubsub — i.e. one gossip round after a remote write, no
        anti-entropy tick required.  Returns the store watch handle.

        ``prefix`` must name a full namespace (its first path segment is
        the ``crdt/<ns>`` topic pushes are published on); an empty prefix
        would silently subscribe to a topic nothing publishes — watch
        everything with ``store.watch("")`` plus ``join_crdt_push`` per
        namespace instead."""
        if not prefix:
            raise ValueError(
                "watch_crdt needs a namespaced prefix; use store.watch('') "
                "+ join_crdt_push(ns) to watch everything")
        self.join_crdt_push(crdt_ns(prefix))
        return self.store.watch(prefix, callback)

    def join_crdt_push(self, ns: str) -> None:
        """Subscribe to ``crdt/<ns>`` delta pushes (idempotent)."""
        topic = f"crdt/{ns}"
        if topic in self._crdt_topics:
            return
        self._crdt_topics.add(topic)
        self.pubsub.subscribe(topic, self._on_crdt_push_msg)

    def _on_crdt_push_msg(self, topic: str, data: Any, frm: PeerId) -> None:
        try:
            deltas = ReplicatedStore.decode_delta(data)
            # local state on these keys not yet flushed, captured before
            # the merge — those keys must stay behind the push baseline
            pending = self.store.delta_since(self._push_vv,
                                             keys=deltas.keys())
            changed = self.store.apply_delta(deltas)
        except (ValueError, TypeError):
            self.crdt_stats["push_rejected"] += 1
            return
        if changed:
            self.crdt_stats["push_applied"] += 1
            # the push plane itself just carried this state to every mesh
            # subscriber; advancing the baseline keeps the next flush from
            # re-broadcasting the whole namespace (repair of missed pushes
            # is IHAVE/IWANT's and anti-entropy's job, not re-publish)
            for k in deltas:
                if k not in pending:
                    self._push_vv[k] = self.store.entry_vv(k)

    def _on_crdt_mutation(self, key: str) -> None:
        """Store local-mutation hook: debounce-schedule one push process so
        a burst of same-instant writes ships as a single delta batch."""
        self._schedule_crdt_push()

    def _schedule_crdt_push(self) -> None:
        if not self.crdt_push or self._push_pending:
            return
        self._push_pending = True
        self.sim.process(self._crdt_push_once())

    def _crdt_push_once(self) -> Generator:
        # window 0.0 batches just the current event instant (the mutating
        # call finishes its write batch); a positive window additionally
        # coalesces every write landing inside it into one delta doc per
        # namespace — high-churn fleets trade one window of push latency
        # for O(window) fewer published docs
        yield self.crdt_push_window
        self._push_pending = False
        yield from self.crdt_push_flush()
        return None

    def crdt_push_flush(self) -> Generator:
        """Publish per-namespace delta documents for everything mutated
        since the last push on the ``crdt/<ns>`` topics; connected
        subscribers converge in one gossip round.  Returns the number of
        topics published (0 when clean or push is disabled)."""
        if not self.crdt_push:
            return 0
        deltas = self.store.delta_since(self._push_vv)
        if not deltas:
            return 0
        self._push_vv = self.store.vv()
        by_ns: Dict[str, Dict[str, Any]] = {}
        for k, frag in deltas.items():
            by_ns.setdefault(crdt_ns(k), {})[k] = frag
        for ns in sorted(by_ns):
            blob = ReplicatedStore.encode_delta(by_ns[ns])
            self.crdt_stats["push_published"] += 1
            self.crdt_stats["push_bytes"] += len(blob)
            yield from self.pubsub.publish(f"crdt/{ns}", blob,
                                           size=max(len(blob), 64))
        return len(by_ns)

    def maintenance_loop(self, interval: float = 10.0) -> Generator:
        """Background upkeep of relay reservations.  Reservations are TTL'd
        on the relay side, so a private peer must (a) refresh each held slot
        before it expires, (b) re-establish reservations whose relay
        connection died (link flap, partition), and (c) replace relays that
        stop accepting it, topping back up to ``RELAY_TARGET`` from the
        candidate set — otherwise it silently loses inbound reachability.
        libp2p's reservation refresh works the same way."""
        while True:
            yield interval
            if self.host.nat is None:
                continue            # truly public hosts have static addrs
            # NAT keepalive: re-confirm our external mapping (STUN-style)
            # through the primary relay — or, for nodes that hold none
            # (e.g. dialable full-cone NATs, whose observed mapping IS
            # their advertised address), through a bootstrap server.
            anchors = self.relay_infos or self._relay_candidates
            if anchors:
                addr = anchors[0].addrs[0]
                try:
                    yield from self.transport.refresh_observed(
                        (addr.ip, MAIN_PORT))
                except DialError:
                    pass
            if self.transport.reachability == "public":
                continue
            for info in list(self.relay_infos):
                meta = self._relay_meta.get(info.peer_id.digest, {})
                relay_host = self.net.hosts.get(info.host_name)
                conn = (self.host.connection_to(relay_host)
                        if relay_host is not None else None)
                expiring = (self.sim.now + 2 * interval
                            >= meta.get("expires_at", 0.0))
                if conn is not None and not conn.closed and not expiring:
                    continue
                try:
                    ok = yield from self.reserve_relay(info)
                except (DialError, RpcError):
                    ok = False
                if not ok:
                    self._drop_relay(info)
            if len(self.relay_infos) < RELAY_TARGET and self._relay_candidates:
                try:
                    yield from self.acquire_relays(self._relay_candidates)
                except (DialError, RpcError):
                    pass

    def anti_entropy_loop(self, interval: float = 5.0) -> Generator:
        """Background gossip: periodically reconcile with a random peer."""
        while True:
            yield interval * (0.5 + self.sim.rng.random())
            if not self.peers:
                continue
            pid = self.sim.rng.choice(sorted(self.peers, key=lambda p: p.digest))
            info = self.peers[pid]
            try:
                yield from self.sync_crdt_with(info)
            except (DialError, RpcError, ValueError):
                # ValueError: peer sent undecodable/forbidden CRDT state —
                # skip the round, don't kill the background loop
                continue

    # ------------------------------------------------------------- artifacts
    def pin_latest(self, tag: str, root: CID) -> None:
        """Pin ``root`` as the latest version of lineage ``tag`` (a fleet,
        an artifact family) and unpin the previous holder — older versions
        become evictable under the blockstore budget while the newest one
        survives any churn."""
        prev = self._pinned_latest.get(tag)
        if prev == root:
            return
        self.blockstore.pin(root)
        if prev is not None:
            self.blockstore.unpin(prev)
        self._pinned_latest[tag] = root

    def unpin_latest(self, tag: str) -> None:
        """Release lineage ``tag`` entirely (a retired replica, a dropped
        artifact family): its current root becomes evictable.  No-op when
        the tag holds nothing."""
        root = self._pinned_latest.pop(tag, None)
        if root is not None:
            self.blockstore.unpin(root)

    def publish_artifact(self, data: bytes, meta: bytes = b"",
                         announce_topic: Optional[str] = None,
                         pin: bool = True,
                         spec: Optional[ChunkSpec] = None) -> Generator:
        """Chunk + store + provide a flat (v1) artifact; returns the root
        CID.  Raw byte blobs keep the flat manifest — the hierarchical path
        is :meth:`publish_tree_artifact`.  ``spec`` selects the chunking
        strategy (fixed-size by default; ``ChunkSpec.cdc`` keeps boundaries
        stable under byte-shifting edits)."""
        dag = build_dag(data, meta=meta, spec=spec)
        yield from self.bitswap.publish_dag(dag.blocks, dag.root)
        if pin:
            self.blockstore.pin(dag.root)
        if announce_topic is not None:
            yield from self.pubsub.publish(
                announce_topic, ("artifact", dag.root, len(data), meta), size=192)
        return dag.root

    def publish_tree_artifact(self, parts: List[Any], meta: bytes = b"",
                              announce_topic: Optional[str] = None,
                              pin: bool = True,
                              spec: Optional[ChunkSpec] = None) -> Generator:
        """Publish ``[(name, data, part_meta), ...]`` as a hierarchical (v2)
        DAG — one sub-DAG per part, so parts unchanged since an earlier
        version reuse their sub-root CIDs (and cost fetchers zero bytes).
        With a ``cdc`` ``spec``, *within-part* byte shifts also dedup: leaf
        boundaries re-synchronize after an edit instead of cascading.
        Returns the root CID."""
        dag = build_tree_dag(parts, meta=meta, spec=spec)
        yield from self.bitswap.publish_dag(dag.blocks, dag.root)
        if pin:
            self.blockstore.pin(dag.root)
        if announce_topic is not None:
            yield from self.pubsub.publish(
                announce_topic,
                ("artifact", dag.root, dag.total_size, meta), size=192)
        return dag.root

    def fetch_artifact(self, root: CID,
                       hint_providers: Optional[List[PeerInfo]] = None,
                       reprovide: bool = True,
                       assemble: bool = True) -> Generator:
        """Swarm-fetch a DAG of either manifest version.  With
        ``assemble=False`` the blocks land in the local store and ``None``
        is returned (structure-aware callers reassemble per entry)."""
        data = yield from self.bitswap.fetch_dag(root, hint_providers,
                                                 assemble=assemble)
        if reprovide:
            yield from self.dht.provide(root.key)
        return data
