"""LatticaNode: the composed stack — what the paper's SDK exposes.

identity + transport (dial/AutoNAT/relay/DCUtR) + RPC router + Kademlia DHT
+ pub/sub + CRDT replicated store + content-addressed blockstore + Bitswap.

``connect_info`` implements the paper's connection policy:
  1. reuse an existing connection;
  2. try direct dial on advertised direct addrs;
  3. fall back to a circuit relay;
  4. attempt a DCUtR hole-punch upgrade, keeping the circuit if it fails.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional, Tuple, TYPE_CHECKING

from .bitswap import Bitswap
from .blockstore import BlockStore
from .cid import CID, ChunkSpec, build_dag, build_tree_dag
from .crdt import (ReplicatedStore, decode_delta_request, decode_summary,
                   decode_vv_map, encode_delta_request, encode_summary,
                   encode_vv_map)
from .dht import KademliaDHT, PeerInfo
from .peer import Multiaddr, PeerId
from .pubsub import PubSub
from .rendezvous import RendezvousServer
from .rpc import RpcContext, RpcError, RpcRouter
from .service import (ByteLength, ClientInterceptor, Fixed, PEER_INFO,
                      RpcMetrics, RpcStatus, Service, ServerInterceptor,
                      ServiceError, Stub, serve_service, unary)
from .simnet import Connection, DialError, Host, Network, Sim
from .traversal import MAIN_PORT, Transport

#: How many relays a private node tries to hold reservations on (primary +
#: failover), ranked by measured RTT.
RELAY_TARGET = 2

#: A failed DCUtR upgrade is retried on the next connect after this long —
#: NAT state and address books evolve, so "relayed once" must not mean
#: "relayed forever" (libp2p retries hole punching the same way).
UPGRADE_RETRY_COOLDOWN = 30.0


class IdentityService(Service):
    """Push-pull identity exchange: each side learns the other's PeerInfo."""

    name = "id"

    def __init__(self, node: "LatticaNode"):
        self.node = node

    @unary("id.exchange", request=PEER_INFO, response=PEER_INFO,
           idempotent=True, timeout=10.0)
    def exchange(self, payload: Any, ctx: RpcContext) -> Generator:
        self.node.remember(payload)
        yield ctx.cpu(2e-6)
        return self.node.info()


class CrdtSyncService(Service):
    """v1 anti-entropy pair: digest probe, then full state exchange+merge.
    Both methods are idempotent — CRDT merge is, by definition.  Kept as
    the complete v1 surface so legacy peers are still served."""

    name = "crdt"

    def __init__(self, node: "LatticaNode"):
        self.node = node

    @unary("crdt.digest", request=Fixed(96), response=Fixed(96),
           idempotent=True, timeout=15.0)
    def digest(self, payload: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(10e-6)
        return self.node.store.digest()

    @unary("crdt.exchange", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=60.0)
    def exchange(self, payload: Any, ctx: RpcContext) -> Generator:
        incoming = ReplicatedStore.deserialize(payload)
        yield ctx.cpu(30e-6)
        self.node.store.merge(incoming)
        return self.node.store.serialize()


class CrdtSyncV2Service(CrdtSyncService):
    """v2 anti-entropy: summary exchange, then per-key delta transfer.

    ``summary`` takes the caller's per-key digest map and answers with our
    version vectors for exactly the keys that differ (or that one side is
    missing); ``delta`` then moves minimal per-key fragments both ways in a
    single RPC — the caller's fragments ride in the request, ours in the
    response.  Bytes moved are O(changed-state); the v1 methods remain
    served for peers that never learned the v2 surface."""

    @unary("crdt.summary", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=30.0)
    def summary(self, payload: Any, ctx: RpcContext) -> Generator:
        theirs = decode_summary(payload)
        yield ctx.cpu(20e-6)
        store = self.node.store
        mine = store.key_digests()
        diff: Dict[str, Any] = {}
        for key, dg in theirs.items():
            if mine.get(key) != dg:
                diff[key] = store.entry_vv(key)
        for key in mine:
            if key not in theirs:
                diff[key] = store.entry_vv(key)
        return encode_vv_map(diff)

    @unary("crdt.delta", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=60.0)
    def delta(self, payload: Any, ctx: RpcContext) -> Generator:
        vv_map, their_deltas = decode_delta_request(payload)
        yield ctx.cpu(30e-6)
        store = self.node.store
        if their_deltas and store.apply_delta(their_deltas):
            self.node._schedule_crdt_push()     # rumor-monger fresh state
        mine = store.delta_since(vv_map, keys=vv_map.keys())
        return ReplicatedStore.encode_delta(mine)


def crdt_ns(key: str) -> str:
    """Namespace of a store key: its first path segment (``ckpt/f`` →
    ``ckpt``).  Delta pushes are published per-namespace on
    ``crdt/<ns>`` pubsub topics."""
    return key.split("/", 1)[0]


class LatticaNode:
    def __init__(self, net: Network, name: str, region: str = "us",
                 zone: str = "a", nat: Optional[Any] = None, cores: int = 4,
                 serve_rendezvous: bool = False,
                 machine: Optional[str] = None,
                 store_budget: Optional[int] = None,
                 crdt_proto: str = "v2",
                 crdt_push: bool = True):
        self.net = net
        self.sim: Sim = net.sim
        self.host: Host = net.host(name, region=region, zone=zone, nat=nat,
                                   cores=cores, machine=machine)
        self.peer_id = PeerId.from_name(name)
        self.transport = Transport(self.host, self.peer_id)
        self.router = RpcRouter(self.host)
        self.rpc_metrics = RpcMetrics()
        self._stub_cache: Dict[Any, Stub] = {}
        self.blockstore = BlockStore(capacity=store_budget)
        self.sim.register_leak_check(
            f"blockstore.holds:{name}", self.blockstore.outstanding_holds)
        self.sim.register_leak_check(
            f"blockstore.pins:{name}", self.blockstore.pinned_root_count)
        self._pinned_latest: Dict[str, CID] = {}
        self.store = ReplicatedStore(replica=name)
        self.peers: Dict[PeerId, PeerInfo] = {}
        self.infos_by_host: Dict[str, PeerInfo] = {}
        if crdt_proto not in ("v1", "v2"):
            raise ValueError(f"unknown crdt_proto {crdt_proto!r}")
        #: "v2" syncs via summary + per-key deltas (falling back per peer);
        #: "v1" forces the legacy digest→full-swap protocol and serves only
        #: the v1 wire surface (used to exercise mixed-version fleets)
        self.crdt_proto = crdt_proto
        #: eager convergence: local mutations publish deltas on crdt/<ns>
        #: pubsub topics so connected subscribers converge in one gossip
        #: round instead of waiting for an anti-entropy tick
        self.crdt_push = crdt_push and crdt_proto == "v2"
        self.crdt_stats = {"rounds": 0, "delta_exchanges": 0,
                           "full_exchanges": 0, "tx_bytes": 0, "rx_bytes": 0,
                           "push_published": 0, "push_bytes": 0,
                           "push_applied": 0, "push_rejected": 0,
                           "summary_skipped": 0}
        self._crdt_peer_proto: Dict[PeerId, str] = {}
        #: per peer (our digest, our vv) snapshotted when both sides last
        #: held identical state — lets steady-state rounds skip the
        #: crdt.summary exchange entirely (see sync_crdt_with)
        self._crdt_sync_cache: Dict[PeerId, Tuple[bytes, Dict[str, Any]]] = {}
        self._push_vv: Dict[str, Any] = {}       # store.vv() at last push
        self._push_pending = False
        self._crdt_topics: set = set()
        self.identity = self.serve(IdentityService(self))
        self.crdt_sync = self.serve(
            CrdtSyncV2Service(self) if crdt_proto == "v2"
            else CrdtSyncService(self))
        if self.crdt_push:
            self.store.on_local_change(self._on_crdt_mutation)
        self.dht = KademliaDHT(self)
        self.pubsub = PubSub(self)
        self.bitswap = Bitswap(self)
        self.relay_infos: List[PeerInfo] = []          # primary first (by RTT)
        self._relay_meta: Dict[bytes, Dict[str, float]] = {}
        self._relay_candidates: List[PeerInfo] = []
        self.rendezvous: Optional[RendezvousServer] = (
            RendezvousServer(self) if serve_rendezvous else None)
        self._upgrade_attempted: Dict[PeerId, float] = {}  # peer -> last try

    # ----------------------------------------------------------- service API
    def serve(self, service: Service,
              interceptors: List[ServerInterceptor] = ()) -> Service:
        """Register every declared RPC method of ``service`` on this node."""
        return serve_service(self.router, service, interceptors=interceptors,
                             metrics=self.rpc_metrics)

    def stub(self, service_cls: type, target: Optional[PeerInfo] = None, *,
             conn: Optional[Connection] = None, scope: Optional[str] = None,
             interceptors: List[ClientInterceptor] = ()) -> Stub:
        """Typed client stub for ``service_cls`` at ``target`` (or over an
        explicit ``conn``).  Connections are acquired lazily per call via
        ``connect_info`` and reused.  Peer-targeted stubs without custom
        interceptors are cached — hot paths (DHT lookups, gossip fan-out)
        request one per RPC."""
        if conn is None and not interceptors and target is not None:
            key = (service_cls, target.peer_id, scope)
            cached = self._stub_cache.get(key)
            if cached is not None:
                cached._target = target      # refresh the PeerInfo snapshot
                return cached
            made = Stub(self, service_cls, target, scope=scope)
            self._stub_cache[key] = made
            return made
        return Stub(self, service_cls, target, conn=conn, scope=scope,
                    interceptors=interceptors)

    # ------------------------------------------------------------- identity
    @property
    def relay_info(self) -> Optional[PeerInfo]:
        """Primary (lowest-RTT) relay this node holds a reservation on."""
        return self.relay_infos[0] if self.relay_infos else None

    def info(self) -> PeerInfo:
        addrs: List[Multiaddr] = []
        if self.host.nat is None:
            addrs.append(Multiaddr(self.host.ip, MAIN_PORT))
        elif self.transport.reachability == "public":
            # e.g. full-cone NAT: our observed mapping is stranger-dialable
            for ip, port in sorted(self.transport.observed_addrs):
                addrs.append(Multiaddr(ip, port))
        for relay_info in self.relay_infos:     # primary first, then failover
            relay_ip = relay_info.addrs[0].ip
            addrs.append(Multiaddr(relay_ip, MAIN_PORT,
                                   relay_peer=relay_info.peer_id))
        return PeerInfo(self.peer_id, self.host.name, tuple(addrs))

    def remember(self, info: PeerInfo) -> None:
        if info.peer_id == self.peer_id:
            return
        old = self.peers.get(info.peer_id)
        if old is not None and not info.addrs:
            return  # don't clobber a dialable record with an empty one
        self.peers[info.peer_id] = info
        self.infos_by_host[info.host_name] = info
        self.dht.table.update(info)

    # ------------------------------------------------------------ connecting
    def connect_info(self, info: PeerInfo) -> Generator:
        """Connect to a peer, NAT-traversing as needed; returns Connection."""
        target_host = self.net.hosts.get(info.host_name)
        if target_host is not None:
            existing = self.host.connection_to(target_host)
            if existing is not None:
                if existing.relayed:
                    # a circuit is a fallback, not a fate: periodically
                    # retry the DCUtR upgrade (cooldown-limited)
                    upgraded = yield from self._maybe_upgrade(existing, info)
                    if upgraded is not None:
                        return upgraded
                return existing
        self.remember(info)
        direct = [a for a in info.addrs if not a.is_relay]
        relayed = [a for a in info.addrs if a.is_relay]
        last_err: Optional[Exception] = None
        for addr in direct:
            try:
                conn = yield from self.transport.dial_direct((addr.ip, addr.port))
                yield from self._identify(conn)
                return conn
            except DialError as e:
                last_err = e
        for addr in relayed:
            try:
                relay_host_conn = yield from self._conn_to_relay(addr)
                circuit = yield from self.transport.relay_connect(
                    relay_host_conn, info.peer_id)
                yield from self._identify(circuit)
                upgraded = yield from self._maybe_upgrade(circuit, info)
                return upgraded or circuit
            except DialError as e:
                last_err = e
        raise DialError(f"cannot connect to {info.peer_id}: {last_err}")

    def _conn_to_relay(self, addr: Multiaddr) -> Generator:
        relay_host = self.net._by_ip.get(addr.ip)
        if relay_host is not None:
            existing = self.host.connection_to(relay_host)
            if existing is not None and not existing.relayed:
                return existing
        conn = yield from self.transport.dial_direct((addr.ip, addr.port))
        return conn

    def _maybe_upgrade(self, circuit: Connection,
                       info: PeerInfo) -> Generator:
        """One DCUtR attempt per peer per cooldown window; returns a direct
        Connection or None (keep the circuit)."""
        last = self._upgrade_attempted.get(info.peer_id)
        if last is not None and self.sim.now - last < UPGRADE_RETRY_COOLDOWN:
            return None
        self._upgrade_attempted[info.peer_id] = self.sim.now
        direct = yield from self.transport.dcutr_upgrade(circuit)
        if direct is not None:
            circuit.close()
            return direct
        return None

    def _identify(self, conn: Connection) -> Generator:
        try:
            stub = self.stub(IdentityService, conn=conn)
            their = yield from stub.exchange(self.info())
            self.remember(their)
        except (RpcError, DialError):
            pass
        return None

    def connect_peer(self, peer_id: PeerId) -> Generator:
        info = self.peers.get(peer_id)
        if info is None:
            # resolve through the DHT
            closest = yield from self.dht.find_node(peer_id.digest)
            info = self.peers.get(peer_id)
            if info is None:
                for c in closest:
                    if c.peer_id == peer_id:
                        info = c
                        break
        if info is None:
            raise DialError(f"unknown peer {peer_id}")
        conn = yield from self.connect_info(info)
        return conn

    # ------------------------------------------------------------- bootstrap
    def bootstrap(self, bootstrap_infos: List[PeerInfo],
                  relay: Optional[PeerInfo] = None) -> Generator:
        """Join the mesh: dial bootstrappers, learn reachability, reserve a
        relay if private, then populate the DHT routing table."""
        conns = []
        probed = False
        for info in bootstrap_infos:
            try:
                conn = yield from self.connect_info(info)
                conns.append(conn)
            except DialError:
                continue
            if not probed:
                # AutoNAT immediately after the FIRST contact: the dial-back
                # is forwarded to a public peer we have never contacted, so
                # cone-NAT filters can't be satisfied by our own traffic.
                yield from self.transport.autonat_probe(conn)
                probed = True
        if not conns:
            raise DialError("all bootstrap nodes unreachable")
        self._relay_candidates = list(bootstrap_infos)
        if relay is not None and all(c.peer_id != relay.peer_id
                                     for c in self._relay_candidates):
            self._relay_candidates.append(relay)
        if self.transport.reachability != "public":
            candidates = [relay] if relay is not None else bootstrap_infos
            got = yield from self.acquire_relays(candidates)
            if not got and relay is not None:
                yield from self.acquire_relays(bootstrap_infos)
        yield from self.dht.bootstrap_lookup()
        for pid in list(self.peers):
            yield from self.pubsub.announce_subscriptions(pid)
        return self.transport.reachability

    # ---------------------------------------------------------------- relays
    def acquire_relays(self, candidates: List[PeerInfo],
                       want: int = RELAY_TARGET) -> Generator:
        """Score candidate relays by RTT and hold reservations on the best
        ``want`` of them (primary + failover).  Returns reservations held."""
        held = {i.peer_id for i in self.relay_infos}
        scored = []
        for info in candidates:
            if info.peer_id == self.peer_id or info.peer_id in held:
                continue
            try:
                conn = yield from self.connect_info(info)
                if conn.relayed:
                    continue        # a relay must be directly reachable
                rtt = yield from self.transport.ping(conn)
            except (DialError, RpcError):
                continue
            scored.append((rtt, info, conn))
        scored.sort(key=lambda s: s[0])
        for rtt, info, conn in scored:
            if len(self.relay_infos) >= want:
                break
            try:
                ok, ttl = yield from self.transport.relay_reserve(conn)
            except DialError:
                continue
            if ok:
                self._note_relay(info, ttl, rtt)
        return len(self.relay_infos)

    def reserve_relay(self, relay_info: PeerInfo) -> Generator:
        """Reserve (or refresh) a slot on one specific relay."""
        conn = yield from self.connect_info(relay_info)
        ok, ttl = yield from self.transport.relay_reserve(conn)
        if ok:
            self._note_relay(relay_info, ttl)
        return ok

    def _note_relay(self, info: PeerInfo, ttl: float,
                    rtt: Optional[float] = None) -> None:
        digest = info.peer_id.digest
        if all(i.peer_id != info.peer_id for i in self.relay_infos):
            self.relay_infos.append(info)
        meta = self._relay_meta.setdefault(digest, {})
        meta["expires_at"] = self.sim.now + ttl
        if rtt is not None:
            meta["rtt"] = rtt
        self.relay_infos.sort(
            key=lambda i: self._relay_meta.get(i.peer_id.digest, {})
                              .get("rtt", float("inf")))

    def _drop_relay(self, info: PeerInfo) -> None:
        self.relay_infos = [i for i in self.relay_infos
                            if i.peer_id != info.peer_id]
        self._relay_meta.pop(info.peer_id.digest, None)

    # ------------------------------------------------------------------ CRDT
    def sync_crdt_with(self, info: PeerInfo) -> Generator:
        """One anti-entropy round with one peer; returns True if state moved.

        v2 (default): digest probe → per-key digest summary → per-key delta
        transfer, so bytes moved are O(changed-state).  Peers that do not
        serve the v2 methods (``NOT_FOUND``) are remembered and get the v1
        full-state exchange; a v1-configured node always speaks v1."""
        stats = self.crdt_stats
        stub = self.stub(CrdtSyncV2Service, info)
        theirs = yield from stub.digest()
        stats["rounds"] += 1
        if theirs == self.store.digest():
            # identical state: snapshot (digest, vv) atomically so the next
            # divergent round can prove "peer == our old self" and skip the
            # summary exchange
            self._crdt_sync_cache[info.peer_id] = (theirs, self.store.vv())
            return False
        if (self.crdt_proto == "v2"
                and self._crdt_peer_proto.get(info.peer_id) != "v1"):
            cached = self._crdt_sync_cache.get(info.peer_id)
            if cached is not None and cached[0] == theirs:
                # the peer still holds exactly the state both sides shared
                # after the last round (content digests match), so what it
                # lacks is precisely delta_since(our vv back then): push it
                # without the crdt.summary round trip
                moved = yield from self._sync_crdt_skip(stub, info, cached[1])
                return moved
            try:
                moved = yield from self._sync_crdt_v2(stub)
                stats["delta_exchanges"] += 1
                self._crdt_sync_cache[info.peer_id] = (
                    self.store.digest(), self.store.vv())
                return moved
            except ServiceError as e:
                if e.status is not RpcStatus.NOT_FOUND:
                    raise
                # peer only serves the v1 surface; remember and fall back
                self._crdt_peer_proto[info.peer_id] = "v1"
        stats["full_exchanges"] += 1
        mine = self.store.serialize()
        resp = yield from stub.exchange(mine)
        stats["tx_bytes"] += len(mine)
        stats["rx_bytes"] += len(resp)
        if self.store.merge(ReplicatedStore.deserialize(resp)):
            # rumor-monger state learned via anti-entropy: a peer the flood
            # could not reach re-publishes once it catches up, so the last
            # stragglers converge epidemically instead of pairwise-randomly
            self._schedule_crdt_push()
        return True

    def _sync_crdt_v2(self, stub: Stub) -> Generator:
        """Summary + delta rounds of the v2 protocol (digest already
        differed).  Returns True if any state moved in either direction."""
        stats = self.crdt_stats
        summary = encode_summary(self.store.key_digests())
        resp = yield from stub.summary(summary)
        stats["tx_bytes"] += len(summary)
        stats["rx_bytes"] += len(resp)
        diff = decode_vv_map(resp)
        if not diff:
            return False
        # their vv per differing key -> what we have that they lack; our vv
        # rides along so the response carries what they have that we lack
        push = self.store.delta_since(diff, keys=diff.keys())
        my_vv = {k: self.store.entry_vv(k) for k in diff}
        req = encode_delta_request(my_vv, push)
        dresp = yield from stub.delta(req)
        stats["tx_bytes"] += len(req)
        stats["rx_bytes"] += len(dresp)
        their_deltas = ReplicatedStore.decode_delta(dresp)
        changed = self.store.apply_delta(their_deltas) if their_deltas else []
        if changed:
            self._schedule_crdt_push()      # rumor-monger what we learned
        return bool(changed) or bool(push)

    def _sync_crdt_skip(self, stub: Stub, info: PeerInfo,
                        since_vv: Dict[str, Any]) -> Generator:
        """Steady-state fast path: the peer's digest equals our snapshot
        from the last converged round, so it is missing exactly
        ``delta_since(since_vv)`` and has nothing we lack — one push-only
        ``crdt.delta``, no summary."""
        stats = self.crdt_stats
        push = self.store.delta_since(since_vv)
        # atomic (digest, vv) of the state the peer will hold post-merge;
        # verified by digest equality before the next skip, so a concurrent
        # local mutation mid-RPC only costs a fallback to the summary path
        snap = (self.store.digest(), self.store.vv())
        req = encode_delta_request({}, push)
        dresp = yield from stub.delta(req)
        stats["summary_skipped"] += 1
        stats["delta_exchanges"] += 1
        stats["tx_bytes"] += len(req)
        stats["rx_bytes"] += len(dresp)
        their_deltas = ReplicatedStore.decode_delta(dresp)
        changed = self.store.apply_delta(their_deltas) if their_deltas else []
        self._crdt_sync_cache[info.peer_id] = snap
        return bool(changed) or bool(push)

    # ------------------------------------------------------- CRDT delta push
    def watch_crdt(self, prefix: str, callback: Any) -> int:
        """Watch store keys under ``prefix`` *and* join the namespace's
        delta-push topic: ``callback(key, value, origin)`` fires on local
        mutations, merged-in anti-entropy state, and pushed deltas arriving
        via pubsub — i.e. one gossip round after a remote write, no
        anti-entropy tick required.  Returns the store watch handle.

        ``prefix`` must name a full namespace (its first path segment is
        the ``crdt/<ns>`` topic pushes are published on); an empty prefix
        would silently subscribe to a topic nothing publishes — watch
        everything with ``store.watch("")`` plus ``join_crdt_push`` per
        namespace instead."""
        if not prefix:
            raise ValueError(
                "watch_crdt needs a namespaced prefix; use store.watch('') "
                "+ join_crdt_push(ns) to watch everything")
        self.join_crdt_push(crdt_ns(prefix))
        return self.store.watch(prefix, callback)

    def join_crdt_push(self, ns: str) -> None:
        """Subscribe to ``crdt/<ns>`` delta pushes (idempotent)."""
        topic = f"crdt/{ns}"
        if topic in self._crdt_topics:
            return
        self._crdt_topics.add(topic)
        self.pubsub.subscribe(topic, self._on_crdt_push_msg)

    def _on_crdt_push_msg(self, topic: str, data: Any, frm: PeerId) -> None:
        try:
            deltas = ReplicatedStore.decode_delta(data)
            changed = self.store.apply_delta(deltas)
        except (ValueError, TypeError):
            self.crdt_stats["push_rejected"] += 1
            return
        if changed:
            self.crdt_stats["push_applied"] += 1

    def _on_crdt_mutation(self, key: str) -> None:
        """Store local-mutation hook: debounce-schedule one push process so
        a burst of same-instant writes ships as a single delta batch."""
        self._schedule_crdt_push()

    def _schedule_crdt_push(self) -> None:
        if not self.crdt_push or self._push_pending:
            return
        self._push_pending = True
        self.sim.process(self._crdt_push_once())

    def _crdt_push_once(self) -> Generator:
        yield 0.0           # let the mutating call finish its write batch
        self._push_pending = False
        yield from self.crdt_push_flush()
        return None

    def crdt_push_flush(self) -> Generator:
        """Publish per-namespace delta documents for everything mutated
        since the last push on the ``crdt/<ns>`` topics; connected
        subscribers converge in one gossip round.  Returns the number of
        topics published (0 when clean or push is disabled)."""
        if not self.crdt_push:
            return 0
        deltas = self.store.delta_since(self._push_vv)
        if not deltas:
            return 0
        self._push_vv = self.store.vv()
        by_ns: Dict[str, Dict[str, Any]] = {}
        for k, frag in deltas.items():
            by_ns.setdefault(crdt_ns(k), {})[k] = frag
        for ns in sorted(by_ns):
            blob = ReplicatedStore.encode_delta(by_ns[ns])
            self.crdt_stats["push_published"] += 1
            self.crdt_stats["push_bytes"] += len(blob)
            yield from self.pubsub.publish(f"crdt/{ns}", blob,
                                           size=max(len(blob), 64))
        return len(by_ns)

    def maintenance_loop(self, interval: float = 10.0) -> Generator:
        """Background upkeep of relay reservations.  Reservations are TTL'd
        on the relay side, so a private peer must (a) refresh each held slot
        before it expires, (b) re-establish reservations whose relay
        connection died (link flap, partition), and (c) replace relays that
        stop accepting it, topping back up to ``RELAY_TARGET`` from the
        candidate set — otherwise it silently loses inbound reachability.
        libp2p's reservation refresh works the same way."""
        while True:
            yield interval
            if self.host.nat is None:
                continue            # truly public hosts have static addrs
            # NAT keepalive: re-confirm our external mapping (STUN-style)
            # through the primary relay — or, for nodes that hold none
            # (e.g. dialable full-cone NATs, whose observed mapping IS
            # their advertised address), through a bootstrap server.
            anchors = self.relay_infos or self._relay_candidates
            if anchors:
                addr = anchors[0].addrs[0]
                try:
                    yield from self.transport.refresh_observed(
                        (addr.ip, MAIN_PORT))
                except DialError:
                    pass
            if self.transport.reachability == "public":
                continue
            for info in list(self.relay_infos):
                meta = self._relay_meta.get(info.peer_id.digest, {})
                relay_host = self.net.hosts.get(info.host_name)
                conn = (self.host.connection_to(relay_host)
                        if relay_host is not None else None)
                expiring = (self.sim.now + 2 * interval
                            >= meta.get("expires_at", 0.0))
                if conn is not None and not conn.closed and not expiring:
                    continue
                try:
                    ok = yield from self.reserve_relay(info)
                except (DialError, RpcError):
                    ok = False
                if not ok:
                    self._drop_relay(info)
            if len(self.relay_infos) < RELAY_TARGET and self._relay_candidates:
                try:
                    yield from self.acquire_relays(self._relay_candidates)
                except (DialError, RpcError):
                    pass

    def anti_entropy_loop(self, interval: float = 5.0) -> Generator:
        """Background gossip: periodically reconcile with a random peer."""
        while True:
            yield interval * (0.5 + self.sim.rng.random())
            if not self.peers:
                continue
            pid = self.sim.rng.choice(sorted(self.peers, key=lambda p: p.digest))
            info = self.peers[pid]
            try:
                yield from self.sync_crdt_with(info)
            except (DialError, RpcError, ValueError):
                # ValueError: peer sent undecodable/forbidden CRDT state —
                # skip the round, don't kill the background loop
                continue

    # ------------------------------------------------------------- artifacts
    def pin_latest(self, tag: str, root: CID) -> None:
        """Pin ``root`` as the latest version of lineage ``tag`` (a fleet,
        an artifact family) and unpin the previous holder — older versions
        become evictable under the blockstore budget while the newest one
        survives any churn."""
        prev = self._pinned_latest.get(tag)
        if prev == root:
            return
        self.blockstore.pin(root)
        if prev is not None:
            self.blockstore.unpin(prev)
        self._pinned_latest[tag] = root

    def publish_artifact(self, data: bytes, meta: bytes = b"",
                         announce_topic: Optional[str] = None,
                         pin: bool = True,
                         spec: Optional[ChunkSpec] = None) -> Generator:
        """Chunk + store + provide a flat (v1) artifact; returns the root
        CID.  Raw byte blobs keep the flat manifest — the hierarchical path
        is :meth:`publish_tree_artifact`.  ``spec`` selects the chunking
        strategy (fixed-size by default; ``ChunkSpec.cdc`` keeps boundaries
        stable under byte-shifting edits)."""
        dag = build_dag(data, meta=meta, spec=spec)
        yield from self.bitswap.publish_dag(dag.blocks, dag.root)
        if pin:
            self.blockstore.pin(dag.root)
        if announce_topic is not None:
            yield from self.pubsub.publish(
                announce_topic, ("artifact", dag.root, len(data), meta), size=192)
        return dag.root

    def publish_tree_artifact(self, parts: List[Any], meta: bytes = b"",
                              announce_topic: Optional[str] = None,
                              pin: bool = True,
                              spec: Optional[ChunkSpec] = None) -> Generator:
        """Publish ``[(name, data, part_meta), ...]`` as a hierarchical (v2)
        DAG — one sub-DAG per part, so parts unchanged since an earlier
        version reuse their sub-root CIDs (and cost fetchers zero bytes).
        With a ``cdc`` ``spec``, *within-part* byte shifts also dedup: leaf
        boundaries re-synchronize after an edit instead of cascading.
        Returns the root CID."""
        dag = build_tree_dag(parts, meta=meta, spec=spec)
        yield from self.bitswap.publish_dag(dag.blocks, dag.root)
        if pin:
            self.blockstore.pin(dag.root)
        if announce_topic is not None:
            yield from self.pubsub.publish(
                announce_topic,
                ("artifact", dag.root, dag.total_size, meta), size=192)
        return dag.root

    def fetch_artifact(self, root: CID,
                       hint_providers: Optional[List[PeerInfo]] = None,
                       reprovide: bool = True,
                       assemble: bool = True) -> Generator:
        """Swarm-fetch a DAG of either manifest version.  With
        ``assemble=False`` the blocks land in the local store and ``None``
        is returned (structure-aware callers reassemble per entry)."""
        data = yield from self.bitswap.fetch_dag(root, hint_providers,
                                                 assemble=assemble)
        if reprovide:
            yield from self.dht.provide(root.key)
        return data
