"""LatticaNode: the composed stack — what the paper's SDK exposes.

identity + transport (dial/AutoNAT/relay/DCUtR) + RPC router + Kademlia DHT
+ pub/sub + CRDT replicated store + content-addressed blockstore + Bitswap.

``connect_info`` implements the paper's connection policy:
  1. reuse an existing connection;
  2. try direct dial on advertised direct addrs;
  3. fall back to a circuit relay;
  4. attempt a DCUtR hole-punch upgrade, keeping the circuit if it fails.
"""

from __future__ import annotations

from typing import Any, Dict, Generator, List, Optional, TYPE_CHECKING

from .bitswap import Bitswap
from .blockstore import BlockStore
from .cid import CID, ChunkSpec, build_dag, build_tree_dag
from .crdt import ReplicatedStore
from .dht import KademliaDHT, PeerInfo
from .peer import Multiaddr, PeerId
from .pubsub import PubSub
from .rendezvous import RendezvousServer
from .rpc import RpcContext, RpcError, RpcRouter
from .service import (ByteLength, ClientInterceptor, Fixed, PEER_INFO,
                      RpcMetrics, Service, ServerInterceptor, Stub,
                      serve_service, unary)
from .simnet import Connection, DialError, Host, Network, Sim
from .traversal import MAIN_PORT, Transport

#: How many relays a private node tries to hold reservations on (primary +
#: failover), ranked by measured RTT.
RELAY_TARGET = 2

#: A failed DCUtR upgrade is retried on the next connect after this long —
#: NAT state and address books evolve, so "relayed once" must not mean
#: "relayed forever" (libp2p retries hole punching the same way).
UPGRADE_RETRY_COOLDOWN = 30.0


class IdentityService(Service):
    """Push-pull identity exchange: each side learns the other's PeerInfo."""

    name = "id"

    def __init__(self, node: "LatticaNode"):
        self.node = node

    @unary("id.exchange", request=PEER_INFO, response=PEER_INFO,
           idempotent=True, timeout=10.0)
    def exchange(self, payload: Any, ctx: RpcContext) -> Generator:
        self.node.remember(payload)
        yield ctx.cpu(2e-6)
        return self.node.info()


class CrdtSyncService(Service):
    """Anti-entropy pair: digest probe, then full state exchange+merge.
    Both methods are idempotent — CRDT merge is, by definition."""

    name = "crdt"

    def __init__(self, node: "LatticaNode"):
        self.node = node

    @unary("crdt.digest", request=Fixed(96), response=Fixed(96),
           idempotent=True, timeout=15.0)
    def digest(self, payload: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(10e-6)
        return self.node.store.digest()

    @unary("crdt.exchange", request=ByteLength(), response=ByteLength(),
           idempotent=True, timeout=60.0)
    def exchange(self, payload: Any, ctx: RpcContext) -> Generator:
        incoming = ReplicatedStore.deserialize(payload)
        yield ctx.cpu(30e-6)
        self.node.store.merge(incoming)
        return self.node.store.serialize()


class LatticaNode:
    def __init__(self, net: Network, name: str, region: str = "us",
                 zone: str = "a", nat: Optional[Any] = None, cores: int = 4,
                 serve_rendezvous: bool = False,
                 machine: Optional[str] = None,
                 store_budget: Optional[int] = None):
        self.net = net
        self.sim: Sim = net.sim
        self.host: Host = net.host(name, region=region, zone=zone, nat=nat,
                                   cores=cores, machine=machine)
        self.peer_id = PeerId.from_name(name)
        self.transport = Transport(self.host, self.peer_id)
        self.router = RpcRouter(self.host)
        self.rpc_metrics = RpcMetrics()
        self._stub_cache: Dict[Any, Stub] = {}
        self.blockstore = BlockStore(capacity=store_budget)
        self._pinned_latest: Dict[str, CID] = {}
        self.store = ReplicatedStore(replica=name)
        self.peers: Dict[PeerId, PeerInfo] = {}
        self.infos_by_host: Dict[str, PeerInfo] = {}
        self.identity = self.serve(IdentityService(self))
        self.crdt_sync = self.serve(CrdtSyncService(self))
        self.dht = KademliaDHT(self)
        self.pubsub = PubSub(self)
        self.bitswap = Bitswap(self)
        self.relay_infos: List[PeerInfo] = []          # primary first (by RTT)
        self._relay_meta: Dict[bytes, Dict[str, float]] = {}
        self._relay_candidates: List[PeerInfo] = []
        self.rendezvous: Optional[RendezvousServer] = (
            RendezvousServer(self) if serve_rendezvous else None)
        self._upgrade_attempted: Dict[PeerId, float] = {}  # peer -> last try

    # ----------------------------------------------------------- service API
    def serve(self, service: Service,
              interceptors: List[ServerInterceptor] = ()) -> Service:
        """Register every declared RPC method of ``service`` on this node."""
        return serve_service(self.router, service, interceptors=interceptors,
                             metrics=self.rpc_metrics)

    def stub(self, service_cls: type, target: Optional[PeerInfo] = None, *,
             conn: Optional[Connection] = None, scope: Optional[str] = None,
             interceptors: List[ClientInterceptor] = ()) -> Stub:
        """Typed client stub for ``service_cls`` at ``target`` (or over an
        explicit ``conn``).  Connections are acquired lazily per call via
        ``connect_info`` and reused.  Peer-targeted stubs without custom
        interceptors are cached — hot paths (DHT lookups, gossip fan-out)
        request one per RPC."""
        if conn is None and not interceptors and target is not None:
            key = (service_cls, target.peer_id, scope)
            cached = self._stub_cache.get(key)
            if cached is not None:
                cached._target = target      # refresh the PeerInfo snapshot
                return cached
            made = Stub(self, service_cls, target, scope=scope)
            self._stub_cache[key] = made
            return made
        return Stub(self, service_cls, target, conn=conn, scope=scope,
                    interceptors=interceptors)

    # ------------------------------------------------------------- identity
    @property
    def relay_info(self) -> Optional[PeerInfo]:
        """Primary (lowest-RTT) relay this node holds a reservation on."""
        return self.relay_infos[0] if self.relay_infos else None

    def info(self) -> PeerInfo:
        addrs: List[Multiaddr] = []
        if self.host.nat is None:
            addrs.append(Multiaddr(self.host.ip, MAIN_PORT))
        elif self.transport.reachability == "public":
            # e.g. full-cone NAT: our observed mapping is stranger-dialable
            for ip, port in sorted(self.transport.observed_addrs):
                addrs.append(Multiaddr(ip, port))
        for relay_info in self.relay_infos:     # primary first, then failover
            relay_ip = relay_info.addrs[0].ip
            addrs.append(Multiaddr(relay_ip, MAIN_PORT,
                                   relay_peer=relay_info.peer_id))
        return PeerInfo(self.peer_id, self.host.name, tuple(addrs))

    def remember(self, info: PeerInfo) -> None:
        if info.peer_id == self.peer_id:
            return
        old = self.peers.get(info.peer_id)
        if old is not None and not info.addrs:
            return  # don't clobber a dialable record with an empty one
        self.peers[info.peer_id] = info
        self.infos_by_host[info.host_name] = info
        self.dht.table.update(info)

    # ------------------------------------------------------------ connecting
    def connect_info(self, info: PeerInfo) -> Generator:
        """Connect to a peer, NAT-traversing as needed; returns Connection."""
        target_host = self.net.hosts.get(info.host_name)
        if target_host is not None:
            existing = self.host.connection_to(target_host)
            if existing is not None:
                if existing.relayed:
                    # a circuit is a fallback, not a fate: periodically
                    # retry the DCUtR upgrade (cooldown-limited)
                    upgraded = yield from self._maybe_upgrade(existing, info)
                    if upgraded is not None:
                        return upgraded
                return existing
        self.remember(info)
        direct = [a for a in info.addrs if not a.is_relay]
        relayed = [a for a in info.addrs if a.is_relay]
        last_err: Optional[Exception] = None
        for addr in direct:
            try:
                conn = yield from self.transport.dial_direct((addr.ip, addr.port))
                yield from self._identify(conn)
                return conn
            except DialError as e:
                last_err = e
        for addr in relayed:
            try:
                relay_host_conn = yield from self._conn_to_relay(addr)
                circuit = yield from self.transport.relay_connect(
                    relay_host_conn, info.peer_id)
                yield from self._identify(circuit)
                upgraded = yield from self._maybe_upgrade(circuit, info)
                return upgraded or circuit
            except DialError as e:
                last_err = e
        raise DialError(f"cannot connect to {info.peer_id}: {last_err}")

    def _conn_to_relay(self, addr: Multiaddr) -> Generator:
        relay_host = self.net._by_ip.get(addr.ip)
        if relay_host is not None:
            existing = self.host.connection_to(relay_host)
            if existing is not None and not existing.relayed:
                return existing
        conn = yield from self.transport.dial_direct((addr.ip, addr.port))
        return conn

    def _maybe_upgrade(self, circuit: Connection,
                       info: PeerInfo) -> Generator:
        """One DCUtR attempt per peer per cooldown window; returns a direct
        Connection or None (keep the circuit)."""
        last = self._upgrade_attempted.get(info.peer_id)
        if last is not None and self.sim.now - last < UPGRADE_RETRY_COOLDOWN:
            return None
        self._upgrade_attempted[info.peer_id] = self.sim.now
        direct = yield from self.transport.dcutr_upgrade(circuit)
        if direct is not None:
            circuit.close()
            return direct
        return None

    def _identify(self, conn: Connection) -> Generator:
        try:
            stub = self.stub(IdentityService, conn=conn)
            their = yield from stub.exchange(self.info())
            self.remember(their)
        except (RpcError, DialError):
            pass
        return None

    def connect_peer(self, peer_id: PeerId) -> Generator:
        info = self.peers.get(peer_id)
        if info is None:
            # resolve through the DHT
            closest = yield from self.dht.find_node(peer_id.digest)
            info = self.peers.get(peer_id)
            if info is None:
                for c in closest:
                    if c.peer_id == peer_id:
                        info = c
                        break
        if info is None:
            raise DialError(f"unknown peer {peer_id}")
        conn = yield from self.connect_info(info)
        return conn

    # ------------------------------------------------------------- bootstrap
    def bootstrap(self, bootstrap_infos: List[PeerInfo],
                  relay: Optional[PeerInfo] = None) -> Generator:
        """Join the mesh: dial bootstrappers, learn reachability, reserve a
        relay if private, then populate the DHT routing table."""
        conns = []
        probed = False
        for info in bootstrap_infos:
            try:
                conn = yield from self.connect_info(info)
                conns.append(conn)
            except DialError:
                continue
            if not probed:
                # AutoNAT immediately after the FIRST contact: the dial-back
                # is forwarded to a public peer we have never contacted, so
                # cone-NAT filters can't be satisfied by our own traffic.
                yield from self.transport.autonat_probe(conn)
                probed = True
        if not conns:
            raise DialError("all bootstrap nodes unreachable")
        self._relay_candidates = list(bootstrap_infos)
        if relay is not None and all(c.peer_id != relay.peer_id
                                     for c in self._relay_candidates):
            self._relay_candidates.append(relay)
        if self.transport.reachability != "public":
            candidates = [relay] if relay is not None else bootstrap_infos
            got = yield from self.acquire_relays(candidates)
            if not got and relay is not None:
                yield from self.acquire_relays(bootstrap_infos)
        yield from self.dht.bootstrap_lookup()
        for pid in list(self.peers):
            yield from self.pubsub.announce_subscriptions(pid)
        return self.transport.reachability

    # ---------------------------------------------------------------- relays
    def acquire_relays(self, candidates: List[PeerInfo],
                       want: int = RELAY_TARGET) -> Generator:
        """Score candidate relays by RTT and hold reservations on the best
        ``want`` of them (primary + failover).  Returns reservations held."""
        held = {i.peer_id for i in self.relay_infos}
        scored = []
        for info in candidates:
            if info.peer_id == self.peer_id or info.peer_id in held:
                continue
            try:
                conn = yield from self.connect_info(info)
                if conn.relayed:
                    continue        # a relay must be directly reachable
                rtt = yield from self.transport.ping(conn)
            except (DialError, RpcError):
                continue
            scored.append((rtt, info, conn))
        scored.sort(key=lambda s: s[0])
        for rtt, info, conn in scored:
            if len(self.relay_infos) >= want:
                break
            try:
                ok, ttl = yield from self.transport.relay_reserve(conn)
            except DialError:
                continue
            if ok:
                self._note_relay(info, ttl, rtt)
        return len(self.relay_infos)

    def reserve_relay(self, relay_info: PeerInfo) -> Generator:
        """Reserve (or refresh) a slot on one specific relay."""
        conn = yield from self.connect_info(relay_info)
        ok, ttl = yield from self.transport.relay_reserve(conn)
        if ok:
            self._note_relay(relay_info, ttl)
        return ok

    def _note_relay(self, info: PeerInfo, ttl: float,
                    rtt: Optional[float] = None) -> None:
        digest = info.peer_id.digest
        if all(i.peer_id != info.peer_id for i in self.relay_infos):
            self.relay_infos.append(info)
        meta = self._relay_meta.setdefault(digest, {})
        meta["expires_at"] = self.sim.now + ttl
        if rtt is not None:
            meta["rtt"] = rtt
        self.relay_infos.sort(
            key=lambda i: self._relay_meta.get(i.peer_id.digest, {})
                              .get("rtt", float("inf")))

    def _drop_relay(self, info: PeerInfo) -> None:
        self.relay_infos = [i for i in self.relay_infos
                            if i.peer_id != info.peer_id]
        self._relay_meta.pop(info.peer_id.digest, None)

    # ------------------------------------------------------------------ CRDT
    def sync_crdt_with(self, info: PeerInfo) -> Generator:
        """One anti-entropy round with one peer; returns True if state moved."""
        stub = self.stub(CrdtSyncService, info)
        theirs = yield from stub.digest()
        if theirs == self.store.digest():
            return False
        mine = self.store.serialize()
        resp = yield from stub.exchange(mine)
        self.store.merge(ReplicatedStore.deserialize(resp))
        return True

    def maintenance_loop(self, interval: float = 10.0) -> Generator:
        """Background upkeep of relay reservations.  Reservations are TTL'd
        on the relay side, so a private peer must (a) refresh each held slot
        before it expires, (b) re-establish reservations whose relay
        connection died (link flap, partition), and (c) replace relays that
        stop accepting it, topping back up to ``RELAY_TARGET`` from the
        candidate set — otherwise it silently loses inbound reachability.
        libp2p's reservation refresh works the same way."""
        while True:
            yield interval
            if self.host.nat is None:
                continue            # truly public hosts have static addrs
            # NAT keepalive: re-confirm our external mapping (STUN-style)
            # through the primary relay — or, for nodes that hold none
            # (e.g. dialable full-cone NATs, whose observed mapping IS
            # their advertised address), through a bootstrap server.
            anchors = self.relay_infos or self._relay_candidates
            if anchors:
                addr = anchors[0].addrs[0]
                try:
                    yield from self.transport.refresh_observed(
                        (addr.ip, MAIN_PORT))
                except DialError:
                    pass
            if self.transport.reachability == "public":
                continue
            for info in list(self.relay_infos):
                meta = self._relay_meta.get(info.peer_id.digest, {})
                relay_host = self.net.hosts.get(info.host_name)
                conn = (self.host.connection_to(relay_host)
                        if relay_host is not None else None)
                expiring = (self.sim.now + 2 * interval
                            >= meta.get("expires_at", 0.0))
                if conn is not None and not conn.closed and not expiring:
                    continue
                try:
                    ok = yield from self.reserve_relay(info)
                except (DialError, RpcError):
                    ok = False
                if not ok:
                    self._drop_relay(info)
            if len(self.relay_infos) < RELAY_TARGET and self._relay_candidates:
                try:
                    yield from self.acquire_relays(self._relay_candidates)
                except (DialError, RpcError):
                    pass

    def anti_entropy_loop(self, interval: float = 5.0) -> Generator:
        """Background gossip: periodically reconcile with a random peer."""
        while True:
            yield interval * (0.5 + self.sim.rng.random())
            if not self.peers:
                continue
            pid = self.sim.rng.choice(sorted(self.peers, key=lambda p: p.digest))
            info = self.peers[pid]
            try:
                yield from self.sync_crdt_with(info)
            except (DialError, RpcError, ValueError):
                # ValueError: peer sent undecodable/forbidden CRDT state —
                # skip the round, don't kill the background loop
                continue

    # ------------------------------------------------------------- artifacts
    def pin_latest(self, tag: str, root: CID) -> None:
        """Pin ``root`` as the latest version of lineage ``tag`` (a fleet,
        an artifact family) and unpin the previous holder — older versions
        become evictable under the blockstore budget while the newest one
        survives any churn."""
        prev = self._pinned_latest.get(tag)
        if prev == root:
            return
        self.blockstore.pin(root)
        if prev is not None:
            self.blockstore.unpin(prev)
        self._pinned_latest[tag] = root

    def publish_artifact(self, data: bytes, meta: bytes = b"",
                         announce_topic: Optional[str] = None,
                         pin: bool = True,
                         spec: Optional[ChunkSpec] = None) -> Generator:
        """Chunk + store + provide a flat (v1) artifact; returns the root
        CID.  Raw byte blobs keep the flat manifest — the hierarchical path
        is :meth:`publish_tree_artifact`.  ``spec`` selects the chunking
        strategy (fixed-size by default; ``ChunkSpec.cdc`` keeps boundaries
        stable under byte-shifting edits)."""
        dag = build_dag(data, meta=meta, spec=spec)
        yield from self.bitswap.publish_dag(dag.blocks, dag.root)
        if pin:
            self.blockstore.pin(dag.root)
        if announce_topic is not None:
            yield from self.pubsub.publish(
                announce_topic, ("artifact", dag.root, len(data), meta), size=192)
        return dag.root

    def publish_tree_artifact(self, parts: List[Any], meta: bytes = b"",
                              announce_topic: Optional[str] = None,
                              pin: bool = True,
                              spec: Optional[ChunkSpec] = None) -> Generator:
        """Publish ``[(name, data, part_meta), ...]`` as a hierarchical (v2)
        DAG — one sub-DAG per part, so parts unchanged since an earlier
        version reuse their sub-root CIDs (and cost fetchers zero bytes).
        With a ``cdc`` ``spec``, *within-part* byte shifts also dedup: leaf
        boundaries re-synchronize after an edit instead of cascading.
        Returns the root CID."""
        dag = build_tree_dag(parts, meta=meta, spec=spec)
        yield from self.bitswap.publish_dag(dag.blocks, dag.root)
        if pin:
            self.blockstore.pin(dag.root)
        if announce_topic is not None:
            yield from self.pubsub.publish(
                announce_topic,
                ("artifact", dag.root, dag.total_size, meta), size=192)
        return dag.root

    def fetch_artifact(self, root: CID,
                       hint_providers: Optional[List[PeerInfo]] = None,
                       reprovide: bool = True,
                       assemble: bool = True) -> Generator:
        """Swarm-fetch a DAG of either manifest version.  With
        ``assemble=False`` the blocks land in the local store and ``None``
        is returned (structure-aware callers reassemble per entry)."""
        data = yield from self.bitswap.fetch_dag(root, hint_providers,
                                                 assemble=assemble)
        if reprovide:
            yield from self.dht.provide(root.key)
        return data
