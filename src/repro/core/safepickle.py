"""Restricted unpickling for peer-supplied payloads.

Anything a Lattica node decodes off the swarm — checkpoint meta, CRDT
anti-entropy state, legacy pickled formats — comes from untrusted peers, and
an open ``pickle.loads`` there is an arbitrary-code-execution vector: the
``find_class`` hook resolves attacker-chosen globals, which ``__reduce__``
payloads then call.  :func:`restricted_loads` closes that hook: only an
explicit ``(module, name)`` allowlist resolves (empty by default, i.e. pure
primitives only), everything else raises ``ValueError``.

Builtin containers with dedicated pickle opcodes (dict/list/tuple/str/int/
float/bytes/bool/None) never touch ``find_class`` and always decode;
``set``/``frozenset`` do resolve through it, so allowlist
``("builtins", "set")`` etc. when a payload legitimately carries them.
"""

from __future__ import annotations

import io
import pickle
from typing import Any, FrozenSet, Tuple

Allowed = FrozenSet[Tuple[str, str]]


class RestrictedUnpickler(pickle.Unpickler):
    def __init__(self, file: io.BytesIO, allowed: Allowed):
        super().__init__(file)
        self._allowed = allowed

    def find_class(self, module: str, name: str):  # noqa: D102
        if (module, name) in self._allowed:
            return super().find_class(module, name)
        raise pickle.UnpicklingError(
            f"refusing to resolve {module}.{name} in untrusted payload")


def restricted_loads(raw: bytes, allowed: Allowed = frozenset()) -> Any:
    """Unpickle ``raw`` resolving only allowlisted globals; raises
    ``ValueError`` on anything malformed or forbidden."""
    try:
        return RestrictedUnpickler(io.BytesIO(raw), allowed).load()
    except ValueError:
        raise
    except Exception as e:  # noqa: BLE001 — malformed/forbidden pickle
        raise ValueError(f"undecodable pickled payload: {e}") from e
