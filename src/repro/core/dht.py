"""Kademlia DHT (Maymounkov & Mazieres 2002) over Lattica RPC.

Provides the paper's content-discovery layer: 256-bit XOR key space shared
with CIDs and peer IDs, k-bucket routing tables, iterative (alpha-parallel)
lookups with O(log N) hop complexity, value records and provider records.
Every query is a real unary RPC over a (possibly relayed) connection, so DHT
performance inherits the traversal layer's behaviour.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Generator, List, Optional, Set, Tuple, TYPE_CHECKING

from .peer import Multiaddr, PeerId
from .rpc import RpcContext, RpcError
from .service import (CodecFn, Fixed, PEER_INFO_LIST, Service, pickled,
                      unary)
from .simnet import DialError

if TYPE_CHECKING:  # pragma: no cover
    from .node import LatticaNode

K = 20
ALPHA = 3
PEERINFO_WIRE_SIZE = 96
MAX_LOOKUP_ROUNDS = 24


@dataclass(frozen=True)
class PeerInfo:
    peer_id: PeerId
    host_name: str
    addrs: Tuple[Multiaddr, ...] = ()

    def wire_size(self) -> int:
        return PEERINFO_WIRE_SIZE


class RoutingTable:
    """256 k-buckets indexed by XOR-distance bit length."""

    def __init__(self, self_id: PeerId, k: int = K):
        self.self_id = self_id
        self.k = k
        self.buckets: List[List[PeerInfo]] = [[] for _ in range(256)]
        self._by_id: Dict[PeerId, PeerInfo] = {}

    def _bucket_index(self, peer_id: PeerId) -> int:
        d = self.self_id.xor_distance(peer_id)
        return max(d.bit_length() - 1, 0)

    def update(self, info: PeerInfo) -> None:
        if info.peer_id == self.self_id:
            return
        idx = self._bucket_index(info.peer_id)
        bucket = self.buckets[idx]
        existing = self._by_id.get(info.peer_id)
        if existing is not None:
            try:
                bucket.remove(existing)
            except ValueError:
                pass
            bucket.append(info)          # move to tail = most-recently-seen
            self._by_id[info.peer_id] = info
            return
        if len(bucket) < self.k:
            bucket.append(info)
            self._by_id[info.peer_id] = info
        # full bucket: Kademlia pings the LRU entry; we keep the old entry
        # (stable-peer preference), dropping the newcomer.

    def remove(self, peer_id: PeerId) -> None:
        info = self._by_id.pop(peer_id, None)
        if info is None:
            return
        bucket = self.buckets[self._bucket_index(peer_id)]
        try:
            bucket.remove(info)
        except ValueError:
            pass

    def closest(self, key: bytes, n: int = K) -> List[PeerInfo]:
        everyone = list(self._by_id.values())
        everyone.sort(key=lambda i: i.peer_id.distance_to_key(key))
        return everyone[:n]

    def __len__(self) -> int:
        return len(self._by_id)


#: tagged-union response sizes for the value/provider lookups
_FIND_VALUE_RESP = CodecFn(
    "find_value_resp",
    lambda p: 256 if p[0] == "value"
    else PEERINFO_WIRE_SIZE * max(len(p[1]), 1))
_GET_PROVIDERS_RESP = CodecFn(
    "get_providers_resp",
    lambda p: PEERINFO_WIRE_SIZE * max(len(p[0]) + len(p[1]), 1))


class KadService(Service):
    """The five Kademlia RPCs.  All are idempotent reads/upserts, so stubs
    may retry them freely; eviction-on-failure stays in ``KademliaDHT``."""

    name = "kad"

    def __init__(self, dht: "KademliaDHT"):
        self.dht = dht

    def _observe(self, ctx: RpcContext) -> None:
        info = self.dht.node.infos_by_host.get(ctx.remote_host.name)
        if info is not None:
            self.dht.table.update(info)

    @unary("kad.find_node", request=Fixed(96), response=PEER_INFO_LIST,
           idempotent=True, timeout=15.0)
    def find_node(self, payload: Any, ctx: RpcContext) -> Generator:
        self._observe(ctx)
        closest = self.dht.table.closest(payload, self.dht.k)
        yield ctx.cpu(5e-6)
        return closest

    @unary("kad.find_value", request=Fixed(96), response=_FIND_VALUE_RESP,
           idempotent=True, timeout=15.0)
    def find_value(self, payload: Any, ctx: RpcContext) -> Generator:
        self._observe(ctx)
        key = payload
        yield ctx.cpu(5e-6)
        if key in self.dht.records:
            val, _ = self.dht.records[key]
            return ("value", val)
        return ("peers", self.dht.table.closest(key, self.dht.k))

    @unary("kad.put", request=pickled(floor=96), response=Fixed(64),
           idempotent=True, timeout=15.0)
    def put(self, payload: Any, ctx: RpcContext) -> Generator:
        self._observe(ctx)
        key, value = payload
        self.dht.records[key] = (value, self.dht.node.sim.now)
        yield ctx.cpu(5e-6)
        return True

    @unary("kad.add_provider", request=Fixed(96 + PEERINFO_WIRE_SIZE),
           response=Fixed(64), idempotent=True, timeout=15.0)
    def add_provider(self, payload: Any, ctx: RpcContext) -> Generator:
        self._observe(ctx)
        key, info = payload
        self.dht.providers.setdefault(key, {})[info.peer_id] = (
            info, self.dht.node.sim.now)
        yield ctx.cpu(5e-6)
        return True

    @unary("kad.drop_provider", request=Fixed(96 + PEERINFO_WIRE_SIZE),
           response=Fixed(64), idempotent=True, timeout=15.0)
    def drop_provider(self, payload: Any, ctx: RpcContext) -> Generator:
        """Withdraw one provider record — the planned-retirement inverse of
        ``add_provider`` (same trust model: records are advisory hints the
        fetch path verifies by actually fetching, so a lying peer can only
        re-create the staleness TTLs already tolerate)."""
        self._observe(ctx)
        key, peer_id = payload
        entry = self.dht.providers.get(key)
        if entry is not None:
            entry.pop(peer_id, None)
            if not entry:
                del self.dht.providers[key]
        yield ctx.cpu(5e-6)
        return True

    @unary("kad.get_providers", request=Fixed(96),
           response=_GET_PROVIDERS_RESP, idempotent=True, timeout=15.0)
    def get_providers(self, payload: Any, ctx: RpcContext) -> Generator:
        self._observe(ctx)
        key = payload
        provs = [i for i, _ in self.dht.providers.get(key, {}).values()]
        closest = self.dht.table.closest(key, self.dht.k)
        yield ctx.cpu(5e-6)
        return provs, closest


class KademliaDHT:
    def __init__(self, node: "LatticaNode", k: int = K, alpha: int = ALPHA):
        self.node = node
        self.k = k
        self.alpha = alpha
        self.table = RoutingTable(node.peer_id, k)
        self.records: Dict[bytes, Tuple[Any, float]] = {}        # key -> (val, ts)
        self.providers: Dict[bytes, Dict[PeerId, Tuple[PeerInfo, float]]] = {}
        self.stats = {"lookups": 0, "rounds": 0, "queries": 0}
        node.serve(KadService(self))

    # ------------------------------------------------------------- queries
    def _query(self, info: PeerInfo, method: str, payload: Any) -> Generator:
        """Single RPC to one peer (``method`` is a KadService attr name);
        returns None on failure (peer evicted)."""
        self.stats["queries"] += 1
        try:
            stub = self.node.stub(KadService, info)
            resp = yield from getattr(stub, method)(payload)
            self.table.update(info)
            return resp
        except (DialError, RpcError):
            self.table.remove(info.peer_id)
            return None

    def _lookup(self, key: bytes, method: str, payload: Any,
                stop_on_value: bool = False) -> Generator:
        """Iterative alpha-parallel lookup.

        Returns (value_or_None, closest_infos, providers, rounds).
        """
        self.stats["lookups"] += 1
        sim = self.node.sim
        shortlist: Dict[PeerId, PeerInfo] = {
            i.peer_id: i for i in self.table.closest(key, self.k)}
        queried: Set[PeerId] = set()
        found_value: Optional[Any] = None
        found_providers: List[PeerInfo] = []
        rounds = 0

        def dist(pid: PeerId) -> int:
            return pid.distance_to_key(key)

        best_seen = min((dist(p) for p in shortlist), default=None)
        while rounds < MAX_LOOKUP_ROUNDS:
            candidates = sorted(
                (p for p in shortlist if p not in queried), key=dist)[: self.alpha]
            if not candidates:
                break
            rounds += 1
            self.stats["rounds"] += 1
            procs = [sim.process(self._query(shortlist[p], method, payload))
                     for p in candidates]
            queried.update(candidates)
            results = yield sim.all_of(procs)
            improved = False
            for resp in results:
                if resp is None:
                    continue
                if method == "find_value" and resp[0] == "value":
                    found_value = resp[1]
                    if stop_on_value:
                        return found_value, self._top(shortlist, key), found_providers, rounds
                    continue
                if method == "get_providers":
                    provs, closer = resp
                    for pi in provs:
                        if pi.peer_id not in {x.peer_id for x in found_providers}:
                            found_providers.append(pi)
                            self.node.remember(pi)
                else:
                    closer = resp if method == "find_node" else resp[1]
                for info in closer:
                    if info.peer_id == self.node.peer_id:
                        continue
                    self.node.remember(info)
                    if info.peer_id not in shortlist:
                        shortlist[info.peer_id] = info
                        d = dist(info.peer_id)
                        if best_seen is None or d < best_seen:
                            best_seen = d
                            improved = True
            if found_providers and method == "get_providers" and stop_on_value:
                break
            if not improved:
                # converged: stop once the k closest have all been queried
                top = sorted(shortlist, key=dist)[: self.k]
                if all(p in queried for p in top):
                    break
        return found_value, self._top(shortlist, key), found_providers, rounds

    def _top(self, shortlist: Dict[PeerId, PeerInfo], key: bytes) -> List[PeerInfo]:
        return [shortlist[p] for p in
                sorted(shortlist, key=lambda q: q.distance_to_key(key))[: self.k]]

    # ------------------------------------------------------------- public API
    def bootstrap_lookup(self) -> Generator:
        """Self-lookup to populate the routing table."""
        yield from self._lookup(self.node.peer_id.digest, "find_node",
                                self.node.peer_id.digest)

    def find_node(self, key: bytes) -> Generator:
        _, closest, _, _ = yield from self._lookup(key, "find_node", key)
        return closest

    def put(self, key: bytes, value: Any) -> Generator:
        """Store a record on the k closest peers."""
        _, closest, _, _ = yield from self._lookup(key, "find_node", key)
        sim = self.node.sim
        procs = [sim.process(self._query(i, "put", (key, value)))
                 for i in closest[: self.k]]
        self.records[key] = (value, sim.now)
        if procs:
            yield sim.all_of(procs)
        return len(procs)

    def get(self, key: bytes) -> Generator:
        if key in self.records:
            return self.records[key][0]
        value, _, _, _ = yield from self._lookup(
            key, "find_value", key, stop_on_value=True)
        return value

    def provide(self, key: bytes) -> Generator:
        """Announce this node as a provider for ``key`` (a CID digest)."""
        me = self.node.info()
        self.providers.setdefault(key, {})[me.peer_id] = (me, self.node.sim.now)
        _, closest, _, _ = yield from self._lookup(key, "find_node", key)
        sim = self.node.sim
        procs = [sim.process(self._query(i, "add_provider", (key, me)))
                 for i in closest[: self.k]]
        if procs:
            yield sim.all_of(procs)
        return len(procs)

    def unprovide(self, key: bytes) -> Generator:
        """Withdraw this node's provider record for ``key`` — locally and
        at the closest nodes :meth:`provide` targeted.  Used by planned
        retirement (a replica scaling back down); crashes still rely on
        record staleness, as ever."""
        me = self.node.info()
        entry = self.providers.get(key)
        if entry is not None:
            entry.pop(me.peer_id, None)
            if not entry:
                self.providers.pop(key, None)
        _, closest, _, _ = yield from self._lookup(key, "find_node", key)
        sim = self.node.sim
        procs = [sim.process(self._query(i, "drop_provider",
                                         (key, me.peer_id)))
                 for i in closest[: self.k]]
        if procs:
            yield sim.all_of(procs)
        return len(procs)

    def find_providers(self, key: bytes, first_only: bool = False) -> Generator:
        local = [i for i, _ in self.providers.get(key, {}).values()]
        if local and first_only:
            return local
        _, _, provs, _ = yield from self._lookup(
            key, "get_providers", key, stop_on_value=first_only)
        merged = {p.peer_id: p for p in local + provs}
        return list(merged.values())
