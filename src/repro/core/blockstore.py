"""CID-indexed block storage with verification on put."""

from __future__ import annotations

from typing import Dict, Iterable, List, Optional

from .cid import CID


class BlockStore:
    def __init__(self) -> None:
        self._blocks: Dict[CID, bytes] = {}
        self.bytes_stored = 0

    def put(self, cid: CID, data: bytes) -> None:
        if not cid.verify(data):
            raise ValueError(f"data does not match {cid}")
        if cid not in self._blocks:
            self.bytes_stored += len(data)
        self._blocks[cid] = data

    def put_many(self, blocks: Dict[CID, bytes]) -> None:
        for cid, data in blocks.items():
            self.put(cid, data)

    def get(self, cid: CID) -> Optional[bytes]:
        return self._blocks.get(cid)

    def has(self, cid: CID) -> bool:
        return cid in self._blocks

    def delete(self, cid: CID) -> None:
        data = self._blocks.pop(cid, None)
        if data is not None:
            self.bytes_stored -= len(data)

    def cids(self) -> List[CID]:
        return list(self._blocks.keys())

    def __len__(self) -> int:
        return len(self._blocks)
