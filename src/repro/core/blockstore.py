"""CID-indexed block storage: verification on put, pinning, LRU eviction.

The store is capacity-bounded (``capacity`` bytes, ``None`` = unbounded).
Blocks reachable from a *pinned* root — the walk follows both flat (v1) and
hierarchical (v2) manifests — are never evicted; everything else is fair
game for LRU eviction once ``bytes_stored`` exceeds the budget.  Pins are
reference-counted, so two checkpoint versions that share tensor sub-DAGs
can be pinned and unpinned independently without stranding shared blocks.

Policy hooks used by the layers above: publishers pin what they announce,
fetchers pin the latest version of each artifact lineage they follow
(``LatticaNode.pin_latest``) so older versions age out first.  Hit/miss/
eviction counters feed ``metrics.dashboard()``.
"""

from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Optional, Set

from .cid import CID, dag_reachable


class BlockStore:
    def __init__(self, capacity: Optional[int] = None) -> None:
        #: insertion/touch order = LRU order (oldest first)
        self._blocks: "OrderedDict[CID, bytes]" = OrderedDict()
        self._pins: Dict[CID, int] = {}
        #: per-root record of exactly which CIDs that pin incremented —
        #: unpin releases this set, never a fresh reachability walk (blocks
        #: that arrived after the pin were never counted, so re-walking at
        #: unpin time would decrement refcounts other roots still rely on)
        self._pin_sets: Dict[CID, List[CID]] = {}
        self.capacity = capacity
        self.bytes_stored = 0
        self.stats = {"hits": 0, "misses": 0, "evictions": 0,
                      "bytes_evicted": 0}

    # ------------------------------------------------------------ block ops
    def put(self, cid: CID, data: bytes) -> None:
        if not cid.verify(data):
            raise ValueError(f"data does not match {cid}")
        if cid not in self._blocks:
            self.bytes_stored += len(data)
        self._blocks[cid] = data
        self._blocks.move_to_end(cid)
        # the incoming block is exempt from its own sweep: when everything
        # older is pinned/held, evicting the block we were just asked to
        # store would turn an over-budget put into silent data loss
        self._evict(exclude=cid)

    def put_many(self, blocks: Dict[CID, bytes]) -> None:
        for cid, data in blocks.items():
            self.put(cid, data)

    def get(self, cid: CID) -> Optional[bytes]:
        data = self._blocks.get(cid)
        if data is None:
            self.stats["misses"] += 1
            return None
        self.stats["hits"] += 1
        self._blocks.move_to_end(cid)
        return data

    def peek(self, cid: CID) -> Optional[bytes]:
        """Read without touching LRU order or hit/miss counters."""
        return self._blocks.get(cid)

    def has(self, cid: CID) -> bool:
        return cid in self._blocks

    def delete(self, cid: CID) -> None:
        if self._pins.get(cid):
            raise ValueError(f"cannot delete pinned block {cid}")
        data = self._blocks.pop(cid, None)
        if data is not None:
            self.bytes_stored -= len(data)

    def cids(self) -> List[CID]:
        return list(self._blocks.keys())

    def __len__(self) -> int:
        return len(self._blocks)

    # ------------------------------------------------------------- pinning
    def _reachable(self, root: CID) -> List[CID]:
        return dag_reachable(root, self.peek)

    @property
    def pinned_roots(self) -> Set[CID]:
        return set(self._pin_sets)

    def pin(self, root: CID) -> int:
        """Pin every block reachable from ``root`` (recursive over manifests
        present in the store).  Idempotent per root; returns the number of
        CIDs pinned.  The exact pinned set is recorded so :meth:`unpin`
        releases it symmetrically."""
        if root in self._pin_sets:
            return 0
        reach = self._reachable(root)
        for c in reach:
            self._pins[c] = self._pins.get(c, 0) + 1
        self._pin_sets[root] = reach
        return len(reach)

    def unpin(self, root: CID) -> int:
        """Release a ``pin``; blocks whose refcount drops to zero become
        evictable (lazily, at the next over-budget put).  Releases exactly
        the CID set recorded at pin time — blocks that became reachable from
        ``root`` only after the pin were never refcounted for it, and must
        not lose refcounts another root may hold."""
        reach = self._pin_sets.pop(root, None)
        if reach is None:
            return 0
        for c in reach:
            n = self._pins.get(c, 0) - 1
            if n <= 0:
                self._pins.pop(c, None)
            else:
                self._pins[c] = n
        self._evict()
        return len(reach)

    def pinned(self, cid: CID) -> bool:
        return self._pins.get(cid, 0) > 0

    def hold(self, cid: CID) -> None:
        """Transient single-block pin for in-flight transfers: a fetch
        session holds blocks as they arrive so LRU eviction can't cannibalize
        a version while it is still being assembled.  Pair with
        :meth:`release` (which deliberately does NOT trigger eviction, so a
        caller can promote the session's root to a real pin first)."""
        self._pins[cid] = self._pins.get(cid, 0) + 1

    def release(self, cid: CID) -> None:
        n = self._pins.get(cid, 0) - 1
        if n <= 0:
            self._pins.pop(cid, None)
        else:
            self._pins[cid] = n

    # ------------------------------------------------------- simsan gauges
    def outstanding_holds(self) -> int:
        """Transient transfer holds currently live: total pin refcounts not
        accounted for by a recorded root pin set.  Zero whenever every
        ``hold`` was paired with a ``release`` — the leak-audit invariant."""
        total = sum(self._pins.values())
        rooted = sum(len(s) for s in self._pin_sets.values())
        return total - rooted

    def pinned_root_count(self) -> int:
        return len(self._pin_sets)

    # ------------------------------------------------------------ eviction
    def set_capacity(self, capacity: Optional[int]) -> None:
        self.capacity = capacity
        self._evict()

    def _evict(self, exclude: Optional[CID] = None) -> None:
        if self.capacity is None or self.bytes_stored <= self.capacity:
            return
        # oldest-first sweep; pinned blocks are skipped, never reordered out
        for cid in list(self._blocks.keys()):
            if self.bytes_stored <= self.capacity:
                break
            if self._pins.get(cid, 0) > 0 or cid == exclude:
                continue
            data = self._blocks.pop(cid)
            self.bytes_stored -= len(data)
            self.stats["evictions"] += 1
            self.stats["bytes_evicted"] += len(data)
