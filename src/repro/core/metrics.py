"""Node metrics & fleet dashboard.

The paper's user study (§5) lists "improved monitoring dashboards" as the
top feedback item.  Every subsystem already keeps counters; this module
aggregates them into a per-node snapshot and renders a fleet-wide text
dashboard (the kind of operational view an SRE would curl off a node).
"""

from __future__ import annotations

from typing import Any, Dict, Iterable, List, TYPE_CHECKING

from .service import MethodStats

if TYPE_CHECKING:  # pragma: no cover
    from .node import LatticaNode


def node_snapshot(node: "LatticaNode") -> Dict[str, Any]:
    """Flat metrics snapshot of one node (all subsystem counters)."""
    t = node.transport
    snap: Dict[str, Any] = {
        "name": node.host.name,
        "region": node.host.region,
        "reachability": t.reachability,
        "is_relay": t.is_relay,
        "n_connections": sum(
            1 for conns in node.host._connections.values()
            for c in conns if not c.closed),
        "n_relayed": sum(
            1 for conns in node.host._connections.values()
            for c in conns if not c.closed and c.relayed),
        "peers_known": len(node.peers),
        "dht_table": len(node.dht.table),
        "dht_records": len(node.dht.records),
        "dht_provider_keys": len(node.dht.providers),
        "blocks": len(node.blockstore),
        "bytes_stored": node.blockstore.bytes_stored,
        "store_capacity": node.blockstore.capacity,
        "pinned_roots": len(node.blockstore.pinned_roots),
        "crdt_keys": len(node.store.entries),
    }
    snap["relay_reservations"] = len(t.relay_reservations)
    snap["relays_held"] = len(node.relay_infos)
    for prefix, stats in (("transport", t.stats),
                          ("relay", t.relay_stats),
                          ("rpc", node.router.stats),
                          ("dht", node.dht.stats),
                          ("pubsub", node.pubsub.stats),
                          ("crdt", node.crdt_stats),
                          ("store", node.blockstore.stats),
                          ("bitswap", node.bitswap.stats)):
        for k, v in stats.items():
            snap[f"{prefix}.{k}"] = v
    # serving plane: a node may host several ShardServers / ShardClients
    # (registered by serving/sharded.py); sum their counters
    servers = getattr(node, "shard_servers", [])
    if servers:
        snap["serving.shards"] = len(servers)
        snap["serving.slots_used"] = sum(s.engine.slots_used for s in servers)
        snap["serving.queue_depth"] = sum(s.engine.queue_depth for s in servers)
        for key in ("admitted", "evicted", "steps", "step_sessions",
                    "slot_reuse", "queue_peak", "pages_peak", "idle_evicted"):
            snap[f"serving.{key}"] = sum(s.engine.stats[key] for s in servers)
    clients = getattr(node, "shard_clients", [])
    if clients:
        for key in ("requests", "completed", "failed_sessions",
                    "sessions_migrated", "failovers", "hedged", "calls"):
            snap[f"serving.client.{key}"] = sum(c.stats[key] for c in clients)
    return snap


_DASH_COLS = [
    ("name", 8), ("region", 6), ("reachability", 9), ("n_connections", 5),
    ("dht_table", 6), ("blocks", 7), ("bytes_stored", 12),
    ("pinned_roots", 4), ("store.evictions", 6),
    ("bitswap.bytes_served", 12), ("bitswap.bytes_fetched", 12),
    ("rpc.unary_served", 8),
]


def rpc_method_stats(nodes: Iterable["LatticaNode"]) -> Dict[str, MethodStats]:
    """Aggregate the metrics interceptor's client-side per-method stats
    across a fleet: method -> merged calls/errors/latency reservoir."""
    merged: Dict[str, MethodStats] = {}
    for node in nodes:
        for method, stats in node.rpc_metrics.client.items():
            agg = merged.get(method)
            if agg is None:
                # unbounded: a bounded deque would silently keep only the
                # last nodes' samples and skew the fleet percentiles
                agg = merged[method] = MethodStats(maxlen=None)
            agg.calls += stats.calls
            agg.errors += stats.errors
            agg.latencies.extend(stats.latencies)
    return merged


def rpc_method_table(nodes: Iterable["LatticaNode"]) -> str:
    """Per-method RPC table (calls, errors, p50/p95 latency in ms)."""
    merged = rpc_method_stats(nodes)
    head = f"{'method':<22} {'calls':>7} {'errors':>6} {'p50_ms':>8} {'p95_ms':>8}"
    lines = [head, "-" * len(head)]
    for method in sorted(merged):
        s = merged[method]
        lines.append(f"{method:<22} {s.calls:>7} {s.errors:>6} "
                     f"{s.percentile(0.50) * 1e3:>8.2f} "
                     f"{s.percentile(0.95) * 1e3:>8.2f}")
    return "\n".join(lines)


def dashboard(nodes: Iterable["LatticaNode"]) -> str:
    """Fleet-wide text dashboard."""
    nodes = list(nodes)
    rows = [node_snapshot(n) for n in nodes]
    head = " ".join(f"{name.split('.')[-1][:w]:>{w}}" for name, w in _DASH_COLS)
    lines = [head, "-" * len(head)]
    for r in rows:
        lines.append(" ".join(
            f"{str(r.get(name, ''))[:w]:>{w}}" for name, w in _DASH_COLS))
    fwd = [r.get("pubsub.forwarded", 0) for r in rows] or [0]
    totals = {
        "direct_ok": sum(r.get("transport.punch_ok", 0) for r in rows),
        "punch_fail": sum(r.get("transport.punch_fail", 0) for r in rows),
        # mesh relay load: a healthy scored mesh keeps max near mean —
        # flood dissemination concentrates on well-known hubs instead
        "mesh_relay_max": max(fwd),
        "mesh_relay_mean": round(sum(fwd) / len(fwd), 1),
        # anti-entropy probe bytes (Merkle summary walks, O(log n)/probe)
        "summary_bytes": sum(r.get("crdt.mst_probe_bytes", 0) for r in rows),
        "bytes_moved": sum(r.get("bitswap.bytes_fetched", 0) for r in rows),
        "rpc_served": sum(r.get("rpc.unary_served", 0) for r in rows),
        "rpc_errors": sum(r.get("rpc.errors", 0) for r in rows),
        "sessions_migrated": sum(
            r.get("serving.client.sessions_migrated", 0) for r in rows),
    }
    lines.append("-" * len(head))
    lines.append("fleet: " + "  ".join(f"{k}={v}" for k, v in totals.items()))
    lines.append("")
    lines.append("per-method RPC (client side, fleet-wide):")
    lines.append(rpc_method_table(nodes))
    return "\n".join(lines)
