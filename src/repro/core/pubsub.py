"""Scored gossipsub-style pub/sub over the connected overlay.

Topics carry model-version announcements and CRDT delta pushes.  Each
subscriber maintains a bounded-degree *mesh* per topic (gossipsub v1.1
style): messages are eagerly pushed along mesh edges only, so per-peer
relay load is bounded by the mesh degree instead of concentrating on
well-known hubs the way the old flood did.  A heartbeat daemon grafts the
mesh back up to degree when peers churn out, prunes it down (worst score
first) when over-subscribed, and lazily advertises recent message IDs
(IHAVE) to a few off-mesh subscribers, who pull anything they missed
(IWANT) — the repair path that heals mesh partitions.

Peer scores feed graft/prune decisions: first-seen deliveries raise a
peer's score, duplicate deliveries and high delivery latency lower it, and
a peer's self-reported relay load discounts it as a graft target so load
spreads across the mesh.  Scores decay every heartbeat, so a formerly-good
peer that stops delivering drifts back toward prune candidacy.

Subscription state is exchanged through the same control surface
(``ps.ctl``): announces carry the full topic set, unsubscribes propagate
both eagerly (to currently-known peers) and lazily (any later announce
returns the current set), so late joiners never see a stale subscription.

Wire surface is a declared :class:`~repro.core.service.Service` — one
non-idempotent ``ps.msg`` push and one idempotent ``ps.ctl`` control
exchange.  Transient mesh state (pending IWANT pulls) registers a leak
gauge so ``Sim(sanitize=True)`` runs prove the repair plane drains.
"""

from __future__ import annotations

import hashlib
import itertools
from collections import OrderedDict
from typing import (Any, Callable, Dict, Generator, List, Optional, Set,
                    Tuple, TYPE_CHECKING)

from .peer import PeerId
from .rpc import RpcContext, RpcError
from .service import DeclaredSizeCodec, Fixed, Service, pickled, unary
from .simnet import DialError

if TYPE_CHECKING:  # pragma: no cover
    from .node import LatticaNode

#: target mesh degree per topic (D), with the low/high water marks the
#: heartbeat grafts up from / prunes down to (gossipsub v1.1 defaults)
MESH_DEGREE = 6
MESH_DEGREE_LO = 4
MESH_DEGREE_HI = 10

#: off-mesh subscribers that receive IHAVE gossip each heartbeat
GOSSIP_LAZY = 6

#: heartbeat cadence; each node's loop is phase-jittered from the sim rng
HEARTBEAT = 2.0

#: message-cache windows kept / advertised in IHAVE gossip (windows rotate
#: once per heartbeat, so repair reaches ~GOSSIP_WINDOWS heartbeats back)
MCACHE_WINDOWS = 5
GOSSIP_WINDOWS = 3

#: a requested-but-never-received message id expires after this long (the
#: pending-IWANT gauge must drain to baseline in sanitized runs)
IWANT_TIMEOUT = 2 * HEARTBEAT

#: most message ids pulled per control exchange — a rejoining node that
#: missed many messages spreads its repair pulls across advertisers and
#: heartbeats instead of turning one peer into the repair hotspot
IWANT_SERVE_CAP = 12

#: per-heartbeat multiplicative score decay
SCORE_DECAY = 0.8

#: mesh members scoring below this are dropped outright at the heartbeat —
#: the churn path: a departed peer fails its eager pushes, accumulates
#: failure penalties, and prunes itself out so a live subscriber is
#: grafted in its place
SCORE_PRUNE_THRESHOLD = -2.0

SEEN_CACHE = 4096

_seq = itertools.count(1)


class PubSubService(Service):
    """Gossip wire surface: eager message push + mesh control exchange.

    ``msg`` is deliberately *not* idempotent at the stub level — the mesh
    already dedups via the seen-cache, and stub retries would distort the
    gossip fan-out accounting.  The message payload carries its declared
    application size as the last tuple element (``DeclaredSizeCodec``).

    ``ctl`` is idempotent: every field is a state assertion (topic sets,
    mesh membership, have/want lists), so replaying one is harmless."""

    name = "ps"

    def __init__(self, pubsub: "PubSub"):
        self.pubsub = pubsub

    @unary("ps.msg", request=DeclaredSizeCodec(), response=Fixed(64),
           timeout=15.0)
    def msg(self, payload: Any, ctx: RpcContext) -> Generator:
        topic, data, mid, from_peer, sent_at, size = payload
        yield ctx.cpu(3e-6)
        self.pubsub._receive(topic, data, mid, from_peer, sent_at, size)
        return True

    @unary("ps.ctl", request=pickled(floor=96), response=pickled(floor=96),
           idempotent=True, timeout=15.0)
    def ctl(self, payload: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(3e-6)
        return self.pubsub._handle_ctl(payload)


class PubSub:
    def __init__(self, node: "LatticaNode"):
        self.node = node
        self.subscriptions: Dict[str, List[Callable[[str, Any, PeerId], None]]] = {}
        self.peer_topics: Dict[PeerId, Set[str]] = {}
        #: per-topic mesh membership (peers we eagerly push to / expect
        #: eager pushes from); bounded by MESH_DEGREE_HI
        self.mesh: Dict[str, Set[PeerId]] = {}
        #: heartbeat-computed peer scores (graft preference / prune order)
        self.scores: Dict[PeerId, float] = {}
        #: raw score inputs since the last heartbeat
        self._perf: Dict[PeerId, Dict[str, float]] = {}
        #: message cache for IWANT serving: mid -> (topic, data, sent_at,
        #: size), plus rotation windows for IHAVE advertisement
        self._mcache: Dict[bytes, Tuple[str, Any, float, int]] = {}
        self._mcache_windows: List[List[bytes]] = [[]]
        #: mids we asked a peer to push (IWANT) but have not yet received;
        #: strictly transient — expired by the heartbeat, gauged for leaks
        self._pending_iwant: Dict[bytes, float] = {}
        self._seen: "OrderedDict[bytes, None]" = OrderedDict()
        #: when set, subscription-change announces go to at most this many
        #: peers (live connections first).  Small fleets leave it None —
        #: every known peer hears every change directly; at 1k+ nodes the
        #: scale harness bounds it, matching gossipsub's rule of announcing
        #: subscriptions only over connected links.
        self.announce_cap: Optional[int] = None
        self.stats = {"published": 0, "delivered": 0, "forwarded": 0,
                      "duplicates": 0, "grafts": 0, "prunes": 0,
                      "ihave_sent": 0, "iwant_sent": 0, "repaired": 0,
                      "iwant_expired": 0, "ctl_rounds": 0}
        node.serve(PubSubService(self))
        node.sim.register_leak_check(
            f"pubsub.pending_iwant:{node.host.name}",
            lambda: len(self._pending_iwant))
        node.sim.process(self._heartbeat_loop(), daemon=True)

    # -- subscription management ---------------------------------------------
    def subscribe(self, topic: str,
                  callback: Callable[[str, Any, PeerId], None]) -> None:
        is_new = topic not in self.subscriptions
        self.subscriptions.setdefault(topic, []).append(callback)
        if is_new:
            self.mesh.setdefault(topic, set())
            self._push_subscription_update()

    def unsubscribe(self, topic: str,
                    callback: Optional[Callable] = None) -> None:
        """Drop one callback (or all with ``callback=None``).  When the
        last callback goes, the topic leaves our subscription set, the
        mesh for it dissolves (PRUNE to every member), and the removal
        propagates: eagerly to currently-known peers, and to late joiners
        through the full-set announce they trigger on contact."""
        cbs = self.subscriptions.get(topic)
        if cbs is None:
            return
        if callback is None:
            cbs.clear()
        else:
            try:
                cbs.remove(callback)
            except ValueError:
                pass
        if cbs:
            return
        del self.subscriptions[topic]
        self.mesh.pop(topic, None)
        self._push_subscription_update()

    def _push_subscription_update(self) -> None:
        """Proactively push our full topic set to every peer we know.
        Subscription state is otherwise exchanged only at announce time
        (bootstrap / explicit ``announce_subscriptions``), so a topic
        change made *after* joining would stay invisible to the mesh —
        fresh subscribers would miss the next publish, and unsubscribed
        peers would keep receiving pushes.  One tiny idempotent unary per
        peer, over reused connections."""
        node = self.node
        targets = self._sorted_peers(node.peers)
        cap = self.announce_cap
        if cap is not None and len(targets) > cap:
            def connected(pid: PeerId) -> bool:
                host = node.net.hosts.get(node.peers[pid].host_name)
                return (host is not None
                        and node.host.connection_to(host) is not None)
            live = [p for p in targets if connected(p)]
            rest = [p for p in targets if p not in set(live)]
            targets = (live + rest)[:cap]
        for pid in targets:
            node.sim.process(self.announce_subscriptions(pid))

    def announce_subscriptions(self, peer: "PeerId") -> Generator:
        """Tell one peer our full topic set (piggybacks on connect); the
        response carries the peer's topics, so both sides learn."""
        yield from self._ctl_roundtrip(peer, {})
        return None

    # -- control exchange -----------------------------------------------------
    def _ctl_doc(self, extra: Dict[str, Any]) -> Dict[str, Any]:
        doc = {"from": self.node.peer_id,
               "topics": sorted(self.subscriptions),
               "load": self.stats["forwarded"]}
        doc.update(extra)
        return doc

    def _ctl_roundtrip(self, peer: PeerId, extra: Dict[str, Any]) -> Generator:
        """One ``ps.ctl`` exchange with ``peer``: our full topic set (plus
        any graft/prune/ihave fields) out, their topic set and reactions
        back.  Responder IWANTs are served by spawning eager pushes of the
        cached messages."""
        info = self.node.peers.get(peer)
        if info is None:
            return None
        try:
            stub = self.node.stub(PubSubService, info)
            resp = yield from stub.ctl(self._ctl_doc(extra))
        except (DialError, RpcError):
            self._perf_of(peer)["fail"] += 1.0
            return None
        self.stats["ctl_rounds"] += 1
        if not isinstance(resp, dict):
            return None
        theirs = resp.get("topics")
        if isinstance(theirs, list):
            self._set_peer_topics(peer, {t for t in theirs
                                         if isinstance(t, str)})
        for t in resp.get("pruned", ()):        # graft refused
            members = self.mesh.get(t)
            if members is not None:
                members.discard(peer)
        wants = [m for m in resp.get("iwant", ()) if isinstance(m, bytes)]
        if wants:
            self._serve_iwant(peer, wants)
        self._note_load(peer, resp.get("load"))
        return resp

    def _handle_ctl(self, doc: Any) -> Dict[str, Any]:
        """Server side of ``ps.ctl``; returns the response doc."""
        if not isinstance(doc, dict) or not isinstance(doc.get("from"), PeerId):
            return {"topics": sorted(self.subscriptions)}
        frm = doc["from"]
        topics = doc.get("topics")
        if isinstance(topics, list):
            self._set_peer_topics(frm, {t for t in topics
                                        if isinstance(t, str)})
        self._note_load(frm, doc.get("load"))
        pruned: List[str] = []
        for t in doc.get("graft", ()):
            members = self.mesh.get(t)
            if (t in self.subscriptions and members is not None
                    and len(members) < MESH_DEGREE_HI):
                if frm not in members:
                    members.add(frm)
                    self.stats["grafts"] += 1
            else:
                pruned.append(t)
        for t in doc.get("prune", ()):
            members = self.mesh.get(t)
            if members is not None:
                members.discard(frm)
        wants: List[bytes] = []
        ihave = doc.get("ihave")
        if isinstance(ihave, dict):
            now = self.node.sim.now
            for t, mids in sorted(ihave.items()):
                if t not in self.subscriptions:
                    continue
                for mid in mids:
                    if len(wants) >= IWANT_SERVE_CAP:
                        break       # un-pulled ids stay unseen; the next
                        # advertiser's IHAVE re-offers them
                    if (isinstance(mid, bytes) and mid not in self._seen
                            and mid not in self._pending_iwant):
                        self._pending_iwant[mid] = now
                        wants.append(mid)
        if wants:
            self.stats["iwant_sent"] += len(wants)
        resp: Dict[str, Any] = {"topics": sorted(self.subscriptions),
                                "load": self.stats["forwarded"]}
        if pruned:
            resp["pruned"] = pruned
        if wants:
            resp["iwant"] = wants
        return resp

    def _set_peer_topics(self, peer: PeerId, topics: Set[str]) -> None:
        """Record a peer's full topic set; mesh edges for topics the peer
        no longer subscribes to dissolve immediately (UNSUBSCRIBE
        propagation — a pushed update or any later announce both land
        here, so late joiners converge on the same view)."""
        self.peer_topics[peer] = topics
        for t, members in self.mesh.items():
            if peer in members and t not in topics:
                members.discard(peer)

    def _note_load(self, peer: PeerId, load: Any) -> None:
        if isinstance(load, int) and load >= 0:
            self._perf_of(peer)["load"] = float(load)

    def _serve_iwant(self, peer: PeerId, mids: List[bytes]) -> None:
        """Push cached messages a peer asked for (repair path)."""
        info = self.node.peers.get(peer)
        if info is None:
            return
        for mid in mids:
            cached = self._mcache.get(mid)
            if cached is None:
                continue
            topic, data, sent_at, size = cached
            self.node.sim.process(self._send_one(
                info, topic, data, mid, sent_at, size))

    # -- message flow -----------------------------------------------------------
    def _msg_id(self, topic: str, data: Any, origin: PeerId, seq: int) -> bytes:
        h = hashlib.sha256()
        h.update(topic.encode())
        h.update(repr(data).encode())
        h.update(origin.digest)
        h.update(seq.to_bytes(8, "big"))
        return h.digest()[:16]

    def _mark_seen(self, mid: bytes) -> bool:
        if mid in self._seen:
            return False
        self._seen[mid] = None
        if len(self._seen) > SEEN_CACHE:
            self._seen.popitem(last=False)
        return True

    def _cache_msg(self, mid: bytes, topic: str, data: Any, sent_at: float,
                   size: int) -> None:
        if mid in self._mcache:
            return
        self._mcache[mid] = (topic, data, sent_at, size)
        self._mcache_windows[0].append(mid)

    def _perf_of(self, peer: PeerId) -> Dict[str, float]:
        return self._perf.setdefault(
            peer, {"first": 0.0, "dup": 0.0, "lat": 0.0, "load": 0.0,
                   "fail": 0.0})

    def _receive(self, topic: str, data: Any, mid: bytes, from_peer: PeerId,
                 sent_at: float, size: int) -> None:
        """A pushed message arrived (eager mesh push or IWANT repair)."""
        now = self.node.sim.now
        if mid in self._pending_iwant:
            del self._pending_iwant[mid]
            self.stats["repaired"] += 1
        perf = self._perf_of(from_peer)
        if not self._mark_seen(mid):
            self.stats["duplicates"] += 1
            perf["dup"] += 1.0
            return
        perf["first"] += 1.0
        # EWMA of how stale this peer's deliveries are (publish->here)
        perf["lat"] = 0.8 * perf["lat"] + 0.2 * max(now - sent_at, 0.0)
        self._cache_msg(mid, topic, data, sent_at, size)
        for cb in self.subscriptions.get(topic, []):
            self.stats["delivered"] += 1
            cb(topic, data, from_peer)
        # eager re-push along our mesh edges (origin/sender excluded);
        # relay load stays bounded by the mesh degree.  A node that is
        # neither subscribed nor meshed may relay only toward peers it
        # knows are interested — blind relays re-pushing to the
        # uninterested turn one publish on a watcher-less topic into an
        # overlay-wide flood (every node forwarding to MESH_DEGREE more)
        self.node.sim.process(self._forward(
            topic, data, mid, sent_at, size,
            exclude={from_peer, self.node.peer_id},
            last_resort=(topic in self.subscriptions
                         or bool(self.mesh.get(topic)))))

    def _eager_targets(self, topic: str, exclude: Set[PeerId],
                       last_resort: bool = True) -> List[PeerId]:
        """Push targets for one hop: the topic mesh when it has formed;
        before the first heartbeat (or for topics we merely relay) fall
        back to known subscribers, then to peers whose topic set we have
        not learned yet — bounded by MESH_DEGREE either way."""
        members = [p for p in self._sorted_peers(self.mesh.get(topic, ()))
                   if p not in exclude]
        if members:
            return members[:MESH_DEGREE_HI]
        interested = [p for p in self._sorted_peers(self.peer_topics)
                      if topic in self.peer_topics[p] and p not in exclude]
        unknown = [p for p in self._sorted_peers(self.node.peers)
                   if p not in self.peer_topics and p not in exclude
                   and p != self.node.peer_id] if last_resort else []
        # last resort: peers whose recorded topic set lacks the topic —
        # that knowledge may be stale, and relays like the bootstrap
        # servers know the *actual* subscribers; dropping them entirely
        # would strand messages whose only eager targets are undialable
        others = [p for p in self._sorted_peers(self.node.peers)
                  if p not in exclude and p != self.node.peer_id
                  and p in self.peer_topics
                  and topic not in self.peer_topics[p]] if last_resort \
            else []
        chosen = interested[:MESH_DEGREE]
        for pool in (unknown, others):
            for p in pool:
                if len(chosen) >= MESH_DEGREE:
                    break
                chosen.append(p)
        return chosen

    @staticmethod
    def _sorted_peers(peers: Any) -> List[PeerId]:
        """Deterministic iteration order for peer sets/dicts."""
        return sorted(peers, key=lambda p: p.digest)

    def publish(self, topic: str, data: Any, size: int = 256) -> Generator:
        self.stats["published"] += 1
        mid = self._msg_id(topic, data, self.node.peer_id, next(_seq))
        self._mark_seen(mid)
        sent_at = self.node.sim.now
        self._cache_msg(mid, topic, data, sent_at, size)
        yield from self._forward(topic, data, mid, sent_at, size,
                                 exclude={self.node.peer_id})
        return mid

    def _forward(self, topic: str, data: Any, mid: bytes, sent_at: float,
                 size: int, exclude: Set[PeerId],
                 last_resort: bool = True) -> Generator:
        targets = self._eager_targets(topic, exclude, last_resort)
        sim = self.node.sim
        procs = []
        for pid in targets:
            info = self.node.peers.get(pid)
            if info is None:
                continue
            procs.append(sim.process(self._send_one(
                info, topic, data, mid, sent_at, size)))
        if procs:
            yield sim.all_of(procs)
        return None

    def _send_one(self, info: Any, topic: str, data: Any, mid: bytes,
                  sent_at: float, size: int) -> Generator:
        try:
            stub = self.node.stub(PubSubService, info)
            yield from stub.msg((topic, data, mid, self.node.peer_id,
                                 sent_at, size))
            self.stats["forwarded"] += 1
        except (DialError, RpcError):
            # a failed eager push marks the peer as likely departed; the
            # penalty drives its score under SCORE_PRUNE_THRESHOLD so the
            # heartbeat replaces it with a live subscriber
            self._perf_of(info.peer_id)["fail"] += 1.0
        return None

    # -- heartbeat: mesh maintenance + lazy gossip ------------------------------
    def _heartbeat_loop(self) -> Generator:
        # phase jitter so a fleet's heartbeats spread across the interval
        # instead of synchronizing into one thundering event instant
        yield self.node.sim.rng.random() * HEARTBEAT
        while True:
            yield HEARTBEAT
            if (not self.subscriptions and not self._pending_iwant
                    and not self._mcache):
                continue        # idle node: keep the tick O(1)
            self._heartbeat()

    def _heartbeat(self) -> None:
        now = self.node.sim.now
        # 1. expire IWANTs that were never answered (peer died / lied)
        for mid in [m for m, t in self._pending_iwant.items()
                    if now - t > IWANT_TIMEOUT]:
            del self._pending_iwant[mid]
            self.stats["iwant_expired"] += 1
        # 2. refresh scores from the window's delivery performance
        self._refresh_scores()
        # 3. per-topic mesh maintenance + IHAVE gossip, batched per peer
        ctl: Dict[PeerId, Dict[str, Any]] = {}
        for topic in sorted(self.subscriptions):
            self._maintain_topic(topic, ctl)
        self._gossip_ihave(ctl)
        for peer in self._sorted_peers(ctl):
            self.node.sim.process(self._ctl_roundtrip(peer, ctl[peer]))
        # 4. rotate the message-cache windows
        self._mcache_windows.insert(0, [])
        while len(self._mcache_windows) > MCACHE_WINDOWS:
            for mid in self._mcache_windows.pop():
                self._mcache.pop(mid, None)

    def _refresh_scores(self) -> None:
        for peer in self._sorted_peers(self._perf):
            perf = self._perf[peer]
            gain = perf["first"] - 0.5 * perf["dup"] - 2.0 * perf["lat"]
            # self-reported relay load discounts overloaded graft targets;
            # delivery failures (dial/rpc errors) weigh hardest — they mean
            # the peer is gone or unreachable, not merely slow
            gain -= 0.01 * perf["load"] + 1.5 * perf.get("fail", 0.0)
            prev = self.scores.get(peer, 0.0)
            self.scores[peer] = SCORE_DECAY * prev + gain
            perf["first"] = perf["dup"] = perf["fail"] = 0.0
        # scores of silent peers decay toward zero
        for peer in self.scores:
            if peer not in self._perf:
                self.scores[peer] *= SCORE_DECAY
        # snap near-zero scores to zero so a penalized peer that has been
        # quiet long enough becomes graft-eligible again (decay alone only
        # approaches zero asymptotically from below)
        for peer, s in self.scores.items():
            if s != 0.0 and abs(s) < 0.05:
                self.scores[peer] = 0.0

    def _score(self, peer: PeerId) -> float:
        return self.scores.get(peer, 0.0)

    def _maintain_topic(self, topic: str,
                        ctl: Dict[PeerId, Dict[str, Any]]) -> None:
        members = self.mesh.setdefault(topic, set())
        # drop mesh members that vanished, no longer subscribe, or whose
        # score collapsed (failed deliveries after churning out)
        for peer in list(members):
            if (peer not in self.node.peers
                    or topic not in self.peer_topics.get(peer, ())
                    or self._score(peer) < SCORE_PRUNE_THRESHOLD):
                members.discard(peer)
                self.stats["prunes"] += 1
        if len(members) < MESH_DEGREE_LO:
            candidates = [p for p in self._sorted_peers(self.peer_topics)
                          if topic in self.peer_topics[p]
                          and p not in members and p != self.node.peer_id
                          and p in self.node.peers
                          and self._score(p) >= 0.0]
            candidates.sort(key=lambda p: (-self._score(p), p.digest))
            for peer in candidates[:MESH_DEGREE - len(members)]:
                members.add(peer)
                self.stats["grafts"] += 1
                ctl.setdefault(peer, {}).setdefault("graft", []).append(topic)
        elif len(members) > MESH_DEGREE_HI:
            ranked = sorted(members, key=lambda p: (self._score(p), p.digest))
            for peer in ranked[:len(members) - MESH_DEGREE]:
                members.discard(peer)
                self.stats["prunes"] += 1
                ctl.setdefault(peer, {}).setdefault("prune", []).append(topic)

    def _gossip_ihave(self, ctl: Dict[PeerId, Dict[str, Any]]) -> None:
        """Advertise recent message ids to a few off-mesh subscribers per
        topic — the lazy pull path that repairs holes the eager mesh
        missed (partitions, churned-out members)."""
        recent: Dict[str, List[bytes]] = {}
        for window in self._mcache_windows[:GOSSIP_WINDOWS]:
            for mid in window:
                cached = self._mcache.get(mid)
                if cached is not None:
                    recent.setdefault(cached[0], []).append(mid)
        if not recent:
            return
        for topic in sorted(recent):
            members = self.mesh.get(topic, set())
            lazy = [p for p in self._sorted_peers(self.peer_topics)
                    if topic in self.peer_topics[p] and p not in members
                    and p != self.node.peer_id and p in self.node.peers]
            if not lazy:
                continue
            rng = self.node.sim.rng
            if len(lazy) > GOSSIP_LAZY:
                lazy = rng.sample(lazy, GOSSIP_LAZY)
            for peer in lazy:
                doc = ctl.setdefault(peer, {})
                doc.setdefault("ihave", {})[topic] = list(recent[topic])
                self.stats["ihave_sent"] += 1
