"""Gossip pub/sub over the connected overlay (gossipsub-lite).

Topics carry model-version announcements and CRDT digests.  Publishing
floods to mesh peers (bounded degree) with a seen-cache to stop echoes;
subscription state is exchanged lazily via the announce RPC itself.
"""

from __future__ import annotations

import hashlib
import itertools
from collections import OrderedDict
from typing import Any, Callable, Dict, Generator, List, Set, TYPE_CHECKING

from .peer import PeerId
from .rpc import RpcContext, RpcError
from .service import DeclaredSizeCodec, Fixed, Service, pickled, unary
from .simnet import DialError

if TYPE_CHECKING:  # pragma: no cover
    from .node import LatticaNode

MESH_DEGREE = 6
SEEN_CACHE = 4096

_seq = itertools.count(1)


class PubSubService(Service):
    """Gossip wire surface: message push + lazy subscription exchange.

    ``msg`` is deliberately *not* idempotent at the stub level — the flood
    already dedups via the seen-cache, and stub retries would distort the
    gossip fan-out accounting.  The message payload carries its declared
    application size as the last tuple element (``DeclaredSizeCodec``)."""

    name = "ps"

    def __init__(self, pubsub: "PubSub"):
        self.pubsub = pubsub

    @unary("ps.msg", request=DeclaredSizeCodec(), response=Fixed(64),
           timeout=15.0)
    def msg(self, payload: Any, ctx: RpcContext) -> Generator:
        topic, data, mid, from_peer, size = payload
        ps = self.pubsub
        yield ctx.cpu(3e-6)
        if not ps._mark_seen(mid):
            ps.stats["duplicates"] += 1
            return True
        for cb in ps.subscriptions.get(topic, []):
            ps.stats["delivered"] += 1
            cb(topic, data, from_peer)
        # re-flood to our mesh (eager push), preserving the declared size
        ps.node.sim.process(ps._forward(
            topic, data, mid, size,
            exclude={from_peer, ps.node.peer_id}))
        return True

    @unary("ps.sub", request=pickled(floor=96), response=pickled(floor=96),
           idempotent=True, timeout=15.0)
    def sub(self, payload: Any, ctx: RpcContext) -> Generator:
        peer_id, topics = payload
        self.pubsub.peer_topics[peer_id] = set(topics)
        yield ctx.cpu(2e-6)
        return sorted(self.pubsub.subscriptions)


class PubSub:
    def __init__(self, node: "LatticaNode"):
        self.node = node
        self.subscriptions: Dict[str, List[Callable[[str, Any, PeerId], None]]] = {}
        self.peer_topics: Dict[PeerId, Set[str]] = {}
        self._seen: "OrderedDict[bytes, None]" = OrderedDict()
        self.stats = {"published": 0, "delivered": 0, "forwarded": 0, "duplicates": 0}
        node.serve(PubSubService(self))

    # -- subscription management ---------------------------------------------
    def subscribe(self, topic: str, callback: Callable[[str, Any, PeerId], None]) -> None:
        is_new = topic not in self.subscriptions
        self.subscriptions.setdefault(topic, []).append(callback)
        if is_new:
            self._push_subscription_update()

    def _push_subscription_update(self) -> None:
        """Proactively push our topic set to every peer we know.
        Subscription state is otherwise exchanged only at announce time
        (bootstrap / explicit ``announce_subscriptions``), so a
        subscription made *after* joining would stay invisible to the mesh
        and the fresh subscriber would miss the next publish.  The update
        is one tiny idempotent unary per peer, over reused connections."""
        node = self.node
        for pid in list(node.peers):
            node.sim.process(self.announce_subscriptions(pid))

    def announce_subscriptions(self, peer: "PeerId") -> Generator:
        """Tell one peer which topics we care about (piggybacks on connect);
        the response carries the peer's topics, so both sides learn."""
        info = self.node.peers.get(peer)
        if info is None:
            return None
        try:
            stub = self.node.stub(PubSubService, info)
            theirs = yield from stub.sub((self.node.peer_id,
                                          sorted(self.subscriptions)))
            if isinstance(theirs, list):
                self.peer_topics[peer] = {
                    t for t in theirs if isinstance(t, str)}
        except (DialError, RpcError):
            pass
        return None

    # -- message flow -----------------------------------------------------------
    def _msg_id(self, topic: str, data: Any, origin: PeerId, seq: int) -> bytes:
        h = hashlib.sha256()
        h.update(topic.encode())
        h.update(repr(data).encode())
        h.update(origin.digest)
        h.update(seq.to_bytes(8, "big"))
        return h.digest()[:16]

    def _mark_seen(self, mid: bytes) -> bool:
        if mid in self._seen:
            return False
        self._seen[mid] = None
        if len(self._seen) > SEEN_CACHE:
            self._seen.popitem(last=False)
        return True

    def _mesh_peers(self, topic: str, exclude: Set[PeerId]) -> List[PeerId]:
        interested = [p for p, t in self.peer_topics.items()
                      if topic in t and p not in exclude]
        unknown = [p for p in self.node.peers
                   if p not in self.peer_topics and p not in exclude
                   and p != self.node.peer_id]
        # prefer peers known to subscribe, then unknowns, then peers whose
        # recorded topic set lacks the topic: that knowledge may be stale
        # (sets are exchanged, not streamed), and relays like the bootstrap
        # servers know the *actual* subscribers — dropping them from the
        # flood used to strand messages whose only eager targets were
        # undialable
        others = [p for p in self.node.peers
                  if p not in exclude and p != self.node.peer_id
                  and p in self.peer_topics and topic not in self.peer_topics[p]]
        chosen = interested[:MESH_DEGREE]
        for pool in (unknown, others):
            for p in pool:
                if len(chosen) >= MESH_DEGREE:
                    break
                chosen.append(p)
        return chosen

    def publish(self, topic: str, data: Any, size: int = 256) -> Generator:
        self.stats["published"] += 1
        mid = self._msg_id(topic, data, self.node.peer_id, next(_seq))
        self._mark_seen(mid)
        yield from self._forward(topic, data, mid, size,
                                 exclude={self.node.peer_id})
        return mid

    def _forward(self, topic: str, data: Any, mid: bytes, size: int,
                 exclude: Set[PeerId]) -> Generator:
        targets = self._mesh_peers(topic, exclude)
        sim = self.node.sim
        procs = []
        for pid in targets:
            info = self.node.peers.get(pid)
            if info is None:
                continue
            procs.append(sim.process(self._send_one(info, topic, data, mid, size)))
        if procs:
            yield sim.all_of(procs)
        return None

    def _send_one(self, info: Any, topic: str, data: Any, mid: bytes,
                  size: int) -> Generator:
        try:
            stub = self.node.stub(PubSubService, info)
            yield from stub.msg((topic, data, mid, self.node.peer_id, size))
            self.stats["forwarded"] += 1
        except (DialError, RpcError):
            pass
        return None
