"""Bitswap-style block exchange (the paper's decentralized-CDN layer).

Wantlist-driven parallel block fetch: a session resolves providers via the
DHT (or a rendezvous hint), pulls the manifest, then swarms the leaf blocks
across every live provider with a bounded in-flight window.  Each block is
hash-verified against its CID on arrival; fetched blocks are stored and
re-provided, so popular artifacts gain seeders as they spread — this is what
makes RL fleet-wide model dissemination scale in the paper's Scenario 3.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Dict, Generator, List, Optional, Set, TYPE_CHECKING

from .cid import CID, decode_manifest
from .dht import PeerInfo
from .rpc import RpcChannel, RpcContext, RpcError
from .service import CodecFn, Fixed, Service, streaming, unary
from .simnet import DialError

if TYPE_CHECKING:  # pragma: no cover
    from .node import LatticaNode

MAX_IN_FLIGHT = 32
BLOCK_REQ_SIZE = 96
#: above this many wanted blocks per provider, use the streaming plane
#: (one backpressured channel per provider) instead of per-block unary
STREAM_FETCH_MIN = 4


class FetchError(Exception):
    pass


_BLOCK_RESP = CodecFn(
    "block_resp",
    lambda p: max(len(p[1]), 64) if p[0] == "block" and p[1] else 64)


class BitswapService(Service):
    """Block exchange: per-block unary gets + bulk streaming fetch."""

    name = "bs"

    def __init__(self, bitswap: "Bitswap"):
        self.bitswap = bitswap

    @unary("bs.get", request=Fixed(BLOCK_REQ_SIZE), response=_BLOCK_RESP,
           idempotent=True, timeout=120.0)
    def get(self, payload: Any, ctx: RpcContext) -> Generator:
        cid: CID = payload
        bs = self.bitswap
        block = bs.node.blockstore.get(cid)
        yield ctx.cpu(8e-6)
        if block is None:
            return ("missing", None)
        bs.stats["blocks_served"] += 1
        bs.stats["bytes_served"] += len(block)
        return ("block", block)

    @streaming("bs.fetch")
    def fetch(self, chan: RpcChannel, ctx: RpcContext) -> Generator:
        """Streaming plane: receive a wantlist, stream the blocks back under
        the channel's byte-credit backpressure (paper §2, streaming mode)."""
        bs = self.bitswap
        try:
            wants = yield from chan.recv(timeout=60.0)
        except RpcError:
            return
        bs.stats["stream_sessions"] += 1
        for cid in wants:
            block = bs.node.blockstore.get(cid)
            yield ctx.cpu(8e-6)
            if block is not None:
                bs.stats["blocks_served"] += 1
                bs.stats["bytes_served"] += len(block)
            try:
                yield from chan.send((cid, block),
                                     len(block) if block else 64)
            except RpcError:
                return
        chan.end()


class Bitswap:
    def __init__(self, node: "LatticaNode"):
        self.node = node
        self.stats = {"blocks_served": 0, "blocks_fetched": 0,
                      "bytes_served": 0, "bytes_fetched": 0, "retries": 0,
                      "stream_sessions": 0}
        node.serve(BitswapService(self))

    # ------------------------------------------------------------- client
    def _fetch_blocks_stream(self, info: PeerInfo,
                             cids: List[CID]) -> Generator:
        """Bulk fetch over one streaming channel; returns {cid: bytes} for
        whatever verified blocks arrived (partial on provider failure)."""
        got: Dict[CID, bytes] = {}
        try:
            stub = self.node.stub(BitswapService, info)
            chan = yield from stub.fetch()
            yield from chan.send(list(cids), 48 * len(cids))
            for _ in range(len(cids)):
                cid, block = yield from chan.recv(timeout=120.0)
                if block is not None and cid.verify(block):
                    got[cid] = block
        except (DialError, RpcError):
            pass
        return got

    def _fetch_block(self, info: PeerInfo, cid: CID) -> Generator:
        """Fetch one block from one provider; returns bytes or None."""
        try:
            stub = self.node.stub(BitswapService, info)
            resp = yield from stub.get(cid)
        except (DialError, RpcError):
            return None
        kind, block = resp
        if kind != "block" or block is None or not cid.verify(block):
            return None
        return block

    def fetch_dag(self, root: CID,
                  hint_providers: Optional[List[PeerInfo]] = None) -> Generator:
        """Fetch a manifest-rooted DAG; returns the reassembled bytes.

        Providers come from hints (rendezvous / pubsub announcement) plus the
        DHT provider records.  Leaf blocks are swarmed across providers with
        a bounded window; failed providers are dropped and their assigned
        blocks requeued on survivors.
        """
        node = self.node
        sim = node.sim
        if node.blockstore.has(root):
            manifest = node.blockstore.get(root)
        else:
            manifest = None
        providers: List[PeerInfo] = list(hint_providers or [])
        if not providers:
            providers = yield from node.dht.find_providers(root.key)
        providers = [p for p in providers if p.peer_id != node.peer_id]
        if manifest is None:
            if not providers:
                raise FetchError(f"no providers for {root}")
            for info in providers:
                manifest = yield from self._fetch_block(info, root)
                if manifest is not None:
                    break
            if manifest is None:
                raise FetchError(f"all providers failed serving manifest {root}")
            node.blockstore.put(root, manifest)
            self.stats["blocks_fetched"] += 1
            self.stats["bytes_fetched"] += len(manifest)

        children, total_size, _meta = decode_manifest(manifest)
        # dedup: repeated content (identical chunks) shares one CID and is
        # fetched once — content addressing's free deduplication
        missing = deque(dict.fromkeys(
            c for c in children if not node.blockstore.has(c)))
        if missing and not providers:
            providers = yield from node.dht.find_providers(root.key)
            providers = [p for p in providers if p.peer_id != node.peer_id]
            if not providers:
                raise FetchError(f"no providers for leaves of {root}")

        live = list(providers)
        failures: Dict[bytes, int] = {}

        # ---- phase 1: bulk transfer over streaming channels --------------
        # stripe the wantlist across providers; any block a provider fails
        # to deliver falls through to the unary retry phase below
        if len(missing) >= STREAM_FETCH_MIN * max(len(live), 1) and live:
            stripes: List[List[CID]] = [[] for _ in live]
            for i, cid in enumerate(missing):
                stripes[i % len(live)].append(cid)

            def stream_worker(idx: int) -> Generator:
                got = yield from self._fetch_blocks_stream(
                    live[idx], stripes[idx])
                for cid, block in got.items():
                    node.blockstore.put(cid, block)
                    self.stats["blocks_fetched"] += 1
                    self.stats["bytes_fetched"] += len(block)
                self.stats["retries"] += len(stripes[idx]) - len(got)
                return len(got)

            procs = [sim.process(stream_worker(i)) for i in range(len(live))]
            yield sim.all_of(procs)
            missing = deque(dict.fromkeys(
                c for c in children if not node.blockstore.has(c)))

        # ---- phase 2: per-block unary with provider failover --------------
        def worker(wid: int) -> Generator:
            while missing:
                cid = missing.popleft()
                got = None
                tries = 0
                while got is None and live and tries < 2 * len(live) + 2:
                    info = live[(wid + tries) % len(live)]
                    got = yield from self._fetch_block(info, cid)
                    tries += 1
                    if got is None:
                        self.stats["retries"] += 1
                        failures[info.peer_id.digest] = \
                            failures.get(info.peer_id.digest, 0) + 1
                        if failures[info.peer_id.digest] >= 3 and info in live:
                            live.remove(info)
                if got is None:
                    raise FetchError(f"block {cid} unavailable")
                node.blockstore.put(cid, got)
                self.stats["blocks_fetched"] += 1
                self.stats["bytes_fetched"] += len(got)
            return None

        n_workers = min(MAX_IN_FLIGHT, max(len(live), 1), max(len(missing), 1))
        procs = [sim.process(worker(i)) for i in range(n_workers)]
        if procs:
            yield sim.all_of(procs)

        parts = []
        for c in children:
            blk = node.blockstore.get(c)
            if blk is None:
                raise FetchError(f"block {c} missing after fetch")
            parts.append(blk)
        data = b"".join(parts)
        if len(data) != total_size:
            raise FetchError("reassembled size mismatch")
        return data

    def publish_dag(self, dag_blocks: Dict[CID, bytes], root: CID,
                    announce: bool = True) -> Generator:
        """Store all blocks locally and announce the root on the DHT."""
        self.node.blockstore.put_many(dag_blocks)
        if announce:
            yield from self.node.dht.provide(root.key)
        return root
