"""Bitswap-style block exchange (the paper's decentralized-CDN layer).

Wantlist-driven parallel block fetch: a session resolves providers via the
DHT (or a rendezvous hint), pulls the root manifest, then swarms the missing
blocks across every live provider with a bounded in-flight window.  Each
block is hash-verified against its CID on arrival; fetched blocks are stored
and re-provided, so popular artifacts gain seeders as they spread — this is
what makes RL fleet-wide model dissemination scale in the paper's Scenario 3.

Hierarchical (v2) manifests are fetched recursively: the root manifest names
sub-DAG roots, any *missing* sub-manifests are pulled next, and then every
missing leaf across all sub-DAGs is striped over the providers in one
scheduling pass — sub-DAGs already in the local store (unchanged tensors
from a previous version) cost zero bytes.

Provider selection is *scored*, not round-robin: each peer carries an EWMA
of delivered throughput plus a failure penalty (``ProviderScore``), and
stripe assignment weights fast peers proportionally.  A cheap ``bs.have``
unary lets the retry path skip providers that lack a block instead of
burning the full 120 s ``bs.get`` deadline on them.
"""

from __future__ import annotations

from collections import deque
from typing import Any, Dict, Generator, List, Optional, TYPE_CHECKING

from .cid import (CID, CODEC_DAG, decode_manifest, decode_manifest_v2,
                  manifest_children, manifest_version, read_dag)
from .dht import PeerInfo
from .rpc import RpcChannel, RpcContext, RpcError
from .service import CodecFn, Fixed, Service, streaming, unary
from .simnet import DialError

if TYPE_CHECKING:  # pragma: no cover
    from .node import LatticaNode

MAX_IN_FLIGHT = 32
BLOCK_REQ_SIZE = 96
#: above this many wanted blocks per provider, use the streaming plane
#: (one backpressured channel per provider) instead of per-block unary
STREAM_FETCH_MIN = 4


class FetchError(Exception):
    pass


class ProviderScore:
    """Per-provider quality estimate: EWMA of delivered bytes/second with a
    multiplicative failure penalty.  New providers start optimistic so they
    get sampled; the penalty halves the score per recent failure and decays
    on the next success."""

    __slots__ = ("ewma_bps", "failures")

    ALPHA = 0.3
    OPTIMISTIC_BPS = 16e6

    def __init__(self) -> None:
        self.ewma_bps: float = self.OPTIMISTIC_BPS
        self.failures: int = 0

    def record(self, nbytes: int, seconds: float) -> None:
        bps = nbytes / max(seconds, 1e-9)
        self.ewma_bps = (1 - self.ALPHA) * self.ewma_bps + self.ALPHA * bps
        if self.failures:
            self.failures -= 1

    def fail(self) -> None:
        self.failures += 1

    def value(self) -> float:
        return self.ewma_bps * (0.5 ** min(self.failures, 10))


_BLOCK_RESP = CodecFn(
    "block_resp",
    lambda p: max(len(p[1]), 64) if p[0] == "block" and p[1] else 64)


class BitswapService(Service):
    """Block exchange: per-block unary gets, presence probes, and bulk
    streaming fetch."""

    name = "bs"

    def __init__(self, bitswap: "Bitswap"):
        self.bitswap = bitswap

    @unary("bs.have", request=Fixed(64), response=Fixed(8),
           idempotent=True, timeout=10.0)
    def have(self, payload: Any, ctx: RpcContext) -> Generator:
        """Presence probe: do we hold this block?  Cheap enough that a
        fetcher can ask before committing to a 120 s ``bs.get``."""
        cid: CID = payload
        yield ctx.cpu(1e-6)
        return self.bitswap.node.blockstore.has(cid)

    @unary("bs.get", request=Fixed(BLOCK_REQ_SIZE), response=_BLOCK_RESP,
           idempotent=True, timeout=120.0)
    def get(self, payload: Any, ctx: RpcContext) -> Generator:
        cid: CID = payload
        bs = self.bitswap
        block = bs.node.blockstore.get(cid)
        yield ctx.cpu(8e-6)
        if block is None:
            return ("missing", None)
        bs.stats["blocks_served"] += 1
        bs.stats["bytes_served"] += len(block)
        return ("block", block)

    @streaming("bs.fetch")
    def fetch(self, chan: RpcChannel, ctx: RpcContext) -> Generator:
        """Streaming plane: receive a wantlist, stream the blocks back under
        the channel's byte-credit backpressure (paper §2, streaming mode)."""
        bs = self.bitswap
        try:
            wants = yield from chan.recv(timeout=60.0)
        except RpcError:
            return
        bs.stats["stream_sessions"] += 1
        for cid in wants:
            block = bs.node.blockstore.get(cid)
            yield ctx.cpu(8e-6)
            if block is not None:
                bs.stats["blocks_served"] += 1
                bs.stats["bytes_served"] += len(block)
            try:
                yield from chan.send((cid, block),
                                     len(block) if block else 64)
            except RpcError:
                return
        chan.end()


class Bitswap:
    def __init__(self, node: "LatticaNode"):
        self.node = node
        self.stats = {"blocks_served": 0, "blocks_fetched": 0,
                      "bytes_served": 0, "bytes_fetched": 0, "retries": 0,
                      "stream_sessions": 0, "have_probes": 0,
                      "have_skips": 0, "unsolicited_rejected": 0,
                      "spec_negotiated": 0, "spec_mismatch": 0}
        self.scores: Dict[bytes, ProviderScore] = {}
        node.serve(BitswapService(self))

    # ----------------------------------------------------------- scoring
    def score(self, info: PeerInfo) -> ProviderScore:
        s = self.scores.get(info.peer_id.digest)
        if s is None:
            s = self.scores[info.peer_id.digest] = ProviderScore()
        return s

    def _stripe(self, wanted: List[CID],
                live: List[PeerInfo]) -> List[List[CID]]:
        """Assign blocks to providers proportionally to their scores: each
        block goes to the provider with the best score-per-assigned-block
        ratio (greedy weighted fill — a fast peer gets a proportionally
        longer stripe than a slow or flaky one)."""
        weights = [max(self.score(p).value(), 1.0) for p in live]
        stripes: List[List[CID]] = [[] for _ in live]
        for c in wanted:
            idx = max(range(len(live)),
                      key=lambda i: weights[i] / (len(stripes[i]) + 1))
            stripes[idx].append(c)
        return stripes

    # ------------------------------------------------------------- client
    def _fetch_blocks_stream(self, info: PeerInfo,
                             cids: List[CID]) -> Generator:
        """Bulk fetch over one streaming channel; returns {cid: bytes} for
        whatever verified blocks arrived (partial on provider failure)."""
        got: Dict[CID, bytes] = {}
        wanted = set(cids)
        sim = self.node.sim
        t0 = sim.now
        try:
            stub = self.node.stub(BitswapService, info)
            chan = yield from stub.fetch()
            yield from chan.send(list(cids), 48 * len(cids))
            for _ in range(len(cids)):
                cid, block = yield from chan.recv(timeout=120.0)
                if cid not in wanted:
                    # a self-verifying block we never asked for: a misbehaving
                    # provider could otherwise stuff the store with junk and
                    # pad its own throughput score with bytes nobody wanted
                    self.stats["unsolicited_rejected"] += 1
                    continue
                if block is not None and cid.verify(block):
                    got[cid] = block
        except (DialError, RpcError):
            pass
        nbytes = sum(len(b) for b in got.values())
        if got:
            self.score(info).record(nbytes, sim.now - t0)
        if len(got) < len(cids):
            self.score(info).fail()
        return got

    def _probe_have(self, info: PeerInfo, cid: CID) -> Generator:
        """True/False/None(=unreachable) presence probe."""
        self.stats["have_probes"] += 1
        try:
            stub = self.node.stub(BitswapService, info)
            return (yield from stub.have(cid))
        except (DialError, RpcError):
            return None

    def _fetch_block(self, info: PeerInfo, cid: CID,
                     probe: bool = False) -> Generator:
        """Fetch one block from one provider; returns bytes or None.  With
        ``probe``, a cheap ``bs.have`` runs first so a provider that lacks
        the block costs a 10 s control round-trip, not a 120 s get."""
        if probe:
            has = yield from self._probe_have(info, cid)
            if not has:
                if has is False:
                    self.stats["have_skips"] += 1
                return None
        sim = self.node.sim
        t0 = sim.now
        try:
            stub = self.node.stub(BitswapService, info)
            resp = yield from stub.get(cid)
        except (DialError, RpcError):
            self.score(info).fail()
            return None
        kind, block = resp
        if kind != "block" or block is None or not cid.verify(block):
            self.score(info).fail()
            return None
        self.score(info).record(len(block), sim.now - t0)
        return block

    def _store_fetched(self, cid: CID, block: bytes,
                       held: Optional[List[CID]] = None) -> None:
        self.node.blockstore.put(cid, block)
        if held is not None:
            self.node.blockstore.hold(cid)
            held.append(cid)
        self.stats["blocks_fetched"] += 1
        self.stats["bytes_fetched"] += len(block)

    def _fetch_one_of(self, cid: CID, providers: List[PeerInfo],
                      probe: bool = False) -> Generator:
        """Try providers in score order until one delivers ``cid``."""
        ranked = sorted(providers, key=lambda p: -self.score(p).value())
        for info in ranked:
            block = yield from self._fetch_block(info, cid, probe=probe)
            if block is not None:
                return block
            self.stats["retries"] += 1
        return None

    def _swarm_missing(self, wanted: List[CID], providers: List[PeerInfo],
                       held: Optional[List[CID]] = None) -> Generator:
        """One scheduling pass: stripe ``wanted`` across providers by score
        (streaming plane when stripes are long enough), then a unary
        failover phase with have-probes for whatever is still missing."""
        node = self.node
        sim = node.sim
        missing = deque(
            dict.fromkeys(c for c in wanted if not node.blockstore.has(c)))
        if not missing:
            return None
        if not providers:
            raise FetchError(f"no providers for {len(missing)} blocks")
        live = list(providers)

        # ---- phase 1: bulk transfer over streaming channels --------------
        # any block a provider fails to deliver falls through to the unary
        # retry phase below
        if len(missing) >= STREAM_FETCH_MIN * max(len(live), 1):
            stripes = self._stripe(list(missing), live)

            def stream_worker(idx: int) -> Generator:
                if not stripes[idx]:
                    return 0
                got = yield from self._fetch_blocks_stream(
                    live[idx], stripes[idx])
                for cid, block in got.items():
                    self._store_fetched(cid, block, held)
                self.stats["retries"] += len(stripes[idx]) - len(got)
                return len(got)

            procs = [sim.process(stream_worker(i)) for i in range(len(live))]
            yield sim.all_of(procs)
            missing = deque(dict.fromkeys(
                c for c in wanted if not node.blockstore.has(c)))

        # ---- phase 2: per-block unary with provider failover --------------
        failures: Dict[bytes, int] = {}

        def worker(wid: int) -> Generator:
            while missing:
                cid = missing.popleft()
                if node.blockstore.has(cid):
                    continue
                got = None
                tries = 0
                while got is None and live and tries < 2 * len(live) + 2:
                    ranked = sorted(live,
                                    key=lambda p: -self.score(p).value())
                    info = ranked[(wid + tries) % len(ranked)]
                    # first attempt goes straight to get; retries probe
                    # bs.have first so block-less providers cost a control
                    # RTT instead of the 120 s get deadline
                    got = yield from self._fetch_block(info, cid,
                                                       probe=tries > 0)
                    tries += 1
                    if got is None:
                        self.stats["retries"] += 1
                        failures[info.peer_id.digest] = \
                            failures.get(info.peer_id.digest, 0) + 1
                        if failures[info.peer_id.digest] >= 3 and info in live:
                            live.remove(info)
                if got is None:
                    raise FetchError(f"block {cid} unavailable")
                self._store_fetched(cid, got, held)
            return None

        n_workers = min(MAX_IN_FLIGHT, max(len(live), 1),
                        max(len(missing), 1))
        procs = [sim.process(worker(i)) for i in range(n_workers)]
        if procs:
            yield sim.all_of(procs)
        return None

    def _resolve_providers(self, root: CID,
                           hint_providers: Optional[List[PeerInfo]],
                           ) -> Generator:
        providers: List[PeerInfo] = list(hint_providers or [])
        if not providers:
            providers = yield from self.node.dht.find_providers(root.key)
        return [p for p in providers if p.peer_id != self.node.peer_id]

    def fetch_dag(self, root: CID,
                  hint_providers: Optional[List[PeerInfo]] = None,
                  assemble: bool = True) -> Generator:
        """Fetch a manifest-rooted DAG (flat v1 or hierarchical v2).

        Providers come from hints (rendezvous / pubsub announcement) plus the
        DHT provider records.  For v2 roots, sub-manifests missing locally
        are pulled first, then all missing leaves across every sub-DAG are
        swarmed in one scored scheduling pass — sub-DAGs already present
        (unchanged entries vs an earlier version) are skipped entirely.

        Returns the reassembled bytes, or ``None`` with every block resident
        in the local store when ``assemble`` is False (structure-aware
        callers reassemble per entry themselves; they should pin the root
        before their next store write, since the session's transfer-holds
        are released on return).
        """
        node = self.node
        providers: List[PeerInfo] = []
        # transfer-holds: every block this session touches is exempt from
        # LRU eviction until the fetch (incl. assembly) completes, so a
        # tight blockstore budget can't cannibalize a version mid-transfer
        held: List[CID] = []

        def hold_local(cid: CID) -> None:
            if node.blockstore.has(cid):
                node.blockstore.hold(cid)
                held.append(cid)

        def need_providers() -> Generator:
            if not providers:
                got = yield from self._resolve_providers(root, hint_providers)
                providers.extend(got)
            return providers

        try:
            manifest = node.blockstore.get(root)
            if manifest is not None:
                hold_local(root)
            else:
                yield from need_providers()
                if not providers:
                    raise FetchError(f"no providers for {root}")
                manifest = yield from self._fetch_one_of(
                    root, providers, probe=len(providers) > 1)
                if manifest is None:
                    raise FetchError(
                        f"all providers failed serving manifest {root}")
                self._store_fetched(root, manifest, held)

            # collect the full leaf want-list, pulling missing sub-manifests;
            # a hash-valid but malformed manifest (truncated, garbage) raises
            # ValueError from the decoders and must surface as FetchError —
            # a misbehaving publisher is a failed fetch, not a node crash
            try:
                version = manifest_version(manifest)
                if version == 1:
                    leaves = decode_manifest(manifest)[0]
                else:
                    entries = decode_manifest_v2(manifest)[0]
            except ValueError as e:
                raise FetchError(f"corrupt manifest {root}: {e}") from e
            if version == 2:
                sub_missing = []
                for e in entries:
                    if e.cid.codec != CODEC_DAG:
                        continue
                    if node.blockstore.has(e.cid):
                        hold_local(e.cid)    # resident sub-manifests must
                        # survive evictions caused by the leaf swarm's puts
                    else:
                        sub_missing.append(e.cid)
                if sub_missing:
                    yield from need_providers()
                    yield from self._swarm_missing(sub_missing, providers,
                                                   held)
                leaves = []
                for e in entries:
                    if e.cid.codec != CODEC_DAG:
                        leaves.append(e.cid)
                        continue
                    sub = node.blockstore.peek(e.cid)
                    if sub is None:
                        raise FetchError(
                            f"sub-manifest {e.cid} missing after fetch")
                    try:
                        leaves.extend(manifest_children(sub))
                    except ValueError as exc:
                        raise FetchError(
                            f"corrupt sub-manifest {e.cid}: {exc}") from exc

            # dedup: repeated content (identical chunks) shares one CID and
            # is fetched once — content addressing's free deduplication
            wanted = list(dict.fromkeys(leaves))
            to_fetch = []
            for c in wanted:
                if node.blockstore.has(c):
                    hold_local(c)
                else:
                    to_fetch.append(c)
            if to_fetch:
                yield from need_providers()
                if not providers:
                    raise FetchError(f"no providers for leaves of {root}")
                yield from self._swarm_missing(to_fetch, providers, held)

            if not assemble:
                return None
            try:
                # blocks were hash-verified on arrival and again by the
                # store's put — skip a third sha256 pass per block
                return read_dag(root, node.blockstore.get, verify=False)
            except (KeyError, ValueError) as e:
                raise FetchError(str(e)) from e
        finally:
            for c in held:
                node.blockstore.release(c)

    def publish_dag(self, dag_blocks: Dict[CID, bytes], root: CID,
                    announce: bool = True) -> Generator:
        """Store all blocks locally and announce the root on the DHT."""
        self.node.blockstore.put_many(dag_blocks)
        if announce:
            yield from self.node.dht.provide(root.key)
        return root
