"""Connectivity: direct dial, AutoNAT, circuit relay, DCUtR hole punching.

This is the paper's Scenario 1.  All reachability decisions happen at the
*packet* level against the NAT models in ``nat.py`` — success/failure of a
hole punch is an emergent property of the NAT state machines, not a table
lookup, so the ~70 % direct-connectivity figure can be measured rather than
asserted.

Key modelling choice (mirrors QUIC/libp2p): every node sends all control
traffic from ONE main socket (port 4001).  Cone NATs therefore reuse the same
external mapping toward the relay and toward punch targets, which is exactly
what makes DCUtR work for them; symmetric NATs mint a fresh external port per
destination, which is exactly what breaks it.
"""

from __future__ import annotations

import itertools
from typing import Dict, Generator, List, Optional, Set, Tuple

from .peer import Multiaddr, PeerId
from .service import stream_request
from .simnet import Connection, DialError, Host, Network, Sim, Stream

# NOTE: traversal control messages run *below* the typed service plane of
# ``service.py`` — they execute while the connection (or even reachability)
# is still being established, so no RPC router is addressable yet.  The
# request/response exchanges that do run over streams share the service
# layer's ``stream_request`` helper instead of hand-rolling send/recv/close.

Addr = Tuple[str, int]

MAIN_PORT = 4001
DIAL_TIMEOUT = 0.8
HANDSHAKE_CPU = 150e-6          # Noise/TLS1.3 asymmetric crypto per side
PUNCH_TRIES = 4
PUNCH_INTERVAL = 0.08

PROTO_RELAY_RESERVE = "/lattica/relay/reserve/1.0"
PROTO_RELAY_CONNECT = "/lattica/relay/connect/1.0"
PROTO_RELAY_STOP = "/lattica/relay/stop/1.0"
PROTO_DCUTR = "/lattica/dcutr/1.0"
PROTO_AUTONAT = "/lattica/autonat/1.0"
PROTO_AUTONAT_FWD = "/lattica/autonat/fwd/1.0"
PROTO_PING = "/lattica/ping/1.0"

_req_seq = itertools.count(1)


class Transport:
    """Per-host connectivity engine: packet listener + dial/punch/relay."""

    def __init__(self, host: Host, peer_id: PeerId):
        self.host = host
        self.peer_id = peer_id
        self.sim: Sim = host.net.sim
        self.net: Network = host.net
        self.sock = host.bind(MAIN_PORT)
        self._pending: Dict[Tuple[str, int], "object"] = {}
        self.observed_addrs: Set[Addr] = set()
        self.observed_of: Dict[str, Addr] = {}   # peer host name -> addr we saw
        self.reachability = "unknown"            # unknown | public | private
        self.relay_reservations: Dict[bytes, Host] = {}  # for relay servers
        self.is_relay = False
        self.stats = {
            "dials_direct_ok": 0, "dials_direct_fail": 0,
            "punch_ok": 0, "punch_fail": 0, "relayed": 0,
        }
        self.sim.process(self._listen())
        host.handle(PROTO_PING, self._ping_handler)
        host.handle(PROTO_DCUTR, self._dcutr_handler)
        host.handle(PROTO_AUTONAT, self._autonat_handler)
        host.handle(PROTO_AUTONAT_FWD, self._autonat_fwd_handler)

    # ---------------------------------------------------------------- listen
    def _listen(self) -> Generator:
        while True:
            pkt = yield from self.sock.recv()
            kind = pkt.payload[0]
            if kind == "syn":
                _, req, name = pkt.payload
                self.observed_of[name] = pkt.src
                # synack echoes the dialer's externally observed address
                self.sock.sendto(pkt.src, ("synack", req, self.host.name, pkt.src), 96)
            elif kind == "synack":
                ev = self._pending.pop(("synack", pkt.payload[1]), None)
                if ev is not None and not ev.triggered:
                    ev.succeed(pkt)
            elif kind == "punch":
                nonce = pkt.payload[1]
                self.sock.sendto(pkt.src, ("punch_ack", nonce), 64)
                ev = self._pending.get(("punch", nonce))
                if ev is not None and not ev.triggered:
                    ev.succeed(pkt)
            elif kind == "punch_ack":
                ev = self._pending.get(("punch", pkt.payload[1]))
                if ev is not None and not ev.triggered:
                    ev.succeed(pkt)
            elif kind == "probe":
                # AutoNAT dial-back probe: just prove reachability.
                self.sock.sendto(pkt.src, ("probe_ack", pkt.payload[1]), 64)

    # ------------------------------------------------------------- direct dial
    def dial_direct(self, addr: Addr, timeout: float = DIAL_TIMEOUT) -> Generator:
        """TCP/QUIC-style dial: SYN → SYNACK (proves reachability), then a
        Noise handshake round-trip.  Returns a secured Connection."""
        req = next(_req_seq)
        ev = self.sim.event()
        self._pending[("synack", req)] = ev
        try:
            got = None
            for _ in range(2):  # one retransmit for lossy paths
                self.sock.sendto(addr, ("syn", req, self.host.name), 80)
                idx, val = yield self.sim.any_of([ev, self.sim.timeout(timeout / 2)])
                if idx == 0:
                    got = val
                    break
            if got is None:
                self.stats["dials_direct_fail"] += 1
                raise DialError(f"dial to {addr} timed out")
        finally:
            self._pending.pop(("synack", req), None)
        _, _, peer_name, my_observed = got.payload
        self.observed_addrs.add(tuple(my_observed))
        peer_host = self.net.hosts[peer_name]
        # Noise XX: one extra round trip + CPU on both sides.
        lat, _, _ = self.net.path(self.host, peer_host)
        yield self.host.cpu.consume(HANDSHAKE_CPU)
        yield peer_host.cpu.consume(HANDSHAKE_CPU)
        yield self.sim.timeout(2 * lat)
        self.stats["dials_direct_ok"] += 1
        return self.net.establish(self.host, peer_host)

    # ------------------------------------------------------------------- ping
    def _ping_handler(self, stream: Stream) -> Generator:
        while True:
            try:
                msg = yield from stream.recv(timeout=30.0)
            except DialError:
                return
            stream.send(("pong", msg[1]), 64)

    def ping(self, conn: Connection) -> Generator:
        """Returns measured RTT over the connection."""
        t0 = self.sim.now
        stream = conn.open_stream(PROTO_PING, self.host)
        yield from stream_request(stream, ("ping", t0), 64, timeout=10.0)
        return self.sim.now - t0

    # ------------------------------------------------------------ hole punch
    def _punch(self, remote: Addr, nonce: int) -> Generator:
        """Send punch datagrams; succeed when any punch/punch_ack arrives."""
        key = ("punch", nonce)
        ev = self._pending.get(key)
        if ev is None or ev.triggered:
            ev = self.sim.event()
            self._pending[key] = ev
        ok = False
        for _ in range(PUNCH_TRIES):
            self.sock.sendto(remote, ("punch", nonce), 64)
            idx, _ = yield self.sim.any_of([ev, self.sim.timeout(PUNCH_INTERVAL)])
            if idx == 0:
                ok = True
                break
        if not ok and ev.triggered:
            ok = True
        self._pending.pop(key, None)
        return ok

    def _dcutr_handler(self, stream: Stream) -> Generator:
        """Responder side of Direct Connection Upgrade through Relay."""
        try:
            msg = yield from stream.recv(timeout=10.0)
            _, initiator_addrs, nonce = msg
            my_addrs = sorted(self.observed_addrs) or [(self.host.ip, MAIN_PORT)]
            stream.send(("connect", my_addrs, nonce), 128)
            yield from stream.recv(timeout=10.0)        # sync
            # pre-arm the punch waiter so an early-arriving punch isn't lost
            key = ("punch", nonce)
            if key not in self._pending or self._pending[key].triggered:
                self._pending[key] = self.sim.event()
            yield from self._punch(tuple(initiator_addrs[0]), nonce)
        except DialError:
            return

    def dcutr_upgrade(self, relayed_conn: Connection) -> Generator:
        """Initiator: attempt to upgrade a relayed connection to direct.

        Returns a direct Connection on success, None on failure (keep relay).
        """
        peer_host = relayed_conn.hosts[1] if relayed_conn.hosts[0] is self.host \
            else relayed_conn.hosts[0]
        nonce = next(_req_seq) * 7919
        my_addrs = sorted(self.observed_addrs) or [(self.host.ip, MAIN_PORT)]
        try:
            stream = relayed_conn.open_stream(PROTO_DCUTR, self.host)
            t0 = self.sim.now
            # pre-arm punch waiter before telling the peer the nonce
            self._pending[("punch", nonce)] = self.sim.event()
            stream.send(("connect", my_addrs, nonce), 128)
            msg = yield from stream.recv(timeout=10.0)
            rtt = self.sim.now - t0
            _, remote_addrs, _ = msg
            stream.send(("sync",), 64)
            yield self.sim.timeout(rtt / 2)
            ok = yield from self._punch(tuple(remote_addrs[0]), nonce)
        except DialError:
            self.stats["punch_fail"] += 1
            return None
        if not ok:
            self.stats["punch_fail"] += 1
            return None
        self.stats["punch_ok"] += 1
        # Reachability proven both ways; secure + establish the direct path.
        yield self.host.cpu.consume(HANDSHAKE_CPU)
        yield peer_host.cpu.consume(HANDSHAKE_CPU)
        lat, _, _ = self.net.path(self.host, peer_host)
        yield self.sim.timeout(2 * lat)
        return self.net.establish(self.host, peer_host)

    # ---------------------------------------------------------------- AutoNAT
    def probe_addr(self, addr: Addr, timeout: float = 0.3) -> Generator:
        """Dial-back probe from an *ephemeral* port (so cone-NAT filters
        aren't satisfied by the client's own earlier traffic to us)."""
        sock = self.host.bind()
        req = next(_req_seq)
        try:
            ok = False
            for _ in range(2):
                sock.sendto(addr, ("probe", req), 64)
                try:
                    pkt = yield from sock.recv(timeout=timeout)
                except DialError:
                    continue
                if pkt.payload[0] == "probe_ack" and pkt.payload[1] == req:
                    ok = True
                    break
            return ok
        finally:
            sock.close()

    def _autonat_fwd_handler(self, stream: Stream) -> Generator:
        """Second-hop prober: dial back an address on another server's behalf."""
        try:
            msg = yield from stream.recv(timeout=10.0)
        except DialError:
            return
        ok = yield from self.probe_addr(tuple(msg[1]))
        stream.send(("dialback", ok), 64)

    def _autonat_handler(self, stream: Stream) -> Generator:
        """Serve dial-back probes.  Prefer forwarding to a public neighbor the
        client has NOT contacted — a dial-back from a fresh (ip, port) is the
        only sound reachability witness against cone NATs."""
        try:
            msg = yield from stream.recv(timeout=10.0)
        except DialError:
            return
        _, addr = msg
        client_host = stream.conn.hosts[0] if stream.conn.hosts[1] is self.host \
            else stream.conn.hosts[1]
        helper_conn = None
        for name, conns in self.host._connections.items():
            neighbor = self.net.hosts.get(name)
            if (neighbor is None or neighbor is client_host
                    or neighbor.nat is not None):
                continue
            live = [c for c in conns if not c.closed and not c.relayed]
            if live:
                helper_conn = live[0]
                break
        if helper_conn is not None:
            fwd = helper_conn.open_stream(PROTO_AUTONAT_FWD, self.host)
            try:
                resp = yield from stream_request(fwd, ("probe", addr), 96,
                                                 timeout=5.0)
                ok = bool(resp[1])
            except DialError:
                ok = False
        else:
            ok = yield from self.probe_addr(tuple(addr))
        stream.send(("dialback", ok), 64)

    def autonat_probe(self, helper_conn: Connection) -> Generator:
        """Ask a connected public peer to dial back our observed address."""
        if not self.observed_addrs:
            self.reachability = "private" if self.host.nat else "public"
            return self.reachability
        addr = sorted(self.observed_addrs)[0]
        stream = helper_conn.open_stream(PROTO_AUTONAT, self.host)
        try:
            msg = yield from stream_request(stream, ("probe", addr), 96,
                                            timeout=5.0)
            ok = bool(msg[1])
        except DialError:
            ok = False
        self.reachability = "public" if ok else "private"
        return self.reachability

    # ------------------------------------------------------------------ relay
    def enable_relay(self) -> None:
        """Make this (public) host a circuit relay."""
        self.is_relay = True
        self.host.handle(PROTO_RELAY_RESERVE, self._relay_reserve_handler)
        self.host.handle(PROTO_RELAY_CONNECT, self._relay_connect_handler)

    def _relay_reserve_handler(self, stream: Stream) -> Generator:
        try:
            msg = yield from stream.recv(timeout=10.0)
        except DialError:
            return
        _, peer_digest, host_name = msg
        self.relay_reservations[peer_digest] = self.net.hosts[host_name]
        stream.send(("reserved", True), 64)

    def _relay_connect_handler(self, stream: Stream) -> Generator:
        try:
            msg = yield from stream.recv(timeout=10.0)
        except DialError:
            return
        _, target_digest, src_name = msg
        target = self.relay_reservations.get(target_digest)
        src_host = self.net.hosts[src_name]
        if target is None:
            stream.send(("error", "no reservation"), 64)
            return
        conn_to_target = self.host.connection_to(target)
        if conn_to_target is None:
            stream.send(("error", "relay lost target"), 64)
            return
        # Notify the target so it can account for the incoming circuit.
        stop = conn_to_target.open_stream(PROTO_RELAY_STOP, self.host)
        stop.send(("incoming", src_name), 96)
        try:
            yield from stop.recv(timeout=5.0)
        except DialError:
            stream.send(("error", "target rejected"), 64)
            return
        circuit = self.net.establish(src_host, target, relayed=True, relay=self.host)
        stream.send(("ok", circuit), 128)

    def relay_reserve(self, relay_conn: Connection) -> Generator:
        """Client: reserve a slot on a relay (listen via circuit)."""
        self.host.handle(PROTO_RELAY_STOP, self._relay_stop_handler)
        stream = relay_conn.open_stream(PROTO_RELAY_RESERVE, self.host)
        msg = yield from stream_request(
            stream, ("reserve", self.peer_id.digest, self.host.name), 96,
            timeout=5.0)
        return bool(msg[1])

    def _relay_stop_handler(self, stream: Stream) -> Generator:
        try:
            yield from stream.recv(timeout=10.0)
            stream.send(("ok",), 64)
        except DialError:
            return

    def relay_connect(self, relay_conn: Connection, target: PeerId) -> Generator:
        """Client: open a circuit to ``target`` through a connected relay."""
        stream = relay_conn.open_stream(PROTO_RELAY_CONNECT, self.host)
        msg = yield from stream_request(
            stream, ("connect", target.digest, self.host.name), 96,
            timeout=10.0)
        if msg[0] != "ok":
            raise DialError(f"relay circuit failed: {msg[1]}")
        self.stats["relayed"] += 1
        return msg[1]
