"""Connectivity: direct dial, AutoNAT, circuit relay, DCUtR hole punching.

This is the paper's Scenario 1.  All reachability decisions happen at the
*packet* level against the NAT models in ``nat.py`` — success/failure of a
hole punch is an emergent property of the NAT state machines, not a table
lookup, so the ~70 % direct-connectivity figure can be measured rather than
asserted.

Key modelling choice (mirrors QUIC/libp2p): every node sends all control
traffic from ONE main socket (port 4001).  Cone NATs therefore reuse the same
external mapping toward the relay and toward punch targets, which is exactly
what makes DCUtR work for them; symmetric NATs mint a fresh external port per
destination, which is exactly what breaks the naive punch.

DCUtR v2 (this module) recovers most symmetric pairs anyway:

* both sides exchange their *full* recent candidate address set (stale
  entries are pruned by age, and one bad candidate no longer sinks the
  upgrade — every candidate is punched in parallel);
* a peer behind an endpoint-dependent (symmetric) NAT learns its box's
  port-allocation fingerprint by probing the relay from fresh sockets: two
  consecutive allocation deltas agreeing ⇒ a predictable stride;
* the counterpart then *sprays* a predicted port window
  ``base + stride·k`` (birthday-paradox style) alongside the advertised
  candidates, catching the fresh mapping the symmetric NAT mints when its
  host punches outward.  Sequential / fixed-delta allocators thus upgrade
  to direct paths; random allocators stay on the relay.

Relay reservations are a managed resource: TTL'd, capacity-bounded,
refreshable only by the same host, and evicted as soon as the relay answers
"relay lost target" for them.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Generator, List, Optional, Set, Tuple

from .peer import PeerId
from .service import stream_request
from .simnet import Connection, DialError, Host, Network, Sim, Stream

# NOTE: traversal control messages run *below* the typed service plane of
# ``service.py`` — they execute while the connection (or even reachability)
# is still being established, so no RPC router is addressable yet.  The
# request/response exchanges that do run over streams share the service
# layer's ``stream_request`` helper instead of hand-rolling send/recv/close.

Addr = Tuple[str, int]

MAIN_PORT = 4001
DIAL_TIMEOUT = 0.8
HANDSHAKE_CPU = 150e-6          # Noise/TLS1.3 asymmetric crypto per side
PUNCH_TRIES = 5
PUNCH_INTERVAL = 0.08
PUNCH_BACKOFF = 1.5             # retry interval growth factor

#: Predicted-port spray: how many ``base + stride·k`` slots to cover.  Must
#: exceed the number of mappings the symmetric side mints while punching the
#: counterpart's candidate list (≤ OBSERVED_ADDR_MAX + slack).
PREDICT_WINDOW = 12
#: Allocation deltas above this are treated as unpredictable.
MAX_PREDICTABLE_STRIDE = 64
#: Observed addresses confirmed within this window count as punch-fresh;
#: anything older triggers a re-learn through the relay before punching.
FRESH_ADDR_AGE = 30.0

#: Observed-address book: drop entries not re-confirmed within the TTL, and
#: keep at most this many (most recent first) as punch candidates.
OBSERVED_ADDR_TTL = 300.0
OBSERVED_ADDR_MAX = 8
#: AutoNAT: how many observed candidates to dial-back before concluding
#: "private" (one stale candidate must not misclassify a reachable host).
AUTONAT_MAX_PROBES = 4

RELAY_RESERVATION_TTL = 120.0
RELAY_MAX_RESERVATIONS = 64

PROTO_RELAY_RESERVE = "/lattica/relay/reserve/1.0"
PROTO_RELAY_CONNECT = "/lattica/relay/connect/1.0"
PROTO_RELAY_STOP = "/lattica/relay/stop/1.0"
PROTO_DCUTR = "/lattica/dcutr/2.0"
PROTO_AUTONAT = "/lattica/autonat/1.0"
PROTO_AUTONAT_FWD = "/lattica/autonat/fwd/1.0"
PROTO_PING = "/lattica/ping/1.0"

_req_seq = itertools.count(1)


@dataclass
class RelayReservation:
    """A relay-side slot: who may be circuit-dialed through this relay."""

    host: Host
    host_name: str
    created_at: float
    expires_at: float
    refreshes: int = 0


class Transport:
    """Per-host connectivity engine: packet listener + dial/punch/relay."""

    def __init__(self, host: Host, peer_id: PeerId):
        self.host = host
        self.peer_id = peer_id
        self.sim: Sim = host.net.sim
        self.net: Network = host.net
        self.sock = host.bind(MAIN_PORT)
        self._pending: Dict[Tuple[str, int], "object"] = {}
        # addr -> sim time last confirmed (insertion refreshed on re-observe)
        self._observed: Dict[Addr, float] = {}
        # sticky: once two ports were seen for one external IP, the NAT is
        # known endpoint-dependent for good (a property of the box, not of
        # whichever observations happen to still be fresh)
        self._seen_endpoint_dependent = False
        self.observed_of: Dict[str, Addr] = {}   # peer host name -> addr we saw
        self.reachability = "unknown"            # unknown | public | private
        self.relay_reservations: Dict[bytes, RelayReservation] = {}
        self.relay_ttl = RELAY_RESERVATION_TTL
        self.relay_capacity = RELAY_MAX_RESERVATIONS
        self.is_relay = False
        self.stats = {
            "dials_direct_ok": 0, "dials_direct_fail": 0,
            "punch_ok": 0, "punch_fail": 0, "relayed": 0,
            "predicted_punch_ok": 0, "fingerprint_probes": 0,
        }
        self.relay_stats = {
            "reserved": 0, "refreshed": 0, "expired": 0,
            "rejected_capacity": 0, "rejected_foreign": 0,
            "dropped_lost_target": 0,
        }
        self.sim.process(self._listen(), daemon=True)
        self.sim.register_leak_check(
            f"relay.reservations:{host.name}", self._live_reservation_count)
        host.handle(PROTO_PING, self._ping_handler)
        host.handle(PROTO_DCUTR, self._dcutr_handler)
        host.handle(PROTO_AUTONAT, self._autonat_handler)
        host.handle(PROTO_AUTONAT_FWD, self._autonat_fwd_handler)

    # --------------------------------------------------------- observed addrs
    @property
    def observed_addrs(self) -> Set[Addr]:
        """Live (non-expired) externally-observed addresses of this host."""
        self._prune_observed()
        return set(self._observed)

    def _observe(self, addr: Addr) -> None:
        addr = tuple(addr)
        if any(ip == addr[0] and port != addr[1]
               for ip, port in self._observed):
            self._seen_endpoint_dependent = True
        self._observed.pop(addr, None)           # refresh recency ordering
        self._observed[addr] = self.sim.now
        self._prune_observed()

    def _prune_observed(self) -> None:
        # Drop entries past the TTL — but always keep the freshest one: the
        # NAT mapping behind it does not expire in this model, and it is the
        # only dialable address a keepalive-less full-cone node has.
        now = self.sim.now
        if not self._observed:
            return
        newest = max(self._observed, key=self._observed.get)
        stale = [a for a, t in self._observed.items()
                 if now - t > OBSERVED_ADDR_TTL and a != newest]
        for a in stale:
            del self._observed[a]
        while len(self._observed) > OBSERVED_ADDR_MAX:
            oldest = min(self._observed, key=self._observed.get)
            del self._observed[oldest]

    def candidate_addrs(self) -> List[Addr]:
        """Punch/dial candidates, most recently confirmed first."""
        if self.host.nat is None:
            return [(self.host.ip, MAIN_PORT)]
        self._prune_observed()
        ranked = sorted(self._observed, key=self._observed.get, reverse=True)
        return ranked or [(self.host.ip, MAIN_PORT)]

    def refresh_observed(self, via: Addr, timeout: float = 0.5) -> Generator:
        """STUN-style keepalive: one syn/synack exchange from the MAIN
        socket toward ``via`` (our relay), re-confirming the external
        mapping punch candidates are built from.  Cone NATs re-confirm their
        single mapping; symmetric NATs re-confirm the relay-facing one."""
        req = next(_req_seq)
        ev = self.sim.event()
        self._pending[("synack", req)] = ev
        try:
            self.sock.sendto(via, ("syn", req, self.host.name), 80)
            idx, _ = yield self.sim.any_of([ev, self.sim.timeout(timeout)])
            return idx == 0          # the synack branch already observed it
        finally:
            self._pending.pop(("synack", req), None)

    def _freshen_for_punch(self, relay: Optional[Host]) -> Generator:
        """Before a punch, make sure we advertise at least one *live*
        candidate: if everything in the address book is stale (or gone),
        re-learn our mapping through the relay."""
        if self.host.nat is None or relay is None:
            return None
        now = self.sim.now
        fresh = [a for a, t in self._observed.items()
                 if now - t <= FRESH_ADDR_AGE]
        if not fresh:
            yield from self.refresh_observed((relay.ip, MAIN_PORT))
        return None

    def endpoint_dependent(self) -> bool:
        """Does our NAT mint a fresh mapping per destination (symmetric)?

        Inferred honestly from the address book: distinct external ports for
        the same external IP ⇒ endpoint-dependent mapping.  (A cone NAT shows
        every server the same mapping of our main socket.)  The verdict is
        sticky — mapping behaviour is a property of the box, so it survives
        the observations that established it aging out.
        """
        if self.host.nat is None:
            return False
        return self._seen_endpoint_dependent

    # ---------------------------------------------------------------- listen
    def _listen(self) -> Generator:
        while True:
            pkt = yield from self.sock.recv()
            kind = pkt.payload[0]
            if kind == "syn":
                _, req, name = pkt.payload
                self.observed_of[name] = pkt.src
                # synack echoes the dialer's externally observed address
                self.sock.sendto(pkt.src, ("synack", req, self.host.name, pkt.src), 96)
            elif kind == "synack":
                # every synack tells us our current external mapping — keep
                # the address book fresh (NAT keepalive / STUN-style)
                self._observe(tuple(pkt.payload[3]))
                ev = self._pending.pop(("synack", pkt.payload[1]), None)
                if ev is not None and not ev.triggered:
                    ev.succeed(pkt)
            elif kind == "punch":
                nonce = pkt.payload[1]
                self.sock.sendto(pkt.src, ("punch_ack", nonce), 64)
                ev = self._pending.get(("punch", nonce))
                if ev is not None and not ev.triggered:
                    ev.succeed(pkt)
            elif kind == "punch_ack":
                ev = self._pending.get(("punch", pkt.payload[1]))
                if ev is not None and not ev.triggered:
                    ev.succeed(pkt)
            elif kind == "probe":
                # AutoNAT dial-back probe: just prove reachability.
                self.sock.sendto(pkt.src, ("probe_ack", pkt.payload[1]), 64)

    # ------------------------------------------------------------- direct dial
    def dial_direct(self, addr: Addr, timeout: float = DIAL_TIMEOUT) -> Generator:
        """TCP/QUIC-style dial: SYN → SYNACK (proves reachability), then a
        Noise handshake round-trip.  Returns a secured Connection."""
        req = next(_req_seq)
        ev = self.sim.event()
        self._pending[("synack", req)] = ev
        try:
            got = None
            for _ in range(2):  # one retransmit for lossy paths
                self.sock.sendto(addr, ("syn", req, self.host.name), 80)
                idx, val = yield self.sim.any_of([ev, self.sim.timeout(timeout / 2)])
                if idx == 0:
                    got = val
                    break
            if got is None:
                self.stats["dials_direct_fail"] += 1
                raise DialError(f"dial to {addr} timed out")
        finally:
            self._pending.pop(("synack", req), None)
        _, _, peer_name, my_observed = got.payload
        self._observe(tuple(my_observed))
        peer_host = self.net.hosts[peer_name]
        # Noise XX: one extra round trip + CPU on both sides.
        lat, _, _ = self.net.path(self.host, peer_host)
        yield self.host.cpu.consume(HANDSHAKE_CPU)
        yield peer_host.cpu.consume(HANDSHAKE_CPU)
        yield self.sim.timeout(2 * lat)
        self.stats["dials_direct_ok"] += 1
        return self.net.establish(self.host, peer_host)

    # ------------------------------------------------------------------- ping
    def _ping_handler(self, stream: Stream) -> Generator:
        # single-shot: ping() opens a fresh stream per probe, so serve one
        # exchange and close (a parked while-True handler would hold the
        # server endpoint open long after the client closed its side)
        try:
            msg = yield from stream.recv(timeout=30.0)
            stream.send(("pong", msg[1]), 64)
        except DialError:
            pass
        finally:
            stream.close()

    def ping(self, conn: Connection) -> Generator:
        """Returns measured RTT over the connection."""
        t0 = self.sim.now
        stream = conn.open_stream(PROTO_PING, self.host)
        yield from stream_request(stream, ("ping", t0), 64, timeout=10.0)
        return self.sim.now - t0

    # --------------------------------------------------------- NAT fingerprint
    def nat_fingerprint(self, via: Addr) -> Generator:
        """Learn our NAT's port-allocation behaviour by opening three fresh
        sockets toward ``via`` (a public echo endpoint — in practice the
        relay we already hold a connection to).

        Each socket mints a new external mapping; the deltas between the
        consecutively observed ports reveal the allocator: two equal small
        deltas ⇒ predictable stride, anything else ⇒ random/unpredictable.
        Returns ``{"ip", "base", "stride", "dependent"}`` or ``None`` when
        the probe could not complete.  ``base`` is the *latest* allocated
        port, so the next mapping our NAT mints lands near
        ``base + stride`` — which is why this is never cached: punching a
        candidate list mints new mappings, and a stale base would put the
        peer's whole spray window below the allocator's next port.
        """
        ports: List[int] = []
        ip: Optional[str] = None
        for _ in range(3):
            sock = self.host.bind()
            req = next(_req_seq)
            try:
                observed = None
                for _retry in range(2):
                    sock.sendto(via, ("syn", req, self.host.name), 80)
                    try:
                        pkt = yield from sock.recv(timeout=0.4)
                    except DialError:
                        continue
                    if pkt.payload[0] == "synack" and pkt.payload[1] == req:
                        observed = tuple(pkt.payload[3])
                        break
                if observed is None:
                    return None
                ip, port = observed
                ports.append(port)
            finally:
                sock.close()
        self.stats["fingerprint_probes"] += 1
        d1, d2 = ports[1] - ports[0], ports[2] - ports[1]
        stride = d1 if (d1 == d2 and 0 < d1 <= MAX_PREDICTABLE_STRIDE) else None
        return {"ip": ip, "base": ports[-1], "stride": stride,
                "dependent": self.endpoint_dependent()}

    @staticmethod
    def predicted_ports(fp: Optional[Dict[str, object]]) -> List[Addr]:
        """Spray window for a peer whose NAT fingerprint is predictable."""
        if not fp or not fp.get("dependent") or not fp.get("stride"):
            return []
        base, stride, ip = int(fp["base"]), int(fp["stride"]), str(fp["ip"])
        return [(ip, base + stride * k) for k in range(1, PREDICT_WINDOW + 1)]

    # ------------------------------------------------------------ hole punch
    def _punch(self, targets: List[Addr], nonce: int,
               n_advertised: Optional[int] = None) -> Generator:
        """Spray punch datagrams at every target each round, with backoff
        between rounds; succeed when any punch/punch_ack arrives.

        ``n_advertised`` marks how many leading targets are advertised
        candidates (the rest are predicted ports) so success accounting can
        attribute predicted punches.
        """
        key = ("punch", nonce)
        ev = self._pending.get(key)
        if ev is None or ev.triggered:
            ev = self.sim.event()
            self._pending[key] = ev
        ok = False
        interval = PUNCH_INTERVAL
        for _ in range(PUNCH_TRIES):
            for t in targets:
                self.sock.sendto(t, ("punch", nonce), 64)
            idx, _ = yield self.sim.any_of([ev, self.sim.timeout(interval)])
            if idx == 0:
                ok = True
                break
            interval *= PUNCH_BACKOFF
        if not ok and ev.triggered:
            ok = True
        self._pending.pop(key, None)
        if ok and n_advertised is not None and len(targets) > n_advertised:
            # cannot tell *which* datagram landed; attribute to prediction
            # only when a spray window was in play at all
            self.stats["predicted_punch_ok"] += 1
        return ok

    def _punch_plan(self, remote_addrs: List[Addr],
                    remote_fp: Optional[Dict[str, object]]) -> Tuple[List[Addr], int]:
        cands = [tuple(a) for a in remote_addrs]
        predicted = [p for p in self.predicted_ports(remote_fp)
                     if p not in cands]
        return cands + predicted, len(cands)

    def _own_fingerprint_for_dcutr(self, relay: Optional[Host]) -> Generator:
        """Fingerprint to advertise in a DCUtR exchange: only meaningful when
        we are behind an endpoint-dependent NAT and a relay is reachable."""
        if relay is None or self.host.nat is None or not self.endpoint_dependent():
            return None
        fp = yield from self.nat_fingerprint((relay.ip, MAIN_PORT))
        return fp

    def _dcutr_handler(self, stream: Stream) -> Generator:
        """Responder side of Direct Connection Upgrade through Relay (v2)."""
        try:
            msg = yield from stream.recv(timeout=10.0)
            _, initiator_addrs, initiator_fp, nonce = msg
            yield from self._freshen_for_punch(stream.conn.relay)
            my_fp = yield from self._own_fingerprint_for_dcutr(stream.conn.relay)
            my_addrs = self.candidate_addrs()
            stream.send(("connect", my_addrs, my_fp, nonce), 160)
            yield from stream.recv(timeout=10.0)        # sync
            # pre-arm the punch waiter so an early-arriving punch isn't lost
            key = ("punch", nonce)
            if key not in self._pending or self._pending[key].triggered:
                self._pending[key] = self.sim.event()
            targets, n_adv = self._punch_plan(initiator_addrs, initiator_fp)
            yield from self._punch(targets, nonce, n_advertised=n_adv)
        except DialError:
            return
        finally:
            stream.close()

    def dcutr_upgrade(self, relayed_conn: Connection) -> Generator:
        """Initiator: attempt to upgrade a relayed connection to direct.

        Returns a direct Connection on success, None on failure (keep relay).
        """
        peer_host = relayed_conn.hosts[1] if relayed_conn.hosts[0] is self.host \
            else relayed_conn.hosts[0]
        nonce = next(_req_seq) * 7919
        try:
            yield from self._freshen_for_punch(relayed_conn.relay)
            my_fp = yield from self._own_fingerprint_for_dcutr(relayed_conn.relay)
            my_addrs = self.candidate_addrs()
            stream = relayed_conn.open_stream(PROTO_DCUTR, self.host)
            t0 = self.sim.now
            # pre-arm punch waiter before telling the peer the nonce
            self._pending[("punch", nonce)] = self.sim.event()
            stream.send(("connect", my_addrs, my_fp, nonce), 160)
            try:
                msg = yield from stream.recv(timeout=10.0)
                rtt = self.sim.now - t0
                _, remote_addrs, remote_fp, _ = msg
                stream.send(("sync",), 64)
            finally:
                stream.close()
            yield self.sim.timeout(rtt / 2)
            targets, n_adv = self._punch_plan(remote_addrs, remote_fp)
            ok = yield from self._punch(targets, nonce, n_advertised=n_adv)
        except DialError:
            self.stats["punch_fail"] += 1
            return None
        if not ok:
            self.stats["punch_fail"] += 1
            return None
        self.stats["punch_ok"] += 1
        # Reachability proven both ways; secure + establish the direct path.
        yield self.host.cpu.consume(HANDSHAKE_CPU)
        yield peer_host.cpu.consume(HANDSHAKE_CPU)
        lat, _, _ = self.net.path(self.host, peer_host)
        yield self.sim.timeout(2 * lat)
        return self.net.establish(self.host, peer_host)

    # ---------------------------------------------------------------- AutoNAT
    def probe_addr(self, addr: Addr, timeout: float = 0.3) -> Generator:
        """Dial-back probe from an *ephemeral* port (so cone-NAT filters
        aren't satisfied by the client's own earlier traffic to us)."""
        sock = self.host.bind()
        req = next(_req_seq)
        try:
            ok = False
            for _ in range(2):
                sock.sendto(addr, ("probe", req), 64)
                try:
                    pkt = yield from sock.recv(timeout=timeout)
                except DialError:
                    continue
                if pkt.payload[0] == "probe_ack" and pkt.payload[1] == req:
                    ok = True
                    break
            return ok
        finally:
            sock.close()

    def _autonat_fwd_handler(self, stream: Stream) -> Generator:
        """Second-hop prober: dial back an address on another server's behalf."""
        try:
            msg = yield from stream.recv(timeout=10.0)
            ok = yield from self.probe_addr(tuple(msg[1]))
            stream.send(("dialback", ok), 64)
        except DialError:
            return
        finally:
            stream.close()

    def _autonat_handler(self, stream: Stream) -> Generator:
        """Serve dial-back probes.  Prefer forwarding to a public neighbor the
        client has NOT contacted — a dial-back from a fresh (ip, port) is the
        only sound reachability witness against cone NATs."""
        try:
            msg = yield from stream.recv(timeout=10.0)
        except DialError:
            stream.close()
            return
        _, addr = msg
        client_host = stream.conn.hosts[0] if stream.conn.hosts[1] is self.host \
            else stream.conn.hosts[1]
        helper_conn = None
        for name, conns in self.host._connections.items():
            neighbor = self.net.hosts.get(name)
            if (neighbor is None or neighbor is client_host
                    or neighbor.nat is not None):
                continue
            live = [c for c in conns if not c.closed and not c.relayed]
            if live:
                helper_conn = live[0]
                break
        if helper_conn is not None:
            fwd = helper_conn.open_stream(PROTO_AUTONAT_FWD, self.host)
            try:
                resp = yield from stream_request(fwd, ("probe", addr), 96,
                                                 timeout=5.0)
                ok = bool(resp[1])
            except DialError:
                ok = False
        else:
            ok = yield from self.probe_addr(tuple(addr))
        stream.send(("dialback", ok), 64)
        stream.close()

    def autonat_probe(self, helper_conn: Connection) -> Generator:
        """Ask a connected public peer to dial back our observed addresses.

        Tries candidates in recency order until one succeeds — a single
        stale (e.g. lexically-smallest) observed address must not
        misclassify a reachable host as private."""
        if not self.observed_addrs:
            self.reachability = "private" if self.host.nat else "public"
            return self.reachability
        ok = False
        for addr in self.candidate_addrs()[:AUTONAT_MAX_PROBES]:
            stream = helper_conn.open_stream(PROTO_AUTONAT, self.host)
            try:
                msg = yield from stream_request(stream, ("probe", addr), 96,
                                                timeout=5.0)
                ok = bool(msg[1])
            except DialError:
                ok = False
            if ok:
                break
        self.reachability = "public" if ok else "private"
        return self.reachability

    # ------------------------------------------------------------------ relay
    def enable_relay(self, ttl: float = RELAY_RESERVATION_TTL,
                     capacity: int = RELAY_MAX_RESERVATIONS) -> None:
        """Make this (public) host a circuit relay."""
        self.is_relay = True
        self.relay_ttl = ttl
        self.relay_capacity = capacity
        self.host.handle(PROTO_RELAY_RESERVE, self._relay_reserve_handler)
        self.host.handle(PROTO_RELAY_CONNECT, self._relay_connect_handler)

    def _prune_reservations(self) -> None:
        now = self.sim.now
        expired = [d for d, r in self.relay_reservations.items()
                   if r.expires_at <= now]
        for d in expired:
            del self.relay_reservations[d]
            self.relay_stats["expired"] += 1

    def _live_reservation_count(self) -> int:
        """simsan gauge: unexpired relay reservations held on this host."""
        self._prune_reservations()
        return len(self.relay_reservations)

    def _peer_host_of(self, stream: Stream) -> Host:
        """The host on the far side of a stream's (authenticated) connection
        — never trust a host name claimed inside the message payload."""
        a, b = stream.conn.hosts
        return a if b is self.host else b

    def _relay_reserve_handler(self, stream: Stream) -> Generator:
        try:
            yield from self._relay_reserve_inner(stream)
        finally:
            stream.close()

    def _relay_reserve_inner(self, stream: Stream) -> Generator:
        try:
            msg = yield from stream.recv(timeout=10.0)
        except DialError:
            return
        _, peer_digest, _claimed_name = msg
        # Bind the reservation to the connection's actual peer: the secured
        # channel is what proves identity (stand-in for Noise binding the
        # PeerId's pubkey), so a claimed digest must match it — otherwise
        # any peer could squat another's slot and capture its circuits.
        client = self._peer_host_of(stream)
        if PeerId.from_name(client.name).digest != peer_digest:
            self.relay_stats["rejected_foreign"] += 1
            stream.send(("reserved", False, 0.0), 64)
            return
        now = self.sim.now
        self._prune_reservations()
        existing = self.relay_reservations.get(peer_digest)
        if existing is None:
            if len(self.relay_reservations) >= self.relay_capacity:
                self.relay_stats["rejected_capacity"] += 1
                stream.send(("reserved", False, 0.0), 64)
                return
            self.relay_reservations[peer_digest] = RelayReservation(
                host=client, host_name=client.name,
                created_at=now, expires_at=now + self.relay_ttl)
            self.relay_stats["reserved"] += 1
        else:
            existing.expires_at = now + self.relay_ttl
            existing.refreshes += 1
            self.relay_stats["refreshed"] += 1
        stream.send(("reserved", True, self.relay_ttl), 64)

    def _relay_connect_handler(self, stream: Stream) -> Generator:
        try:
            yield from self._relay_connect_inner(stream)
        finally:
            stream.close()

    def _relay_connect_inner(self, stream: Stream) -> Generator:
        try:
            msg = yield from stream.recv(timeout=10.0)
        except DialError:
            return
        _, target_digest, _claimed_src = msg
        self._prune_reservations()
        res = self.relay_reservations.get(target_digest)
        # the circuit's source is whoever actually opened this stream
        src_host = self._peer_host_of(stream)
        if res is None:
            stream.send(("error", "no reservation"), 64)
            return
        target = res.host
        conn_to_target = self.host.connection_to(target)
        if conn_to_target is None:
            # the reserved peer is gone — evict its slot immediately
            del self.relay_reservations[target_digest]
            self.relay_stats["dropped_lost_target"] += 1
            stream.send(("error", "relay lost target"), 64)
            return
        # Notify the target so it can account for the incoming circuit.
        stop = conn_to_target.open_stream(PROTO_RELAY_STOP, self.host)
        try:
            yield from stream_request(stop, ("incoming", src_host.name), 96,
                                      timeout=5.0)
        except DialError:
            stream.send(("error", "target rejected"), 64)
            return
        circuit = self.net.establish(src_host, target, relayed=True, relay=self.host)
        stream.send(("ok", circuit), 128)

    def relay_reserve(self, relay_conn: Connection) -> Generator:
        """Client: reserve (or refresh) a slot on a relay.

        Returns ``(ok, ttl)`` — the relay's TTL bounds when the client must
        refresh to keep inbound reachability."""
        self.host.handle(PROTO_RELAY_STOP, self._relay_stop_handler)
        stream = relay_conn.open_stream(PROTO_RELAY_RESERVE, self.host)
        msg = yield from stream_request(
            stream, ("reserve", self.peer_id.digest, self.host.name), 96,
            timeout=5.0)
        return bool(msg[1]), float(msg[2])

    def _relay_stop_handler(self, stream: Stream) -> Generator:
        try:
            yield from stream.recv(timeout=10.0)
            stream.send(("ok",), 64)
        except DialError:
            return
        finally:
            stream.close()

    def relay_connect(self, relay_conn: Connection, target: PeerId) -> Generator:
        """Client: open a circuit to ``target`` through a connected relay."""
        stream = relay_conn.open_stream(PROTO_RELAY_CONNECT, self.host)
        msg = yield from stream_request(
            stream, ("connect", target.digest, self.host.name), 96,
            timeout=10.0)
        if msg[0] != "ok":
            raise DialError(f"relay circuit failed: {msg[1]}")
        self.stats["relayed"] += 1
        return msg[1]
