"""Conflict-free Replicated Data Types (Shapiro et al. 2011).

State-based (CvRDT) implementations with join-semilattice ``merge``:
merge is commutative, associative and idempotent, so replicas converge
regardless of delivery order, duplication, or partitions — exactly the
property Lattica's decentralized store relies on, and exactly what the
hypothesis tests in ``tests/test_crdt.py`` verify.

The ``ReplicatedStore`` composes named CRDTs into a document, exposes a
digest for cheap anti-entropy ("are we synced?"), and serializes deltas for
gossip over the Lattica mesh.
"""

from __future__ import annotations

import hashlib
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, Optional, Set, Tuple


class CRDT:
    """Interface: value(), merge(other) -> changed(bool), copy()."""

    def value(self) -> Any:
        raise NotImplementedError

    def merge(self, other: "CRDT") -> bool:
        raise NotImplementedError

    def copy(self) -> "CRDT":
        import copy as _copy

        return _copy.deepcopy(self)


# ---------------------------------------------------------------- counters


class GCounter(CRDT):
    """Grow-only counter: per-replica max."""

    def __init__(self) -> None:
        self.counts: Dict[str, int] = {}

    def increment(self, replica: str, n: int = 1) -> None:
        if n < 0:
            raise ValueError("GCounter cannot decrease")
        self.counts[replica] = self.counts.get(replica, 0) + n

    def value(self) -> int:
        return sum(self.counts.values())

    def merge(self, other: "GCounter") -> bool:
        changed = False
        for r, c in other.counts.items():
            if c > self.counts.get(r, 0):
                self.counts[r] = c
                changed = True
        return changed


class PNCounter(CRDT):
    """Increment/decrement counter as a pair of GCounters."""

    def __init__(self) -> None:
        self.p = GCounter()
        self.n = GCounter()

    def increment(self, replica: str, n: int = 1) -> None:
        self.p.increment(replica, n)

    def decrement(self, replica: str, n: int = 1) -> None:
        self.n.increment(replica, n)

    def value(self) -> int:
        return self.p.value() - self.n.value()

    def merge(self, other: "PNCounter") -> bool:
        a = self.p.merge(other.p)
        b = self.n.merge(other.n)
        return a or b


# ---------------------------------------------------------------- registers


class LWWRegister(CRDT):
    """Last-writer-wins register; ties broken by replica id (total order)."""

    def __init__(self) -> None:
        self.ts: Tuple[float, str] = (-1.0, "")
        self._value: Any = None

    def set(self, value: Any, timestamp: float, replica: str) -> None:
        if (timestamp, replica) > self.ts:
            self.ts = (timestamp, replica)
            self._value = value

    def value(self) -> Any:
        return self._value

    def merge(self, other: "LWWRegister") -> bool:
        if other.ts > self.ts:
            self.ts = other.ts
            self._value = other._value
            return True
        return False


class MVRegister(CRDT):
    """Multi-value register with vector-clock causality (keeps siblings)."""

    def __init__(self) -> None:
        self.versions: Dict[FrozenSet[Tuple[str, int]], Any] = {}
        self.clock: Dict[str, int] = {}

    def set(self, value: Any, replica: str) -> None:
        self.clock[replica] = self.clock.get(replica, 0) + 1
        vc = frozenset(self.clock.items())
        self.versions = {vc: value}

    @staticmethod
    def _dominates(a: FrozenSet[Tuple[str, int]], b: FrozenSet[Tuple[str, int]]) -> bool:
        da, db = dict(a), dict(b)
        keys = set(da) | set(db)
        ge = all(da.get(k, 0) >= db.get(k, 0) for k in keys)
        gt = any(da.get(k, 0) > db.get(k, 0) for k in keys)
        return ge and gt

    def value(self) -> Tuple[Any, ...]:
        return tuple(self.versions[k] for k in sorted(self.versions, key=sorted))

    def merge(self, other: "MVRegister") -> bool:
        combined = dict(self.versions)
        combined.update(other.versions)
        keep = {}
        for vc, val in combined.items():
            if not any(self._dominates(o, vc) for o in combined if o != vc):
                keep[vc] = val
        changed = keep.keys() != self.versions.keys()
        self.versions = keep
        for r, c in other.clock.items():
            self.clock[r] = max(self.clock.get(r, 0), c)
        return changed


# -------------------------------------------------------------------- sets


class ORSet(CRDT):
    """Observed-remove set: add wins over concurrent remove."""

    def __init__(self) -> None:
        self.adds: Dict[Any, Set[Tuple[str, int]]] = {}
        self.tombstones: Set[Tuple[str, int]] = set()
        self._tag_seq: Dict[str, int] = {}

    def add(self, element: Any, replica: str) -> None:
        self._tag_seq[replica] = self._tag_seq.get(replica, 0) + 1
        tag = (replica, self._tag_seq[replica])
        self.adds.setdefault(element, set()).add(tag)

    def remove(self, element: Any) -> None:
        tags = self.adds.get(element, set())
        self.tombstones |= tags

    def contains(self, element: Any) -> bool:
        live = self.adds.get(element, set()) - self.tombstones
        return bool(live)

    def value(self) -> Set[Any]:
        return {e for e, tags in self.adds.items() if tags - self.tombstones}

    def merge(self, other: "ORSet") -> bool:
        changed = False
        for e, tags in other.adds.items():
            mine = self.adds.setdefault(e, set())
            if not tags <= mine:
                mine |= tags
                changed = True
        if not other.tombstones <= self.tombstones:
            self.tombstones |= other.tombstones
            changed = True
        for r, s in other._tag_seq.items():
            self._tag_seq[r] = max(self._tag_seq.get(r, 0), s)
        return changed


# ----------------------------------------------------------- composed store


_KINDS = {"g": GCounter, "pn": PNCounter, "lww": LWWRegister,
          "mv": MVRegister, "orset": ORSet}


def _str_int_map(d: Any) -> bool:
    return isinstance(d, dict) and all(
        isinstance(k, str) and isinstance(v, int) for k, v in d.items())


def _tag_set(s: Any) -> bool:
    """Replica tags: a set/frozenset of ``(replica, seq)`` pairs."""
    return isinstance(s, (set, frozenset)) and all(
        isinstance(t, tuple) and len(t) == 2
        and isinstance(t[0], str) and isinstance(t[1], int) for t in s)


def _wire_valid(entry: Any) -> bool:
    """Deep shape check for a peer-supplied CRDT: the restricted unpickler
    guarantees the *classes*, but an attacker still controls the instance
    state, and type-confused internals (a str count, an unsortable clock)
    would blow up later inside merge()/digest() — after partial mutation.
    Validate everything merge relies on before any of it is let near local
    state.  User-level values (register contents, set elements) stay
    arbitrary primitives; only the CRDT bookkeeping is constrained."""
    try:
        t = type(entry)
        if t is GCounter:
            return (_str_int_map(entry.counts)
                    and all(v >= 0 for v in entry.counts.values()))
        if t is PNCounter:
            return (type(entry.p) is GCounter and _wire_valid(entry.p)
                    and type(entry.n) is GCounter and _wire_valid(entry.n))
        if t is LWWRegister:
            ts = entry.ts
            return (isinstance(ts, tuple) and len(ts) == 2
                    and isinstance(ts[0], (int, float))
                    and not isinstance(ts[0], bool) and isinstance(ts[1], str))
        if t is MVRegister:
            return (_str_int_map(entry.clock)
                    and isinstance(entry.versions, dict)
                    and all(isinstance(vc, frozenset) and _tag_set(vc)
                            for vc in entry.versions))
        if t is ORSet:
            return (isinstance(entry.adds, dict)
                    and all(_tag_set(tags) for tags in entry.adds.values())
                    and _tag_set(entry.tombstones)
                    and _str_int_map(entry._tag_seq))
        return False
    except AttributeError:      # attacker-controlled __dict__ may omit slots
        return False


class ReplicatedStore(CRDT):
    """A named map of CRDTs — Lattica's decentralized data store.

    Used as the model-version registry: an ORSet of published checkpoint
    CIDs, an LWW pointer to the latest manifest, and G-Counters for global
    step / sample counts.  ``digest()`` gives a cheap state fingerprint for
    anti-entropy rounds; ``delta_since`` is full-state here (state-based
    CRDTs tolerate that; gossip batches keep it amortized).
    """

    def __init__(self, replica: str = "") -> None:
        self.replica = replica
        self.entries: Dict[str, CRDT] = {}

    # -- typed accessors ----------------------------------------------------
    def _get(self, key: str, kind: str) -> CRDT:
        if key not in self.entries:
            self.entries[key] = _KINDS[kind]()
        entry = self.entries[key]
        if not isinstance(entry, _KINDS[kind]):
            raise TypeError(f"{key} is {type(entry).__name__}, wanted {kind}")
        return entry

    def counter(self, key: str) -> GCounter:
        return self._get(key, "g")  # type: ignore[return-value]

    def pncounter(self, key: str) -> PNCounter:
        return self._get(key, "pn")  # type: ignore[return-value]

    def register(self, key: str) -> LWWRegister:
        return self._get(key, "lww")  # type: ignore[return-value]

    def orset(self, key: str) -> ORSet:
        return self._get(key, "orset")  # type: ignore[return-value]

    def mv(self, key: str) -> MVRegister:
        return self._get(key, "mv")  # type: ignore[return-value]

    # -- CRDT interface ------------------------------------------------------
    def value(self) -> Dict[str, Any]:
        return {k: v.value() for k, v in self.entries.items()}

    def merge(self, other: "ReplicatedStore") -> bool:
        changed = False
        for k, v in other.entries.items():
            if k in self.entries:
                if self.entries[k].merge(v):  # type: ignore[arg-type]
                    changed = True
            else:
                self.entries[k] = v.copy()
                changed = True
        return changed

    # -- sync helpers ----------------------------------------------------------
    def digest(self) -> bytes:
        """Order-independent fingerprint of the full state."""
        h = hashlib.sha256()
        for k in sorted(self.entries):
            h.update(k.encode())
            h.update(hashlib.sha256(self._canonical(self.entries[k])).digest())
        return h.digest()

    @staticmethod
    def _canonical(entry: CRDT) -> bytes:
        if isinstance(entry, GCounter):
            state: Any = sorted(entry.counts.items())
        elif isinstance(entry, PNCounter):
            state = (sorted(entry.p.counts.items()), sorted(entry.n.counts.items()))
        elif isinstance(entry, LWWRegister):
            state = (entry.ts, entry._value)
        elif isinstance(entry, ORSet):
            state = (sorted((repr(e), tuple(sorted(t))) for e, t in entry.adds.items()),
                     tuple(sorted(entry.tombstones)))
        elif isinstance(entry, MVRegister):
            state = sorted((tuple(sorted(vc)), repr(v)) for vc, v in entry.versions.items())
        else:  # pragma: no cover
            state = entry
        return pickle.dumps(state)

    #: globals anti-entropy state may resolve: the CRDT classes themselves
    #: plus set/frozenset (which pickle routes through find_class).  The
    #: payload arrives from arbitrary peers, so everything else is refused —
    #: an open pickle.loads here would hand the sender code execution.
    _WIRE_ALLOWED = frozenset({
        ("repro.core.crdt", "GCounter"),
        ("repro.core.crdt", "PNCounter"),
        ("repro.core.crdt", "LWWRegister"),
        ("repro.core.crdt", "MVRegister"),
        ("repro.core.crdt", "ORSet"),
        ("builtins", "set"),
        ("builtins", "frozenset"),
    })

    def serialize(self) -> bytes:
        return pickle.dumps(self.entries)

    @classmethod
    def deserialize(cls, data: bytes, replica: str = "") -> "ReplicatedStore":
        """Decode peer-supplied state; raises ``ValueError`` on payloads that
        are malformed or carry anything beyond CRDTs and primitives."""
        from .safepickle import restricted_loads

        entries = restricted_loads(data, cls._WIRE_ALLOWED)
        if not isinstance(entries, dict):
            raise ValueError("CRDT state must be a {name: CRDT} dict")
        for k, v in entries.items():
            if not isinstance(k, str) or not _wire_valid(v):
                raise ValueError(f"malformed CRDT state for entry {k!r}")
        store = cls(replica)
        store.entries = entries
        return store
