"""Conflict-free Replicated Data Types (Shapiro et al. 2011).

State-based (CvRDT) implementations with join-semilattice ``merge``:
merge is commutative, associative and idempotent, so replicas converge
regardless of delivery order, duplication, or partitions — exactly the
property Lattica's decentralized store relies on, and exactly what the
hypothesis tests in ``tests/test_crdt.py`` verify.

Since the delta-state redesign every kind is additionally a *delta-state*
CRDT (Almeida et al. 2018 style): ``vv()`` reports a replica's causal state
as a compact version-vector summary, and ``delta_since(vv)`` returns a
minimal mergeable fragment — the same type, carrying only the state the
summarized replica has not seen.  Syncing two replicas therefore moves
O(changed-state), not O(total-state), and a fragment is safe to merge at
*any* replica (fragments never overstate causal coverage: counters are
cumulative, registers ship full state, and ORSet coverage is recomputed
from the tags actually held).

The wire format is a canonical, versioned JSON codec (one schema per kind,
``encode_entry``/``decode_entry``); digests are computed over the canonical
encoding so two honest replicas can never disagree on a digest for equal
state (pickle bytes vary across Python/protocol versions — the old codec).
``ReplicatedStore.deserialize`` still accepts legacy pickled v1 state
through the ``safepickle`` restricted unpickler.

The ``ReplicatedStore`` composes named CRDTs into a document, exposes
per-key digests and a store-level causal context for the v2 sync protocol,
and a ``watch(prefix, callback)`` subscription API that fires on local and
merged-in remote changes — the foundation of the mesh's event-driven delta
push plane (``LatticaNode.watch_crdt``).
"""

from __future__ import annotations

import base64
import hashlib
import json
from typing import (Any, Callable, Dict, FrozenSet, Iterable, List, Optional,
                    Set, Tuple)

#: magic prefix of the canonical JSON wire format (store snapshots and
#: delta documents); anything else falls back to the legacy pickle path
WIRE_MAGIC = b"CRD2"

#: current wire schema version
WIRE_VERSION = 2


# ---------------------------------------------------------------------------
# Canonical value codec
# ---------------------------------------------------------------------------
#
# CRDT user values (register contents, set elements) are restricted to JSON
# primitives plus bytes / tuple / set / frozenset / non-str-keyed dicts,
# encoded with reserved single-key tag objects.  The encoding is canonical:
# dict keys sort, set elements sort by their encoded JSON — so equal values
# always produce identical bytes, which is what makes digests comparable
# across replicas.


def canonical_dumps(doc: Any) -> bytes:
    """Deterministic JSON bytes: sorted keys, no whitespace, no NaN/Inf."""
    return json.dumps(doc, sort_keys=True, separators=(",", ":"),
                      allow_nan=False).encode("utf-8")


def _enc_val(v: Any) -> Any:
    """Python value -> JSON-able doc.  Raises ``ValueError`` on types the
    canonical codec does not cover (store values must stay primitive).

    Numerics are normalized by Python value-equality: ``3.0`` encodes as
    ``3`` (and ``-0.0`` as ``0``), because ``3 == 3.0`` means they are the
    *same* set element / dict key to every replica — encoding them
    differently would let two equal-state replicas disagree on a digest
    forever.  (Bools keep their own type; mixing ``True`` with ``1`` in
    one container is outside the canonical domain.)"""
    if v is None or type(v) in (bool, int, str):
        return v
    if type(v) is float:
        if v != v or v in (float("inf"), float("-inf")):
            raise ValueError("canonical codec: NaN/Inf not representable")
        if v == int(v) and abs(v) < 2.0 ** 53:
            return int(v)
        return v
    if isinstance(v, bytes):
        return {"__b": base64.b64encode(v).decode("ascii")}
    if type(v) is tuple:
        return {"__t": [_enc_val(x) for x in v]}
    if type(v) is list:
        return {"__l": [_enc_val(x) for x in v]}
    if type(v) in (set, frozenset):
        enc = [_enc_val(x) for x in v]
        enc.sort(key=lambda d: canonical_dumps(d))
        return {"__s": enc}
    if type(v) is dict:
        pairs = [[_enc_val(k), _enc_val(x)] for k, x in v.items()]
        pairs.sort(key=lambda p: canonical_dumps(p[0]))
        return {"__d": pairs}
    raise ValueError(f"canonical codec: unsupported value type {type(v)!r}")


def _dec_val(doc: Any) -> Any:
    """Inverse of :func:`_enc_val`; raises ``ValueError`` on malformed docs.
    Sets decode to ``frozenset`` (hashable, ``==``-equal to the original)."""
    if doc is None or type(doc) in (bool, int, str, float):
        return doc
    if type(doc) is dict:
        if len(doc) != 1:
            raise ValueError("canonical codec: malformed tag object")
        tag, body = next(iter(doc.items()))
        if tag == "__b":
            if not isinstance(body, str):
                raise ValueError("canonical codec: bad bytes payload")
            try:
                return base64.b64decode(body.encode("ascii"), validate=True)
            except Exception as e:  # noqa: BLE001
                raise ValueError(f"canonical codec: bad base64: {e}") from e
        if tag == "__t" and isinstance(body, list):
            return tuple(_dec_val(x) for x in body)
        if tag == "__l" and isinstance(body, list):
            return [_dec_val(x) for x in body]
        if tag == "__s" and isinstance(body, list):
            return frozenset(_dec_val(x) for x in body)
        if tag == "__d" and isinstance(body, list):
            out = {}
            for p in body:
                if not (isinstance(p, list) and len(p) == 2):
                    raise ValueError("canonical codec: bad dict pair")
                out[_dec_val(p[0])] = _dec_val(p[1])
            return out
    raise ValueError(f"canonical codec: undecodable doc {type(doc)!r}")


def _str_int_map(d: Any) -> bool:
    """``{str: int}`` with genuine ints (bools refused)."""
    return isinstance(d, dict) and all(
        isinstance(k, str) and type(v) is int for k, v in d.items())


def _is_count_map(d: Any) -> bool:
    """``{replica: count}``: a :func:`_str_int_map` of non-negatives."""
    return _str_int_map(d) and all(v >= 0 for v in d.values())


def _vv_counts(vv: Any, field: str) -> Dict[str, int]:
    """Extract a count map from a peer-supplied version-vector summary;
    malformed summaries degrade to {} (send full state) instead of raising —
    a hostile vv must never crash the responder mid-sync."""
    if isinstance(vv, dict):
        m = vv.get(field)
        if _is_count_map(m):
            return m
    return {}


def _dec_tags(doc: Any) -> Set[Tuple[str, int]]:
    """Decode ``[[replica, seq], ...]`` into a tag set, validating shape."""
    if not isinstance(doc, list):
        raise ValueError("crdt codec: tag list expected")
    tags = set()
    for t in doc:
        if not (isinstance(t, list) and len(t) == 2
                and isinstance(t[0], str) and type(t[1]) is int and t[1] > 0):
            raise ValueError("crdt codec: malformed replica tag")
        tags.add((t[0], t[1]))
    return tags


def _enc_tags(tags: Iterable[Tuple[str, int]]) -> List[List[Any]]:
    return [[r, n] for r, n in sorted(tags)]


# ---------------------------------------------------------------------------
# CRDT kinds
# ---------------------------------------------------------------------------


class CRDT:
    """Interface: value(), merge(other) -> changed, vv(), delta_since(vv),
    to_doc()/from_doc(), copy()."""

    #: optional mutation listener, set by :class:`ReplicatedStore` so local
    #: writes fire ``watch`` callbacks and the node's delta push plane;
    #: never serialized (see ``__getstate__``)
    _listener: Optional[Callable[[], None]] = None

    def value(self) -> Any:
        raise NotImplementedError

    def merge(self, other: "CRDT") -> bool:
        raise NotImplementedError

    def vv(self) -> Dict[str, Any]:
        """Compact causal summary of this replica's state (JSON-able)."""
        raise NotImplementedError

    def delta_since(self, vv: Any) -> Optional["CRDT"]:
        """Minimal fragment a replica summarized by ``vv`` is missing, or
        ``None`` when it has seen everything.  ``vv=None`` (or malformed)
        means "knows nothing" — the fragment is then the full state."""
        raise NotImplementedError

    def to_doc(self) -> Dict[str, Any]:
        """Canonical JSON document for this state (one schema per kind)."""
        raise NotImplementedError

    def copy(self) -> "CRDT":
        import copy as _copy

        return _copy.deepcopy(self)

    # -- plumbing -----------------------------------------------------------
    def _notify(self) -> None:
        if self._listener is not None:
            self._listener()

    def __getstate__(self) -> Dict[str, Any]:
        state = dict(self.__dict__)
        state.pop("_listener", None)
        return state


# ---------------------------------------------------------------- counters


class GCounter(CRDT):
    """Grow-only counter: per-replica max.  The counts map doubles as the
    version vector, and deltas are cumulative — safe to merge anywhere."""

    def __init__(self) -> None:
        self.counts: Dict[str, int] = {}

    def increment(self, replica: str, n: int = 1) -> None:
        if n < 0:
            raise ValueError("GCounter cannot decrease")
        if n > 0:
            # never materialize a zero entry: merge can't propagate it
            # (0 > 0 is false), so it would exist on this replica only and
            # desynchronize digests between replicas of equal value forever
            self.counts[replica] = self.counts.get(replica, 0) + n
        self._notify()

    def value(self) -> int:
        return sum(self.counts.values())

    def merge(self, other: "GCounter") -> bool:
        changed = False
        for r, c in other.counts.items():
            if c > self.counts.get(r, 0):
                self.counts[r] = c
                changed = True
        return changed

    def vv(self) -> Dict[str, Any]:
        return {"c": dict(self.counts)}

    def delta_since(self, vv: Any) -> Optional["GCounter"]:
        seen = _vv_counts(vv, "c")
        news = {r: c for r, c in self.counts.items() if c > seen.get(r, 0)}
        if not news:
            return None
        d = GCounter()
        d.counts = news
        return d

    def to_doc(self) -> Dict[str, Any]:
        # zero entries (legacy unpickled state) are stripped: they carry no
        # information and never propagate through merge
        return {"k": "g", "c": {r: c for r, c in self.counts.items() if c}}

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "GCounter":
        if not _is_count_map(doc.get("c")):
            raise ValueError("gcounter doc: bad counts map")
        c = cls()
        c.counts = {r: n for r, n in doc["c"].items() if n}
        return c


class PNCounter(CRDT):
    """Increment/decrement counter as a pair of GCounters.

    The causal summary is the per-replica *sum* p+n: both halves grow
    monotonically at their owner, so observed (p, n) snapshots of one
    replica form a chain totally ordered by their sum."""

    def __init__(self) -> None:
        self.p = GCounter()
        self.n = GCounter()

    def increment(self, replica: str, n: int = 1) -> None:
        self.p.increment(replica, n)
        self._notify()

    def decrement(self, replica: str, n: int = 1) -> None:
        self.n.increment(replica, n)
        self._notify()

    def value(self) -> int:
        return self.p.value() - self.n.value()

    def merge(self, other: "PNCounter") -> bool:
        a = self.p.merge(other.p)
        b = self.n.merge(other.n)
        return a or b

    def vv(self) -> Dict[str, Any]:
        tot = {}
        for r in set(self.p.counts) | set(self.n.counts):
            tot[r] = self.p.counts.get(r, 0) + self.n.counts.get(r, 0)
        return {"c": tot}

    def delta_since(self, vv: Any) -> Optional["PNCounter"]:
        seen = _vv_counts(vv, "c")
        d = PNCounter()
        stale = True
        for r in set(self.p.counts) | set(self.n.counts):
            tot = self.p.counts.get(r, 0) + self.n.counts.get(r, 0)
            if tot > seen.get(r, 0):
                stale = False
                if r in self.p.counts:
                    d.p.counts[r] = self.p.counts[r]
                if r in self.n.counts:
                    d.n.counts[r] = self.n.counts[r]
        return None if stale else d

    def to_doc(self) -> Dict[str, Any]:
        return {"k": "pn",
                "p": {r: c for r, c in self.p.counts.items() if c},
                "n": {r: c for r, c in self.n.counts.items() if c}}

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "PNCounter":
        if not (_is_count_map(doc.get("p")) and _is_count_map(doc.get("n"))):
            raise ValueError("pncounter doc: bad counts maps")
        c = cls()
        c.p.counts = {r: n for r, n in doc["p"].items() if n}
        c.n.counts = {r: n for r, n in doc["n"].items() if n}
        return c


# ---------------------------------------------------------------- registers


class LWWRegister(CRDT):
    """Last-writer-wins register; ties broken by replica id (total order).

    Carries a per-replica write counter so ``delta_since`` can tell whether
    a peer has seen our latest write.  Deltas ship the full (tiny) state —
    a register fragment always justifies the clock it carries, so it is
    safe to merge at any replica."""

    def __init__(self) -> None:
        self.ts: Tuple[float, str] = (-1.0, "")
        self._value: Any = None
        self.clock: Dict[str, int] = {}

    def set(self, value: Any, timestamp: float, replica: str) -> None:
        self.clock[replica] = self.clock.get(replica, 0) + 1
        # float() keeps the canonical encoding stable: an int timestamp
        # would re-encode differently after a wire roundtrip
        if (float(timestamp), replica) > self.ts:
            self.ts = (float(timestamp), replica)
            self._value = value
        self._notify()

    def value(self) -> Any:
        return self._value

    def merge(self, other: "LWWRegister") -> bool:
        changed = False
        if other.ts > self.ts:
            self.ts = other.ts
            self._value = other._value
            changed = True
        for r, c in getattr(other, "clock", {}).items():
            if c > self.clock.get(r, 0):
                self.clock[r] = c
        return changed

    def vv(self) -> Dict[str, Any]:
        return {"c": dict(self.clock)}

    def delta_since(self, vv: Any) -> Optional["LWWRegister"]:
        if self.ts == (-1.0, "") and not self.clock:
            return None                         # virgin register: no state
        seen = _vv_counts(vv, "c")
        if self.clock and all(c <= seen.get(r, 0)
                              for r, c in self.clock.items()):
            return None
        return self.copy()

    def to_doc(self) -> Dict[str, Any]:
        return {"k": "lww", "t": [self.ts[0], self.ts[1]],
                "v": _enc_val(self._value), "c": dict(self.clock)}

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "LWWRegister":
        ts = doc.get("t")
        if not (isinstance(ts, list) and len(ts) == 2
                and type(ts[0]) in (int, float) and isinstance(ts[1], str)
                and _is_count_map(doc.get("c"))):
            raise ValueError("lww doc: bad timestamp/clock")
        r = cls()
        r.ts = (float(ts[0]), ts[1])
        r._value = _dec_val(doc.get("v"))
        r.clock = dict(doc["c"])
        return r

    def __setstate__(self, state: Dict[str, Any]) -> None:
        # legacy pickled registers predate the write clock and may carry
        # an int timestamp; normalize both
        self.__dict__.update(state)
        self.__dict__.setdefault("clock", {})
        ts = self.__dict__.get("ts")
        if (isinstance(ts, tuple) and len(ts) == 2
                and isinstance(ts[0], (int, float))
                and not isinstance(ts[0], bool)):
            self.ts = (float(ts[0]), ts[1])


class MVRegister(CRDT):
    """Multi-value register with vector-clock causality (keeps siblings).
    The vector clock is the causal summary; deltas ship full state (the
    sibling set is already minimal)."""

    def __init__(self) -> None:
        self.versions: Dict[FrozenSet[Tuple[str, int]], Any] = {}
        self.clock: Dict[str, int] = {}

    def set(self, value: Any, replica: str) -> None:
        self.clock[replica] = self.clock.get(replica, 0) + 1
        vc = frozenset(self.clock.items())
        self.versions = {vc: value}
        self._notify()

    @staticmethod
    def _dominates(a: FrozenSet[Tuple[str, int]], b: FrozenSet[Tuple[str, int]]) -> bool:
        da, db = dict(a), dict(b)
        keys = set(da) | set(db)
        ge = all(da.get(k, 0) >= db.get(k, 0) for k in keys)
        gt = any(da.get(k, 0) > db.get(k, 0) for k in keys)
        return ge and gt

    def value(self) -> Tuple[Any, ...]:
        return tuple(self.versions[k] for k in sorted(self.versions, key=sorted))

    def merge(self, other: "MVRegister") -> bool:
        combined = dict(self.versions)
        combined.update(other.versions)
        keep = {}
        for vc, val in combined.items():
            if not any(self._dominates(o, vc) for o in combined if o != vc):
                keep[vc] = val
        changed = keep.keys() != self.versions.keys()
        self.versions = keep
        for r, c in other.clock.items():
            self.clock[r] = max(self.clock.get(r, 0), c)
        return changed

    def vv(self) -> Dict[str, Any]:
        return {"c": dict(self.clock)}

    def delta_since(self, vv: Any) -> Optional["MVRegister"]:
        if not self.clock and not self.versions:
            return None
        seen = _vv_counts(vv, "c")
        if self.clock and all(c <= seen.get(r, 0)
                              for r, c in self.clock.items()):
            return None
        return self.copy()

    def to_doc(self) -> Dict[str, Any]:
        vs = [[_enc_tags(vc), _enc_val(val)]
              for vc, val in self.versions.items()]
        vs.sort(key=lambda p: canonical_dumps(p[0]))
        return {"k": "mv", "vs": vs, "c": dict(self.clock)}

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "MVRegister":
        if not (_is_count_map(doc.get("c")) and isinstance(doc.get("vs"), list)):
            raise ValueError("mv doc: bad clock/versions")
        r = cls()
        for p in doc["vs"]:
            if not (isinstance(p, list) and len(p) == 2):
                raise ValueError("mv doc: bad version pair")
            r.versions[frozenset(_dec_tags(p[0]))] = _dec_val(p[1])
        r.clock = dict(doc["c"])
        return r


# -------------------------------------------------------------------- sets


class ORSet(CRDT):
    """Observed-remove set: add wins over concurrent remove.

    Delta interface: adds are summarized by a per-replica *contiguous*
    coverage vector recomputed from the tags actually held (``coverage``),
    so a fragment merged at a replica that missed earlier fragments can
    never overstate what it has seen — gaps keep the coverage low and a
    later sync refills them.  Tombstones are summarized by a digest: any
    difference ships the (typically tiny) tombstone set whole."""

    def __init__(self) -> None:
        self.adds: Dict[Any, Set[Tuple[str, int]]] = {}
        self.tombstones: Set[Tuple[str, int]] = set()
        self._tag_seq: Dict[str, int] = {}

    def add(self, element: Any, replica: str) -> None:
        self._tag_seq[replica] = self._tag_seq.get(replica, 0) + 1
        tag = (replica, self._tag_seq[replica])
        self.adds.setdefault(element, set()).add(tag)
        self._notify()

    def remove(self, element: Any) -> None:
        tags = self.adds.get(element, set())
        self.tombstones |= tags
        self._notify()

    def contains(self, element: Any) -> bool:
        live = self.adds.get(element, set()) - self.tombstones
        return bool(live)

    def value(self) -> Set[Any]:
        return {e for e, tags in self.adds.items() if tags - self.tombstones}

    def merge(self, other: "ORSet") -> bool:
        changed = False
        for e, tags in other.adds.items():
            mine = self.adds.setdefault(e, set())
            if not tags <= mine:
                mine |= tags
                changed = True
        if not other.tombstones <= self.tombstones:
            self.tombstones |= other.tombstones
            changed = True
        for r, s in other._tag_seq.items():
            self._tag_seq[r] = max(self._tag_seq.get(r, 0), s)
        return changed

    # -- causal summary -----------------------------------------------------
    def coverage(self) -> Dict[str, int]:
        """Per-replica contiguous add-tag prefix actually held.  At a
        replica that never merged a gapped fragment this equals the tag
        allocator; after a gap it is truthfully lower, so peers resend."""
        held: Dict[str, Set[int]] = {}
        for tags in self.adds.values():
            for r, n in tags:
                held.setdefault(r, set()).add(n)
        cov = {}
        for r, seqs in held.items():
            c = 0
            while c + 1 in seqs:
                c += 1
            if c:
                cov[r] = c
        return cov

    def _tomb_digest(self) -> str:
        raw = canonical_dumps(_enc_tags(self.tombstones))
        return base64.b64encode(
            hashlib.sha256(raw).digest()[:8]).decode("ascii")

    def vv(self) -> Dict[str, Any]:
        return {"s": self.coverage(), "t": self._tomb_digest()}

    def delta_since(self, vv: Any) -> Optional["ORSet"]:
        seen = _vv_counts(vv, "s")
        tomb_seen = vv.get("t") if isinstance(vv, dict) else None
        d = ORSet()
        fresh = False
        for e, tags in self.adds.items():
            new = {t for t in tags if t[1] > seen.get(t[0], 0)}
            if new:
                d.adds[e] = new
                fresh = True
        if self.tombstones and tomb_seen != self._tomb_digest():
            d.tombstones = set(self.tombstones)
            fresh = True
        if not fresh:
            return None
        d._tag_seq = dict(self._tag_seq)    # allocator state, not coverage
        return d

    def to_doc(self) -> Dict[str, Any]:
        adds = [[_enc_val(e), _enc_tags(tags)]
                for e, tags in self.adds.items()]
        adds.sort(key=lambda p: canonical_dumps(p[0]))
        return {"k": "orset", "a": adds,
                "t": _enc_tags(self.tombstones), "s": dict(self._tag_seq)}

    @classmethod
    def from_doc(cls, doc: Dict[str, Any]) -> "ORSet":
        if not (isinstance(doc.get("a"), list) and _is_count_map(doc.get("s"))):
            raise ValueError("orset doc: bad adds/seq")
        s = cls()
        for p in doc["a"]:
            if not (isinstance(p, list) and len(p) == 2):
                raise ValueError("orset doc: bad add pair")
            elem = _dec_val(p[0])
            try:
                hash(elem)
            except TypeError as e:
                raise ValueError("orset doc: unhashable element") from e
            s.adds[elem] = _dec_tags(p[1])
        s.tombstones = _dec_tags(doc.get("t", []))
        s._tag_seq = dict(doc["s"])
        return s


# ----------------------------------------------------------- codec dispatch


_KINDS = {"g": GCounter, "pn": PNCounter, "lww": LWWRegister,
          "mv": MVRegister, "orset": ORSet}
_KIND_TAGS = {cls: tag for tag, cls in _KINDS.items()}


def encode_entry(entry: CRDT) -> Dict[str, Any]:
    """CRDT -> canonical JSON document (tagged with its kind)."""
    if type(entry) not in _KIND_TAGS:
        raise ValueError(f"unknown CRDT kind {type(entry).__name__}")
    return entry.to_doc()


def decode_entry(doc: Any) -> CRDT:
    """Canonical JSON document -> CRDT; raises ``ValueError`` on anything
    malformed (documents arrive from arbitrary peers)."""
    if not isinstance(doc, dict):
        raise ValueError("crdt doc: object expected")
    cls = _KINDS.get(doc.get("k"))
    if cls is None:
        raise ValueError(f"crdt doc: unknown kind {doc.get('k')!r}")
    return cls.from_doc(doc)


def entry_digest(entry: CRDT) -> bytes:
    """Stable state fingerprint: sha256 over the canonical encoding."""
    return hashlib.sha256(canonical_dumps(encode_entry(entry))).digest()


def _tag_set(s: Any) -> bool:
    """Replica tags: a set/frozenset of ``(replica, seq)`` pairs."""
    return isinstance(s, (set, frozenset)) and all(
        isinstance(t, tuple) and len(t) == 2
        and isinstance(t[0], str) and isinstance(t[1], int) for t in s)


def _wire_valid(entry: Any) -> bool:
    """Deep shape check for a peer-supplied *legacy pickled* CRDT: the
    restricted unpickler guarantees the classes, but an attacker still
    controls the instance state, and type-confused internals (a str count,
    an unsortable clock) would blow up later inside merge()/digest() —
    after partial mutation.  Validate everything merge relies on before any
    of it is let near local state.  (The canonical JSON path validates in
    ``from_doc`` instead.)"""
    try:
        t = type(entry)
        if t is GCounter:
            return (_str_int_map(entry.counts)
                    and all(v >= 0 for v in entry.counts.values()))
        if t is PNCounter:
            return (type(entry.p) is GCounter and _wire_valid(entry.p)
                    and type(entry.n) is GCounter and _wire_valid(entry.n))
        if t is LWWRegister:
            ts = entry.ts
            return (isinstance(ts, tuple) and len(ts) == 2
                    and isinstance(ts[0], (int, float))
                    and not isinstance(ts[0], bool) and isinstance(ts[1], str)
                    and _str_int_map(getattr(entry, "clock", {})))
        if t is MVRegister:
            return (_str_int_map(entry.clock)
                    and isinstance(entry.versions, dict)
                    and all(isinstance(vc, frozenset) and _tag_set(vc)
                            for vc in entry.versions))
        if t is ORSet:
            return (isinstance(entry.adds, dict)
                    and all(_tag_set(tags) for tags in entry.adds.values())
                    and _tag_set(entry.tombstones)
                    and _str_int_map(entry._tag_seq))
        return False
    except AttributeError:      # attacker-controlled __dict__ may omit slots
        return False


# ----------------------------------------------------------- composed store


class ReplicatedStore(CRDT):
    """A named map of CRDTs — Lattica's decentralized data store.

    Used as the model-version registry: an ORSet of published checkpoint
    CIDs, an LWW pointer to the latest manifest, and G-Counters for global
    step / sample counts.

    Sync surface (the v2 anti-entropy protocol is built on these):

    * ``digest()``          — order-independent full-state fingerprint
    * ``key_digests()``     — per-key truncated fingerprints (summary round)
    * ``vv()``              — store-level causal context {key: kind vv}
    * ``delta_since(vv)``   — {key: fragment} of everything a peer misses
    * ``apply_delta(...)``  — merge fragments, firing ``watch`` callbacks

    ``watch(prefix, callback)`` subscribes to changes: the callback fires as
    ``callback(key, value, origin)`` on local mutations (origin="local") and
    on merged-in remote state (origin="remote").
    """

    def __init__(self, replica: str = "") -> None:
        self.replica = replica
        self.entries: Dict[str, CRDT] = {}
        self._watchers: Dict[int, Tuple[str, Callable[[str, Any, str], None]]] = {}
        self._watch_seq = 0
        self._local_hooks: List[Callable[[str], None]] = []
        # per-key digest cache: entry_digest() re-serializes the whole entry,
        # which turns every anti-entropy probe into O(keys x state) at fleet
        # scale; invalidated on any touch (merge may mutate bookkeeping such
        # as ORSet._tag_seq even when it reports no change)
        self._digest_cache: Dict[str, bytes] = {}
        self._summary_gen = 0
        self._forest_cache: Optional[
            Tuple[int, Dict[str, "MerkleSummaryTree"]]] = None

    # -- typed accessors ----------------------------------------------------
    def _get(self, key: str, kind: str) -> CRDT:
        if key not in self.entries:
            self._adopt(key, _KINDS[kind]())
        entry = self.entries[key]
        if not isinstance(entry, _KINDS[kind]):
            raise TypeError(f"{key} is {type(entry).__name__}, wanted {kind}")
        return entry

    def counter(self, key: str) -> GCounter:
        return self._get(key, "g")  # type: ignore[return-value]

    def pncounter(self, key: str) -> PNCounter:
        return self._get(key, "pn")  # type: ignore[return-value]

    def register(self, key: str) -> LWWRegister:
        return self._get(key, "lww")  # type: ignore[return-value]

    def orset(self, key: str) -> ORSet:
        return self._get(key, "orset")  # type: ignore[return-value]

    def mv(self, key: str) -> MVRegister:
        return self._get(key, "mv")  # type: ignore[return-value]

    def _adopt(self, key: str, entry: CRDT) -> CRDT:
        """Install ``entry`` under ``key`` wired to the watch plane."""
        self.entries[key] = entry
        entry._listener = lambda k=key: self._on_local_mutation(k)
        self._dirty(key)
        return entry

    def _dirty(self, key: str) -> None:
        """Drop cached summary state for a touched key."""
        self._digest_cache.pop(key, None)
        self._summary_gen += 1

    # -- watch plane ---------------------------------------------------------
    def watch(self, prefix: str,
              callback: Callable[[str, Any, str], None]) -> int:
        """Subscribe ``callback(key, value, origin)`` to every change of a
        key starting with ``prefix`` ("" watches everything).  Fires on
        local mutations and on merged-in remote state.  Returns a handle
        for :meth:`unwatch`."""
        self._watch_seq += 1
        self._watchers[self._watch_seq] = (prefix, callback)
        return self._watch_seq

    def unwatch(self, handle: int) -> None:
        self._watchers.pop(handle, None)

    def on_local_change(self, hook: Callable[[str], None]) -> None:
        """Register a store-wide local-mutation hook (the node's delta push
        plane); called with the mutated key before watch callbacks."""
        self._local_hooks.append(hook)

    def _on_local_mutation(self, key: str) -> None:
        self._dirty(key)
        for hook in list(self._local_hooks):
            hook(key)
        self._fire(key, "local")

    def _fire(self, key: str, origin: str) -> None:
        entry = self.entries.get(key)
        if entry is None:       # defensive: watcher raced an adoption
            return
        for prefix, cb in list(self._watchers.values()):
            if key.startswith(prefix):
                cb(key, entry.value(), origin)

    # -- CRDT interface ------------------------------------------------------
    def value(self) -> Dict[str, Any]:
        return {k: v.value() for k, v in self.entries.items()}

    def merge(self, other: "ReplicatedStore") -> bool:
        changed_keys = []
        for k, v in other.entries.items():
            if k in self.entries:
                self._dirty(k)
                if self.entries[k].merge(v):  # type: ignore[arg-type]
                    changed_keys.append(k)
            else:
                self._adopt(k, v.copy())
                changed_keys.append(k)
        for k in changed_keys:
            self._fire(k, "remote")
        return bool(changed_keys)

    # -- causal context / deltas ----------------------------------------------
    def vv(self) -> Dict[str, Any]:
        """Store-level causal context: {key: kind-specific version vector}."""
        return {k: e.vv() for k, e in self.entries.items()}

    def entry_vv(self, key: str) -> Optional[Dict[str, Any]]:
        entry = self.entries.get(key)
        return None if entry is None else entry.vv()

    def entry_digest_cached(self, key: str) -> bytes:
        """Full 32-byte state fingerprint of one entry, memoized until the
        entry is next touched (mutation, merge, or delta application)."""
        d = self._digest_cache.get(key)
        if d is None:
            d = self._digest_cache[key] = entry_digest(self.entries[key])
        return d

    def key_digests(self) -> Dict[str, str]:
        """Per-key truncated state fingerprints — the *flat* v2 summary
        round, O(keys) bytes per probe.  Superseded by the Merkle summary
        forest (:meth:`summary_forest`) for sim-executing sync paths; kept
        as the negotiated v2 wire fallback (latlint L007 flags new callers
        outside the fallback path)."""
        return {k: base64.b64encode(self.entry_digest_cached(k)[:8]).decode("ascii")
                for k in self.entries}

    def summary_forest(self) -> Dict[str, "MerkleSummaryTree"]:
        """Namespace-sharded Merkle summary trees (independent roots per
        namespace), rebuilt lazily when any entry has been touched.  The
        MST probe walks these to localize differing keys in O(log n) tree
        nodes instead of shipping every key's digest."""
        cached = self._forest_cache
        if cached is not None and cached[0] == self._summary_gen:
            return cached[1]
        by_ns: Dict[str, Dict[str, bytes]] = {}
        for k in self.entries:
            ns = k.split("/", 1)[0]
            by_ns.setdefault(ns, {})[k] = self.entry_digest_cached(k)[:8]
        forest = {ns: MerkleSummaryTree(kd) for ns, kd in by_ns.items()}
        self._forest_cache = (self._summary_gen, forest)
        return forest

    def summary_roots(self) -> Dict[str, str]:
        """{namespace: MST root hash (hex)} — the O(namespaces) probe."""
        return {ns: t.root() for ns, t in self.summary_forest().items()}

    def delta_since(self, vv_map: Any,
                    keys: Optional[Iterable[str]] = None) -> Dict[str, CRDT]:
        """Per-key fragments a replica summarized by ``vv_map`` is missing.
        ``vv_map`` maps key -> kind vv (or None = key unknown there); keys
        absent from the map count as unknown.  With ``keys``, only those
        are considered (the per-key protocol round)."""
        if not isinstance(vv_map, dict):
            vv_map = {}
        out: Dict[str, CRDT] = {}
        for k in (keys if keys is not None else self.entries):
            entry = self.entries.get(k)
            if entry is None:
                continue
            d = entry.delta_since(vv_map.get(k))
            if d is not None:
                out[k] = d
        return out

    def apply_delta(self, deltas: Dict[str, CRDT],
                    origin: str = "remote") -> List[str]:
        """Merge per-key fragments; returns the keys that changed (watch
        callbacks fire for each).  Raises ``ValueError`` on a kind conflict
        with local state — and validates the *whole* document before
        merging any of it, so a poisoned fragment can never land part of a
        delta without its watch callbacks firing."""
        for k, frag in deltas.items():
            if not isinstance(k, str) or not isinstance(frag, CRDT):
                raise ValueError("delta: malformed fragment map")
            cur = self.entries.get(k)
            if cur is not None and type(cur) is not type(frag):
                raise ValueError(
                    f"delta kind conflict for {k!r}: "
                    f"{type(cur).__name__} vs {type(frag).__name__}")
        changed = []
        for k, frag in deltas.items():
            cur = self.entries.get(k)
            if cur is None:
                self._adopt(k, frag.copy())
                changed.append(k)
            else:
                self._dirty(k)
                if cur.merge(frag):  # type: ignore[arg-type]
                    changed.append(k)
        for k in changed:
            self._fire(k, origin)
        return changed

    # -- sync helpers ----------------------------------------------------------
    def digest(self) -> bytes:
        """Order-independent fingerprint of the full state."""
        h = hashlib.sha256()
        for k in sorted(self.entries):
            h.update(k.encode())
            h.update(self.entry_digest_cached(k))
        return h.digest()

    @staticmethod
    def _canonical(entry: CRDT) -> bytes:
        """Canonical bytes of one entry's state (codec-based; stable across
        Python and pickle-protocol versions, unlike the old pickle.dumps)."""
        return canonical_dumps(encode_entry(entry))

    # -- wire format -----------------------------------------------------------
    #: globals legacy anti-entropy state may resolve: the CRDT classes
    #: themselves plus set/frozenset (which pickle routes through
    #: find_class).  The payload arrives from arbitrary peers, so everything
    #: else is refused — an open pickle.loads here would hand the sender
    #: code execution.
    _WIRE_ALLOWED = frozenset({
        ("repro.core.crdt", "GCounter"),
        ("repro.core.crdt", "PNCounter"),
        ("repro.core.crdt", "LWWRegister"),
        ("repro.core.crdt", "MVRegister"),
        ("repro.core.crdt", "ORSet"),
        ("builtins", "set"),
        ("builtins", "frozenset"),
    })

    def serialize(self) -> bytes:
        """Canonical versioned snapshot (v2 JSON wire format)."""
        doc = {"v": WIRE_VERSION,
               "entries": {k: encode_entry(e) for k, e in self.entries.items()}}
        return WIRE_MAGIC + canonical_dumps(doc)

    @staticmethod
    def encode_delta(deltas: Dict[str, CRDT]) -> bytes:
        """Per-key fragments -> canonical versioned delta document."""
        doc = {"v": WIRE_VERSION,
               "d": {k: encode_entry(e) for k, e in deltas.items()}}
        return WIRE_MAGIC + canonical_dumps(doc)

    @staticmethod
    def decode_delta(raw: bytes) -> Dict[str, CRDT]:
        """Decode + validate a peer-supplied delta document."""
        doc = _load_wire_doc(raw)
        d = doc.get("d")
        if not isinstance(d, dict):
            raise ValueError("delta doc: missing fragment map")
        return {_chk_key(k): decode_entry(v) for k, v in d.items()}

    @classmethod
    def deserialize(cls, data: bytes, replica: str = "") -> "ReplicatedStore":
        """Decode peer-supplied state; raises ``ValueError`` on payloads
        that are malformed or carry anything beyond CRDTs and primitives.
        Accepts both the canonical v2 JSON format and legacy pickled v1
        state (restricted unpickling, CRDT classes only)."""
        if data[:len(WIRE_MAGIC)] == WIRE_MAGIC:
            doc = _load_wire_doc(data)
            raw_entries = doc.get("entries")
            if not isinstance(raw_entries, dict):
                raise ValueError("CRDT state must be a {name: doc} map")
            store = cls(replica)
            for k, d in raw_entries.items():
                store._adopt(_chk_key(k), decode_entry(d))
            return store
        from .safepickle import restricted_loads

        entries = restricted_loads(data, cls._WIRE_ALLOWED)
        if not isinstance(entries, dict):
            raise ValueError("CRDT state must be a {name: CRDT} dict")
        for k, v in entries.items():
            if not isinstance(k, str) or not _wire_valid(v):
                raise ValueError(f"malformed CRDT state for entry {k!r}")
        store = cls(replica)
        for k, v in entries.items():
            store._adopt(k, v)
        return store


def _chk_key(k: Any) -> str:
    if not isinstance(k, str) or not k:
        raise ValueError("crdt doc: entry keys must be non-empty strings")
    return k


def _load_wire_doc(raw: bytes) -> Dict[str, Any]:
    """Parse + version-check a ``CRD2``-magic wire document."""
    if raw[:len(WIRE_MAGIC)] != WIRE_MAGIC:
        raise ValueError("crdt wire: bad magic")
    try:
        doc = json.loads(raw[len(WIRE_MAGIC):].decode("utf-8"))
    except Exception as e:  # noqa: BLE001 — undecodable peer payload
        raise ValueError(f"crdt wire: undecodable JSON: {e}") from e
    if not isinstance(doc, dict) or doc.get("v") != WIRE_VERSION:
        raise ValueError("crdt wire: unsupported document version")
    return doc


# ----------------------------------------------------------- summary wire


def encode_summary(digests: Dict[str, str]) -> bytes:
    """Per-key digest map -> summary request document."""
    return WIRE_MAGIC + canonical_dumps({"v": WIRE_VERSION, "kd": digests})


def decode_summary(raw: bytes) -> Dict[str, str]:
    doc = _load_wire_doc(raw)
    kd = doc.get("kd")
    if not (isinstance(kd, dict) and all(
            isinstance(k, str) and isinstance(v, str) for k, v in kd.items())):
        raise ValueError("summary doc: bad digest map")
    return kd


def encode_vv_map(vv_map: Dict[str, Optional[Dict[str, Any]]]) -> bytes:
    """{key: kind vv or None} -> summary response document."""
    return WIRE_MAGIC + canonical_dumps({"v": WIRE_VERSION, "vv": vv_map})


def decode_vv_map(raw: bytes) -> Dict[str, Optional[Dict[str, Any]]]:
    doc = _load_wire_doc(raw)
    vv = doc.get("vv")
    if not (isinstance(vv, dict) and all(
            isinstance(k, str) and (v is None or isinstance(v, dict))
            for k, v in vv.items())):
        raise ValueError("vv doc: bad version-vector map")
    return vv


def encode_delta_request(vv_map: Dict[str, Optional[Dict[str, Any]]],
                         deltas: Dict[str, CRDT]) -> bytes:
    """The delta round's request: the caller's per-key vv for the keys it
    wants updates on, plus its own fragments for the responder."""
    doc = {"v": WIRE_VERSION, "vv": vv_map,
           "d": {k: encode_entry(e) for k, e in deltas.items()}}
    return WIRE_MAGIC + canonical_dumps(doc)


def decode_delta_request(raw: bytes) -> Tuple[
        Dict[str, Optional[Dict[str, Any]]], Dict[str, CRDT]]:
    doc = _load_wire_doc(raw)
    vv = doc.get("vv")
    d = doc.get("d")
    if not (isinstance(vv, dict) and isinstance(d, dict)):
        raise ValueError("delta request: bad vv/fragment maps")
    vv_map = {}
    for k, v in vv.items():
        if not isinstance(k, str) or not (v is None or isinstance(v, dict)):
            raise ValueError("delta request: bad vv entry")
        vv_map[k] = v
    deltas = {_chk_key(k): decode_entry(v) for k, v in d.items()}
    return vv_map, deltas


def _chk_vv_map(vv: Any, what: str) -> Dict[str, Optional[Dict[str, Any]]]:
    if not isinstance(vv, dict):
        raise ValueError(f"{what}: bad vv map")
    out: Dict[str, Optional[Dict[str, Any]]] = {}
    for k, v in vv.items():
        if not isinstance(k, str) or not (v is None or isinstance(v, dict)):
            raise ValueError(f"{what}: bad vv entry")
        out[k] = v
    return out


def encode_delta2_request(vv_map: Dict[str, Optional[Dict[str, Any]]],
                          deltas: Dict[str, "CRDT"],
                          buckets: List[Tuple[str, str]]) -> bytes:
    """The MST delta round's request: the caller's per-key vv (including
    every key it holds under the listed reconcile buckets), its push
    fragments, and the differing leaf-bucket paths — the responder ships
    full state for its keys under those paths absent from the vv map."""
    doc = {"v": WIRE_VERSION, "vv": vv_map,
           "d": {k: encode_entry(e) for k, e in deltas.items()},
           "b": [[ns, p] for ns, p in buckets]}
    return WIRE_MAGIC + canonical_dumps(doc)


def decode_delta2_request(raw: bytes) -> Tuple[
        Dict[str, Optional[Dict[str, Any]]], Dict[str, "CRDT"],
        List[Tuple[str, str]]]:
    doc = _load_wire_doc(raw)
    vv_map = _chk_vv_map(doc.get("vv"), "delta2 request")
    d = doc.get("d")
    b = doc.get("b")
    if not isinstance(d, dict) or not isinstance(b, list) or len(b) > 4096:
        raise ValueError("delta2 request: bad fragment/bucket lists")
    deltas = {_chk_key(k): decode_entry(v) for k, v in d.items()}
    buckets = []
    for item in b:
        if not (isinstance(item, list) and len(item) == 2):
            raise ValueError("delta2 request: bad bucket")
        buckets.append((_chk_key(item[0]), _chk_path(item[1])))
    return vv_map, deltas, buckets


def encode_delta2_response(deltas: Dict[str, "CRDT"],
                           want: Dict[str, Optional[Dict[str, Any]]]
                           ) -> bytes:
    """The responder's fragments plus ``want`` — its vv for the keys where
    the caller's vv shows state the responder lacks, answered by one
    push-only ``crdt.delta`` follow-up."""
    doc = {"v": WIRE_VERSION,
           "d": {k: encode_entry(e) for k, e in deltas.items()},
           "w": want}
    return WIRE_MAGIC + canonical_dumps(doc)


def decode_delta2_response(raw: bytes) -> Tuple[
        Dict[str, "CRDT"], Dict[str, Optional[Dict[str, Any]]]]:
    doc = _load_wire_doc(raw)
    d = doc.get("d")
    if not isinstance(d, dict):
        raise ValueError("delta2 response: bad fragment map")
    deltas = {_chk_key(k): decode_entry(v) for k, v in d.items()}
    return deltas, _chk_vv_map(doc.get("w"), "delta2 response")


# ----------------------------------------------------------- Merkle summary


#: children per internal MST node (one hex nibble of the key-placement hash)
MST_FANOUT = 16

#: maximum keys a leaf bucket holds before it splits into an internal node
MST_LEAF_SIZE = 8

#: hex chars of a subtree hash shipped on the wire.  The walk only ever
#: compares hashes for equality, so 32 bits is collision headroom against
#: the ~1e3 comparisons a probe makes — and the astronomically-rare false
#: equality merely delays one subtree to the next anti-entropy round.
#: Full-width hashes stay internal to the tree.
MST_WIRE_HASH = 8


def mst_wire_hash(h: str) -> str:
    """Truncate an internal node hash to its wire width."""
    return h[:MST_WIRE_HASH]


def _mst_place(key: str) -> str:
    """Deterministic trie placement for a key: hex of sha256(key).  Equal
    key sets therefore always produce identical tree *shapes* regardless of
    insertion order or which replica built the tree."""
    return hashlib.sha256(key.encode("utf-8")).hexdigest()


class MerkleSummaryTree:
    """Deterministic Merkle prefix trie over ``{key: digest8}``.

    Keys are placed by the hex prefix of ``sha256(key)``; a subtree with at
    most :data:`MST_LEAF_SIZE` keys is a leaf bucket, anything larger splits
    on the next nibble.  Node hashes cover the sorted ``(key, digest)``
    content of the whole subtree, so two replicas with equal key state agree
    on every node hash — and a differing key is localized by walking the
    O(log n) differing path instead of exchanging every key's digest.

    The tree is immutable once built; ``ReplicatedStore.summary_forest``
    rebuilds (from cached per-key digests) only when an entry was touched.
    """

    def __init__(self, key_digests: Dict[str, bytes]) -> None:
        self._kd = dict(key_digests)
        self._paths = {k: _mst_place(k) for k in self._kd}
        # sorted once: children and leaf listings derive from slices
        self._order = sorted(self._kd)
        self._hash_cache: Dict[str, str] = {}

    def __len__(self) -> int:
        return len(self._kd)

    def keys_under(self, path: str) -> List[str]:
        """All keys whose placement hash starts with ``path`` (hex)."""
        return [k for k in self._order if self._paths[k].startswith(path)]

    def is_leaf(self, path: str) -> bool:
        return len(self.keys_under(path)) <= MST_LEAF_SIZE

    def node_hash(self, path: str) -> str:
        """Hex hash of the subtree at ``path`` ('' = root).  Empty subtrees
        hash to a distinguished constant so presence/absence is visible."""
        h = self._hash_cache.get(path)
        if h is None:
            keys = self.keys_under(path)
            acc = hashlib.sha256(b"MST1")
            for k in keys:
                acc.update(k.encode("utf-8"))
                acc.update(self._kd[k])
            h = self._hash_cache[path] = acc.hexdigest()
        return h

    def root(self) -> str:
        return self.node_hash("")

    def children(self, path: str) -> Dict[str, str]:
        """{nibble: child hash} for the non-empty children of an internal
        node (callers must not ask for children of a leaf)."""
        out: Dict[str, str] = {}
        for k in self.keys_under(path):
            nib = self._paths[k][len(path)]
            out.setdefault(nib, "")
        return {nib: self.node_hash(path + nib) for nib in out}

    def leaf_digests(self, path: str) -> Dict[str, str]:
        """{key: digest8 (b64)} for the keys in a leaf bucket."""
        return {k: base64.b64encode(self._kd[k]).decode("ascii")
                for k in self.keys_under(path)}


# MST probe wire documents.  One idempotent unary (``crdt.mst``) carries a
# batch of subtree queries; responses describe each queried node (internal
# children, or a leaf's keys with digest + per-key vv so the caller can run
# the existing delta round without another O(keys) exchange).

_HEX_NIBBLES = frozenset("0123456789abcdef")


def _chk_path(p: Any) -> str:
    if not isinstance(p, str) or len(p) > 64 or not set(p) <= _HEX_NIBBLES:
        raise ValueError("mst doc: bad subtree path")
    return p


def encode_mst_request(queries: List[Tuple[str, str]],
                       want_roots: bool = False) -> bytes:
    """Batch of ``(namespace, path)`` subtree queries, grouped by namespace
    so each ns string ships once; ``want_roots`` asks the responder to
    include its full {ns: root} map (first round)."""
    by_ns: Dict[str, List[str]] = {}
    for ns, p in queries:
        by_ns.setdefault(ns, []).append(p)
    doc: Dict[str, Any] = {"v": WIRE_VERSION, "q": by_ns}
    if want_roots:
        doc["r"] = True
    return WIRE_MAGIC + canonical_dumps(doc)


def decode_mst_request(raw: bytes) -> Tuple[bool, List[Tuple[str, str]]]:
    doc = _load_wire_doc(raw)
    q = doc.get("q")
    if not isinstance(q, dict):
        raise ValueError("mst request: bad query map")
    queries = []
    for ns, paths in q.items():
        if not isinstance(paths, list):
            raise ValueError("mst request: bad path list")
        for p in paths:
            queries.append((_chk_key(ns), _chk_path(p)))
    if len(queries) > 4096:
        raise ValueError("mst request: bad query list")
    return bool(doc.get("r")), queries


_CHILD_STRIDE = 1 + MST_WIRE_HASH


def _pack_children(children: Dict[str, str]) -> str:
    """{nibble: full hash} -> fixed-stride ``<nib><hash8>`` string (the
    probe's dominant wire term; a JSON map of full hashes costs ~8x)."""
    return "".join(nib + mst_wire_hash(h)
                   for nib, h in sorted(children.items()))


def _unpack_children(packed: str) -> Dict[str, str]:
    if len(packed) % _CHILD_STRIDE:
        raise ValueError("mst response: bad child packing")
    out: Dict[str, str] = {}
    for i in range(0, len(packed), _CHILD_STRIDE):
        nib = packed[i]
        if nib not in _HEX_NIBBLES:
            raise ValueError("mst response: bad child nibble")
        out[nib] = packed[i + 1:i + _CHILD_STRIDE]
    return out


def encode_mst_response(nodes: List[Dict[str, Any]],
                        roots: Optional[Dict[str, str]] = None) -> bytes:
    """``nodes``: one doc per query — {"ns", "p", "t": "i"|"l"|"x", and
    "c" (internal: {nibble: full hash}, packed + truncated on the wire) or
    "kd" (leaf: {key: [digest8, vv]})}.  Root hashes are truncated too."""
    wire_nodes = []
    for nd in nodes:
        if nd.get("t") == "i":
            nd = dict(nd)
            nd["c"] = _pack_children(nd["c"])
        wire_nodes.append(nd)
    doc: Dict[str, Any] = {"v": WIRE_VERSION, "n": wire_nodes}
    if roots is not None:
        doc["roots"] = {ns: mst_wire_hash(h) for ns, h in roots.items()}
    return WIRE_MAGIC + canonical_dumps(doc)


def decode_mst_response(raw: bytes) -> Tuple[
        Optional[Dict[str, str]], List[Dict[str, Any]]]:
    doc = _load_wire_doc(raw)
    roots = doc.get("roots")
    if roots is not None:
        if not (isinstance(roots, dict) and all(
                isinstance(k, str) and isinstance(v, str)
                for k, v in roots.items())):
            raise ValueError("mst response: bad roots map")
    nodes = doc.get("n")
    if not isinstance(nodes, list):
        raise ValueError("mst response: bad node list")
    for nd in nodes:
        if not (isinstance(nd, dict) and isinstance(nd.get("ns"), str)):
            raise ValueError("mst response: bad node doc")
        _chk_path(nd.get("p"))
        t = nd.get("t")
        if t == "i":
            c = nd.get("c")
            if not isinstance(c, str):
                raise ValueError("mst response: bad child packing")
            nd["c"] = _unpack_children(c)
        elif t == "l":
            kd = nd.get("kd")
            if not isinstance(kd, dict):
                raise ValueError("mst response: bad leaf map")
            for k, pair in kd.items():
                if not (isinstance(k, str) and isinstance(pair, list)
                        and len(pair) == 2 and isinstance(pair[0], str)
                        and (pair[1] is None or isinstance(pair[1], dict))):
                    raise ValueError("mst response: bad leaf entry")
        elif t != "x":
            raise ValueError("mst response: unknown node type")
    return roots, nodes
