"""Lattica core: decentralized cross-NAT communication substrate.

The paper's contribution, as composable pieces:

* :mod:`repro.core.simnet` — deterministic discrete-event network
* :mod:`repro.core.nat` / :mod:`repro.core.traversal` — NAT models, dialer,
  AutoNAT, circuit relay, DCUtR hole punching (Scenario 1)
* :mod:`repro.core.cid` / :mod:`repro.core.blockstore` /
  :mod:`repro.core.bitswap` — content addressing + block exchange (Scenario 2)
* :mod:`repro.core.dht` — Kademlia discovery/provider records
* :mod:`repro.core.crdt` — the decentralized replicated store
* :mod:`repro.core.rpc` — dual-plane RPC (unary + backpressured streaming)
* :mod:`repro.core.service` — the typed service layer (specs, codecs, stubs)
* :mod:`repro.core.pubsub` / :mod:`repro.core.rendezvous` — announcement paths
* :mod:`repro.core.node` — ``LatticaNode``, the composed SDK surface
"""

from .cid import (CID, DAG, ChunkSpec, ManifestEntry, build_dag,
                  build_tree_dag, chunk, dag_reachable, decode_manifest,
                  decode_manifest_v2, encode_manifest, encode_manifest_v2,
                  manifest_children, manifest_version, read_dag)
from .crdt import (GCounter, LWWRegister, MVRegister, ORSet, PNCounter,
                   ReplicatedStore, decode_entry, encode_entry)
from .dht import KademliaDHT, KadService, PeerInfo, RoutingTable
from .nat import NATBox, NATKind, PortAlloc, aggregate_nat_stats, nat_label
from .node import (CrdtSyncService, CrdtSyncV2Service, IdentityService,
                   LatticaNode, crdt_ns)
from .peer import Multiaddr, PeerId
from .rpc import RpcChannel, RpcError, RpcRouter, call_unary, open_channel
from .service import (ClientInterceptor, Codec, Fixed, MethodSpec,
                      RpcMetrics, RpcStatus, ServerInterceptor, Service,
                      ServiceError, Stub, pickled, streaming, unary)
from .simnet import Connection, DialError, Host, Network, Sim, Stream

__all__ = [
    "CID", "DAG", "ChunkSpec", "ManifestEntry", "build_dag",
    "build_tree_dag", "chunk",
    "dag_reachable", "decode_manifest", "decode_manifest_v2",
    "encode_manifest", "encode_manifest_v2", "manifest_children",
    "manifest_version", "read_dag",
    "GCounter", "LWWRegister", "MVRegister", "ORSet", "PNCounter",
    "ReplicatedStore", "decode_entry", "encode_entry",
    "KademliaDHT", "KadService", "PeerInfo",
    "RoutingTable", "NATBox", "NATKind", "PortAlloc",
    "aggregate_nat_stats", "nat_label", "CrdtSyncService",
    "CrdtSyncV2Service", "crdt_ns",
    "IdentityService", "LatticaNode", "Multiaddr", "PeerId",
    "RpcChannel", "RpcError", "RpcRouter", "call_unary", "open_channel",
    "ClientInterceptor", "Codec", "Fixed", "MethodSpec", "RpcMetrics",
    "RpcStatus", "ServerInterceptor", "Service", "ServiceError", "Stub",
    "pickled", "streaming", "unary",
    "Connection", "DialError", "Host", "Network", "Sim", "Stream",
]
