"""NAT box models (RFC 3489 / Ford et al. 2005 taxonomy).

Four classical behaviours, driving the hole-punch success matrix that the
paper's ~70 % direct-connectivity figure comes from:

* FULL_CONE        endpoint-independent mapping, endpoint-independent filter
* RESTRICTED_CONE  endpoint-independent mapping, address-restricted filter
* PORT_RESTRICTED  endpoint-independent mapping, address+port-restricted filter
* SYMMETRIC        endpoint-DEPENDENT mapping (new external port per dst),
                   address+port-restricted filter

Hole punching (simultaneous open coordinated over a relay) succeeds iff each
side's punch packet passes the other side's filter given the externally
*observed* address each peer advertised.  Symmetric NATs advertise a port that
differs from the one they will actually use toward the peer, so punches into
port-restricted or symmetric counterparts fail *unless* the peer can predict
the next mapping — which is only possible when the NAT's port allocator is
regular.  Following the measurement literature (Trautwein et al.,
"Challenging Tribal Knowledge"), real symmetric NATs fall into a few
allocation families, modelled here by :class:`PortAlloc`:

* SEQUENTIAL   next external port = previous + 1 (very common CPE firmware)
* FIXED_DELTA  next = previous + delta for a device-constant delta
* RANDOM       uniformly random free port — unpredictable, punch-proof

Sequential and fixed-delta allocators make predicted-port hole punching
(DCUtR v2 in ``traversal.py``) viable; random allocators force relay
fallback.  Every box also keeps per-box counters so fleets can report
per-NAT-kind traversal behaviour (``Network.nat_stats`` aggregates them).
"""

from __future__ import annotations

import itertools
from enum import Enum
from typing import Dict, List, Optional, Set, Tuple, TYPE_CHECKING, Union

if TYPE_CHECKING:  # pragma: no cover
    from .simnet import Host, Network

Addr = Tuple[str, int]


class NATKind(Enum):
    FULL_CONE = "full_cone"
    RESTRICTED_CONE = "restricted_cone"
    PORT_RESTRICTED = "port_restricted"
    SYMMETRIC = "symmetric"


class PortAlloc(Enum):
    """External-port allocation policy of a NAT box."""

    SEQUENTIAL = "sequential"
    FIXED_DELTA = "fixed_delta"
    RANDOM = "random"


#: Random allocators draw from this external port range.
RANDOM_PORT_RANGE = (21000, 61000)


class NATBox:
    _ip_seq = itertools.count(1)

    def __init__(self, net: "Network", kind: NATKind,
                 alloc: Union[PortAlloc, str] = PortAlloc.SEQUENTIAL,
                 delta: int = 1, port_base: int = 20000,
                 ttl: Optional[float] = None):
        self.net = net
        self.kind = kind
        self.alloc = PortAlloc(alloc)
        self.delta = int(delta) if self.alloc is not PortAlloc.SEQUENTIAL else 1
        if self.alloc is PortAlloc.FIXED_DELTA and self.delta < 1:
            raise ValueError("fixed_delta allocator needs delta >= 1")
        if ttl is not None and ttl <= 0:
            raise ValueError("nat mapping ttl must be positive")
        #: Idle seconds after which a mapping expires (RFC 4787 REQ-5 UDP
        #: timer).  ``None`` keeps mappings forever — the pre-expiry model.
        self.ttl = ttl
        self.public_ip = f"198.51.{next(NATBox._ip_seq)}.1"
        self._next_port = port_base
        # cone NATs: (int_ip, int_port) -> ext_port
        self._cone_map: Dict[Tuple[str, int], int] = {}
        # symmetric NATs: (int_ip, int_port, dst) -> ext_port
        self._sym_map: Dict[Tuple[str, int, Addr], int] = {}
        # reverse: ext_port -> (host, int_port)
        self._rev: Dict[int, Tuple["Host", int]] = {}
        # filter state: ext_port -> set of remote addrs/ips sent to
        self._sent_to: Dict[int, Set[Addr]] = {}
        # expiry state: ext_port -> last traffic time / owning map key
        self._last_used: Dict[int, float] = {}
        self._key_of: Dict[int, Tuple] = {}
        self._hosts: Dict[str, "Host"] = {}
        #: Per-box traversal counters (aggregated per kind by
        #: ``Network.nat_stats``).
        self.stats = {
            "mappings": 0,            # external mappings minted
            "inbound_ok": 0,          # inbound datagrams routed through
            "inbound_filtered": 0,    # dropped by the filter state machine
            "inbound_unmapped": 0,    # dropped: no mapping at that ext port
            "expired": 0,             # mappings reclaimed by the idle timer
        }
        net.register_nat(self)

    def attach(self, host: "Host") -> None:
        self._hosts[host.ip] = host

    # -- allocation ----------------------------------------------------------
    def _alloc_port(self) -> int:
        if self.alloc is PortAlloc.RANDOM:
            lo, hi = RANDOM_PORT_RANGE
            while True:
                port = self.net.sim.rng.randrange(lo, hi)
                if port not in self._rev:
                    return port
        port = self._next_port
        self._next_port += self.delta
        while port in self._rev:  # skip ports still held by older mappings
            port += self.delta
            self._next_port = port + self.delta
        return port

    def _mint(self, host: "Host", int_port: int) -> int:
        ext = self._alloc_port()
        self._rev[ext] = (host, int_port)
        self._sent_to[ext] = set()
        self._last_used[ext] = self.net.sim.now
        self.stats["mappings"] += 1
        return ext

    # -- expiry --------------------------------------------------------------
    def _expired(self, ext: int) -> bool:
        if self.ttl is None:
            return False
        last = self._last_used.get(ext)
        return last is not None and self.net.sim.now - last > self.ttl

    def _purge(self, ext: int) -> None:
        """Reclaim one idle mapping: external port, filter state, and the
        owning cone/symmetric table entry all go together, so the next
        outbound flow mints a *fresh* external port (which is exactly what
        breaks stale advertised addresses on real NATs)."""
        self._rev.pop(ext, None)
        self._sent_to.pop(ext, None)
        self._last_used.pop(ext, None)
        key = self._key_of.pop(ext, None)
        if key is not None:
            if len(key) == 3:
                self._sym_map.pop(key, None)
            else:
                self._cone_map.pop(key, None)
        self.stats["expired"] += 1

    # -- outbound ------------------------------------------------------------
    def map_outbound(self, host: "Host", int_port: int, dst: Addr) -> Addr:
        if self.kind is NATKind.SYMMETRIC:
            key: Tuple = (host.ip, int_port, dst)
            table: Dict = self._sym_map
        else:
            key = (host.ip, int_port)
            table = self._cone_map
        ext = table.get(key)
        if ext is not None and self._expired(ext):
            self._purge(ext)
            ext = None
        if ext is None:
            ext = table[key] = self._mint(host, int_port)
            self._key_of[ext] = key
        self._sent_to[ext].add(dst)
        self._last_used[ext] = self.net.sim.now
        return (self.public_ip, ext)

    # -- inbound -------------------------------------------------------------
    def filter_inbound(self, ext_port: int, src: Addr) -> Optional[Tuple["Host", int]]:
        entry = self._rev.get(ext_port)
        if entry is not None and self._expired(ext_port):
            self._purge(ext_port)
            entry = None
        if entry is None:
            self.stats["inbound_unmapped"] += 1
            return None
        sent = self._sent_to.get(ext_port, set())
        if self.kind is NATKind.FULL_CONE:
            return self._pass(ext_port, entry)
        if self.kind is NATKind.RESTRICTED_CONE:
            if any(a[0] == src[0] for a in sent):
                return self._pass(ext_port, entry)
            self.stats["inbound_filtered"] += 1
            return None
        # PORT_RESTRICTED and SYMMETRIC both filter on (ip, port)
        if src in sent:
            return self._pass(ext_port, entry)
        self.stats["inbound_filtered"] += 1
        return None

    def _pass(self, ext_port: int,
              entry: Tuple["Host", int]) -> Tuple["Host", int]:
        """Route one inbound datagram through; established flows keep
        their mapping alive in both directions (RFC 4787 REQ-6)."""
        self.stats["inbound_ok"] += 1
        self._last_used[ext_port] = self.net.sim.now
        return entry


def nat_label(box: Optional[NATBox]) -> str:
    """Human-readable NAT class: ``"symmetric/<alloc>"`` for symmetric boxes
    (where the allocator determines punchability), the bare kind for cone
    boxes (their allocator is irrelevant to mapping behaviour), and
    ``"public"`` for no NAT.  Shared by stats aggregation and fleet
    reporting so per-kind rows always correlate."""
    if box is None:
        return "public"
    if box.kind is NATKind.SYMMETRIC:
        return f"{box.kind.value}/{box.alloc.value}"
    return box.kind.value


def aggregate_nat_stats(boxes: List[NATBox]) -> Dict[str, Dict[str, int]]:
    """Sum per-box counters into per-:func:`nat_label` rows."""
    out: Dict[str, Dict[str, int]] = {}
    for box in boxes:
        key = nat_label(box)
        row = out.setdefault(key, {"boxes": 0})
        row["boxes"] += 1
        for k, v in box.stats.items():
            row[k] = row.get(k, 0) + v
    return out
