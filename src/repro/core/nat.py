"""NAT box models (RFC 3489 / Ford et al. 2005 taxonomy).

Four classical behaviours, driving the hole-punch success matrix that the
paper's ~70 % direct-connectivity figure comes from:

* FULL_CONE        endpoint-independent mapping, endpoint-independent filter
* RESTRICTED_CONE  endpoint-independent mapping, address-restricted filter
* PORT_RESTRICTED  endpoint-independent mapping, address+port-restricted filter
* SYMMETRIC        endpoint-DEPENDENT mapping (new external port per dst),
                   address+port-restricted filter

Hole punching (simultaneous open coordinated over a relay) succeeds iff each
side's punch packet passes the other side's filter given the externally
*observed* address each peer advertised.  Symmetric NATs advertise a port that
differs from the one they will actually use toward the peer, so punches into
port-restricted or symmetric counterparts fail *unless* the peer can predict
the next mapping — which is only possible when the NAT's port allocator is
regular.  Following the measurement literature (Trautwein et al.,
"Challenging Tribal Knowledge"), real symmetric NATs fall into a few
allocation families, modelled here by :class:`PortAlloc`:

* SEQUENTIAL   next external port = previous + 1 (very common CPE firmware)
* FIXED_DELTA  next = previous + delta for a device-constant delta
* RANDOM       uniformly random free port — unpredictable, punch-proof

Sequential and fixed-delta allocators make predicted-port hole punching
(DCUtR v2 in ``traversal.py``) viable; random allocators force relay
fallback.  Every box also keeps per-box counters so fleets can report
per-NAT-kind traversal behaviour (``Network.nat_stats`` aggregates them).
"""

from __future__ import annotations

import itertools
from enum import Enum
from typing import Dict, List, Optional, Set, Tuple, TYPE_CHECKING, Union

if TYPE_CHECKING:  # pragma: no cover
    from .simnet import Host, Network

Addr = Tuple[str, int]


class NATKind(Enum):
    FULL_CONE = "full_cone"
    RESTRICTED_CONE = "restricted_cone"
    PORT_RESTRICTED = "port_restricted"
    SYMMETRIC = "symmetric"


class PortAlloc(Enum):
    """External-port allocation policy of a NAT box."""

    SEQUENTIAL = "sequential"
    FIXED_DELTA = "fixed_delta"
    RANDOM = "random"


#: Random allocators draw from this external port range.
RANDOM_PORT_RANGE = (21000, 61000)


class NATBox:
    _ip_seq = itertools.count(1)

    def __init__(self, net: "Network", kind: NATKind,
                 alloc: Union[PortAlloc, str] = PortAlloc.SEQUENTIAL,
                 delta: int = 1, port_base: int = 20000):
        self.net = net
        self.kind = kind
        self.alloc = PortAlloc(alloc)
        self.delta = int(delta) if self.alloc is not PortAlloc.SEQUENTIAL else 1
        if self.alloc is PortAlloc.FIXED_DELTA and self.delta < 1:
            raise ValueError("fixed_delta allocator needs delta >= 1")
        self.public_ip = f"198.51.{next(NATBox._ip_seq)}.1"
        self._next_port = port_base
        # cone NATs: (int_ip, int_port) -> ext_port
        self._cone_map: Dict[Tuple[str, int], int] = {}
        # symmetric NATs: (int_ip, int_port, dst) -> ext_port
        self._sym_map: Dict[Tuple[str, int, Addr], int] = {}
        # reverse: ext_port -> (host, int_port)
        self._rev: Dict[int, Tuple["Host", int]] = {}
        # filter state: ext_port -> set of remote addrs/ips sent to
        self._sent_to: Dict[int, Set[Addr]] = {}
        self._hosts: Dict[str, "Host"] = {}
        #: Per-box traversal counters (aggregated per kind by
        #: ``Network.nat_stats``).
        self.stats = {
            "mappings": 0,            # external mappings minted
            "inbound_ok": 0,          # inbound datagrams routed through
            "inbound_filtered": 0,    # dropped by the filter state machine
            "inbound_unmapped": 0,    # dropped: no mapping at that ext port
        }
        net.register_nat(self)

    def attach(self, host: "Host") -> None:
        self._hosts[host.ip] = host

    # -- allocation ----------------------------------------------------------
    def _alloc_port(self) -> int:
        if self.alloc is PortAlloc.RANDOM:
            lo, hi = RANDOM_PORT_RANGE
            while True:
                port = self.net.sim.rng.randrange(lo, hi)
                if port not in self._rev:
                    return port
        port = self._next_port
        self._next_port += self.delta
        while port in self._rev:  # skip ports still held by older mappings
            port += self.delta
            self._next_port = port + self.delta
        return port

    def _mint(self, host: "Host", int_port: int) -> int:
        ext = self._alloc_port()
        self._rev[ext] = (host, int_port)
        self._sent_to[ext] = set()
        self.stats["mappings"] += 1
        return ext

    # -- outbound ------------------------------------------------------------
    def map_outbound(self, host: "Host", int_port: int, dst: Addr) -> Addr:
        if self.kind is NATKind.SYMMETRIC:
            key = (host.ip, int_port, dst)
            if key not in self._sym_map:
                self._sym_map[key] = self._mint(host, int_port)
            ext = self._sym_map[key]
        else:
            ckey = (host.ip, int_port)
            if ckey not in self._cone_map:
                self._cone_map[ckey] = self._mint(host, int_port)
            ext = self._cone_map[ckey]
        self._sent_to[ext].add(dst)
        return (self.public_ip, ext)

    # -- inbound -------------------------------------------------------------
    def filter_inbound(self, ext_port: int, src: Addr) -> Optional[Tuple["Host", int]]:
        entry = self._rev.get(ext_port)
        if entry is None:
            self.stats["inbound_unmapped"] += 1
            return None
        sent = self._sent_to.get(ext_port, set())
        if self.kind is NATKind.FULL_CONE:
            self.stats["inbound_ok"] += 1
            return entry
        if self.kind is NATKind.RESTRICTED_CONE:
            if any(a[0] == src[0] for a in sent):
                self.stats["inbound_ok"] += 1
                return entry
            self.stats["inbound_filtered"] += 1
            return None
        # PORT_RESTRICTED and SYMMETRIC both filter on (ip, port)
        if src in sent:
            self.stats["inbound_ok"] += 1
            return entry
        self.stats["inbound_filtered"] += 1
        return None


def nat_label(box: Optional[NATBox]) -> str:
    """Human-readable NAT class: ``"symmetric/<alloc>"`` for symmetric boxes
    (where the allocator determines punchability), the bare kind for cone
    boxes (their allocator is irrelevant to mapping behaviour), and
    ``"public"`` for no NAT.  Shared by stats aggregation and fleet
    reporting so per-kind rows always correlate."""
    if box is None:
        return "public"
    if box.kind is NATKind.SYMMETRIC:
        return f"{box.kind.value}/{box.alloc.value}"
    return box.kind.value


def aggregate_nat_stats(boxes: List[NATBox]) -> Dict[str, Dict[str, int]]:
    """Sum per-box counters into per-:func:`nat_label` rows."""
    out: Dict[str, Dict[str, int]] = {}
    for box in boxes:
        key = nat_label(box)
        row = out.setdefault(key, {"boxes": 0, "mappings": 0, "inbound_ok": 0,
                                   "inbound_filtered": 0, "inbound_unmapped": 0})
        row["boxes"] += 1
        for k, v in box.stats.items():
            row[k] += v
    return out
