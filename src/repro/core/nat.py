"""NAT box models (RFC 3489 / Ford et al. 2005 taxonomy).

Four classical behaviours, driving the hole-punch success matrix that the
paper's ~70 % direct-connectivity figure comes from:

* FULL_CONE        endpoint-independent mapping, endpoint-independent filter
* RESTRICTED_CONE  endpoint-independent mapping, address-restricted filter
* PORT_RESTRICTED  endpoint-independent mapping, address+port-restricted filter
* SYMMETRIC        endpoint-DEPENDENT mapping (new external port per dst),
                   address+port-restricted filter

Hole punching (simultaneous open coordinated over a relay) succeeds iff each
side's punch packet passes the other side's filter given the externally
*observed* address each peer advertised.  Symmetric NATs advertise a port that
differs from the one they will actually use toward the peer, so punches into
port-restricted or symmetric counterparts fail — exactly the pairs that fall
back to relays in the paper.
"""

from __future__ import annotations

import itertools
from enum import Enum
from typing import Dict, Optional, Set, Tuple, TYPE_CHECKING

if TYPE_CHECKING:  # pragma: no cover
    from .simnet import Host, Network

Addr = Tuple[str, int]


class NATKind(Enum):
    FULL_CONE = "full_cone"
    RESTRICTED_CONE = "restricted_cone"
    PORT_RESTRICTED = "port_restricted"
    SYMMETRIC = "symmetric"


class NATBox:
    _ip_seq = itertools.count(1)

    def __init__(self, net: "Network", kind: NATKind):
        self.net = net
        self.kind = kind
        self.public_ip = f"198.51.{next(NATBox._ip_seq)}.1"
        self._ext_seq = itertools.count(20000)
        # cone NATs: (int_ip, int_port) -> ext_port
        self._cone_map: Dict[Tuple[str, int], int] = {}
        # symmetric NATs: (int_ip, int_port, dst) -> ext_port
        self._sym_map: Dict[Tuple[str, int, Addr], int] = {}
        # reverse: ext_port -> (host, int_port)
        self._rev: Dict[int, Tuple["Host", int]] = {}
        # filter state: ext_port -> set of remote addrs/ips sent to
        self._sent_to: Dict[int, Set[Addr]] = {}
        self._hosts: Dict[str, "Host"] = {}
        net.register_nat(self)

    def attach(self, host: "Host") -> None:
        self._hosts[host.ip] = host

    # -- outbound ------------------------------------------------------------
    def map_outbound(self, host: "Host", int_port: int, dst: Addr) -> Addr:
        if self.kind is NATKind.SYMMETRIC:
            key = (host.ip, int_port, dst)
            if key not in self._sym_map:
                ext = next(self._ext_seq)
                self._sym_map[key] = ext
                self._rev[ext] = (host, int_port)
                self._sent_to[ext] = set()
            ext = self._sym_map[key]
        else:
            ckey = (host.ip, int_port)
            if ckey not in self._cone_map:
                ext = next(self._ext_seq)
                self._cone_map[ckey] = ext
                self._rev[ext] = (host, int_port)
                self._sent_to[ext] = set()
            ext = self._cone_map[ckey]
        self._sent_to[ext].add(dst)
        return (self.public_ip, ext)

    # -- inbound -------------------------------------------------------------
    def filter_inbound(self, ext_port: int, src: Addr) -> Optional[Tuple["Host", int]]:
        entry = self._rev.get(ext_port)
        if entry is None:
            return None
        sent = self._sent_to.get(ext_port, set())
        if self.kind is NATKind.FULL_CONE:
            return entry
        if self.kind is NATKind.RESTRICTED_CONE:
            if any(a[0] == src[0] for a in sent):
                return entry
            return None
        # PORT_RESTRICTED and SYMMETRIC both filter on (ip, port)
        if src in sent:
            return entry
        return None
