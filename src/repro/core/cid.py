"""Content identifiers, chunking, and Merkle DAGs.

CIDs follow the multihash spirit: ``<version><codec><sha256 digest>``.  Large
artifacts (model checkpoints) are split into fixed-size chunks, each chunk
becoming a leaf block; a manifest block (codec ``dag``) lists the child CIDs
in order so any peer can verify and reassemble the artifact.

Two manifest layouts coexist on the wire, distinguished by magic:

* **v1 flat** (``LDAG``): an ordered list of leaf-chunk CIDs + total size.
  Produced by :func:`build_dag`; the right shape for opaque byte blobs.
* **v2 hierarchical** (``LDG2``): an ordered list of *named entries*, each
  pointing at a sub-DAG root (or a raw leaf) with its size and a per-entry
  meta blob.  Produced by :func:`build_tree_dag`; the shape that makes
  *structural sharing* between artifact versions real: a checkpoint whose
  root lists one sub-DAG per tensor reuses the sub-root CIDs of unchanged
  tensors verbatim, so a fetcher only swarms the sub-DAGs it lacks.

Decoders dispatch on the magic (:func:`manifest_version`), so v2-aware
nodes still read every v1 manifest ever published.
"""

from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

CHUNK_SIZE = 256 * 1024  # 256 KiB, matching Bitswap-typical block size

CODEC_RAW = 0x55
CODEC_DAG = 0x70


class CID:
    __slots__ = ("codec", "digest")

    def __init__(self, codec: int, digest: bytes):
        assert len(digest) == 32
        self.codec = codec
        self.digest = digest

    @classmethod
    def for_data(cls, data: bytes, codec: int = CODEC_RAW) -> "CID":
        return cls(codec, hashlib.sha256(data).digest())

    def verify(self, data: bytes) -> bool:
        return hashlib.sha256(data).digest() == self.digest

    @property
    def key(self) -> bytes:
        """DHT key for this CID (the raw digest)."""
        return self.digest

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, CID) and other.codec == self.codec
                and other.digest == self.digest)

    def __hash__(self) -> int:
        return hash((self.codec, self.digest))

    def __repr__(self) -> str:
        return f"CID({'raw' if self.codec == CODEC_RAW else 'dag'}:{self.digest.hex()[:12]})"


def chunk(data: bytes, chunk_size: int = CHUNK_SIZE) -> List[bytes]:
    if not data:
        return [b""]
    return [data[i:i + chunk_size] for i in range(0, len(data), chunk_size)]


# -- Merkle DAG manifests ----------------------------------------------------

_MAGIC = b"LDAG"       # v1: flat chunk list
_MAGIC2 = b"LDG2"      # v2: named sub-DAG entries


def manifest_version(data: bytes) -> int:
    """1 for flat v1, 2 for hierarchical v2; raises on anything else."""
    if data[:4] == _MAGIC:
        return 1
    if data[:4] == _MAGIC2:
        return 2
    raise ValueError("not a manifest block")


def is_manifest(data: bytes) -> bool:
    return data[:4] in (_MAGIC, _MAGIC2)


def encode_manifest(children: Sequence[CID], total_size: int,
                    meta: bytes = b"") -> bytes:
    out = [_MAGIC, struct.pack(">QI", total_size, len(children))]
    for c in children:
        out.append(struct.pack(">B", c.codec))
        out.append(c.digest)
    out.append(struct.pack(">I", len(meta)))
    out.append(meta)
    return b"".join(out)


def decode_manifest(data: bytes) -> Tuple[List[CID], int, bytes]:
    assert data[:4] == _MAGIC, "not a manifest block"
    total_size, n = struct.unpack(">QI", data[4:16])
    off = 16
    children = []
    for _ in range(n):
        codec = data[off]
        digest = data[off + 1:off + 33]
        children.append(CID(codec, digest))
        off += 33
    (meta_len,) = struct.unpack(">I", data[off:off + 4])
    meta = data[off + 4:off + 4 + meta_len]
    return children, total_size, meta


# -- v2 hierarchical manifests -----------------------------------------------


@dataclass(frozen=True)
class ManifestEntry:
    """One named sub-DAG in a v2 root manifest.

    ``cid`` is either a sub-manifest root (``CODEC_DAG``) or a raw leaf
    (``CODEC_RAW``); ``size`` is the decoded byte length of the entry's
    content; ``meta`` is opaque per-entry metadata (e.g. a tensor's
    dtype/shape) that travels in the *root* manifest so entry content stays
    a pure function of its bytes — maximizing sub-DAG reuse across versions.
    """

    name: str
    cid: CID
    size: int
    meta: bytes = b""


def encode_manifest_v2(entries: Sequence[ManifestEntry], total_size: int,
                       meta: bytes = b"") -> bytes:
    out = [_MAGIC2, struct.pack(">QI", total_size, len(entries))]
    for e in entries:
        name = e.name.encode("utf-8")
        out.append(struct.pack(">H", len(name)))
        out.append(name)
        out.append(struct.pack(">B", e.cid.codec))
        out.append(e.cid.digest)
        out.append(struct.pack(">QI", e.size, len(e.meta)))
        out.append(e.meta)
    out.append(struct.pack(">I", len(meta)))
    out.append(meta)
    return b"".join(out)


def decode_manifest_v2(data: bytes) -> Tuple[List[ManifestEntry], int, bytes]:
    assert data[:4] == _MAGIC2, "not a v2 manifest block"
    total_size, n = struct.unpack(">QI", data[4:16])
    off = 16
    entries: List[ManifestEntry] = []
    for _ in range(n):
        (name_len,) = struct.unpack(">H", data[off:off + 2])
        off += 2
        name = data[off:off + name_len].decode("utf-8")
        off += name_len
        codec = data[off]
        digest = data[off + 1:off + 33]
        off += 33
        size, meta_len = struct.unpack(">QI", data[off:off + 12])
        off += 12
        meta = data[off:off + meta_len]
        off += meta_len
        entries.append(ManifestEntry(name, CID(codec, digest), size, meta))
    (meta_len,) = struct.unpack(">I", data[off:off + 4])
    meta = data[off + 4:off + 4 + meta_len]
    return entries, total_size, meta


def manifest_children(data: bytes) -> List[CID]:
    """Direct children of a manifest block, either version."""
    if manifest_version(data) == 1:
        return decode_manifest(data)[0]
    return [e.cid for e in decode_manifest_v2(data)[0]]


@dataclass
class DAG:
    root: CID
    blocks: Dict[CID, bytes]
    total_size: int
    #: v2 only: the root manifest's entries, in order
    entries: List[ManifestEntry] = field(default_factory=list)


def build_dag(data: bytes, chunk_size: int = CHUNK_SIZE, meta: bytes = b"") -> DAG:
    """Chunk ``data`` into leaf blocks + one flat (v1) manifest root block."""
    leaves = chunk(data, chunk_size)
    blocks: Dict[CID, bytes] = {}
    children: List[CID] = []
    for piece in leaves:
        c = CID.for_data(piece, CODEC_RAW)
        blocks[c] = piece
        children.append(c)
    manifest = encode_manifest(children, len(data), meta)
    root = CID.for_data(manifest, CODEC_DAG)
    blocks[root] = manifest
    return DAG(root=root, blocks=blocks, total_size=len(data))


def build_tree_dag(parts: Sequence[Tuple[str, bytes, bytes]],
                   chunk_size: int = CHUNK_SIZE, meta: bytes = b"") -> DAG:
    """Build a hierarchical (v2) DAG: one sub-DAG per ``(name, data, meta)``
    part, rooted in a named-entry manifest.

    Identical part bytes (across parts, or vs a previously built version)
    hash to the identical sub-root CID — that is the structural-sharing
    property the delta-sync path relies on.
    """
    blocks: Dict[CID, bytes] = {}
    entries: List[ManifestEntry] = []
    total = 0
    for name, data, part_meta in parts:
        sub = build_dag(data, chunk_size=chunk_size)
        blocks.update(sub.blocks)
        entries.append(ManifestEntry(name, sub.root, len(data), part_meta))
        total += len(data)
    manifest = encode_manifest_v2(entries, total, meta)
    root = CID.for_data(manifest, CODEC_DAG)
    blocks[root] = manifest
    return DAG(root=root, blocks=blocks, total_size=total, entries=entries)


def reassemble(root_block: bytes, fetch: Dict[CID, bytes]) -> bytes:
    children, total_size, _meta = decode_manifest(root_block)
    parts = []
    for c in children:
        blk = fetch[c]
        if not c.verify(blk):
            raise ValueError(f"block {c} failed verification")
        parts.append(blk)
    data = b"".join(parts)
    assert len(data) == total_size
    return data


def read_dag(root: CID, get: Callable[[CID], Optional[bytes]],
             verify: bool = True) -> bytes:
    """Reassemble a DAG of either manifest version from a block getter.

    Raises ``KeyError`` on a missing block and ``ValueError`` on a
    hash-verification failure, so callers can distinguish "fetch more"
    from "corrupt data".  ``verify=False`` skips the per-block sha256 —
    correct when the getter is a store that already verified on put
    (``BlockStore``); keep the default for untrusted mappings.
    """
    block = get(root)
    if block is None:
        raise KeyError(f"missing block {root}")
    if verify and not root.verify(block):
        raise ValueError(f"block {root} failed verification")
    if root.codec == CODEC_RAW:
        return block
    if manifest_version(block) == 1:
        children, total_size, _ = decode_manifest(block)
        data = b"".join(read_dag(c, get, verify) for c in children)
    else:
        entries, total_size, _ = decode_manifest_v2(block)
        data = b"".join(read_dag(e.cid, get, verify) for e in entries)
    if len(data) != total_size:
        raise ValueError(f"reassembled size mismatch under {root}")
    return data


def dag_reachable(root: CID,
                  get: Callable[[CID], Optional[bytes]]) -> List[CID]:
    """All CIDs reachable from ``root`` through manifests resolvable via
    ``get`` (deduplicated, pre-order).  Children whose blocks are absent are
    still listed — their sub-trees just aren't expanded."""
    seen: Dict[CID, None] = {}
    stack = [root]
    while stack:
        c = stack.pop()
        if c in seen:
            continue
        seen[c] = None
        if c.codec != CODEC_DAG:
            continue
        block = get(c)
        if block is None or not is_manifest(block):
            continue
        stack.extend(reversed(manifest_children(block)))
    return list(seen)
