"""Content identifiers, chunking, and Merkle DAGs.

CIDs follow the multihash spirit: ``<version><codec><sha256 digest>``.  Large
artifacts (model checkpoints) are split into fixed-size chunks, each chunk
becoming a leaf block; a manifest block (codec ``dag``) lists the child CIDs
in order so any peer can verify and reassemble the artifact.
"""

from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Tuple

CHUNK_SIZE = 256 * 1024  # 256 KiB, matching Bitswap-typical block size

CODEC_RAW = 0x55
CODEC_DAG = 0x70


class CID:
    __slots__ = ("codec", "digest")

    def __init__(self, codec: int, digest: bytes):
        assert len(digest) == 32
        self.codec = codec
        self.digest = digest

    @classmethod
    def for_data(cls, data: bytes, codec: int = CODEC_RAW) -> "CID":
        return cls(codec, hashlib.sha256(data).digest())

    def verify(self, data: bytes) -> bool:
        return hashlib.sha256(data).digest() == self.digest

    @property
    def key(self) -> bytes:
        """DHT key for this CID (the raw digest)."""
        return self.digest

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, CID) and other.codec == self.codec
                and other.digest == self.digest)

    def __hash__(self) -> int:
        return hash((self.codec, self.digest))

    def __repr__(self) -> str:
        return f"CID({'raw' if self.codec == CODEC_RAW else 'dag'}:{self.digest.hex()[:12]})"


def chunk(data: bytes, chunk_size: int = CHUNK_SIZE) -> List[bytes]:
    if not data:
        return [b""]
    return [data[i:i + chunk_size] for i in range(0, len(data), chunk_size)]


# -- Merkle DAG manifests ----------------------------------------------------

_MAGIC = b"LDAG"


def encode_manifest(children: Sequence[CID], total_size: int,
                    meta: bytes = b"") -> bytes:
    out = [_MAGIC, struct.pack(">QI", total_size, len(children))]
    for c in children:
        out.append(struct.pack(">B", c.codec))
        out.append(c.digest)
    out.append(struct.pack(">I", len(meta)))
    out.append(meta)
    return b"".join(out)


def decode_manifest(data: bytes) -> Tuple[List[CID], int, bytes]:
    assert data[:4] == _MAGIC, "not a manifest block"
    total_size, n = struct.unpack(">QI", data[4:16])
    off = 16
    children = []
    for _ in range(n):
        codec = data[off]
        digest = data[off + 1:off + 33]
        children.append(CID(codec, digest))
        off += 33
    (meta_len,) = struct.unpack(">I", data[off:off + 4])
    meta = data[off + 4:off + 4 + meta_len]
    return children, total_size, meta


@dataclass
class DAG:
    root: CID
    blocks: Dict[CID, bytes]
    total_size: int


def build_dag(data: bytes, chunk_size: int = CHUNK_SIZE, meta: bytes = b"") -> DAG:
    """Chunk ``data`` into leaf blocks + one manifest root block."""
    leaves = chunk(data, chunk_size)
    blocks: Dict[CID, bytes] = {}
    children: List[CID] = []
    for piece in leaves:
        c = CID.for_data(piece, CODEC_RAW)
        blocks[c] = piece
        children.append(c)
    manifest = encode_manifest(children, len(data), meta)
    root = CID.for_data(manifest, CODEC_DAG)
    blocks[root] = manifest
    return DAG(root=root, blocks=blocks, total_size=len(data))


def reassemble(root_block: bytes, fetch: Dict[CID, bytes]) -> bytes:
    children, total_size, _meta = decode_manifest(root_block)
    parts = []
    for c in children:
        blk = fetch[c]
        if not c.verify(blk):
            raise ValueError(f"block {c} failed verification")
        parts.append(blk)
    data = b"".join(parts)
    assert len(data) == total_size
    return data
