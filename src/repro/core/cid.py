"""Content identifiers, chunking, and Merkle DAGs.

CIDs follow the multihash spirit: ``<version><codec><sha256 digest>``.  Large
artifacts (model checkpoints) are split into chunks, each chunk becoming a
leaf block; a manifest block (codec ``dag``) lists the child CIDs in order
so any peer can verify and reassemble the artifact.

Chunking is governed by a :class:`ChunkSpec` with two strategies:

* ``fixed`` — fixed-size slices (the historical default).  Cheap, but a
  single inserted/removed byte shifts every downstream boundary, so every
  later chunk gets a fresh CID even though its content barely moved.
* ``cdc`` — content-defined chunking via a Gear/FastCDC-style rolling hash
  with ``min``/``avg``/``max`` bounds.  Boundaries are a pure function of
  local content, so byte-shifting edits (grown vocabularies, appended
  optimizer state, partial in-place edits) re-synchronize after the edit
  point and the unchanged tail keeps its leaf CIDs — the property that makes
  re-publishing a slightly different artifact move bytes proportional to the
  edit, not the artifact.

Both strategies are fully deterministic (the gear table is derived from
fixed sha256 seeds), so a re-publish under the same ``ChunkSpec`` reproduces
identical boundaries and therefore identical CIDs.

Two manifest layouts coexist on the wire, distinguished by magic:

* **v1 flat** (``LDAG``): an ordered list of leaf-chunk CIDs + total size.
  Produced by :func:`build_dag`; the right shape for opaque byte blobs.
* **v2 hierarchical** (``LDG2``): an ordered list of *named entries*, each
  pointing at a sub-DAG root (or a raw leaf) with its size and a per-entry
  meta blob.  Produced by :func:`build_tree_dag`; the shape that makes
  *structural sharing* between artifact versions real: a checkpoint whose
  root lists one sub-DAG per tensor reuses the sub-root CIDs of unchanged
  tensors verbatim, so a fetcher only swarms the sub-DAGs it lacks.

Decoders dispatch on the magic (:func:`manifest_version`), so v2-aware
nodes still read every v1 manifest ever published.
"""

from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

CHUNK_SIZE = 256 * 1024  # 256 KiB, matching Bitswap-typical block size

CODEC_RAW = 0x55
CODEC_DAG = 0x70


class CID:
    __slots__ = ("codec", "digest")

    def __init__(self, codec: int, digest: bytes):
        assert len(digest) == 32
        self.codec = codec
        self.digest = digest

    @classmethod
    def for_data(cls, data: bytes, codec: int = CODEC_RAW) -> "CID":
        return cls(codec, hashlib.sha256(data).digest())

    def verify(self, data: bytes) -> bool:
        return hashlib.sha256(data).digest() == self.digest

    @property
    def key(self) -> bytes:
        """DHT key for this CID (the raw digest)."""
        return self.digest

    def __eq__(self, other: object) -> bool:
        return (isinstance(other, CID) and other.codec == self.codec
                and other.digest == self.digest)

    def __hash__(self) -> int:
        return hash((self.codec, self.digest))

    def __repr__(self) -> str:
        return f"CID({'raw' if self.codec == CODEC_RAW else 'dag'}:{self.digest.hex()[:12]})"


def chunk(data: bytes, chunk_size: int = CHUNK_SIZE) -> List[bytes]:
    if not data:
        return [b""]
    return [data[i:i + chunk_size] for i in range(0, len(data), chunk_size)]


# -- content-defined chunking (Gear/FastCDC-style) ---------------------------

_GEAR_TABLE: Optional[np.ndarray] = None

#: cap on the rolling-hash mask width: candidates only test the low ``bits``
#: bits, so uint32 arithmetic suffices (identical low bits, half the memory)
_CDC_MAX_BITS = 30
#: scan slab: bounds peak temporaries to a constant regardless of part size
_CDC_SLAB = 8 * 2**20


def _gear_table() -> np.ndarray:
    """256 pseudo-random 32-bit gear values derived from fixed sha256 seeds:
    deterministic across platforms and interpreter versions, which is what
    makes CDC boundaries (and therefore CIDs) reproducible forever."""
    global _GEAR_TABLE
    if _GEAR_TABLE is None:
        raw = b"".join(hashlib.sha256(b"lattica-gear-%d" % i).digest()[:4]
                       for i in range(256))
        _GEAR_TABLE = np.frombuffer(raw, dtype=">u4").astype(np.uint32)
    return _GEAR_TABLE


def _windowed_hash(g: np.ndarray, width: int) -> np.ndarray:
    """``h[i] = Σ_{k < width} g[i-k] << k`` (mod 2**32, truncated at the
    array start) for every position at once.

    Built by window doubling instead of ``width`` shifted adds: a window
    sum of size ``w+v`` is ``W_w[i] + (W_v[i-w] << w)``, so power-of-two
    window sums compose along the binary decomposition of ``width`` —
    ~``2*log2(width)`` vectorized passes over the slab instead of
    ``width``.  Bitwise identical to the naive accumulation (uint32
    wraparound is associative/commutative), so boundaries never move.
    """
    n = len(g)
    h = np.zeros(n, dtype=np.uint32)
    if n == 0:
        return h
    width = min(width, n)       # terms past the array start don't exist
    p = g.astype(np.uint32)     # power-of-two window sums, starting at 1
    pw = 1
    done = 0                    # terms k < done are accumulated into h
    rem = width
    while rem:
        if rem & 1:
            h[done:] += p[:n - done] << np.uint32(done)
            done += pw
        rem >>= 1
        if rem:
            p2 = p.copy()
            if n > pw:
                p2[pw:] += p[:n - pw] << np.uint32(pw)
            p = p2
            pw *= 2
    return h


def _cdc_candidates(data: bytes, bits: int, norm: int = 0,
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Boundary-candidate positions as ``(strict, loose)`` arrays: the
    strict mask tests the low ``bits+norm`` bits (fires ~every
    ``2**(bits+norm)`` bytes), the loose mask ``bits-norm``.  ``norm=0``
    returns the same array twice — the legacy single-mask behavior.

    The gear recurrence ``h = (h << 1) + G[b]`` means bit ``k`` of ``h``
    only sees the last ``k+1`` bytes, so a mask of ``m`` low bits only
    needs the window sum of the last ``m`` bytes (carries flow strictly
    upward, mod-2**m truncation is exact).  The same property makes one
    scan serve both masks: the low ``bits-norm`` bits of the wide-window
    hash equal the narrow-window hash's, so the loose candidates fall out
    of the strict scan for free — and a ``norm>0`` scan stays
    gear-table-compatible with legacy ``norm=0`` boundaries.  The scan
    runs in overlapping slabs: a position only needs the window before
    it, so each slab recomputes that overlap and peak temporaries stay
    ~10x the slab size instead of scaling with the whole part.
    """
    bits_s = min(bits + norm, 31)
    bits_l = max(bits - norm, 1)
    buf = np.frombuffer(data, dtype=np.uint8)
    table = _gear_table()
    mask_s = np.uint32((1 << bits_s) - 1)
    mask_l = np.uint32((1 << bits_l) - 1)
    outs: List[np.ndarray] = []
    outl: List[np.ndarray] = []
    for start in range(0, len(data), _CDC_SLAB):
        lo = max(start - (bits_s - 1), 0)
        g = table[buf[lo:start + _CDC_SLAB]]
        h = _windowed_hash(g, bits_s)
        for mask, out in (((mask_s, outs),) if norm == 0 else
                          ((mask_s, outs), (mask_l, outl))):
            cand = np.nonzero((h & mask) == mask)[0] + lo
            out.append(cand[cand >= start])   # overlap → the prior slab
    strict = (np.concatenate(outs) if outs else np.zeros(0, dtype=np.int64))
    if norm == 0:
        return strict, strict
    loose = (np.concatenate(outl) if outl else np.zeros(0, dtype=np.int64))
    return strict, loose


def cdc_cut_points(data: bytes, min_size: int, avg_size: int,
                   max_size: int, norm: int = 0) -> List[int]:
    """Boundary offsets (exclusive chunk ends, last == ``len(data)``) for
    content-defined chunking.  Every chunk is in ``[min_size, max_size]``
    except possibly the final tail.  Boundaries depend only on nearby
    content, so an insertion re-synchronizes at the next surviving candidate
    instead of cascading through the rest of the buffer.

    ``norm`` enables FastCDC-style normalized chunking: below ``avg_size``
    only a *stricter* mask (``norm`` extra bits) may cut, past it a
    *looser* one — chunk sizes concentrate around the average instead of
    following the bare geometric distribution, which shrinks both the
    tiny-chunk overhead tail and the max-size forced cuts.  ``norm=0``
    reproduces the single-mask boundaries of earlier releases exactly.
    """
    n = len(data)
    if n <= min_size:
        return [n]
    bits = min(max(avg_size.bit_length() - 1, 6), _CDC_MAX_BITS)
    strict, loose = _cdc_candidates(data, bits, norm)
    # boundary *offsets*: a candidate at byte i ends a chunk after i
    strict = strict + 1
    loose = loose + 1 if norm else strict
    cuts: List[int] = []
    last = 0
    while last < n:
        if n - last <= min_size:
            cuts.append(n)
            break
        hi_limit = min(last + max_size, n)
        mid = min(last + avg_size, hi_limit)
        cut = hi_limit
        i0 = int(np.searchsorted(strict, last + min_size, side="left"))
        i1 = int(np.searchsorted(strict, mid, side="left"))
        if i0 < i1:                       # strict mask cut in [min, avg)
            cut = int(strict[i0])
        else:
            j0 = int(np.searchsorted(loose, mid, side="left"))
            j1 = int(np.searchsorted(loose, hi_limit, side="right"))
            if j0 < j1:                   # loose mask cut in [avg, max]
                cut = int(loose[j0])
        cuts.append(cut)
        last = cut
    return cuts


@dataclass(frozen=True)
class ChunkSpec:
    """How an artifact's bytes are split into leaf blocks.

    ``strategy="fixed"`` slices every ``chunk_size`` bytes; ``strategy="cdc"``
    places boundaries where a rolling gear hash fires, bounded by
    ``min_size``/``max_size`` around an expected ``avg_size``, with
    ``norm`` extra mask bits of FastCDC-style normalization (0 = the
    legacy single-mask behavior).  Specs encode to a compact ASCII form
    (``fixed:262144`` / ``cdc:65536:262144:1048576`` /
    ``cdc:65536:262144:1048576:2`` when normalized) so publishers can
    record them in manifest meta and a re-publish — or a delta re-publish
    against a ``base`` version — reproduces identical boundaries, which is
    the whole point: boundary determinism is what makes unchanged content
    keep its CIDs.
    """

    strategy: str = "fixed"
    chunk_size: int = CHUNK_SIZE
    min_size: int = CHUNK_SIZE // 4
    avg_size: int = CHUNK_SIZE
    max_size: int = CHUNK_SIZE * 4
    norm: int = 0

    def __post_init__(self) -> None:
        if self.strategy not in ("fixed", "cdc"):
            raise ValueError(f"unknown chunking strategy {self.strategy!r}")
        if not isinstance(self.norm, int) or self.norm < 0:
            raise ValueError(f"norm must be a non-negative int, got "
                             f"{self.norm!r}")
        if self.strategy == "fixed":
            if self.chunk_size <= 0:
                raise ValueError("chunk_size must be positive")
            if self.norm:
                raise ValueError("norm only applies to cdc chunking")
        else:
            if not 0 < self.min_size <= self.avg_size <= self.max_size:
                raise ValueError(
                    "cdc requires 0 < min_size <= avg_size <= max_size, got "
                    f"{self.min_size}/{self.avg_size}/{self.max_size}")
            # chunk_size is unused for cdc: normalize it to avg_size so
            # equality and encode()/decode() round-trips never diverge on
            # derivable state
            object.__setattr__(self, "chunk_size", self.avg_size)

    @classmethod
    def cdc(cls, avg_size: int = 64 * 1024, min_size: Optional[int] = None,
            max_size: Optional[int] = None, norm: int = 0) -> "ChunkSpec":
        return cls(strategy="cdc", chunk_size=avg_size,
                   min_size=min_size if min_size is not None else avg_size // 4,
                   avg_size=avg_size,
                   max_size=max_size if max_size is not None else avg_size * 4,
                   norm=norm)

    def split(self, data: bytes) -> List[bytes]:
        if not data:
            return [b""]
        if self.strategy == "fixed":
            return chunk(data, self.chunk_size)
        cuts = cdc_cut_points(data, self.min_size, self.avg_size,
                              self.max_size, norm=self.norm)
        out = []
        last = 0
        for cut in cuts:
            out.append(data[last:cut])
            last = cut
        return out

    def encode(self) -> bytes:
        if self.strategy == "fixed":
            return b"fixed:%d" % self.chunk_size
        if self.norm:
            return b"cdc:%d:%d:%d:%d" % (self.min_size, self.avg_size,
                                         self.max_size, self.norm)
        # norm=0 keeps the 4-field form older releases wrote and read
        return b"cdc:%d:%d:%d" % (self.min_size, self.avg_size, self.max_size)

    @classmethod
    def decode(cls, raw: bytes) -> "ChunkSpec":
        try:
            fields = raw.decode("ascii").split(":")
            if fields[0] == "fixed" and len(fields) == 2:
                return cls(strategy="fixed", chunk_size=int(fields[1]))
            if fields[0] == "cdc" and len(fields) in (4, 5):
                mn, avg, mx = (int(f) for f in fields[1:4])
                norm = int(fields[4]) if len(fields) == 5 else 0
                return cls(strategy="cdc", chunk_size=avg, min_size=mn,
                           avg_size=avg, max_size=mx, norm=norm)
        except (UnicodeDecodeError, ValueError) as e:
            raise ValueError(f"bad ChunkSpec encoding {raw!r}") from e
        raise ValueError(f"bad ChunkSpec encoding {raw!r}")


# -- Merkle DAG manifests ----------------------------------------------------

_MAGIC = b"LDAG"       # v1: flat chunk list
_MAGIC2 = b"LDG2"      # v2: named sub-DAG entries


def manifest_version(data: bytes) -> int:
    """1 for flat v1, 2 for hierarchical v2; raises on anything else."""
    if data[:4] == _MAGIC:
        return 1
    if data[:4] == _MAGIC2:
        return 2
    raise ValueError("not a manifest block")


def is_manifest(data: bytes) -> bool:
    return data[:4] in (_MAGIC, _MAGIC2)


def encode_manifest(children: Sequence[CID], total_size: int,
                    meta: bytes = b"") -> bytes:
    out = [_MAGIC, struct.pack(">QI", total_size, len(children))]
    for c in children:
        out.append(struct.pack(">B", c.codec))
        out.append(c.digest)
    out.append(struct.pack(">I", len(meta)))
    out.append(meta)
    return b"".join(out)


def _take(data: bytes, off: int, n: int, what: str) -> Tuple[bytes, int]:
    """Bounds-checked slice for manifest decoding.  Truncated or garbage
    blocks must surface as ``ValueError`` (which the fetch paths translate to
    ``FetchError``), never as ``struct.error``/``IndexError`` — a corrupt
    block from a misbehaving peer is a protocol error, not a node crash."""
    end = off + n
    if n < 0 or end > len(data):
        raise ValueError(
            f"truncated manifest: {what} at offset {off} needs {n} bytes, "
            f"{len(data) - off} remain")
    return data[off:end], end


def decode_manifest(data: bytes) -> Tuple[List[CID], int, bytes]:
    if data[:4] != _MAGIC:
        raise ValueError("not a v1 manifest block")
    head, off = _take(data, 4, 12, "header")
    total_size, n = struct.unpack(">QI", head)
    children = []
    for i in range(n):
        raw, off = _take(data, off, 33, f"child {i}")
        children.append(CID(raw[0], raw[1:]))
    raw, off = _take(data, off, 4, "meta length")
    (meta_len,) = struct.unpack(">I", raw)
    meta, off = _take(data, off, meta_len, "meta")
    return children, total_size, meta


# -- v2 hierarchical manifests -----------------------------------------------


@dataclass(frozen=True)
class ManifestEntry:
    """One named sub-DAG in a v2 root manifest.

    ``cid`` is either a sub-manifest root (``CODEC_DAG``) or a raw leaf
    (``CODEC_RAW``); ``size`` is the decoded byte length of the entry's
    content; ``meta`` is opaque per-entry metadata (e.g. a tensor's
    dtype/shape) that travels in the *root* manifest so entry content stays
    a pure function of its bytes — maximizing sub-DAG reuse across versions.
    """

    name: str
    cid: CID
    size: int
    meta: bytes = b""


def encode_manifest_v2(entries: Sequence[ManifestEntry], total_size: int,
                       meta: bytes = b"") -> bytes:
    out = [_MAGIC2, struct.pack(">QI", total_size, len(entries))]
    for e in entries:
        name = e.name.encode("utf-8")
        out.append(struct.pack(">H", len(name)))
        out.append(name)
        out.append(struct.pack(">B", e.cid.codec))
        out.append(e.cid.digest)
        out.append(struct.pack(">QI", e.size, len(e.meta)))
        out.append(e.meta)
    out.append(struct.pack(">I", len(meta)))
    out.append(meta)
    return b"".join(out)


def decode_manifest_v2(data: bytes) -> Tuple[List[ManifestEntry], int, bytes]:
    if data[:4] != _MAGIC2:
        raise ValueError("not a v2 manifest block")
    head, off = _take(data, 4, 12, "header")
    total_size, n = struct.unpack(">QI", head)
    entries: List[ManifestEntry] = []
    for i in range(n):
        raw, off = _take(data, off, 2, f"entry {i} name length")
        (name_len,) = struct.unpack(">H", raw)
        raw, off = _take(data, off, name_len, f"entry {i} name")
        try:
            name = raw.decode("utf-8")
        except UnicodeDecodeError as e:
            raise ValueError(f"entry {i} name is not utf-8") from e
        raw, off = _take(data, off, 33, f"entry {i} cid")
        child = CID(raw[0], raw[1:])
        raw, off = _take(data, off, 12, f"entry {i} size/meta length")
        size, meta_len = struct.unpack(">QI", raw)
        meta, off = _take(data, off, meta_len, f"entry {i} meta")
        entries.append(ManifestEntry(name, child, size, meta))
    raw, off = _take(data, off, 4, "meta length")
    (meta_len,) = struct.unpack(">I", raw)
    meta, off = _take(data, off, meta_len, "meta")
    return entries, total_size, meta


def manifest_children(data: bytes) -> List[CID]:
    """Direct children of a manifest block, either version."""
    if manifest_version(data) == 1:
        return decode_manifest(data)[0]
    return [e.cid for e in decode_manifest_v2(data)[0]]


@dataclass
class DAG:
    root: CID
    blocks: Dict[CID, bytes]
    total_size: int
    #: v2 only: the root manifest's entries, in order
    entries: List[ManifestEntry] = field(default_factory=list)


def build_dag(data: bytes, chunk_size: int = CHUNK_SIZE, meta: bytes = b"",
              spec: Optional[ChunkSpec] = None) -> DAG:
    """Chunk ``data`` into leaf blocks + one flat (v1) manifest root block.

    ``spec`` selects the chunking strategy; when omitted, the historical
    fixed-``chunk_size`` layout is used, so pre-existing artifacts keep their
    root CIDs."""
    if spec is None:
        spec = ChunkSpec(strategy="fixed", chunk_size=chunk_size)
    leaves = spec.split(data)
    blocks: Dict[CID, bytes] = {}
    children: List[CID] = []
    for piece in leaves:
        c = CID.for_data(piece, CODEC_RAW)
        blocks[c] = piece
        children.append(c)
    manifest = encode_manifest(children, len(data), meta)
    root = CID.for_data(manifest, CODEC_DAG)
    blocks[root] = manifest
    return DAG(root=root, blocks=blocks, total_size=len(data))


def build_tree_dag(parts: Sequence[Tuple[str, bytes, bytes]],
                   chunk_size: int = CHUNK_SIZE, meta: bytes = b"",
                   spec: Optional[ChunkSpec] = None) -> DAG:
    """Build a hierarchical (v2) DAG: one sub-DAG per ``(name, data, meta)``
    part, rooted in a named-entry manifest.

    Identical part bytes (across parts, or vs a previously built version)
    hash to the identical sub-root CID — that is the structural-sharing
    property the delta-sync path relies on.  With a ``cdc`` :class:`ChunkSpec`
    sharing also survives *within-part* byte shifts: leaf boundaries are
    content-defined, so only the chunks overlapping an edit change CIDs.
    """
    blocks: Dict[CID, bytes] = {}
    entries: List[ManifestEntry] = []
    total = 0
    for name, data, part_meta in parts:
        sub = build_dag(data, chunk_size=chunk_size, spec=spec)
        blocks.update(sub.blocks)
        entries.append(ManifestEntry(name, sub.root, len(data), part_meta))
        total += len(data)
    manifest = encode_manifest_v2(entries, total, meta)
    root = CID.for_data(manifest, CODEC_DAG)
    blocks[root] = manifest
    return DAG(root=root, blocks=blocks, total_size=total, entries=entries)


def reassemble(root_block: bytes, fetch: Dict[CID, bytes]) -> bytes:
    children, total_size, _meta = decode_manifest(root_block)
    parts = []
    for c in children:
        blk = fetch[c]
        if not c.verify(blk):
            raise ValueError(f"block {c} failed verification")
        parts.append(blk)
    data = b"".join(parts)
    assert len(data) == total_size
    return data


def read_dag(root: CID, get: Callable[[CID], Optional[bytes]],
             verify: bool = True) -> bytes:
    """Reassemble a DAG of either manifest version from a block getter.

    Raises ``KeyError`` on a missing block and ``ValueError`` on a
    hash-verification failure, so callers can distinguish "fetch more"
    from "corrupt data".  ``verify=False`` skips the per-block sha256 —
    correct when the getter is a store that already verified on put
    (``BlockStore``); keep the default for untrusted mappings.
    """
    block = get(root)
    if block is None:
        raise KeyError(f"missing block {root}")
    if verify and not root.verify(block):
        raise ValueError(f"block {root} failed verification")
    if root.codec == CODEC_RAW:
        return block
    if manifest_version(block) == 1:
        children, total_size, _ = decode_manifest(block)
        data = b"".join(read_dag(c, get, verify) for c in children)
    else:
        entries, total_size, _ = decode_manifest_v2(block)
        data = b"".join(read_dag(e.cid, get, verify) for e in entries)
    if len(data) != total_size:
        raise ValueError(f"reassembled size mismatch under {root}")
    return data


def dag_reachable(root: CID,
                  get: Callable[[CID], Optional[bytes]]) -> List[CID]:
    """All CIDs reachable from ``root`` through manifests resolvable via
    ``get`` (deduplicated, pre-order).  Children whose blocks are absent are
    still listed — their sub-trees just aren't expanded."""
    seen: Dict[CID, None] = {}
    stack = [root]
    while stack:
        c = stack.pop()
        if c in seen:
            continue
        seen[c] = None
        if c.codec != CODEC_DAG:
            continue
        block = get(c)
        if block is None or not is_manifest(block):
            continue
        stack.extend(reversed(manifest_children(block)))
    return list(seen)
