"""Fleet builder: spin up a realistic Lattica mesh in one call.

Used by tests, benchmarks and examples.  The default NAT-type mix follows
measured Internet distributions (Ford et al. 2005-era surveys: most NATs are
cone-like, a substantial minority symmetric), which is what produces the
paper's ~70 % direct hole-punch success among NAT'd pairs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Generator, List, Optional, Sequence, Tuple

from .nat import NATBox, NATKind
from .node import LatticaNode
from .simnet import Network, Sim

#: (kind, weight); ``None`` = publicly addressable host.  Weighted toward
#: hard NATs (port-restricted + symmetric ≈ 60%), which yields ≈70% direct
#: connectivity across random pairs — the paper's §4 figure.
DEFAULT_NAT_MIX: List[Tuple[Optional[NATKind], float]] = [
    (None, 0.10),
    (NATKind.FULL_CONE, 0.15),
    (NATKind.RESTRICTED_CONE, 0.15),
    (NATKind.PORT_RESTRICTED, 0.30),
    (NATKind.SYMMETRIC, 0.30),
]

REGIONS = ["us", "eu", "ap"]


@dataclass
class Fleet:
    sim: Sim
    net: Network
    bootstrap: List[LatticaNode]
    peers: List[LatticaNode]

    @property
    def all_nodes(self) -> List[LatticaNode]:
        return self.bootstrap + self.peers

    def node_by_name(self, name: str) -> LatticaNode:
        for n in self.all_nodes:
            if n.host.name == name:
                return n
        raise KeyError(name)


def make_fleet(n_peers: int, seed: int = 0, n_bootstrap: int = 2,
               nat_mix: Optional[Sequence[Tuple[Optional[NATKind], float]]] = None,
               regions: Optional[List[str]] = None,
               same_region: Optional[str] = None,
               join: bool = True,
               cores: int = 4) -> Fleet:
    """Build bootstrap/relay servers + ``n_peers`` NAT-mixed peers.

    With ``join=True`` every peer runs the full bootstrap (dial, AutoNAT,
    relay reservation if private, DHT self-lookup) before this returns.
    """
    sim = Sim(seed=seed)
    net = Network(sim)
    nat_mix = list(nat_mix if nat_mix is not None else DEFAULT_NAT_MIX)
    regions = regions or REGIONS

    boots = []
    for b in range(n_bootstrap):
        node = LatticaNode(net, f"boot{b}", region=regions[b % len(regions)],
                           zone="core", serve_rendezvous=(b == 0), cores=cores)
        node.transport.enable_relay()
        boots.append(node)
    # interconnect bootstrap servers (sound AutoNAT forwarding needs a
    # public neighbor that joiners have not contacted yet)
    for b in boots[1:]:
        sim.run_process(b.connect_info(boots[0].info()))

    binfos = [b.info() for b in boots]
    kinds, weights = zip(*nat_mix)
    peers: List[LatticaNode] = []
    for i in range(n_peers):
        kind = sim.rng.choices(kinds, weights=weights)[0]
        nat = NATBox(net, kind) if kind is not None else None
        region = same_region or regions[i % len(regions)]
        zone = "a" if same_region else sim.rng.choice(["a", "b"])
        node = LatticaNode(net, f"peer{i}", region=region, zone=zone,
                           nat=nat, cores=cores)
        peers.append(node)

    if join:
        for node in peers:
            def _join(n: LatticaNode = node) -> Generator:
                yield from n.bootstrap(binfos)
                return None
            sim.run_process(_join())

    return Fleet(sim=sim, net=net, bootstrap=boots, peers=peers)
