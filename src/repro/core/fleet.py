"""Fleet builder: spin up a realistic Lattica mesh in one call.

Used by tests, benchmarks and examples.  The default NAT-type mix follows
measured Internet distributions (Ford et al. 2005-era surveys: most NATs are
cone-like, a substantial minority symmetric), which is what produces the
paper's ~70 % direct hole-punch success among NAT'd pairs.  Symmetric boxes
additionally draw a port-allocation model (``sym_alloc_mix``): sequential
and fixed-delta allocators are predictable enough for DCUtR v2's
predicted-port spray, random ones force relay fallback — mirroring the NAT
measurement literature (Trautwein et al.).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Generator, List, Optional, Sequence, Tuple, Union

from .nat import NATBox, NATKind, PortAlloc, nat_label
from .node import LatticaNode
from .simnet import Network, Sim

#: (kind, weight); ``None`` = publicly addressable host.  Weighted toward
#: hard NATs (port-restricted + symmetric ≈ 60%), which yields ≈70% direct
#: connectivity across random pairs — the paper's §4 figure.
DEFAULT_NAT_MIX: List[Tuple[Optional[NATKind], float]] = [
    (None, 0.10),
    (NATKind.FULL_CONE, 0.15),
    (NATKind.RESTRICTED_CONE, 0.15),
    (NATKind.PORT_RESTRICTED, 0.30),
    (NATKind.SYMMETRIC, 0.30),
]

#: Port-allocation model mix for SYMMETRIC boxes: (alloc, delta, weight).
#: Most CPE firmware allocates sequentially or with a small fixed stride
#: (predictable); a minority randomizes (punch-proof).
DEFAULT_SYM_ALLOC_MIX: List[Tuple[PortAlloc, int, float]] = [
    (PortAlloc.SEQUENTIAL, 1, 0.50),
    (PortAlloc.FIXED_DELTA, 2, 0.30),
    (PortAlloc.RANDOM, 1, 0.20),
]

REGIONS = ["us", "eu", "ap"]


@dataclass
class Fleet:
    sim: Sim
    net: Network
    bootstrap: List[LatticaNode]
    peers: List[LatticaNode]

    @property
    def all_nodes(self) -> List[LatticaNode]:
        return self.bootstrap + self.peers

    def node_by_name(self, name: str) -> LatticaNode:
        for n in self.all_nodes:
            if n.host.name == name:
                return n
        raise KeyError(name)

    def nat_kind_of(self, node: LatticaNode) -> str:
        """Human-readable NAT class of a node (for per-kind reporting)."""
        return nat_label(node.host.nat)


#: A per-peer NAT spec: ``None`` (public), a bare ``NATKind`` (default
#: allocator), or ``(NATKind, alloc, delta)`` for full control.
NatSpec = Union[None, NATKind, Tuple[NATKind, Union[PortAlloc, str], int]]


def wait_converged(sim: Sim, nodes_or_stores: Sequence[object],
                   timeout: float = 120.0) -> bool:
    """Run the sim until every replica's store digest agrees (or timeout).

    Built on the CRDT watch API: a change at *any* replica re-checks
    convergence immediately, so tests and examples no longer guess how many
    anti-entropy rounds to sleep through (the old registry-convergence
    flakiness).  Accepts ``LatticaNode``s or bare ``ReplicatedStore``s;
    background processes (gossip, fetch loops) keep running while this
    pumps the event loop.  Returns True once all digests are equal."""
    stores = [getattr(s, "store", s) for s in nodes_or_stores]

    def waiter() -> Generator:
        deadline = sim.now + timeout
        wake = [sim.event()]

        def ping(_key: object, _value: object, _origin: str) -> None:
            if not wake[0].triggered:
                wake[0].succeed()

        handles = [(s, s.watch("", ping)) for s in stores]
        try:
            while True:
                if len({s.digest() for s in stores}) == 1:
                    return True
                if sim.now >= deadline:
                    return False
                yield sim.any_of([wake[0], sim.timeout(deadline - sim.now)])
                wake[0] = sim.event()
        finally:
            for s, h in handles:
                s.unwatch(h)

    return sim.run_process(waiter(), until=sim.now + timeout + 1.0)


def make_nat(net: Network, spec: NatSpec) -> Optional[NATBox]:
    """Materialize a :data:`NatSpec` into a NAT box (or None for public)."""
    if spec is None:
        return None
    if isinstance(spec, NATKind):
        return NATBox(net, spec)
    kind, alloc, delta = spec
    return NATBox(net, kind, alloc=alloc, delta=delta)


def make_fleet(n_peers: int, seed: int = 0, n_bootstrap: int = 2,
               nat_mix: Optional[Sequence[Tuple[Optional[NATKind], float]]] = None,
               sym_alloc_mix: Optional[Sequence[Tuple[PortAlloc, int, float]]] = None,
               nat_kinds: Optional[Sequence[NatSpec]] = None,
               regions: Optional[List[str]] = None,
               same_region: Optional[str] = None,
               join: bool = True,
               maintenance: bool = True,
               cores: int = 4,
               sim: Optional[Sim] = None) -> Fleet:
    """Build bootstrap/relay servers + ``n_peers`` NAT-mixed peers.

    ``nat_kinds`` pins the exact per-peer NAT spec (overriding the random
    mix) — used by the punch-matrix benchmark and tests that need a
    controlled composition; it must have ``n_peers`` entries.

    With ``join=True`` every peer runs the full bootstrap (dial, AutoNAT,
    relay reservations if private, DHT self-lookup) before this returns.
    With ``maintenance=True`` (default) every peer also runs its background
    ``maintenance_loop`` — started right after that peer joins, so relay
    reservations (TTL'd on the relay side) are refreshed both while later
    peers are still joining and across long simulations.
    """
    if nat_kinds is not None and len(nat_kinds) != n_peers:
        raise ValueError("nat_kinds must have n_peers entries")
    # ``sim=`` lets callers supply a pre-configured simulator (e.g.
    # ``Sim(sanitize=True)`` for the simsan determinism/leak gates);
    # ``seed`` is ignored in that case.
    sim = Sim(seed=seed) if sim is None else sim
    net = Network(sim)
    nat_mix = list(nat_mix if nat_mix is not None else DEFAULT_NAT_MIX)
    alloc_mix = list(sym_alloc_mix if sym_alloc_mix is not None
                     else DEFAULT_SYM_ALLOC_MIX)
    regions = regions or REGIONS

    boots = []
    for b in range(n_bootstrap):
        node = LatticaNode(net, f"boot{b}", region=regions[b % len(regions)],
                           zone="core", serve_rendezvous=(b == 0), cores=cores)
        node.transport.enable_relay()
        boots.append(node)
    # interconnect bootstrap servers (sound AutoNAT forwarding needs a
    # public neighbor that joiners have not contacted yet)
    for b in boots[1:]:
        sim.run_process(b.connect_info(boots[0].info()))

    binfos = [b.info() for b in boots]
    kinds, weights = zip(*nat_mix)
    alloc_choices = [(a, d) for a, d, _w in alloc_mix]
    alloc_weights = [w for _a, _d, w in alloc_mix]
    peers: List[LatticaNode] = []
    for i in range(n_peers):
        if nat_kinds is not None:
            nat = make_nat(net, nat_kinds[i])
        else:
            kind = sim.rng.choices(kinds, weights=weights)[0]
            if kind is NATKind.SYMMETRIC:
                alloc, delta = sim.rng.choices(alloc_choices,
                                               weights=alloc_weights)[0]
                nat = NATBox(net, kind, alloc=alloc, delta=delta)
            elif kind is not None:
                nat = NATBox(net, kind)
            else:
                nat = None
        region = same_region or regions[i % len(regions)]
        zone = "a" if same_region else sim.rng.choice(["a", "b"])
        node = LatticaNode(net, f"peer{i}", region=region, zone=zone,
                           nat=nat, cores=cores)
        peers.append(node)

    for node in peers:
        if join:
            def _join(n: LatticaNode = node) -> Generator:
                yield from n.bootstrap(binfos)
                return None
            sim.run_process(_join())
        if maintenance:
            sim.process(node.maintenance_loop(), daemon=True)

    return Fleet(sim=sim, net=net, bootstrap=boots, peers=peers)
