"""Fleet builder: spin up a realistic Lattica mesh in one call.

Used by tests, benchmarks and examples.  The default NAT-type mix follows
measured Internet distributions (Ford et al. 2005-era surveys: most NATs are
cone-like, a substantial minority symmetric), which is what produces the
paper's ~70 % direct hole-punch success among NAT'd pairs.  Symmetric boxes
additionally draw a port-allocation model (``sym_alloc_mix``): sequential
and fixed-delta allocators are predictable enough for DCUtR v2's
predicted-port spray, random ones force relay fallback — mirroring the NAT
measurement literature (Trautwein et al.).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Generator, List, Optional, Sequence, Tuple, Union

from .nat import NATBox, NATKind, PortAlloc, nat_label
from .node import LatticaNode
from .simnet import Network, Sim

#: NAT-type mix from the Trautwein et al. decentralized hole-punching
#: measurement campaign (PAPERS.md): live DHT crawls see fewer public
#: hosts than the Ford-era surveys and a heavier tail of address/port-
#: dependent (symmetric) boxes — the composition that makes 1k–10k-node
#: churn scenarios representative rather than optimistic.
TRAUTWEIN_NAT_MIX: List[Tuple[Optional[NATKind], float]] = [
    (None, 0.08),
    (NATKind.FULL_CONE, 0.10),
    (NATKind.RESTRICTED_CONE, 0.12),
    (NATKind.PORT_RESTRICTED, 0.38),
    (NATKind.SYMMETRIC, 0.32),
]

#: (kind, weight); ``None`` = publicly addressable host.  Weighted toward
#: hard NATs (port-restricted + symmetric ≈ 60%), which yields ≈70% direct
#: connectivity across random pairs — the paper's §4 figure.
DEFAULT_NAT_MIX: List[Tuple[Optional[NATKind], float]] = [
    (None, 0.10),
    (NATKind.FULL_CONE, 0.15),
    (NATKind.RESTRICTED_CONE, 0.15),
    (NATKind.PORT_RESTRICTED, 0.30),
    (NATKind.SYMMETRIC, 0.30),
]

#: Port-allocation model mix for SYMMETRIC boxes: (alloc, delta, weight).
#: Most CPE firmware allocates sequentially or with a small fixed stride
#: (predictable); a minority randomizes (punch-proof).
DEFAULT_SYM_ALLOC_MIX: List[Tuple[PortAlloc, int, float]] = [
    (PortAlloc.SEQUENTIAL, 1, 0.50),
    (PortAlloc.FIXED_DELTA, 2, 0.30),
    (PortAlloc.RANDOM, 1, 0.20),
]

REGIONS = ["us", "eu", "ap"]


@dataclass
class Fleet:
    sim: Sim
    net: Network
    bootstrap: List[LatticaNode]
    peers: List[LatticaNode]

    @property
    def all_nodes(self) -> List[LatticaNode]:
        return self.bootstrap + self.peers

    def node_by_name(self, name: str) -> LatticaNode:
        for n in self.all_nodes:
            if n.host.name == name:
                return n
        raise KeyError(name)

    def nat_kind_of(self, node: LatticaNode) -> str:
        """Human-readable NAT class of a node (for per-kind reporting)."""
        return nat_label(node.host.nat)


#: A per-peer NAT spec: ``None`` (public), a bare ``NATKind`` (default
#: allocator), or ``(NATKind, alloc, delta)`` for full control.
NatSpec = Union[None, NATKind, Tuple[NATKind, Union[PortAlloc, str], int]]


def wait_converged(sim: Sim, nodes_or_stores: Sequence[object],
                   timeout: float = 120.0) -> bool:
    """Run the sim until every replica's store digest agrees (or timeout).

    Built on the CRDT watch API: a change at *any* replica re-checks
    convergence immediately, so tests and examples no longer guess how many
    anti-entropy rounds to sleep through (the old registry-convergence
    flakiness).  Accepts ``LatticaNode``s or bare ``ReplicatedStore``s;
    background processes (gossip, fetch loops) keep running while this
    pumps the event loop.  Returns True once all digests are equal."""
    stores = [getattr(s, "store", s) for s in nodes_or_stores]

    def waiter() -> Generator:
        deadline = sim.now + timeout
        wake = [sim.event()]

        def ping(_key: object, _value: object, _origin: str) -> None:
            if not wake[0].triggered:
                wake[0].succeed()

        handles = [(s, s.watch("", ping)) for s in stores]
        try:
            while True:
                if len({s.digest() for s in stores}) == 1:
                    return True
                if sim.now >= deadline:
                    return False
                yield sim.any_of([wake[0], sim.timeout(deadline - sim.now)])
                wake[0] = sim.event()
        finally:
            for s, h in handles:
                s.unwatch(h)

    return sim.run_process(waiter(), until=sim.now + timeout + 1.0)


def make_nat(net: Network, spec: NatSpec) -> Optional[NATBox]:
    """Materialize a :data:`NatSpec` into a NAT box (or None for public)."""
    if spec is None:
        return None
    if isinstance(spec, NATKind):
        return NATBox(net, spec)
    kind, alloc, delta = spec
    return NATBox(net, kind, alloc=alloc, delta=delta)


def make_fleet(n_peers: int, seed: int = 0, n_bootstrap: int = 2,
               nat_mix: Optional[Sequence[Tuple[Optional[NATKind], float]]] = None,
               sym_alloc_mix: Optional[Sequence[Tuple[PortAlloc, int, float]]] = None,
               nat_kinds: Optional[Sequence[NatSpec]] = None,
               regions: Optional[List[str]] = None,
               same_region: Optional[str] = None,
               join: bool = True,
               maintenance: bool = True,
               cores: int = 4,
               sim: Optional[Sim] = None) -> Fleet:
    """Build bootstrap/relay servers + ``n_peers`` NAT-mixed peers.

    ``nat_kinds`` pins the exact per-peer NAT spec (overriding the random
    mix) — used by the punch-matrix benchmark and tests that need a
    controlled composition; it must have ``n_peers`` entries.

    With ``join=True`` every peer runs the full bootstrap (dial, AutoNAT,
    relay reservations if private, DHT self-lookup) before this returns.
    With ``maintenance=True`` (default) every peer also runs its background
    ``maintenance_loop`` — started right after that peer joins, so relay
    reservations (TTL'd on the relay side) are refreshed both while later
    peers are still joining and across long simulations.
    """
    if nat_kinds is not None and len(nat_kinds) != n_peers:
        raise ValueError("nat_kinds must have n_peers entries")
    # ``sim=`` lets callers supply a pre-configured simulator (e.g.
    # ``Sim(sanitize=True)`` for the simsan determinism/leak gates);
    # ``seed`` is ignored in that case.
    sim = Sim(seed=seed) if sim is None else sim
    net = Network(sim)
    nat_mix = list(nat_mix if nat_mix is not None else DEFAULT_NAT_MIX)
    alloc_mix = list(sym_alloc_mix if sym_alloc_mix is not None
                     else DEFAULT_SYM_ALLOC_MIX)
    regions = regions or REGIONS

    boots = []
    for b in range(n_bootstrap):
        node = LatticaNode(net, f"boot{b}", region=regions[b % len(regions)],
                           zone="core", serve_rendezvous=(b == 0), cores=cores)
        node.transport.enable_relay()
        boots.append(node)
    # interconnect bootstrap servers (sound AutoNAT forwarding needs a
    # public neighbor that joiners have not contacted yet)
    for b in boots[1:]:
        sim.run_process(b.connect_info(boots[0].info()))

    binfos = [b.info() for b in boots]
    kinds, weights = zip(*nat_mix)
    alloc_choices = [(a, d) for a, d, _w in alloc_mix]
    alloc_weights = [w for _a, _d, w in alloc_mix]
    peers: List[LatticaNode] = []
    for i in range(n_peers):
        if nat_kinds is not None:
            nat = make_nat(net, nat_kinds[i])
        else:
            kind = sim.rng.choices(kinds, weights=weights)[0]
            if kind is NATKind.SYMMETRIC:
                alloc, delta = sim.rng.choices(alloc_choices,
                                               weights=alloc_weights)[0]
                nat = NATBox(net, kind, alloc=alloc, delta=delta)
            elif kind is not None:
                nat = NATBox(net, kind)
            else:
                nat = None
        region = same_region or regions[i % len(regions)]
        zone = "a" if same_region else sim.rng.choice(["a", "b"])
        node = LatticaNode(net, f"peer{i}", region=region, zone=zone,
                           nat=nat, cores=cores)
        peers.append(node)

    for node in peers:
        if join:
            def _join(n: LatticaNode = node) -> Generator:
                yield from n.bootstrap(binfos)
                return None
            sim.run_process(_join())
        if maintenance:
            sim.process(node.maintenance_loop(), daemon=True)

    return Fleet(sim=sim, net=net, bootstrap=boots, peers=peers)


# ---------------------------------------------------------------------------
# Scale harness: 1k–10k virtual-clock nodes in seconds
# ---------------------------------------------------------------------------

#: approximate direct hole-punch success probabilities by NAT-kind pairing,
#: sampled instead of simulated at scale (the full DCUtR state machine is
#: exercised by ``make_fleet``/the traversal tests; re-running it for every
#: overlay edge of a 10k-node fleet would dominate build time without
#: changing the topology statistics).  Numbers bracket the ~70% aggregate
#: direct-connectivity figure the measurement campaign reports.
_PUNCH_P_CONE = 0.85          # neither side symmetric
_PUNCH_P_ONE_SYM = 0.65       # one symmetric (predictable allocator helps)
_PUNCH_P_BOTH_SYM = 0.15      # both symmetric: predicted-port spray rarely
_PUNCH_P_RANDOM_SYM = 0.02    # symmetric with randomized allocation


@dataclass
class ScaleFleet:
    """A pre-wired overlay of ``n`` nodes for fleet-scale benchmarks.

    Unlike :func:`make_fleet`, nodes do not run the full bootstrap
    (AutoNAT probes, relay reservations, DHT self-lookups): reachability
    is assigned from the NAT spec, address books and routing tables are
    seeded with sampled public contacts, and overlay connections are
    established directly — NAT'd nodes dial outbound, NAT'd↔NAT'd edges
    are kept with the measured punch-success probability.  That is what
    lets a 10k-node fleet stand up in seconds of wall time while keeping
    the topology statistics (public fraction, punchable-pair fraction,
    degree) faithful to the measurement campaign.
    """

    sim: Sim
    net: Network
    nodes: List[LatticaNode]
    publics: List[LatticaNode]
    natted: List[LatticaNode]
    degree: int
    public_contacts: int
    stats: Dict[str, int] = field(default_factory=lambda: {
        "edges": 0, "edges_public": 0, "edges_punched": 0,
        "edges_skipped": 0, "churn_events": 0})

    def node_by_name(self, name: str) -> LatticaNode:
        for n in self.nodes:
            if n.host.name == name:
                return n
        raise KeyError(name)

    # -- wiring -------------------------------------------------------------
    def _connectable(self, a: LatticaNode, b: LatticaNode) -> Optional[str]:
        """Edge classification: 'public' (at least one dialable side),
        'punched' (NAT'd pair that wins the punch-probability draw) or
        None (edge dropped)."""
        if a.host.nat is None or b.host.nat is None:
            return "public"
        kinds = (a.host.nat.kind, b.host.nat.kind)
        allocs = (a.host.nat.alloc, b.host.nat.alloc)
        if NATKind.SYMMETRIC in kinds:
            if PortAlloc.RANDOM in allocs:
                p = _PUNCH_P_RANDOM_SYM
            elif kinds == (NATKind.SYMMETRIC, NATKind.SYMMETRIC):
                p = _PUNCH_P_BOTH_SYM
            else:
                p = _PUNCH_P_ONE_SYM
        else:
            p = _PUNCH_P_CONE
        return "punched" if self.sim.rng.random() < p else None

    def _connect(self, a: LatticaNode, b: LatticaNode) -> bool:
        """Establish one overlay edge (both address books learn it)."""
        if a.host.connection_to(b.host) is not None:
            return True
        edge = self._connectable(a, b)
        if edge is None:
            self.stats["edges_skipped"] += 1
            return False
        self.net.establish(a.host, b.host)
        a.remember(b.info())
        b.remember(a.info())
        self.stats["edges"] += 1
        self.stats["edges_public" if edge == "public" else
                    "edges_punched"] += 1
        return True

    def wire_node(self, node: LatticaNode) -> None:
        """Seed one node's contacts and overlay edges (also the rejoin
        path after churn): remember a sample of public nodes (address
        book + routing table), then dial out until ``degree`` overlay
        edges exist."""
        rng = self.sim.rng
        publics = [p for p in self.publics if p is not node]
        if publics:
            k = min(self.public_contacts, len(publics))
            for pub in rng.sample(publics, k):
                node.remember(pub.info())
        # draw candidates lazily — O(degree) expected per node, where a
        # full shuffle would make standing up a 10k fleet O(n^2)
        n = len(self.nodes)
        wired = 0
        attempts = 0
        tried = {node.host.name}
        while (wired < self.degree and attempts < 20 * self.degree
               and len(tried) <= n):
            attempts += 1
            cand = self.nodes[rng.randrange(n)]
            if cand.host.name in tried:
                continue
            tried.add(cand.host.name)
            if self._connect(node, cand):
                wired += 1

    # -- churn --------------------------------------------------------------
    def churn_wave(self, frac: float) -> List[LatticaNode]:
        """Restart ``frac`` of the NAT'd population: connections drop,
        transient mesh/sync state is lost, and each victim rejoins
        through fresh contacts.  Peers notice only through failed
        deliveries (score collapse → prune → re-graft), exactly like a
        real churn event.  Returns the restarted nodes."""
        rng = self.sim.rng
        k = max(1, int(len(self.natted) * frac))
        victims = rng.sample(self.natted, min(k, len(self.natted)))
        for node in victims:
            self._restart(node)
        self.stats["churn_events"] += len(victims)
        return victims

    def churn_loop(self, frac: float, interval: float) -> Generator:
        """Continuous churn driver: one :meth:`churn_wave` per interval.
        Run it as a daemon process alongside the measured workload."""
        while True:
            yield interval
            self.churn_wave(frac)

    def _restart(self, node: LatticaNode) -> None:
        for conns in list(node.host._connections.values()):
            for c in list(conns):
                if not c.closed:
                    c.close()
        ps = node.pubsub
        for members in ps.mesh.values():
            members.clear()
        ps.peer_topics.clear()
        ps._pending_iwant.clear()
        ps._mcache.clear()
        ps._mcache_windows[:] = [[]]
        ps._seen.clear()
        node.peers.clear()
        node.infos_by_host.clear()
        node._stub_cache.clear()
        node._crdt_peer_proto.clear()
        node._crdt_sync_cache.clear()
        self.wire_node(node)
        # a restarted process re-announces its subscriptions on rejoin
        if ps.subscriptions:
            ps._push_subscription_update()

    # -- views --------------------------------------------------------------
    def relay_load(self) -> List[int]:
        """Per-node forwarded-message counts (mesh relay load)."""
        return [n.pubsub.stats["forwarded"] for n in self.nodes]

    def summary_bytes(self) -> Dict[str, int]:
        """Fleet-wide anti-entropy localization cost counters."""
        out = {"mst_probe_bytes": 0, "flat_summary_bytes": 0,
               "mst_exchanges": 0, "delta_exchanges": 0}
        for n in self.nodes:
            out["mst_probe_bytes"] += n.crdt_stats["mst_probe_bytes"]
            out["flat_summary_bytes"] += n.crdt_stats["summary_bytes"]
            out["mst_exchanges"] += n.crdt_stats["mst_exchanges"]
            out["delta_exchanges"] += n.crdt_stats["delta_exchanges"]
        return out


def make_scale_fleet(n_nodes: int, seed: int = 0,
                     nat_mix: Optional[Sequence[
                         Tuple[Optional[NATKind], float]]] = None,
                     sym_alloc_mix: Optional[Sequence[
                         Tuple[PortAlloc, int, float]]] = None,
                     degree: int = 8,
                     public_contacts: int = 16,
                     cores: int = 2,
                     crdt_push_window: float = 0.25,
                     nat_ttl: Optional[float] = 90.0,
                     regions: Optional[Sequence[str]] = None,
                     latency: Optional[Dict[str, float]] = None,
                     bandwidth: Optional[Dict[str, float]] = None,
                     sim: Optional[Sim] = None) -> ScaleFleet:
    """Stand up ``n_nodes`` virtual-clock nodes with the Trautwein NAT mix.

    Every node gets ``public_contacts`` sampled public peers in its
    address book / routing table and ``degree`` pre-established overlay
    edges (outbound from behind NAT; NAT'd↔NAT'd kept with the measured
    punch probability).  ``crdt_push_window`` defaults to a positive
    coalescing window — at fleet scale, per-instant delta docs are
    exactly the hot-namespace flood the batching window exists to stop.

    ``regions`` round-robins node placement over the given region labels
    (default: all of :data:`REGIONS`); ``latency``/``bandwidth`` override
    link-class parameters on the fabric — together they model
    heterogeneous-bandwidth multi-region fleets (e.g. two regions joined
    by a thin ``inter`` path for cross-region training rounds).
    """
    sim = Sim(seed=seed) if sim is None else sim
    net = Network(sim, latency=latency, bandwidth=bandwidth)
    region_cycle = list(regions) if regions else list(REGIONS)
    nat_mix = list(nat_mix if nat_mix is not None else TRAUTWEIN_NAT_MIX)
    alloc_mix = list(sym_alloc_mix if sym_alloc_mix is not None
                     else DEFAULT_SYM_ALLOC_MIX)
    kinds, weights = zip(*nat_mix)
    alloc_choices = [(a, d) for a, d, _w in alloc_mix]
    alloc_weights = [w for _a, _d, w in alloc_mix]

    nodes: List[LatticaNode] = []
    publics: List[LatticaNode] = []
    natted: List[LatticaNode] = []
    for i in range(n_nodes):
        kind = sim.rng.choices(kinds, weights=weights)[0]
        if kind is NATKind.SYMMETRIC:
            alloc, delta = sim.rng.choices(alloc_choices,
                                           weights=alloc_weights)[0]
            nat: Optional[NATBox] = NATBox(net, kind, alloc=alloc,
                                           delta=delta, ttl=nat_ttl)
        elif kind is not None:
            nat = NATBox(net, kind, ttl=nat_ttl)
        else:
            nat = None
        node = LatticaNode(net, f"n{i}",
                           region=region_cycle[i % len(region_cycle)],
                           zone=sim.rng.choice(["a", "b"]), nat=nat,
                           cores=cores, crdt_push_window=crdt_push_window)
        # reachability is assigned, not probed: the AutoNAT dance is a
        # per-node constant cost that adds nothing at this scale
        node.transport.reachability = "public" if nat is None else "private"
        # bound subscription-announce fan-out to roughly the overlay
        # degree (gossipsub announces over connected links only)
        node.pubsub.announce_cap = degree + 4
        nodes.append(node)
        (publics if nat is None else natted).append(node)

    fleet = ScaleFleet(sim=sim, net=net, nodes=nodes, publics=publics,
                       natted=natted, degree=degree,
                       public_contacts=public_contacts)
    for node in nodes:
        fleet.wire_node(node)
    return fleet
