"""xLSTM-1.3B — mLSTM blocks with an sLSTM every 8th block (≈7:1 ratio)
[arXiv:2405.04517].  d_ff=0: feed-forward capacity lives inside the xLSTM
blocks (mLSTM pre-up-projection ×2, sLSTM post-FF ×8/3)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", arch="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=512,
    slstm_every=8,
)
