"""Qwen1.5/2-MoE-A2.7B — 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", arch="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=1408, vocab=151936, head_dim=128, rope_theta=1e6,
    n_experts=60, n_shared_experts=4, moe_top_k=4, d_expert=1408,
)
