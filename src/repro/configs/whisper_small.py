"""Whisper-small — enc-dec; conv/mel frontend stubbed to frame embeddings
[arXiv:2212.04356]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", arch="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64, rope_theta=1e4,
    enc_layers=12, enc_seq=1500, d_source=768,
)
