"""GLM-4-9B — RoPE, extreme GQA (kv=2) [hf:THUDM/glm-4-9b]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b", arch="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2,
    d_ff=13696, vocab=151552, head_dim=128, rope_theta=1e4,
)
