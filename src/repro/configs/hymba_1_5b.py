"""Hymba-1.5B — parallel attention + Mamba heads per block
[arXiv:2411.13676].

Deviation (DESIGN.md): sliding-window attention (2048) on ALL layers; the
paper keeps 3 layers global.  The Mamba branch supplies global context, and
a uniform window keeps the ring-buffer decode cache homogeneous under scan.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", arch="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, head_dim=64, rope_theta=1e4,
    ssm_state=16, d_inner=3200, window=2048,
)
