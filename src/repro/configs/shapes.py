"""Assigned input shapes + ShapeDtypeStruct specs for the dry-run.

``input_specs`` builds weak-type-correct, shardable stand-ins for every
model input — no device allocation, exactly what ``jax.jit(...).lower()``
needs for the multi-pod dry-run.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

#: Sliding window used when a full-attention arch runs long_500k via the
#: implemented sliding-window variant (see DESIGN.md §5).
LONG_CONTEXT_WINDOW = 8192


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    """Is (arch, shape) runnable?  Returns (supported, reason)."""
    if shape.name == "long_500k":
        if cfg.arch == "audio":
            return False, ("encoder-decoder ASR has no 500k-token decode use "
                           "case (source is <=enc_seq frames); skipped per "
                           "DESIGN.md carve-out")
        # ssm/hybrid run natively; dense/moe/vlm run the sliding-window
        # variant (cfg_for_shape swaps the window in)
    return True, ""


def cfg_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Variant selection: full-attention archs get a sliding window for
    long_500k so decode memory is O(window), not O(seq)."""
    if (shape.name == "long_500k" and cfg.window == 0
            and cfg.arch in ("dense", "moe", "vlm")):
        return replace(cfg, window=LONG_CONTEXT_WINDOW)
    return cfg


# ---------------------------------------------------------------------------


def _sds(shape: Tuple[int, ...], dtype: Any) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape,
                dtype: Any = jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × shape) step's data inputs.

    train/prefill: the token batch (+ modality stubs).  decode: ONE new
    token per sequence (the KV/state cache is built separately via
    ``cache_specs``).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.arch == "vlm":
            P = cfg.n_patches
            batch["tokens"] = _sds((B, S - P), jnp.int32)
            batch["vision_embeds"] = _sds((B, P, cfg.d_model), dtype)
            batch["positions3"] = _sds((3, B, S), jnp.int32)
            if shape.kind == "train":
                batch["labels"] = _sds((B, S - P), jnp.int32)
        elif cfg.arch == "audio":
            batch["tokens"] = _sds((B, S), jnp.int32)
            batch["frames"] = _sds((B, cfg.enc_seq, cfg.d_source), dtype)
            if shape.kind == "train":
                batch["labels"] = _sds((B, S), jnp.int32)
        else:
            batch["tokens"] = _sds((B, S), jnp.int32)
            if shape.kind == "train":
                batch["labels"] = _sds((B, S), jnp.int32)
        return batch
    # decode: one token per sequence
    return {"token": _sds((B,), jnp.int32)}


def cache_specs(cfg: ModelConfig, shape: InputShape,
                dtype: Any = jnp.bfloat16) -> Any:
    """ShapeDtypeStruct pytree matching ``ops.init_cache`` for decode."""
    from repro.models import ops_for

    ops = ops_for(cfg)
    cache = jax.eval_shape(
        lambda: ops.init_cache(cfg, shape.global_batch, shape.seq_len, dtype))
    return cache


def concrete_batch(cfg: ModelConfig, shape: InputShape, seed: int = 0,
                   dtype: Any = jnp.float32) -> Dict[str, Any]:
    """Small-scale concrete inputs (smoke tests / examples)."""
    key = jax.random.PRNGKey(seed)
    specs = input_specs(cfg, shape, dtype)
    out: Dict[str, Any] = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab,
                                           dtype=s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32
                                          ).astype(s.dtype)
    return out
