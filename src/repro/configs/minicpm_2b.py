"""MiniCPM-2B — llama-like arch; WSD schedule lives in repro.optim
[arXiv:2404.06395]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b", arch="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36,
    d_ff=5760, vocab=122753, head_dim=64, rope_theta=1e4,
    tie_embeddings=True,
)
