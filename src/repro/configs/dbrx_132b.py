"""DBRX-132B — fine-grained MoE, 16 experts top-4 [hf:databricks/dbrx-base]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", arch="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, head_dim=128, rope_theta=5e5,
    n_experts=16, n_shared_experts=0, moe_top_k=4, d_expert=10752,
)
