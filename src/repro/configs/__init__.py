"""Architecture registry: ``--arch <id>`` resolution for every entry point."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

from .shapes import (LONG_CONTEXT_WINDOW, SHAPES, InputShape, cache_specs,
                     cfg_for_shape, concrete_batch, input_specs,
                     shape_supported)

_MODULES: Dict[str, str] = {
    "qwen2-vl-7b": "qwen2_vl_7b",
    "qwen3-32b": "qwen3_32b",
    "granite-8b": "granite_8b",
    "whisper-small": "whisper_small",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "minicpm-2b": "minicpm_2b",
    "hymba-1.5b": "hymba_1_5b",
    "dbrx-132b": "dbrx_132b",
    "glm4-9b": "glm4_9b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_IDS: List[str] = list(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


__all__ = [
    "ARCH_IDS", "SHAPES", "InputShape", "LONG_CONTEXT_WINDOW",
    "get_config", "all_configs", "input_specs", "cache_specs",
    "concrete_batch", "cfg_for_shape", "shape_supported",
]
