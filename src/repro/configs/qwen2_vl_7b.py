"""Qwen2-VL-7B language backbone — M-RoPE, dynamic resolution [arXiv:2409.12191].

Vision encoder (ViT + merger) is stubbed: input_specs() supplies precomputed
patch embeddings of shape (B, n_patches, d_model).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", arch="vlm",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    mrope=True, mrope_sections=(16, 24, 24), rope_theta=1e6,
    n_patches=256,
)
