"""DiLoCo-style collaborative training over the Lattica mesh.

Each worker trains locally for ``inner_steps`` (H) AdamW steps, then
publishes its **pseudo-gradient** — the outer delta ``theta_round_start -
theta_after_H`` — as a content DAG over bitswap, compressed by top-k
sparsification + int8 block quantization with local error feedback
(:mod:`repro.train.compress`).  One communication round per H steps at a
few percent of the fp32 bytes is what makes geo-distributed training over
heterogeneous inter-region links viable at all (BlockTrain / ScaleAcross
setting; DiLoCo is the outer-optimizer recipe).

**No coordinator exists.**  Round state lives in the CRDT store under a
``train/<fleet>`` namespace and rides the delta push plane:

  * ``train/<fleet>/r<k>/members``     ORSet of worker names in round k
  * ``train/<fleet>/r<k>/c/<worker>``  LWW → (cid codec, digest, bytes…)
  * ``train/<fleet>/r<k>/closed``      LWW → sorted contributor tuple

A round *closes* when a quorum fraction of announced members have
contribution CIDs visible and a settle window has passed; any contributor
may then write the ``closed`` register.  Concurrent closers converge
deterministically: the register is written with a constant timestamp
(the round index), so the LWW tie-break on replica id picks the same
winner on every replica regardless of merge order.  A worker that applied
a losing closed-set detects the flip at the next round boundary and
**rebases**: it rewinds to its saved pre-round outer state and replays the
authoritative sets, so outer state never forks.  Stragglers that miss the
closed set fold their already-computed delta back into their error-feedback
residual — work is deferred, not lost.  Workers that drop mid-round simply
stop contributing; the quorum closes without them, and on rejoin they merge
the closed rounds from the CRDT store and replay the pinned contribution
DAGs to catch up (``catch_up``).

Every worker that saw the same contribution set applies the identical
Nesterov outer step (float64-accumulated average, float32 outer math), so
outer params are bit-identical across the fleet — verifiable remotely via
``CollabService.status`` digests without shipping any state.

Contribution DAGs are pinned for ``keep_rounds`` rounds (the rejoin replay
window) and unpinned after; a simsan leak gauge counts overdue pins so a
forgotten unpin fails the sanitizer, not production memory.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import (Any, Callable, Dict, Generator, Iterator, List, Optional,
                    Tuple)

import jax
import numpy as np

from repro.core.bitswap import FetchError
from repro.core.cid import CID, decode_manifest_v2, manifest_version, read_dag
from repro.core.node import LatticaNode
from repro.core.rpc import RpcContext, RpcError
from repro.core.service import (Fixed, RpcStatus, Service, ServiceError,
                                pickled, unary)
from repro.core.simnet import DialError
from repro.models import ops_for
from repro.models.config import ModelConfig

from .compress import (average_flat, compress_pseudograd, flat_digest,
                       flat_from_entries, pseudo_gradient, tree_to_flat)
from .step import TrainState, make_train_step

__all__ = ["CollabConfig", "CollabService", "CollabWorker", "serve_collab"]


@dataclass
class CollabConfig:
    """Knobs of the collaborative round protocol."""

    inner_steps: int = 50        #: H — local AdamW steps per round
    quorum: float = 0.5          #: fraction of announced members that closes
    settle: float = 1.0          #: extra seconds after quorum for stragglers
    round_timeout: float = 120.0  #: close with whatever landed after this
    topk_frac: float = 0.05      #: kept fraction per leaf
    quant: Optional[str] = "int8_block"  #: kept-value codec (None = raw f32)
    outer_lr: float = 0.7        #: Nesterov outer-SGD learning rate
    outer_momentum: float = 0.9
    nesterov: bool = True
    keep_rounds: int = 2         #: pinned past rounds (rejoin replay window)


class CollabService(Service):
    """Remote view of a node's collaborative workers: current round,
    outer-state digest, round counters.  Lets peers (and tests) verify
    replicated outer state converged without shipping parameters, and
    lets a rejoiner learn how far behind it is.  Read-only → idempotent."""

    name = "collab"

    def __init__(self, node: LatticaNode):
        self.node = node
        self.workers: Dict[str, "CollabWorker"] = {}

    @unary("collab.status", request=Fixed(64), response=pickled(floor=96),
           idempotent=True, timeout=15.0)
    def status(self, fleet: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(2e-6)
        w = self.workers.get(fleet)
        if w is None:
            raise ServiceError(RpcStatus.NOT_FOUND,
                               f"no collab worker for fleet {fleet!r}")
        return {"round": w.outer_round, "digest": w.outer_digest(),
                "closed": w.stats["rounds_closed"],
                "rebases": w.stats["rebases"]}


def serve_collab(node: LatticaNode) -> CollabService:
    """Expose (and share) the node's ``CollabService`` — one per node, so
    several fleets' workers on the same node register with one router
    entry."""
    svc = getattr(node, "_collab_service", None)
    if svc is None:
        svc = node.serve(CollabService(node))
        node._collab_service = svc
    return svc


class CollabWorker:
    """One fleet member of a DiLoCo-style collaborative run.

    Drive it with :meth:`run` as a sim process.  ``stop()`` models a crash
    (the worker bails at the next await point); a later :meth:`run` on the
    same object rejoins — ``catch_up`` replays the rounds that closed
    while it was gone from the CRDT record + pinned contribution DAGs,
    so the rejoiner lands on the fleet's bit-identical outer state
    instead of forking it.
    """

    def __init__(self, node: LatticaNode, cfg: ModelConfig,
                 state: TrainState, schedule: Callable,
                 data: Iterator[Dict[str, np.ndarray]], fleet: str,
                 collab: Optional[CollabConfig] = None,
                 step_seconds: float = 0.5,
                 eval_batch: Optional[Dict[str, np.ndarray]] = None):
        self.node = node
        self.sim = node.sim
        self.cfg = cfg
        self.fleet = fleet
        self.ccfg = collab or CollabConfig()
        self.name = node.host.name
        self.step_seconds = step_seconds
        self.data = data
        self._like = state.params
        self._state = state
        self.step_fn = jax.jit(make_train_step(cfg, schedule))
        ops = ops_for(cfg)
        self._eval_fn = (jax.jit(lambda p, b: ops.loss_fn(p, cfg, b)[0])
                         if eval_batch is not None else None)
        self.eval_batch = eval_batch

        #: replicated outer state (float32 numpy, path-keyed)
        self.outer_flat = tree_to_flat(state.params)
        self.outer_mom = {k: np.zeros_like(v) for k, v in self.outer_flat.items()}
        self.outer_round = 0
        #: error-feedback residual: pseudo-gradient mass not yet shipped
        self.residual = {k: np.zeros_like(v) for k, v in self.outer_flat.items()}

        self.history: List[Dict[str, float]] = []
        self.round_log: List[Dict[str, float]] = []
        self.stats: Dict[str, int] = {
            "rounds_closed": 0, "rounds_degraded": 0, "rounds_aborted": 0,
            "rebases": 0, "catchup_rounds": 0, "contribs_fetched": 0,
            "wire_bytes": 0, "dense_bytes": 0}
        self.alive = True

        #: round -> roots pinned for the rejoin replay window
        self._contrib_pins: Dict[int, List[CID]] = {}
        #: round -> closed set we applied (rebase detection window)
        self._applied: Dict[int, Tuple[str, ...]] = {}
        #: round -> (outer_flat, outer_mom) snapshot before the outer step
        self._pre_round: Dict[int, Tuple[Dict[str, np.ndarray],
                                         Dict[str, np.ndarray]]] = {}

        self._wake = self.sim.event()
        node.watch_crdt(f"train/{fleet}", self._on_change)
        serve_collab(node).workers[fleet] = self
        self.sim.register_leak_check(
            f"collab.overdue_pins:{self.name}", self.overdue_pins)

    # ------------------------------------------------------------- CRDT keys
    def _members_key(self, r: int) -> str:
        return f"train/{self.fleet}/r{r}/members"

    def _contrib_key(self, r: int, worker: str) -> str:
        return f"train/{self.fleet}/r{r}/c/{worker}"

    def _closed_key(self, r: int) -> str:
        return f"train/{self.fleet}/r{r}/closed"

    def _contrib(self, r: int, worker: str) -> Optional[Tuple]:
        val = self.node.store.register(self._contrib_key(r, worker)).value()
        return tuple(val) if val is not None else None

    def _closed(self, r: int) -> Optional[Tuple[str, ...]]:
        val = self.node.store.register(self._closed_key(r)).value()
        return tuple(val) if val is not None else None

    # ----------------------------------------------------------------- views
    def outer_digest(self) -> str:
        return flat_digest(self.outer_flat)

    def outer_params(self) -> Any:
        """Outer params in the model's pytree structure (for eval/ckpt)."""
        from repro.checkpoint.serial import params_from_parts
        return params_from_parts(dict(self.outer_flat), self._like)

    def overdue_pins(self) -> int:
        """Contribution roots still pinned past the replay window — the
        simsan leak gauge (anything here after quiesce is a leaked pin)."""
        horizon = self.outer_round - 1 - self.ccfg.keep_rounds
        return sum(len(v) for r, v in self._contrib_pins.items()
                   if r <= horizon)

    # ------------------------------------------------------------- lifecycle
    def stop(self) -> None:
        """Model a crash/departure: the worker bails at its next await
        point; CRDT state and pinned blocks survive on the node."""
        self.alive = False
        self._wakeup()

    def _on_change(self, key: str, value: Any, origin: str) -> None:
        self._wakeup()

    def _wakeup(self) -> None:
        if not self._wake.triggered:
            self._wake.succeed()

    def run(self, n_rounds: int,
            log: Optional[Callable[[str], None]] = None) -> Generator:
        """Sim process: catch up on rounds closed while away, then drive
        ``n_rounds`` collaborative rounds.  Returns rounds applied."""
        self.alive = True
        applied = yield from self.catch_up()
        for _ in range(n_rounds):
            if not self.alive:
                break
            done = yield from self.run_round(log)
            if done:
                applied += 1
        return applied

    # -------------------------------------------------------- one full round
    def run_round(self, log: Optional[Callable[[str], None]] = None,
                  ) -> Generator:
        r = self.outer_round
        store = self.node.store
        store.orset(self._members_key(r)).add(self.name, self.name)
        yield from self.node.crdt_push_flush()

        # -- inner phase: H local AdamW steps from the replicated outer state
        start_flat = {k: v.copy() for k, v in self.outer_flat.items()}
        from repro.checkpoint.serial import params_from_parts
        self._state = TrainState(
            params=params_from_parts(dict(start_flat), self._like),
            opt=self._state.opt)
        for i in range(self.ccfg.inner_steps):
            if not self.alive:
                return False
            batch = next(self.data)
            self._state, metrics = self.step_fn(self._state, batch)
            rec = {k: float(v) for k, v in metrics.items()}
            rec["round"] = r
            self.history.append(rec)
            yield self.step_seconds
        if not self.alive:
            return False

        # -- compress + publish the pseudo-gradient as a content DAG
        end_flat = tree_to_flat(self._state.params)
        grad = pseudo_gradient(start_flat, end_flat)
        for k in grad:
            grad[k] = grad[k] + self.residual[k]
        parts, sent, cstats = compress_pseudograd(
            grad, frac=self.ccfg.topk_frac, quant=self.ccfg.quant)
        root = yield from self.node.publish_tree_artifact(parts, pin=True)
        self._contrib_pins.setdefault(r, []).append(root)
        self.stats["wire_bytes"] += cstats["wire_bytes"]
        self.stats["dense_bytes"] += cstats["dense_bytes"]
        store.register(self._contrib_key(r, self.name)).set(
            (root.codec, root.digest, cstats["wire_bytes"],
             cstats["dense_bytes"]),
            self.sim.now, self.name)
        yield from self.node.crdt_push_flush()

        # -- wait for the round to close, then apply the averaged outer step
        closed = yield from self._await_close(r)
        if closed is None:
            return False
        if self.name in closed:
            # shipped mass that the fleet applied: residual keeps the rest
            self.residual = {k: grad[k] - sent[k] for k in grad}
        else:
            # our contribution missed the close: defer the WHOLE delta
            self.residual = grad
        yield from self._apply_round(r, closed)
        if self.eval_batch is not None and self._eval_fn is not None:
            loss = float(self._eval_fn(self.outer_params(), self.eval_batch))
            self.round_log.append(
                {"round": r + 1, "eval_loss": loss,
                 "contributors": len(closed),
                 "wire_bytes": cstats["wire_bytes"]})
        if log is not None:
            log(f"[{self.name}] round {r} closed with {len(closed)} "
                f"contributors digest={self.outer_digest()[:12]}")
        return True

    def _await_close(self, r: int) -> Generator:
        """Block until round ``r`` has a converged closed set; write it
        ourselves once quorum + settle allow.  Event-driven via the CRDT
        watch plane, with the round timeout as the hard deadline."""
        sim = self.sim
        deadline = sim.now + self.ccfg.round_timeout
        quorum_at: Optional[float] = None
        while self.alive:
            self._wake = sim.event()    # re-arm BEFORE reading (no lost wake)
            closed = self._closed(r)
            if closed is not None:
                return closed
            members = sorted(self.node.store.orset(
                self._members_key(r)).value())
            contribs = [w for w in members
                        if self._contrib(r, w) is not None]
            need = max(1, math.ceil(self.ccfg.quorum * max(1, len(members))))
            now = sim.now
            if len(contribs) >= need and quorum_at is None:
                quorum_at = now
            settled = (quorum_at is not None
                       and now >= quorum_at + self.ccfg.settle)
            if (settled or now >= deadline) and contribs:
                if len(contribs) < need:
                    self.stats["rounds_degraded"] += 1
                # constant timestamp per round: every concurrent closer's
                # write carries ts=r, so the LWW replica-id tie-break picks
                # one deterministic winner no matter the merge order
                self.node.store.register(self._closed_key(r)).set(
                    tuple(sorted(contribs)), float(r), self.name)
                yield from self.node.crdt_push_flush()
                continue                # next loop iteration returns it
            if now >= deadline:
                self.stats["rounds_aborted"] += 1
                return None
            horizon = deadline
            if quorum_at is not None:
                horizon = min(horizon, quorum_at + self.ccfg.settle)
            yield sim.any_of([self._wake,
                              sim.timeout(max(horizon - now, 0.05))])
        return None

    # -------------------------------------------------------- applying rounds
    def _apply_round(self, r: int, closed: Tuple[str, ...]) -> Generator:
        """Fetch every contribution in ``closed``, average, Nesterov outer
        step.  Identical inputs → bit-identical outer state fleet-wide."""
        yield from self._maybe_rebase(r)
        grads = []
        for w in closed:                # sorted tuple: deterministic order
            flat = yield from self._fetch_contrib(r, w)
            grads.append(flat)
        self._pre_round[r] = (
            {k: v.copy() for k, v in self.outer_flat.items()},
            {k: v.copy() for k, v in self.outer_mom.items()})
        self._outer_step(average_flat(grads))
        self._applied[r] = closed
        self.outer_round = r + 1
        self.stats["rounds_closed"] += 1
        self._gc(r)
        return None

    def _outer_step(self, g: Dict[str, np.ndarray]) -> None:
        lr, mu = self.ccfg.outer_lr, self.ccfg.outer_momentum
        for k in sorted(g):
            m = mu * self.outer_mom[k].astype(np.float64) \
                + g[k].astype(np.float64)
            upd = g[k].astype(np.float64) + mu * m if self.ccfg.nesterov else m
            self.outer_flat[k] = (
                self.outer_flat[k].astype(np.float64) - lr * upd
            ).astype(np.float32)
            self.outer_mom[k] = m.astype(np.float32)

    def _fetch_contrib(self, r: int, worker: str) -> Generator:
        """Resolve + swarm-fetch one contribution DAG; decode to a flat
        gradient.  Pins the root for the rejoin replay window."""
        val = self._contrib(r, worker)
        deadline = self.sim.now + self.ccfg.round_timeout
        while val is None:
            # the closed set names a contribution our CRDT replica has not
            # merged yet — the push plane or anti-entropy must deliver it
            if self.sim.now >= deadline:
                raise FetchError(
                    f"round {r}: contribution record of {worker} never "
                    f"reached this replica")
            self._wake = self.sim.event()
            yield self.sim.any_of([self._wake, self.sim.timeout(1.0)])
            val = self._contrib(r, worker)
        root = CID(val[0], val[1])
        hint = self.node.infos_by_host.get(worker)
        if self.node.blockstore.peek(root) is None:
            yield from self.node.fetch_artifact(
                root, hint_providers=[hint] if hint is not None else None,
                assemble=False)
            self.stats["contribs_fetched"] += 1
        if root not in self._contrib_pins.get(r, []):
            self.node.blockstore.pin(root)
            self._contrib_pins.setdefault(r, []).append(root)
        manifest = self.node.blockstore.peek(root)
        if manifest is None or manifest_version(manifest) != 2:
            raise FetchError(f"round {r}: contribution of {worker} is not "
                             f"a v2 tree DAG")
        entries = decode_manifest_v2(manifest)[0]
        return flat_from_entries(
            [(e.name, read_dag(e.cid, self.node.blockstore.get,
                               verify=False), e.meta)
             for e in entries])

    def _maybe_rebase(self, upto: int) -> Generator:
        """Before applying round ``upto``: if any retained round's
        converged closed set differs from what we applied (we raced a
        concurrent closer and lost the LWW tie-break), rewind to the saved
        pre-round outer state and replay the authoritative sets.  This is
        what keeps optimistic application from ever forking outer state."""
        for p in sorted(self._applied):
            cur = self._closed(p)
            if cur is None or cur == self._applied[p]:
                continue
            self.stats["rebases"] += 1
            flat, mom = self._pre_round[p]
            self.outer_flat = {k: v.copy() for k, v in flat.items()}
            self.outer_mom = {k: v.copy() for k, v in mom.items()}
            for q in range(p, upto):
                authoritative = self._closed(q)
                if authoritative is None:
                    break
                grads = []
                for w in authoritative:
                    g = yield from self._fetch_contrib(q, w)
                    grads.append(g)
                self._pre_round[q] = (
                    {k: v.copy() for k, v in self.outer_flat.items()},
                    {k: v.copy() for k, v in self.outer_mom.items()})
                self._outer_step(average_flat(grads))
                self._applied[q] = authoritative
            break
        return None

    def _gc(self, r: int) -> None:
        """Drop rounds past the replay window: unpin their contribution
        DAGs, forget rebase snapshots."""
        horizon = r - self.ccfg.keep_rounds
        for old in [q for q in self._contrib_pins if q <= horizon]:
            for root in self._contrib_pins.pop(old):
                self.node.blockstore.unpin(root)
        for old in [q for q in self._applied if q <= horizon]:
            del self._applied[old]
            self._pre_round.pop(old, None)

    # --------------------------------------------------------------- rejoin
    def catch_up(self) -> Generator:
        """Replay rounds that closed while this worker was away.

        Syncs the CRDT replica with a few known peers first (a restarted
        node's push subscriptions start empty), then applies each closed
        round in sequence from the pinned/pinnable contribution DAGs —
        landing on the fleet's bit-identical outer state instead of
        forking from stale params.  Returns rounds replayed."""
        yield from self._sync_peers()
        replayed = 0
        while self.alive:
            closed = self._closed(self.outer_round)
            if closed is None:
                break
            yield from self._apply_round(self.outer_round, closed)
            self.stats["catchup_rounds"] += 1
            replayed += 1
        return replayed

    def _sync_peers(self, fanout: int = 3) -> Generator:
        peers = sorted(self.node.peers, key=lambda p: p.digest)
        for pid in peers[:fanout]:
            try:
                yield from self.node.sync_crdt_with(self.node.peers[pid])
            except (DialError, RpcError, ValueError):
                continue
        return None

    def peer_status(self, info: Any) -> Generator:
        """Ask a peer's ``CollabService`` where the fleet is (round,
        digest) — the rejoiner's view of how far behind it is."""
        stub = self.node.stub(CollabService, info)
        result = yield from stub.status(self.fleet)
        return result
