"""Pseudo-gradient compression for collaborative training rounds.

A DiLoCo-style round ships each worker's *outer delta* (``theta_round_start
- theta_after_H_inner_steps``) instead of per-step gradients.  Two lossy
stages make that delta cheap on the wire:

* **top-k sparsification** — only the ``topk_frac`` largest-magnitude
  entries of each leaf survive (per-leaf, so small layers are not starved
  by large ones); the dropped mass goes into a local *error-feedback
  residual* the caller folds into the next round's delta, so nothing is
  permanently lost, only deferred.
* **int8 block quantization of the kept values** — the PR 7 ``int8_block``
  codec applied to the dense vector of kept values (the sparse ``topk``
  entry codec in :mod:`repro.checkpoint.serial`).

Together a part costs ``k * (4 index + 1 value)`` bytes plus per-4096-block
scale/zero-point tails — ~1.6 % of the fp32 bytes at ``topk_frac=1/80``,
~6 % at the default 0.05 — against 4 bytes/element for a dense fp32
exchange.  Parts are ``(path, payload, meta)`` triples compatible with
``build_tree_dag``/``publish_tree_artifact``, so a contribution is an
ordinary content DAG: identical bytes hash to identical CIDs, fetchers
dequantize through :func:`repro.checkpoint.serial.leaf_from_part`, and the
delta plane (bitswap scheduling, pins, provider scoring) needs no new code.

Everything here is plain numpy on float32 (float64 accumulation for the
averages): every worker that decodes the same contribution set computes the
bit-identical average, which is what lets the outer step run replicated
with no coordinator.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.serial import (_sorted_leaves, encode_leaf_meta,
                                     encode_sparse_leaf, leaf_from_part)

__all__ = ["DEFAULT_TOPK_FRAC", "SPARSE_MIN_SIZE", "tree_to_flat",
           "pseudo_gradient", "topk_select", "compress_pseudograd",
           "flat_from_entries", "average_flat", "flat_digest"]

#: default fraction of entries kept per leaf
DEFAULT_TOPK_FRAC = 0.05

#: leaves smaller than this ship dense fp32 — the 4-byte index per kept
#: entry would cost more than it saves
SPARSE_MIN_SIZE = 256


def tree_to_flat(params: Any) -> Dict[str, np.ndarray]:
    """``{path: float32 ndarray}`` view of a pytree, sorted-path keyed
    (the :func:`params_to_parts` naming, so flats and parts interconvert)."""
    return {name: np.asarray(arr, dtype=np.float32)
            for name, arr in _sorted_leaves(params)}


def pseudo_gradient(start: Dict[str, np.ndarray],
                    end: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Outer delta ``start - end`` per leaf: the direction the inner
    optimizer moved, expressed as a gradient for the outer optimizer
    (which *subtracts* it)."""
    return {k: (start[k].astype(np.float64)
                - end[k].astype(np.float64)).astype(np.float32)
            for k in start}


def topk_select(arr: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """Indices (sorted ascending) and values of the ``k``
    largest-magnitude entries of ``arr`` flattened.  Deterministic for a
    given input, which is all replicated decoding needs — every peer sees
    the encoded bytes, not this selection."""
    flat = arr.reshape(-1)
    if k >= flat.size:
        idx = np.arange(flat.size, dtype=np.uint32)
        return idx, flat.astype(np.float32)
    mag = np.abs(flat)
    idx = np.argpartition(-mag, k - 1)[:k]
    idx = np.sort(idx).astype(np.uint32)
    return idx, flat[idx].astype(np.float32)


def compress_pseudograd(grad: Dict[str, np.ndarray],
                        frac: float = DEFAULT_TOPK_FRAC,
                        quant: Optional[str] = "int8_block",
                        ) -> Tuple[List[Tuple[str, bytes, bytes]],
                                   Dict[str, np.ndarray], Dict[str, int]]:
    """Compress a flat pseudo-gradient into content-DAG parts.

    Returns ``(parts, sent, stats)``: ``parts`` feed
    ``publish_tree_artifact``; ``sent`` is the *decoded* (post-sparsify,
    post-quantize) gradient actually on the wire — the caller keeps
    ``grad - sent`` as its error-feedback residual; ``stats`` counts
    ``dense_bytes`` (fp32 full-exchange cost) vs ``wire_bytes``."""
    if not 0.0 < frac <= 1.0:
        raise ValueError(f"topk_frac must be in (0, 1], got {frac}")
    parts: List[Tuple[str, bytes, bytes]] = []
    sent: Dict[str, np.ndarray] = {}
    dense_bytes = 0
    wire_bytes = 0
    for name in sorted(grad):
        arr = np.ascontiguousarray(grad[name], dtype=np.float32)
        dense_bytes += arr.nbytes
        if arr.size < SPARSE_MIN_SIZE:
            raw = arr.tobytes()
            meta = encode_leaf_meta("float32", arr.shape)
            parts.append((name, raw, meta))
            wire_bytes += len(raw)
            sent[name] = arr.copy()
            continue
        k = max(1, int(np.ceil(frac * arr.size)))
        idx, vals = topk_select(arr, k)
        raw, enc = encode_sparse_leaf(
            idx, vals, arr.shape,
            vals="int8_block" if quant == "int8_block" else None)
        meta = encode_leaf_meta("float32", arr.shape, enc)
        parts.append((name, raw, meta))
        wire_bytes += len(raw)
        # decode our own payload: `sent` must equal what receivers apply,
        # or the error-feedback residual silently drifts off the fleet
        sent[name] = leaf_from_part(raw, meta)
    return parts, sent, {"dense_bytes": dense_bytes, "wire_bytes": wire_bytes}


def flat_from_entries(pairs: List[Tuple[str, bytes, bytes]],
                      ) -> Dict[str, np.ndarray]:
    """Decode fetched ``(name, payload, meta)`` entries back into a flat
    gradient (peer-supplied bytes; malformed input raises ``ValueError``)."""
    return {name: leaf_from_part(raw, meta) for name, raw, meta in pairs}


def average_flat(grads: List[Dict[str, np.ndarray]],
                 ) -> Dict[str, np.ndarray]:
    """Elementwise mean over contributor gradients.  float64 accumulation
    in the caller-given (sorted-set) order, downcast once — replicas that
    average the same contribution set get bit-identical results."""
    if not grads:
        raise ValueError("cannot average zero contributions")
    out: Dict[str, np.ndarray] = {}
    for k in sorted(grads[0]):
        acc = np.zeros(grads[0][k].shape, np.float64)
        for g in grads:
            acc += g[k].astype(np.float64)
        out[k] = (acc / len(grads)).astype(np.float32)
    return out


def flat_digest(flat: Dict[str, np.ndarray]) -> str:
    """Order-insensitive content digest of a flat tree — replicas compare
    outer states without shipping them."""
    import hashlib

    h = hashlib.sha256()
    for k in sorted(flat):
        h.update(k.encode("utf-8"))
        h.update(np.ascontiguousarray(flat[k], dtype=np.float32).tobytes())
    return h.hexdigest()
