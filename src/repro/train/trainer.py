"""Training loops.

``Trainer`` — plain local training (used by examples/tests).

``LatticaSyncTrainer`` — the paper's RL-pipeline / collaborative-training
scenario: a *publisher* cluster trains and periodically pushes model
versions into the mesh (content-addressed chunks + CRDT registry update);
*subscriber* clusters watch the pubsub topic / CRDT register and swarm-fetch
new versions.  No coordinator exists anywhere: discovery is the DHT,
consistency is the CRDT store, and transport survives NATs.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, Generator, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.lattica_ckpt import (CheckpointRegistry,
                                           CheckpointService,
                                           fetch_checkpoint,
                                           publish_checkpoint,
                                           serve_checkpoints)
from repro.core.dht import PeerInfo
from repro.core.cid import CID, ChunkSpec
from repro.core.node import LatticaNode
from repro.models.config import ModelConfig

from .step import TrainState, make_train_step


class Trainer:
    def __init__(self, cfg: ModelConfig, state: TrainState,
                 schedule: Callable, data: Iterator[Dict[str, np.ndarray]],
                 jit: bool = True):
        self.cfg = cfg
        self.state = state
        self.data = data
        step = make_train_step(cfg, schedule)
        self.step_fn = jax.jit(step) if jit else step
        self.history: List[Dict[str, float]] = []

    def run(self, n_steps: int, log_every: int = 10,
            log: Optional[Callable[[str], None]] = print) -> List[Dict[str, float]]:
        for i in range(n_steps):
            batch = next(self.data)
            self.state, metrics = self.step_fn(self.state, batch)
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = i
            self.history.append(rec)
            if log is not None and (i % log_every == 0 or i == n_steps - 1):
                log(f"step {i:5d}  loss={rec['loss']:.4f}  "
                    f"lr={rec['lr']:.2e}  gnorm={rec['grad_norm']:.2f}")
        return self.history


class LatticaSyncTrainer(Trainer):
    """Trainer that publishes model versions into a Lattica mesh.

    The simulation clock advances only inside mesh operations; jax compute
    is charged to the node's CPU via an estimated step time.
    """

    def __init__(self, cfg: ModelConfig, state: TrainState,
                 schedule: Callable, data: Iterator[Dict[str, np.ndarray]],
                 node: LatticaNode, fleet: str,
                 publish_every: int = 50, step_seconds: float = 0.5,
                 chunk_spec: Optional[ChunkSpec] = None):
        super().__init__(cfg, state, schedule, data)
        self.node = node
        self.fleet = fleet
        self.publish_every = publish_every
        self.step_seconds = step_seconds
        #: chunking strategy for published versions; every publish uses the
        #: same spec so leaf boundaries (and unchanged-content CIDs)
        #: reproduce across versions
        self.chunk_spec = chunk_spec
        self.published: List[CID] = []
        serve_checkpoints(node)   # subscribers may resolve 'latest' directly

    def run_mesh(self, n_steps: int,
                 log: Optional[Callable[[str], None]] = print) -> Generator:
        """A sim-process: train; every ``publish_every`` steps, publish.
        Each publish passes the previous version as ``base`` so the
        announcement carries delta stats (new vs reused blocks/bytes)."""
        for i in range(n_steps):
            batch = next(self.data)
            self.state, metrics = self.step_fn(self.state, batch)
            rec = {k: float(v) for k, v in metrics.items()}
            rec["step"] = i
            self.history.append(rec)
            yield self.step_seconds                    # wall-clock of the step
            if (i + 1) % self.publish_every == 0 or i == n_steps - 1:
                base = self.published[-1] if self.published else None
                root = yield from publish_checkpoint(
                    self.node, self.state.params, i + 1, self.fleet,
                    base=base, spec=self.chunk_spec)
                self.published.append(root)
                yield from self._gossip_registry()
                if log is not None:
                    log(f"[{self.node.host.name}] published step {i+1} "
                        f"loss={rec['loss']:.4f} root={root}")
        return self.published

    def _gossip_registry(self, fanout: int = 2) -> Generator:
        """Propagate the fresh registry entry right after a publish.

        Primary path: flush the delta push plane — the mutations from
        ``publish_checkpoint`` go out as per-key delta documents on the
        ``crdt/<ns>`` topics, so connected subscribers' ``watch`` callbacks
        fire within one gossip round.  Fallback: a couple of direct
        anti-entropy rounds with random peers for anyone the flood missed
        (NAT'd stragglers, empty meshes) — each of those now moves only
        per-key deltas, not the whole serialized store."""
        yield from self.node.crdt_push_flush()
        sim = self.node.sim
        peers = sorted(self.node.peers, key=lambda p: p.digest)
        if not peers:
            return None
        for pid in sim.rng.sample(peers, min(fanout, len(peers))):
            try:
                yield from self.node.sync_crdt_with(self.node.peers[pid])
            except Exception:        # noqa: BLE001 — unreachable peer
                continue
        return None


class ModelSubscriber:
    """Inference-cluster side: follow a fleet's model versions.

    Registry freshness is event-driven: the subscriber *watches*
    ``ckpt/<fleet>`` through the node's CRDT delta push plane, so a
    publisher's registry write lands here one gossip round after the
    publish and wakes the follow loop immediately — no anti-entropy
    lottery.  With ``resolve_from`` (the publisher's PeerInfo), each poll
    additionally asks that peer's ``CheckpointService`` for the fleet's
    latest version as a fallback — convergence survives missed floods and
    partitions (an unreachable peer just falls back to local knowledge).
    """

    def __init__(self, node: LatticaNode, cfg: ModelConfig, fleet: str,
                 like: Any = None, resolve_from: Optional[PeerInfo] = None):
        self.node = node
        self.cfg = cfg
        self.fleet = fleet
        self.like = like
        self.resolve_from = resolve_from
        self.registry = CheckpointRegistry(node, fleet)
        self.current_step = -1
        self.params: Any = None
        self.fetch_log: List[Dict[str, float]] = []
        self._announced: List[Any] = []
        self._wake = node.sim.event()
        node.pubsub.subscribe(self.registry.topic, self._on_announce)
        # pushed registry deltas (and merged-in anti-entropy state) wake
        # the follow loop the moment the local replica learns of a change
        node.watch_crdt(f"ckpt/{fleet}", self._on_registry_change)

    def _on_announce(self, topic: str, data: Any, frm: Any) -> None:
        self._announced.append(data)
        self._wakeup()

    def _on_registry_change(self, key: str, value: Any, origin: str) -> None:
        if origin == "remote":      # our own record_fetched must not self-wake
            self._wakeup()

    def _wakeup(self) -> None:
        if not self._wake.triggered:
            self._wake.succeed()

    def _best_known(self) -> Any:
        """Newest version from the CRDT register AND live announcements;
        returns ((step, root) or None, publisher PeerInfo or None)."""
        from repro.checkpoint.lattica_ckpt import safe_meta_loads

        best = self.registry.latest()
        publisher: Optional[PeerInfo] = None
        for d in self._announced:
            if not (isinstance(d, tuple) and d and d[0] == "artifact"):
                continue
            try:
                # announcement meta is peer-supplied: restricted unpickle
                meta = safe_meta_loads(d[3])
                step = meta["step"]
            except Exception:        # noqa: BLE001 — malformed announcement
                continue
            if best is None or step > best[0]:
                best = (step, d[1])
                publisher = meta.get("publisher")
        self._announced.clear()
        return best, publisher

    def _resolve_remote(self) -> Generator:
        """Ask the publisher's CheckpointService for its latest (step, root);
        None when unset or unreachable."""
        if self.resolve_from is None:
            return None
        try:
            stub = self.node.stub(CheckpointService, self.resolve_from)
            return (yield from stub.latest(self.fleet))
        except Exception:            # noqa: BLE001 — partition/dead peer
            return None

    def poll_and_fetch(self) -> Generator:
        """Fetch the newest known version (CheckpointService resolution,
        CRDT register, or pubsub announcement) if newer than ours.  Returns
        the step, or None."""
        latest, publisher = self._best_known()
        remote = yield from self._resolve_remote()
        if remote is not None and (latest is None or remote[0] > latest[0]):
            latest = remote
            publisher = self.resolve_from
        if latest is None:
            return None
        step, root = latest
        if step <= self.current_step:
            return None
        t0 = self.node.sim.now
        hints = [publisher] if publisher is not None else None
        params = yield from fetch_checkpoint(self.node, root, self.like,
                                             hint_providers=hints,
                                             fleet=self.fleet)
        self.fetch_log.append({
            "step": step, "t_fetch": self.node.sim.now - t0,
            "bytes": self.node.bitswap.stats["bytes_fetched"]})
        self.current_step = step
        self.params = params
        # note the version in our ORSet replica (never the LWW pointer —
        # see CheckpointRegistry.record_fetched)
        self.registry.record_fetched(step, root)
        if publisher is not None:
            # one direct anti-entropy round with the publisher pins the LWW
            # register to what we just fetched — registry convergence no
            # longer waits on random gossip reaching this replica
            try:
                yield from self.node.sync_crdt_with(publisher)
            except Exception:        # noqa: BLE001 — partition/dead peer
                pass
        return step

    def follow(self, interval: float = 5.0, until_step: int = 10**9) -> Generator:
        """Background process: fetch new versions as they appear.

        Event-driven: a pushed registry delta (or a pubsub announcement)
        wakes the loop immediately; the ``interval`` poll is the fallback
        when no push arrives (partitions, missed floods), resolving through
        the publisher's ``CheckpointService`` when ``resolve_from`` is set.
        The old random-peer anti-entropy round per tick is gone — the push
        plane delivers registry changes in one gossip round instead."""
        sim = self.node.sim
        while self.current_step < until_step:
            yield sim.any_of([self._wake, sim.timeout(interval)])
            # always a fresh event: re-arming only on trigger would leave
            # the timeout path accumulating stale any_of waiters on the
            # same Event forever; re-arming *before* the poll means a push
            # arriving mid-fetch wakes the next iteration immediately
            self._wake = sim.event()
            try:
                yield from self.poll_and_fetch()
            except Exception:           # noqa: BLE001 — a partition or a
                continue                # dead provider must not kill the loop
        return self.current_step
