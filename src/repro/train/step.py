"""Train step: loss → grads → clip → AdamW, as one jit/pjit-able function."""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import ops_for
from repro.models.config import ModelConfig
from repro.optim import (AdamWState, adamw_init, adamw_update,
                         clip_by_global_norm)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState


def train_state_init(cfg: ModelConfig, key: jax.Array,
                     dtype: Any = jnp.float32) -> TrainState:
    ops = ops_for(cfg)
    params = ops.init(cfg, key, dtype)
    return TrainState(params=params, opt=adamw_init(params))


def _micro_split(batch: Dict[str, jax.Array], k: int) -> Dict[str, jax.Array]:
    """Reshape each leaf's batch dim B -> (k, B/k) for microbatch scan."""
    out = {}
    for name, v in batch.items():
        if name == "positions3":                    # (3, B, S)
            b = v.shape[1]
            out[name] = v.reshape(3, k, b // k, *v.shape[2:]).swapaxes(0, 1)
        else:
            b = v.shape[0]
            out[name] = v.reshape(k, b // k, *v.shape[1:])
    return out


def make_train_step(cfg: ModelConfig, schedule: Callable,
                    max_grad_norm: float = 1.0,
                    weight_decay: float = 0.1,
                    microbatches: int = 1) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    ``microbatches > 1`` runs gradient accumulation over batch slices —
    the per-layer activation stash shrinks by that factor while the global
    batch (and the optimizer math) stays identical.
    """
    ops = ops_for(cfg)

    def grads_of(params: Any, batch: Dict[str, jax.Array]):
        return jax.value_and_grad(ops.loss_fn, has_aux=True)(
            params, cfg, batch)

    def step(state: TrainState, batch: Dict[str, jax.Array]
             ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        if microbatches > 1:
            micro = _micro_split(batch, microbatches)

            def body(acc, mb):
                (loss, metrics), g = grads_of(state.params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / microbatches,
                    acc, g)
                return acc, (loss, metrics)

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            grads, (losses, ms) = jax.lax.scan(body, acc0, micro)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, ms)
        else:
            (loss, metrics), grads = grads_of(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = schedule(state.opt.step)
        params, opt = adamw_update(state.params, grads, state.opt, lr,
                                   weight_decay=weight_decay)
        out = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        out.update(metrics)
        return TrainState(params, opt), out

    return step
