from .step import TrainState, make_train_step, train_state_init
from .trainer import Trainer, LatticaSyncTrainer

__all__ = ["TrainState", "make_train_step", "train_state_init",
           "Trainer", "LatticaSyncTrainer"]
