"""AdamW on pytrees (no optax dependency; shards like the params)."""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    mu: Any                  # first moment, like params
    nu: Any                  # second moment, like params


def adamw_init(params: Any, dtype: Any = jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def adamw_update(params: Any, grads: Any, state: AdamWState,
                 lr: jax.Array, *, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 ) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v)
