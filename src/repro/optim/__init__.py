from .adamw import AdamWState, adamw_init, adamw_update
from .schedules import constant_schedule, cosine_schedule, wsd_schedule
from .clip import global_norm, clip_by_global_norm

__all__ = ["AdamWState", "adamw_init", "adamw_update",
           "constant_schedule", "cosine_schedule", "wsd_schedule",
           "global_norm", "clip_by_global_norm"]
