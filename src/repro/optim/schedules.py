"""LR schedules.  WSD (warmup-stable-decay) is MiniCPM's schedule
[arXiv:2404.06395] — included because minicpm-2b is an assigned arch."""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp


def constant_schedule(lr: float) -> Callable:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, warmup: int, total: int,
                    final_frac: float = 0.1) -> Callable:
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac * lr + (1 - final_frac) * lr * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return fn


def wsd_schedule(lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.01) -> Callable:
    """Warmup -> Stable (constant) -> Decay (exponential-ish linear-log).

    MiniCPM decays to ``final_frac``·lr over the last ``decay`` steps.
    """
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = lr * step / max(warmup, 1)
        in_decay = jnp.clip((step - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = lr * jnp.exp(jnp.log(final_frac) * in_decay)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < warmup + stable, lr, dec))
        return out
    return fn
