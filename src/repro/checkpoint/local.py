"""Plain local-disk checkpointing (same canonical blob as the mesh path)."""

from __future__ import annotations

import os
from typing import Any

from .serial import params_from_bytes, params_to_bytes


def save_local(path: str, params: Any) -> int:
    data = params_to_bytes(params)
    tmp = path + ".tmp"
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)
    return len(data)


def load_local(path: str, like: Any = None) -> Any:
    with open(path, "rb") as f:
        data = f.read()
    return params_from_bytes(data, like)
