"""Plain local-disk checkpointing (same canonical blob as the mesh path).

Two on-disk layouts:

* legacy flat (default): the canonical ``LCK*`` blob written verbatim —
  one file, zero dependencies, byte-identical to previous releases.
* chunked (``spec=``): the blob is cut by the given :class:`ChunkSpec`
  into content-addressed blocks stored under ``<path>.blocks/``; the
  checkpoint file itself is a tiny root manifest.  Blocks already present
  from an earlier save are *not rewritten* — with a ``cdc`` spec, boundary
  re-synchronization means a byte-shifting edit (a resized layer, a new
  optimizer slot) re-saves only the chunks that actually changed, exactly
  like the mesh publish path reuses sub-DAG CIDs.
"""

from __future__ import annotations

import os
from typing import Any, Optional

from repro.core.cid import CID, ChunkSpec, build_dag, read_dag

from .serial import params_from_bytes, params_to_bytes

#: magic of the chunked root-manifest file: points into ``<path>.blocks/``
_MAGIC_CHUNKED = b"LCKD"


def _block_path(blocks_dir: str, cid: CID) -> str:
    return os.path.join(blocks_dir, f"{cid.codec:02x}{cid.digest.hex()}")


def save_local(path: str, params: Any, quant: Optional[str] = None,
               spec: Optional[ChunkSpec] = None) -> int:
    """Write a checkpoint; returns bytes written to disk *this save*.

    With ``spec`` the blob lands as content-addressed blocks (see module
    docstring) and the return value counts only the new blocks plus the
    manifest — a near-duplicate save of a slightly-edited tree costs a
    fraction of the blob, the dedup signal tests assert on."""
    data = params_to_bytes(params, quant=quant)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    if spec is None:
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, path)
        return len(data)
    dag = build_dag(data, spec=spec)
    blocks_dir = path + ".blocks"
    os.makedirs(blocks_dir, exist_ok=True)
    written = 0
    for cid, blk in dag.blocks.items():
        dst = _block_path(blocks_dir, cid)
        if os.path.exists(dst):       # content-addressed: present == correct
            continue
        btmp = dst + ".tmp"
        with open(btmp, "wb") as f:
            f.write(blk)
        os.replace(btmp, dst)
        written += len(blk)
    root = _MAGIC_CHUNKED + bytes([dag.root.codec]) + dag.root.digest
    with open(tmp, "wb") as f:
        f.write(root)
    os.replace(tmp, path)
    return written + len(root)


def load_local(path: str, like: Any = None) -> Any:
    with open(path, "rb") as f:
        data = f.read()
    if data[:4] == _MAGIC_CHUNKED:
        root = CID(data[4], data[5:])
        blocks_dir = path + ".blocks"

        def get(cid: CID) -> bytes:
            with open(_block_path(blocks_dir, cid), "rb") as bf:
                return bf.read()

        data = read_dag(root, get)
    return params_from_bytes(data, like)
