"""Canonical pytree <-> bytes serialization for content addressing.

Deterministic layout (sorted key-paths) so identical params always produce
identical CIDs — the property that makes checkpoints deduplicate across the
mesh and lets unchanged chunks skip re-transfer between model versions.

Two granularities:

* ``params_to_bytes`` / ``params_from_bytes`` — the whole tree as one flat
  blob (local checkpoints, v1 flat-manifest artifacts).
* ``params_to_parts`` / ``params_from_parts`` — one ``(path, raw-bytes,
  dtype/shape-meta)`` part per leaf, feeding the hierarchical (v2) manifest
  path: each tensor becomes its own sub-DAG, so a new version's root
  manifest reuses the sub-root CIDs of unchanged tensors verbatim.

Everything decoded here can arrive off the swarm, i.e. from untrusted
peers, so the wire formats are deliberately dumb: JSON for the index and
per-leaf dtype/shape meta, raw C-order bytes for tensor data.  Earlier
releases pickled the index/meta; those artifacts still decode, but only
through a restricted unpickler that refuses every class/global lookup —
the legacy payloads are pure primitives, and blocking ``find_class``
closes the arbitrary-code-execution path ``pickle.loads`` would open.
"""

from __future__ import annotations

import json
import struct
from typing import Any, Dict, List, Sequence, Tuple

import jax
import numpy as np

from repro.core.safepickle import restricted_loads

_MAGIC = b"LCK1"    # legacy: pickled index (decoded via the safe shim only)
_MAGIC2 = b"LCK2"   # current: JSON index


def _safe_pickle_loads(raw: bytes) -> Any:
    """Decode a legacy pickled index/meta: primitives only — no allowlist,
    so any global resolution (the ACE hook) raises ``ValueError``."""
    return restricted_loads(raw)


def _checked_dtype(dtype: Any) -> np.dtype:
    """Validate an untrusted dtype string.  Object/void dtypes would make
    ``np.frombuffer`` reinterpret attacker bytes as Python object pointers —
    that is memory corruption, not deserialization."""
    if not isinstance(dtype, str):
        raise ValueError(f"dtype must be a string, got {type(dtype).__name__}")
    try:
        dt = np.dtype(dtype)
    except TypeError as e:
        raise ValueError(f"bad dtype {dtype!r}") from e
    if dt.hasobject or dt.kind in ("O", "V"):
        raise ValueError(f"refusing unsafe dtype {dtype!r}")
    return dt


def _checked_shape(shape: Any) -> Tuple[int, ...]:
    if not isinstance(shape, (list, tuple)) or not all(
            isinstance(s, int) and s >= 0 for s in shape):
        raise ValueError(f"bad shape {shape!r}")
    return tuple(shape)


def _path_str(path: Tuple) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def params_to_bytes(params: Any) -> bytes:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    entries = sorted(
        ((_path_str(path), np.asarray(leaf)) for path, leaf in leaves_with_paths),
        key=lambda kv: kv[0])
    index: List[Tuple[str, str, List[int], int]] = []
    blobs: List[bytes] = []
    off = 0
    for name, arr in entries:
        raw = np.ascontiguousarray(arr).tobytes()
        index.append((name, str(arr.dtype), list(arr.shape), off))
        blobs.append(raw)
        off += len(raw)
    head = json.dumps(index, separators=(",", ":")).encode("utf-8")
    return b"".join([_MAGIC2, struct.pack(">I", len(head)), head] + blobs)


def encode_leaf_meta(dtype: str, shape: Sequence[int]) -> bytes:
    """Safe fixed encoding of a tensor's ``(dtype, shape)`` for v2 manifest
    entry meta: compact JSON, deterministic, and decodable without pickle."""
    return json.dumps({"dtype": dtype, "shape": list(shape)},
                      separators=(",", ":"), sort_keys=True).encode("utf-8")


def decode_leaf_meta(meta: bytes) -> Tuple[np.dtype, Tuple[int, ...]]:
    """Decode entry meta from either the JSON encoding or (shim) a legacy
    primitive-only pickle; raises ``ValueError`` on anything else."""
    if meta[:1] == b"{":
        try:
            obj = json.loads(meta.decode("utf-8"))
            dtype, shape = obj["dtype"], obj["shape"]
        except (UnicodeDecodeError, ValueError, KeyError, TypeError) as e:
            raise ValueError(f"bad leaf meta {meta!r}") from e
    else:
        decoded = _safe_pickle_loads(meta)
        if not (isinstance(decoded, (tuple, list)) and len(decoded) == 2):
            raise ValueError(f"bad legacy leaf meta {meta!r}")
        dtype, shape = decoded[0], list(decoded[1])
    return _checked_dtype(dtype), _checked_shape(shape)


def params_to_parts(params: Any) -> List[Tuple[str, bytes, bytes]]:
    """Per-leaf parts ``(path, raw bytes, encoded (dtype, shape))``, sorted
    by path — the unit of structural sharing for delta-friendly DAGs."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    entries = sorted(
        ((_path_str(path), np.asarray(leaf)) for path, leaf in leaves_with_paths),
        key=lambda kv: kv[0])
    return [(name, np.ascontiguousarray(arr).tobytes(),
             encode_leaf_meta(str(arr.dtype), arr.shape))
            for name, arr in entries]


def leaf_from_part(raw: bytes, meta: bytes) -> np.ndarray:
    """Decode one part's bytes back into an ndarray using its dtype/shape
    meta (the v2 manifest entry's ``meta`` field).  ``meta`` and ``raw`` are
    both peer-supplied; malformed input raises ``ValueError``."""
    dt, shape = decode_leaf_meta(meta)
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    return np.frombuffer(raw, dtype=dt, count=count).reshape(shape)


def params_from_parts(flat: Dict[str, np.ndarray], like: Any = None) -> Any:
    """Restore a ``{path: ndarray}`` mapping into the structure of ``like``
    (or return the mapping itself when ``like`` is None)."""
    if like is None:
        return flat
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_and_leaves[0]:
        name = _path_str(path)
        arr = flat[name]
        assert tuple(arr.shape) == tuple(np.shape(leaf)), (name, arr.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths_and_leaves[1], leaves)


def _decode_index(data: bytes) -> Tuple[List, int]:
    """Index + payload offset from a checkpoint blob of either magic."""
    if len(data) < 8:
        raise ValueError("truncated checkpoint blob")
    magic = data[:4]
    (hlen,) = struct.unpack(">I", data[4:8])
    if 8 + hlen > len(data):
        raise ValueError("truncated checkpoint index")
    head = data[8:8 + hlen]
    if magic == _MAGIC2:
        try:
            index = json.loads(head.decode("utf-8"))
        except (UnicodeDecodeError, ValueError) as e:
            raise ValueError(f"bad checkpoint index: {e}") from e
    elif magic == _MAGIC:
        index = _safe_pickle_loads(head)     # legacy shim, primitives only
    else:
        raise ValueError("not a checkpoint blob")
    if not isinstance(index, list):
        raise ValueError("checkpoint index is not a list")
    return index, 8 + hlen


def params_from_bytes(data: bytes, like: Any = None) -> Any:
    index, base = _decode_index(data)
    flat: Dict[str, np.ndarray] = {}
    for entry in index:
        if not (isinstance(entry, (list, tuple)) and len(entry) == 4):
            raise ValueError(f"bad checkpoint index entry {entry!r}")
        name, dtype, shape, off = entry
        if not isinstance(name, str) or not isinstance(off, int) or off < 0:
            raise ValueError(f"bad checkpoint index entry {entry!r}")
        dt = _checked_dtype(dtype)
        shp = _checked_shape(shape)
        arr = np.frombuffer(
            data, dtype=dt, offset=base + off,
            count=int(np.prod(shp, dtype=np.int64)) if shp else 1,
        ).reshape(shp)
        flat[name] = arr
    return params_from_parts(flat, like)
