"""Canonical pytree <-> bytes serialization for content addressing.

Deterministic layout (sorted key-paths) so identical params always produce
identical CIDs — the property that makes checkpoints deduplicate across the
mesh and lets unchanged chunks skip re-transfer between model versions.

Two granularities:

* ``params_to_bytes`` / ``params_from_bytes`` — the whole tree as one flat
  blob (local checkpoints, v1 flat-manifest artifacts).
* ``params_to_parts`` / ``params_from_parts`` — one ``(path, raw-bytes,
  dtype/shape-meta)`` part per leaf, feeding the hierarchical (v2) manifest
  path: each tensor becomes its own sub-DAG, so a new version's root
  manifest reuses the sub-root CIDs of unchanged tensors verbatim.
"""

from __future__ import annotations

import pickle
import struct
from typing import Any, Dict, List, Tuple

import jax
import numpy as np

_MAGIC = b"LCK1"


def _path_str(path: Tuple) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def params_to_bytes(params: Any) -> bytes:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    entries = sorted(
        ((_path_str(path), np.asarray(leaf)) for path, leaf in leaves_with_paths),
        key=lambda kv: kv[0])
    index: List[Tuple[str, str, Tuple[int, ...], int]] = []
    blobs: List[bytes] = []
    off = 0
    for name, arr in entries:
        raw = np.ascontiguousarray(arr).tobytes()
        index.append((name, str(arr.dtype), tuple(arr.shape), off))
        blobs.append(raw)
        off += len(raw)
    head = pickle.dumps(index)
    return b"".join([_MAGIC, struct.pack(">I", len(head)), head] + blobs)


def params_to_parts(params: Any) -> List[Tuple[str, bytes, bytes]]:
    """Per-leaf parts ``(path, raw bytes, pickled (dtype, shape))``, sorted
    by path — the unit of structural sharing for delta-friendly DAGs."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    entries = sorted(
        ((_path_str(path), np.asarray(leaf)) for path, leaf in leaves_with_paths),
        key=lambda kv: kv[0])
    return [(name, np.ascontiguousarray(arr).tobytes(),
             pickle.dumps((str(arr.dtype), tuple(arr.shape))))
            for name, arr in entries]


def leaf_from_part(raw: bytes, meta: bytes) -> np.ndarray:
    """Decode one part's bytes back into an ndarray using its dtype/shape
    meta (the v2 manifest entry's ``meta`` field)."""
    dtype, shape = pickle.loads(meta)
    count = int(np.prod(shape, dtype=np.int64)) if shape else 1
    return np.frombuffer(raw, dtype=np.dtype(dtype), count=count).reshape(shape)


def params_from_parts(flat: Dict[str, np.ndarray], like: Any = None) -> Any:
    """Restore a ``{path: ndarray}`` mapping into the structure of ``like``
    (or return the mapping itself when ``like`` is None)."""
    if like is None:
        return flat
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_and_leaves[0]:
        name = _path_str(path)
        arr = flat[name]
        assert tuple(arr.shape) == tuple(np.shape(leaf)), (name, arr.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths_and_leaves[1], leaves)


def params_from_bytes(data: bytes, like: Any = None) -> Any:
    assert data[:4] == _MAGIC, "not a checkpoint blob"
    (hlen,) = struct.unpack(">I", data[4:8])
    index = pickle.loads(data[8:8 + hlen])
    base = 8 + hlen
    flat: Dict[str, np.ndarray] = {}
    for name, dtype, shape, off in index:
        arr = np.frombuffer(
            data, dtype=np.dtype(dtype), offset=base + off,
            count=int(np.prod(shape, dtype=np.int64)) if shape else 1,
        ).reshape(shape)
        flat[name] = arr
    return params_from_parts(flat, like)
