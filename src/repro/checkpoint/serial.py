"""Canonical pytree <-> bytes serialization for content addressing.

Deterministic layout (sorted key-paths) so identical params always produce
identical CIDs — the property that makes checkpoints deduplicate across the
mesh and lets unchanged chunks skip re-transfer between model versions.

Two granularities:

* ``params_to_bytes`` / ``params_from_bytes`` — the whole tree as one flat
  blob (local checkpoints, v1 flat-manifest artifacts).
* ``params_to_parts`` / ``params_from_parts`` — one ``(path, raw-bytes,
  dtype/shape-meta)`` part per leaf, feeding the hierarchical (v2) manifest
  path: each tensor becomes its own sub-DAG, so a new version's root
  manifest reuses the sub-root CIDs of unchanged tensors verbatim.

Both granularities accept ``quant="int8_block"``: large float leaves ship
as per-block scale+zero-point int8 (``_QUANT_BLOCK`` elements per block,
asymmetric: ``x̂ = q*scale + zp``, elementwise error ≤ block_range/508) —
~4x fewer bytes on the wire for bounded error.  Quantization happens at
*encode* time only; the caller's fp32 tree is untouched, so the lossless
master stays local and re-publishing at full precision needs no state.
Quantized flat blobs carry the ``LCK3`` magic (5-field index entries);
``LCK2``/``LCK1`` blobs and unquantized parts decode exactly as before,
and ``quant=None`` output is byte-identical to pre-LCK3 releases, so
existing CIDs are stable.

Everything decoded here can arrive off the swarm, i.e. from untrusted
peers, so the wire formats are deliberately dumb: JSON for the index and
per-leaf dtype/shape meta, raw C-order bytes for tensor data.  Earlier
releases pickled the index/meta; those artifacts still decode, but only
through a restricted unpickler that refuses every class/global lookup —
the legacy payloads are pure primitives, and blocking ``find_class``
closes the arbitrary-code-execution path ``pickle.loads`` would open.
"""

from __future__ import annotations

import json
import struct
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core.safepickle import restricted_loads

_MAGIC = b"LCK1"    # legacy: pickled index (decoded via the safe shim only)
_MAGIC2 = b"LCK2"   # current: JSON index
_MAGIC3 = b"LCK3"   # JSON index with per-entry codec field (quantized blobs)

_QUANT_BLOCK = 4096       # elements per int8_block quantization group
_QUANT_MIN_SIZE = 1024    # leaves smaller than this ship unquantized

_QUANT_MODES = (None, "int8_block")


def _safe_pickle_loads(raw: bytes) -> Any:
    """Decode a legacy pickled index/meta: primitives only — no allowlist,
    so any global resolution (the ACE hook) raises ``ValueError``."""
    return restricted_loads(raw)


def _checked_dtype(dtype: Any) -> np.dtype:
    """Validate an untrusted dtype string.  Object/void dtypes would make
    ``np.frombuffer`` reinterpret attacker bytes as Python object pointers —
    that is memory corruption, not deserialization."""
    if not isinstance(dtype, str):
        raise ValueError(f"dtype must be a string, got {type(dtype).__name__}")
    try:
        dt = np.dtype(dtype)
    except TypeError as e:
        raise ValueError(f"bad dtype {dtype!r}") from e
    if dt.hasobject or dt.kind in ("O", "V"):
        raise ValueError(f"refusing unsafe dtype {dtype!r}")
    return dt


def _checked_shape(shape: Any) -> Tuple[int, ...]:
    if not isinstance(shape, (list, tuple)) or not all(
            isinstance(s, int) and s >= 0 for s in shape):
        raise ValueError(f"bad shape {shape!r}")
    return tuple(shape)


def _path_str(path: Tuple) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _quant_blocks(n: int, block: int) -> int:
    return -(-n // block)


def _quantizable(arr: np.ndarray) -> bool:
    return arr.dtype.kind == "f" and arr.size >= _QUANT_MIN_SIZE


def _quant_int8_block(arr: np.ndarray, block: int = _QUANT_BLOCK) -> bytes:
    """Asymmetric per-block int8: payload = int8 values ‖ f32 scales ‖ f32
    zero-points.  ``x̂ = q*scale + zp`` with |x̂-x| ≤ scale/2 =
    block_range/508 elementwise."""
    flat = np.ascontiguousarray(arr, dtype=np.float32).reshape(-1)
    n = flat.size
    nb = _quant_blocks(n, block)
    padded = np.zeros(nb * block, np.float32)
    padded[:n] = flat
    blocks = padded.reshape(nb, block)
    mx = blocks.max(axis=1)
    mn = blocks.min(axis=1)
    zp = ((mx + mn) * 0.5).astype(np.float32)
    scale = np.where(mx > mn, (mx - mn) / 254.0, 1.0).astype(np.float32)
    q = np.clip(np.rint((blocks - zp[:, None]) / scale[:, None]),
                -127, 127).astype(np.int8)
    return q.reshape(-1)[:n].tobytes() + scale.tobytes() + zp.tobytes()


def _dequant_int8_block(raw: bytes, shape: Tuple[int, ...],
                        block: int) -> np.ndarray:
    """Inverse of :func:`_quant_int8_block` (raw is peer-supplied)."""
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    if not isinstance(block, int) or block <= 0:
        raise ValueError(f"bad quant block {block!r}")
    nb = _quant_blocks(n, block)
    if len(raw) != n + 8 * nb:
        raise ValueError(f"bad int8_block payload: {len(raw)} bytes for "
                         f"{n} values in {nb} blocks")
    q = np.frombuffer(raw, np.int8, count=n)
    scale = np.frombuffer(raw, np.float32, count=nb, offset=n)
    zp = np.frombuffer(raw, np.float32, count=nb, offset=n + 4 * nb)
    padded = np.zeros(nb * block, np.float32)
    padded[:n] = q
    out = padded.reshape(nb, block) * scale[:, None] + zp[:, None]
    return out.reshape(-1)[:n].reshape(shape)


def encode_sparse_leaf(indices: np.ndarray, values: np.ndarray,
                       shape: Tuple[int, ...], vals: Optional[str] = None,
                       ) -> Tuple[bytes, Dict[str, Any]]:
    """Encode a top-k sparse view of a leaf as an LCK3 part payload.

    Payload layout: ``uint32 flat-indices[k]`` ‖ value payload, where the
    value payload is raw float32 (``vals=None``) or an
    :func:`_quant_int8_block` blob over the k kept values
    (``vals="int8_block"``) — the same per-entry codec machinery dense
    quantized parts use, so a sparse pseudo-gradient part decodes through
    :func:`leaf_from_part` like any other entry.  Absent positions decode
    to zero.  Returns ``(raw, enc)``; pass ``enc`` to
    :func:`encode_leaf_meta`."""
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    idx = np.ascontiguousarray(indices, dtype=np.uint32).reshape(-1)
    val = np.ascontiguousarray(values, dtype=np.float32).reshape(-1)
    if idx.size != val.size:
        raise ValueError(f"sparse leaf: {idx.size} indices vs "
                         f"{val.size} values")
    if idx.size and int(idx.max()) >= n:
        raise ValueError(f"sparse index {int(idx.max())} out of range "
                         f"for {n} elements")
    if vals not in (None, "int8_block"):
        raise ValueError(f"unknown sparse value codec {vals!r}")
    enc: Dict[str, Any] = {"codec": "topk", "k": int(idx.size)}
    if vals == "int8_block":
        enc["vals"] = "int8_block"
        enc["block"] = _QUANT_BLOCK
        payload = _quant_int8_block(val) if idx.size else b""
    else:
        payload = val.tobytes()
    return idx.tobytes() + payload, enc


def _decode_sparse_leaf(raw: bytes, shape: Tuple[int, ...],
                        enc: Dict[str, Any]) -> np.ndarray:
    """Inverse of :func:`encode_sparse_leaf` (raw is peer-supplied)."""
    n = int(np.prod(shape, dtype=np.int64)) if shape else 1
    k = enc.get("k")
    if not isinstance(k, int) or k < 0 or k > n:
        raise ValueError(f"bad sparse k {k!r} for {n} elements")
    if len(raw) < 4 * k:
        raise ValueError(f"truncated sparse payload: {len(raw)} bytes "
                         f"for k={k}")
    idx = np.frombuffer(raw, np.uint32, count=k)
    if k and int(idx.max()) >= n:
        raise ValueError(f"sparse index {int(idx.max())} out of range "
                         f"for {n} elements")
    vals_raw = raw[4 * k:]
    if enc.get("vals") == "int8_block":
        val = (_dequant_int8_block(vals_raw, (k,), enc.get("block"))
               if k else np.zeros(0, np.float32))
    else:
        if len(vals_raw) != 4 * k:
            raise ValueError(f"bad sparse value payload: {len(vals_raw)} "
                             f"bytes for k={k}")
        val = np.frombuffer(vals_raw, np.float32, count=k)
    out = np.zeros(n, np.float32)
    out[idx] = val
    return out.reshape(shape)


#: per-entry codecs the LCK3 layer understands
_LEAF_CODECS = ("int8_block", "topk")


def _encode_leaf(arr: np.ndarray, quant: Optional[str],
                 ) -> Tuple[bytes, Optional[Dict[str, Any]]]:
    """One leaf's wire payload and its codec descriptor (None = raw)."""
    if quant == "int8_block" and _quantizable(arr):
        return (_quant_int8_block(arr),
                {"codec": "int8_block", "block": _QUANT_BLOCK})
    return np.ascontiguousarray(arr).tobytes(), None


def _decode_leaf(raw: bytes, dt: np.dtype, shape: Tuple[int, ...],
                 enc: Optional[Dict[str, Any]]) -> np.ndarray:
    if enc is None:
        count = int(np.prod(shape, dtype=np.int64)) if shape else 1
        return np.frombuffer(raw, dtype=dt, count=count).reshape(shape)
    if not isinstance(enc, dict) or enc.get("codec") not in _LEAF_CODECS:
        raise ValueError(f"unknown leaf codec {enc!r}")
    if enc["codec"] == "topk":
        return _decode_sparse_leaf(raw, shape, enc).astype(dt)
    return _dequant_int8_block(raw, shape, enc.get("block")).astype(dt)


def _sorted_leaves(params: Any) -> List[Tuple[str, np.ndarray]]:
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(params)[0]
    return sorted(((_path_str(path), np.asarray(leaf))
                   for path, leaf in leaves_with_paths),
                  key=lambda kv: kv[0])


def params_to_bytes(params: Any, quant: Optional[str] = None) -> bytes:
    if quant not in _QUANT_MODES:
        raise ValueError(f"unknown quant mode {quant!r}")
    entries = _sorted_leaves(params)
    # Raw leaves are copied straight into the output buffer via frombuffer
    # views (one copy, no intermediate tobytes); this loop is the flat-blob
    # encode hot path for multi-GB checkpoints.
    index: List[Any] = []
    sizes: List[int] = []
    encs: List[Optional[Dict[str, Any]]] = []
    payloads: List[Optional[bytes]] = []
    off = 0
    for name, arr in entries:
        if quant == "int8_block" and _quantizable(arr):
            raw = _quant_int8_block(arr)
            enc: Optional[Dict[str, Any]] = {"codec": "int8_block",
                                             "block": _QUANT_BLOCK}
        else:
            raw, enc = None, None
        size = arr.nbytes if raw is None else len(raw)
        if quant is None:
            index.append((name, str(arr.dtype), list(arr.shape), off))
        else:
            index.append((name, str(arr.dtype), list(arr.shape), off, enc))
        sizes.append(size)
        encs.append(enc)
        payloads.append(raw)
        off += size
    head = json.dumps(index, separators=(",", ":")).encode("utf-8")
    magic = _MAGIC2 if quant is None else _MAGIC3
    prefix = magic + struct.pack(">I", len(head)) + head
    buf = bytearray(len(prefix) + off)
    buf[:len(prefix)] = prefix
    pos = len(prefix)
    for (name, arr), size, raw in zip(entries, sizes, payloads):
        if raw is None:
            view = np.frombuffer(buf, dtype=arr.dtype, count=arr.size,
                                 offset=pos).reshape(arr.shape)
            np.copyto(view, arr)
        else:
            buf[pos:pos + size] = raw
        pos += size
    return bytes(buf)


def encode_leaf_meta(dtype: str, shape: Sequence[int],
                     enc: Optional[Dict[str, Any]] = None) -> bytes:
    """Safe fixed encoding of a tensor's ``(dtype, shape[, codec])`` for v2
    manifest entry meta: compact JSON, deterministic, decodable without
    pickle.  ``enc=None`` output is byte-identical to pre-quant releases."""
    obj: Dict[str, Any] = {"dtype": dtype, "shape": list(shape)}
    if enc is not None:
        obj["enc"] = enc
    return json.dumps(obj, separators=(",", ":"),
                      sort_keys=True).encode("utf-8")


def _decode_leaf_meta_full(meta: bytes,
                           ) -> Tuple[np.dtype, Tuple[int, ...],
                                      Optional[Dict[str, Any]]]:
    if meta[:1] == b"{":
        try:
            obj = json.loads(meta.decode("utf-8"))
            dtype, shape = obj["dtype"], obj["shape"]
            enc = obj.get("enc")
        except (UnicodeDecodeError, ValueError, KeyError, TypeError) as e:
            raise ValueError(f"bad leaf meta {meta!r}") from e
    else:
        decoded = _safe_pickle_loads(meta)
        if not (isinstance(decoded, (tuple, list)) and len(decoded) == 2):
            raise ValueError(f"bad legacy leaf meta {meta!r}")
        dtype, shape, enc = decoded[0], list(decoded[1]), None
    if enc is not None and (not isinstance(enc, dict)
                            or enc.get("codec") not in _LEAF_CODECS):
        raise ValueError(f"unknown leaf codec in meta {meta!r}")
    return _checked_dtype(dtype), _checked_shape(shape), enc


def decode_leaf_meta(meta: bytes) -> Tuple[np.dtype, Tuple[int, ...]]:
    """Decode entry meta from the JSON encoding (with or without a codec
    field) or (shim) a legacy primitive-only pickle; raises ``ValueError``
    on anything else."""
    dt, shape, _ = _decode_leaf_meta_full(meta)
    return dt, shape


def params_to_parts(params: Any,
                    quant: Optional[str] = None) -> List[Tuple[str, bytes, bytes]]:
    """Per-leaf parts ``(path, payload bytes, encoded meta)``, sorted by
    path — the unit of structural sharing for delta-friendly DAGs.

    ``quant="int8_block"`` ships large float leaves block-quantized (meta
    carries the codec); small/integer leaves and ``quant=None`` parts are
    raw bytes with meta identical to previous releases, so unchanged
    tensors keep their sub-DAG CIDs."""
    if quant not in _QUANT_MODES:
        raise ValueError(f"unknown quant mode {quant!r}")
    parts = []
    for name, arr in _sorted_leaves(params):
        raw, enc = _encode_leaf(arr, quant)
        parts.append((name, raw,
                      encode_leaf_meta(str(arr.dtype), arr.shape, enc)))
    return parts


def leaf_from_part(raw: bytes, meta: bytes) -> np.ndarray:
    """Decode one part's bytes back into an ndarray using its dtype/shape
    (+ optional codec) meta.  ``meta`` and ``raw`` are both peer-supplied;
    malformed input raises ``ValueError``."""
    dt, shape, enc = _decode_leaf_meta_full(meta)
    return _decode_leaf(raw, dt, shape, enc)


def params_from_parts(flat: Dict[str, np.ndarray], like: Any = None) -> Any:
    """Restore a ``{path: ndarray}`` mapping into the structure of ``like``
    (or return the mapping itself when ``like`` is None)."""
    if like is None:
        return flat
    paths_and_leaves = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_and_leaves[0]:
        name = _path_str(path)
        arr = flat[name]
        assert tuple(arr.shape) == tuple(np.shape(leaf)), (name, arr.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(paths_and_leaves[1], leaves)


def _decode_index(data: bytes) -> Tuple[List, int]:
    """Index + payload offset from a checkpoint blob of either magic."""
    if len(data) < 8:
        raise ValueError("truncated checkpoint blob")
    magic = data[:4]
    (hlen,) = struct.unpack(">I", data[4:8])
    if 8 + hlen > len(data):
        raise ValueError("truncated checkpoint index")
    head = data[8:8 + hlen]
    if magic in (_MAGIC2, _MAGIC3):
        try:
            index = json.loads(head.decode("utf-8"))
        except (UnicodeDecodeError, ValueError) as e:
            raise ValueError(f"bad checkpoint index: {e}") from e
    elif magic == _MAGIC:
        index = _safe_pickle_loads(head)     # legacy shim, primitives only
    else:
        raise ValueError("not a checkpoint blob")
    if not isinstance(index, list):
        raise ValueError("checkpoint index is not a list")
    return index, 8 + hlen


def params_from_bytes(data: bytes, like: Any = None) -> Any:
    index, base = _decode_index(data)
    flat: Dict[str, np.ndarray] = {}
    for i, entry in enumerate(index):
        if not (isinstance(entry, (list, tuple)) and len(entry) in (4, 5)):
            raise ValueError(f"bad checkpoint index entry {entry!r}")
        name, dtype, shape, off = entry[:4]
        enc = entry[4] if len(entry) == 5 else None
        if not isinstance(name, str) or not isinstance(off, int) or off < 0:
            raise ValueError(f"bad checkpoint index entry {entry!r}")
        dt = _checked_dtype(dtype)
        shp = _checked_shape(shape)
        if enc is None:
            count = int(np.prod(shp, dtype=np.int64)) if shp else 1
            arr = np.frombuffer(data, dtype=dt, offset=base + off,
                                count=count).reshape(shp)
        else:
            # quantized entry: payload runs to the next entry's offset (the
            # index is offset-ordered) or the end of the blob
            end = (index[i + 1][3] if i + 1 < len(index) else
                   len(data) - base)
            if not isinstance(end, int) or end < off:
                raise ValueError(f"bad checkpoint index entry {entry!r}")
            arr = _decode_leaf(data[base + off:base + end], dt, shp, enc)
        flat[name] = arr
    return params_from_parts(flat, like)
