from .serial import params_from_bytes, params_to_bytes
from .lattica_ckpt import (CheckpointRegistry, fetch_checkpoint,
                           fetch_latest, publish_checkpoint)
from .local import load_local, save_local

__all__ = ["params_to_bytes", "params_from_bytes", "CheckpointRegistry",
           "publish_checkpoint", "fetch_checkpoint", "fetch_latest",
           "save_local", "load_local"]
