from .serial import (leaf_from_part, params_from_bytes, params_from_parts,
                     params_to_bytes, params_to_parts)
from .lattica_ckpt import (CheckpointRegistry, CheckpointService,
                           checkpoint_delta, fetch_checkpoint, fetch_latest,
                           fetch_latest_from, publish_checkpoint,
                           serve_checkpoints)
from .local import load_local, save_local

__all__ = ["params_to_bytes", "params_from_bytes", "params_to_parts",
           "params_from_parts", "leaf_from_part", "CheckpointRegistry",
           "CheckpointService", "checkpoint_delta", "publish_checkpoint",
           "fetch_checkpoint", "fetch_latest", "fetch_latest_from",
           "serve_checkpoints", "save_local", "load_local"]
