from .serial import params_from_bytes, params_to_bytes
from .lattica_ckpt import (CheckpointRegistry, CheckpointService,
                           fetch_checkpoint, fetch_latest, fetch_latest_from,
                           publish_checkpoint, serve_checkpoints)
from .local import load_local, save_local

__all__ = ["params_to_bytes", "params_from_bytes", "CheckpointRegistry",
           "CheckpointService", "publish_checkpoint", "fetch_checkpoint",
           "fetch_latest", "fetch_latest_from", "serve_checkpoints",
           "save_local", "load_local"]
