"""Checkpoints over Lattica: publish/fetch model versions through the mesh.

The paper's RL-pipeline scenario (Fig. 1-3): a training cluster publishes a
new model version as CID-addressed chunks; inference clusters discover it
(pubsub announcement or CRDT register) and swarm-fetch it via Bitswap.  The
CRDT store is the *model version registry*:

  * ``ckpt/<fleet>``            ORSet of (step, root-CID) — every version
  * ``ckpt/<fleet>/latest``     LWW register → (step, root-CID)
  * ``steps/<fleet>``           GCounter of total optimizer steps
"""

from __future__ import annotations

import pickle
from typing import Any, Generator, List, Optional, Tuple

from repro.core.cid import CID
from repro.core.dht import PeerInfo
from repro.core.node import LatticaNode
from repro.core.rpc import RpcContext
from repro.core.service import Fixed, Service, pickled, unary

from .serial import params_from_bytes, params_to_bytes


class CheckpointRegistry:
    """Typed view over a node's CRDT store for one model fleet."""

    def __init__(self, node: LatticaNode, fleet: str):
        self.node = node
        self.fleet = fleet

    @property
    def topic(self) -> str:
        return f"{self.fleet}/models"

    def record(self, step: int, root: CID) -> None:
        """Publisher-side: new version + move the LWW 'latest' pointer."""
        name = self.node.host.name
        self.node.store.orset(f"ckpt/{self.fleet}").add(
            (step, root.codec, root.digest), name)
        self.node.store.register(f"ckpt/{self.fleet}/latest").set(
            (step, root.codec, root.digest), self.node.sim.now, name)

    def record_fetched(self, step: int, root: CID) -> None:
        """Subscriber-side: note a version we hold WITHOUT touching the LWW
        pointer — re-setting 'latest' with a fresh local timestamp would
        let an old version win over a newer one after a merge."""
        self.node.store.orset(f"ckpt/{self.fleet}").add(
            (step, root.codec, root.digest), self.node.host.name)

    def versions(self) -> List[Tuple[int, CID]]:
        raw = self.node.store.orset(f"ckpt/{self.fleet}").value()
        return sorted((s, CID(c, d)) for s, c, d in raw)

    def latest(self) -> Optional[Tuple[int, CID]]:
        val = self.node.store.register(f"ckpt/{self.fleet}/latest").value()
        if val is None:
            return None
        s, c, d = val
        return s, CID(c, d)


class CheckpointService(Service):
    """Remote view of a node's checkpoint registry: resolve a fleet's
    latest/known versions directly from one peer, without waiting for CRDT
    anti-entropy to converge first.  Read-only, hence idempotent."""

    name = "ckpt"

    def __init__(self, node: LatticaNode):
        self.node = node

    @unary("ckpt.latest", request=Fixed(64), response=pickled(floor=96),
           idempotent=True, timeout=15.0)
    def latest(self, fleet: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(2e-6)
        return CheckpointRegistry(self.node, fleet).latest()

    @unary("ckpt.versions", request=Fixed(64), response=pickled(floor=96),
           idempotent=True, timeout=15.0)
    def versions(self, fleet: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(2e-6)
        return CheckpointRegistry(self.node, fleet).versions()


def serve_checkpoints(node: LatticaNode) -> CheckpointService:
    """Expose this node's checkpoint registry over the RPC plane."""
    return node.serve(CheckpointService(node))


def fetch_latest_from(node: LatticaNode, peer: PeerInfo, fleet: str,
                      like: Any = None) -> Generator:
    """Ask ``peer`` for the fleet's latest version and swarm-fetch it (the
    peer doubles as a provider hint).  Returns (step, params) or
    (None, None)."""
    stub = node.stub(CheckpointService, peer)
    latest = yield from stub.latest(fleet)
    if latest is None:
        return None, None
    step, root = latest
    params = yield from fetch_checkpoint(node, root, like,
                                         hint_providers=[peer])
    CheckpointRegistry(node, fleet).record_fetched(step, root)
    return step, params


def publish_checkpoint(node: LatticaNode, params: Any, step: int,
                       fleet: str) -> Generator:
    """Serialize → chunk → provide on the DHT → announce → record in CRDT.
    Returns the root CID."""
    reg = CheckpointRegistry(node, fleet)
    data = params_to_bytes(params)
    meta = pickle.dumps({"step": step, "fleet": fleet, "bytes": len(data)})
    root = yield from node.publish_artifact(data, meta=meta,
                                            announce_topic=reg.topic)
    reg.record(step, root)
    node.store.counter(f"steps/{fleet}").increment(node.host.name, 1)
    return root


def fetch_checkpoint(node: LatticaNode, root: CID, like: Any = None,
                     hint_providers: Optional[List[PeerInfo]] = None,
                     ) -> Generator:
    """Swarm-fetch a model version; returns the params pytree."""
    data = yield from node.fetch_artifact(root, hint_providers)
    return params_from_bytes(data, like)


def fetch_latest(node: LatticaNode, fleet: str, like: Any = None,
                 ) -> Generator:
    """Resolve the fleet's latest version from the CRDT registry and fetch.
    Returns (step, params) or (None, None) when no version is known."""
    reg = CheckpointRegistry(node, fleet)
    latest = reg.latest()
    if latest is None:
        return None, None
    step, root = latest
    params = yield from fetch_checkpoint(node, root, like)
    return step, params
