"""Checkpoints over Lattica: publish/fetch model versions through the mesh.

The paper's RL-pipeline scenario (Fig. 1-3): a training cluster publishes a
new model version as CID-addressed chunks; inference clusters discover it
(pubsub announcement or CRDT register) and swarm-fetch it via Bitswap.  The
CRDT store is the *model version registry*:

  * ``ckpt/<fleet>``            ORSet of (step, root-CID) — every version
  * ``ckpt/<fleet>/latest``     LWW register → (step, root-CID)
  * ``steps/<fleet>``           GCounter of total optimizer steps

Versions are *delta-friendly*: each pytree leaf is serialized as its own
sub-DAG under a hierarchical (v2) root manifest, so consecutive versions
share the sub-root CIDs of unchanged tensors and fetchers only move the
changed ones.  ``publish_checkpoint(base=...)`` reports new-vs-reused
block/byte stats in the announcement meta; fetchers pin the latest fetched
version per fleet (older ones become evictable under a blockstore budget).
"""

from __future__ import annotations

import pickle
from typing import Any, Dict, Generator, List, Optional, Tuple

from repro.core.bitswap import FetchError
from repro.core.cid import (CID, CODEC_DAG, ChunkSpec, build_tree_dag,
                            dag_reachable, decode_manifest_v2,
                            encode_manifest_v2, manifest_version, read_dag)
from repro.core.dht import PeerInfo
from repro.core.node import LatticaNode
from repro.core.rpc import RpcContext
from repro.core.safepickle import restricted_loads
from repro.core.service import Fixed, Service, pickled, unary

from .serial import (leaf_from_part, params_from_bytes, params_from_parts,
                     params_to_parts)


class CheckpointRegistry:
    """Typed view over a node's CRDT store for one model fleet."""

    def __init__(self, node: LatticaNode, fleet: str):
        self.node = node
        self.fleet = fleet

    @property
    def topic(self) -> str:
        return f"{self.fleet}/models"

    def record(self, step: int, root: CID) -> None:
        """Publisher-side: new version + move the LWW 'latest' pointer."""
        name = self.node.host.name
        self.node.store.orset(f"ckpt/{self.fleet}").add(
            (step, root.codec, root.digest), name)
        self.node.store.register(f"ckpt/{self.fleet}/latest").set(
            (step, root.codec, root.digest), self.node.sim.now, name)

    def record_fetched(self, step: int, root: CID) -> None:
        """Subscriber-side: note a version we hold WITHOUT touching the LWW
        pointer — re-setting 'latest' with a fresh local timestamp would
        let an old version win over a newer one after a merge."""
        self.node.store.orset(f"ckpt/{self.fleet}").add(
            (step, root.codec, root.digest), self.node.host.name)

    def versions(self) -> List[Tuple[int, CID]]:
        raw = self.node.store.orset(f"ckpt/{self.fleet}").value()
        return sorted((s, CID(c, d)) for s, c, d in raw)

    def latest(self) -> Optional[Tuple[int, CID]]:
        val = self.node.store.register(f"ckpt/{self.fleet}/latest").value()
        if val is None:
            return None
        s, c, d = val
        return s, CID(c, d)


class CheckpointService(Service):
    """Remote view of a node's checkpoint registry: resolve a fleet's
    latest/known versions directly from one peer, without waiting for CRDT
    anti-entropy to converge first.  Read-only, hence idempotent."""

    name = "ckpt"

    def __init__(self, node: LatticaNode):
        self.node = node

    @unary("ckpt.latest", request=Fixed(64), response=pickled(floor=96),
           idempotent=True, timeout=15.0)
    def latest(self, fleet: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(2e-6)
        return CheckpointRegistry(self.node, fleet).latest()

    @unary("ckpt.versions", request=Fixed(64), response=pickled(floor=96),
           idempotent=True, timeout=15.0)
    def versions(self, fleet: Any, ctx: RpcContext) -> Generator:
        yield ctx.cpu(2e-6)
        return CheckpointRegistry(self.node, fleet).versions()


def serve_checkpoints(node: LatticaNode) -> CheckpointService:
    """Expose this node's checkpoint registry over the RPC plane."""
    return node.serve(CheckpointService(node))


def fetch_latest_from(node: LatticaNode, peer: PeerInfo, fleet: str,
                      like: Any = None) -> Generator:
    """Ask ``peer`` for the fleet's latest version and swarm-fetch it (the
    peer doubles as a provider hint).  Returns (step, params) or
    (None, None)."""
    stub = node.stub(CheckpointService, peer)
    latest = yield from stub.latest(fleet)
    if latest is None:
        return None, None
    step, root = latest
    params = yield from fetch_checkpoint(node, root, like,
                                         hint_providers=[peer], fleet=fleet)
    CheckpointRegistry(node, fleet).record_fetched(step, root)
    return step, params


def _classify_blocks(items, base_set) -> Dict[str, int]:
    """Split ``(cid, size)`` pairs into new vs reused against ``base_set``."""
    stats = {"new_blocks": 0, "new_bytes": 0,
             "reused_blocks": 0, "reused_bytes": 0}
    for c, size in items:
        kind = "reused" if c in base_set else "new"
        stats[f"{kind}_blocks"] += 1
        stats[f"{kind}_bytes"] += size
    return stats


def checkpoint_delta(node: LatticaNode, root: CID,
                     base: Optional[CID]) -> Dict[str, int]:
    """Block/byte sharing between two locally-held DAG roots: how much of
    ``root`` is new vs reused verbatim from ``base``.  Blocks missing from
    the local store count as new with size 0 (their bytes are unknown)."""
    store = node.blockstore
    base_set = set(dag_reachable(base, store.peek)) if base is not None else set()
    blk = store.peek
    return _classify_blocks(
        ((c, len(blk(c)) if blk(c) is not None else 0)
         for c in dag_reachable(root, store.peek)), base_set)


#: classes a checkpoint announcement's pickled meta may legitimately carry
#: (the publisher's PeerInfo); everything else is refused — announcement
#: meta arrives off pubsub / fetched manifests, i.e. from untrusted peers,
#: and an open ``pickle.loads`` there is an arbitrary-code-execution vector
_META_ALLOWED = frozenset({
    ("repro.core.dht", "PeerInfo"),
    ("repro.core.peer", "PeerId"),
    ("repro.core.peer", "Multiaddr"),
})


def safe_meta_loads(raw: bytes) -> Any:
    """Decode a checkpoint announcement/manifest meta blob without giving
    the sender code execution: only the allowlisted PeerInfo classes
    resolve.  Raises ``ValueError`` on anything malformed or forbidden."""
    return restricted_loads(raw, _META_ALLOWED)


def chunk_spec_of(node: LatticaNode, root: CID) -> Optional[ChunkSpec]:
    """The ``ChunkSpec`` recorded in a locally-held checkpoint root's meta,
    or None when absent/undecodable.  Publishing a delta against ``base``
    must chunk with the *same* spec the base used — identical boundaries are
    what make unchanged content keep its leaf CIDs."""
    manifest = node.blockstore.peek(root)
    if manifest is None:
        return None
    try:
        if manifest_version(manifest) != 2:
            return None
        meta = safe_meta_loads(decode_manifest_v2(manifest)[2])
        return ChunkSpec.decode(meta["chunking"].encode("ascii"))
    except Exception:        # noqa: BLE001 — older meta without a spec
        return None


def negotiate_chunk_spec(node: LatticaNode, root: CID,
                         prefer: Optional[ChunkSpec] = None,
                         ) -> Optional[ChunkSpec]:
    """Settle which ``ChunkSpec`` governs a fetched checkpoint.

    Content addressing means the publisher always wins — the DAG's
    boundaries are baked into its CIDs and a fetcher cannot re-cut them —
    so "negotiation" is the graceful-degradation half: a fetcher with a
    different preference accepts the recorded spec, the mismatch is
    counted on ``bitswap.stats`` so operators can see a fleet fragmenting
    into incompatible chunking, and the returned spec is what the fetcher
    must use for its own delta re-publishes to keep unchanged-content
    CIDs stable.  Falls back to the fetcher's preference when the
    manifest records nothing (v1 / spec-less meta)."""
    recorded = chunk_spec_of(node, root)
    stats = node.bitswap.stats
    stats["spec_negotiated"] += 1
    if recorded is None:
        return prefer
    if prefer is not None and prefer != recorded:
        stats["spec_mismatch"] += 1
    return recorded


def publish_checkpoint(node: LatticaNode, params: Any, step: int,
                       fleet: str, base: Optional[CID] = None,
                       spec: Optional[ChunkSpec] = None,
                       quant: Optional[str] = None) -> Generator:
    """Per-tensor chunk → provide on the DHT → announce → record in CRDT.

    Each pytree leaf becomes its own sub-DAG under a hierarchical (v2) root
    manifest, so a new version reuses the sub-root CIDs of unchanged tensors
    verbatim and fetchers only swarm what changed.  ``spec`` picks the
    chunking strategy (a ``cdc`` spec additionally dedups *within-tensor*
    byte-shifting edits); when omitted, the spec recorded in ``base``'s
    manifest meta is reused so boundaries — and therefore unchanged-content
    CIDs — reproduce exactly.  With ``base`` (the previous version's root),
    delta stats (new vs reused blocks/bytes) are embedded in the
    announcement meta.  ``quant="int8_block"`` publishes large float
    tensors block-quantized (~4x fewer bytes on top of delta reuse; the
    local fp32 master is untouched) — fetchers dequantize transparently
    from the part meta.  Returns the root CID.
    """
    reg = CheckpointRegistry(node, fleet)
    if spec is None and base is not None:
        spec = chunk_spec_of(node, base)
    if spec is None:
        spec = ChunkSpec()
    parts = params_to_parts(params, quant=quant)
    dag = build_tree_dag(parts, spec=spec)
    delta = None
    if base is not None:
        base_set = set(dag_reachable(base, node.blockstore.peek))
        delta = _classify_blocks(
            ((c, len(blk)) for c, blk in dag.blocks.items()), base_set)
    meta = pickle.dumps({"step": step, "fleet": fleet,
                         "bytes": dag.total_size, "delta": delta,
                         "chunking": spec.encode().decode("ascii"),
                         "publisher": node.info()})
    # re-encode only the root manifest with the final meta (the sub-DAGs —
    # all the hashing work — are reused as built)
    manifest = encode_manifest_v2(dag.entries, dag.total_size, meta)
    blocks = dict(dag.blocks)
    del blocks[dag.root]
    root = CID.for_data(manifest, CODEC_DAG)
    blocks[root] = manifest
    yield from node.bitswap.publish_dag(blocks, root)
    node.pin_latest(f"ckpt/{fleet}", root)
    yield from node.pubsub.publish(
        reg.topic, ("artifact", root, dag.total_size, meta), size=192)
    reg.record(step, root)
    node.store.counter(f"steps/{fleet}").increment(node.host.name, 1)
    return root


def fetch_checkpoint(node: LatticaNode, root: CID, like: Any = None,
                     hint_providers: Optional[List[PeerInfo]] = None,
                     fleet: Optional[str] = None,
                     prefer_spec: Optional[ChunkSpec] = None) -> Generator:
    """Swarm-fetch a model version; returns the params pytree.

    Hierarchical (v2) roots reassemble per-tensor — sub-DAGs already in the
    local store (tensors unchanged since the last fetched version) are not
    re-fetched.  Flat (v1) roots take the legacy whole-blob path.  With
    ``fleet``, the fetched root is pinned as that fleet's latest (evicting
    older versions under a blockstore budget).  ``prefer_spec`` states the
    fetcher's chunking preference: when it differs from what the publisher
    recorded, the fetch still proceeds on the publisher's boundaries (see
    :func:`negotiate_chunk_spec`) and the mismatch is counted."""
    yield from node.fetch_artifact(root, hint_providers, assemble=False)
    negotiate_chunk_spec(node, root, prefer_spec)
    manifest = node.blockstore.peek(root)
    try:
        # store blocks were verified on put; skip re-hashing on reassembly
        if manifest is not None and manifest_version(manifest) == 2:
            entries = decode_manifest_v2(manifest)[0]
            flat = {e.name: leaf_from_part(
                        read_dag(e.cid, node.blockstore.get, verify=False),
                        e.meta)
                    for e in entries}
            params = params_from_parts(flat, like)
        else:
            params = params_from_bytes(
                read_dag(root, node.blockstore.get, verify=False), like)
    except (KeyError, ValueError) as e:
        raise FetchError(str(e)) from e
    if fleet is not None:
        node.pin_latest(f"ckpt/{fleet}", root)
    return params


def fetch_latest(node: LatticaNode, fleet: str, like: Any = None,
                 ) -> Generator:
    """Resolve the fleet's latest version from the CRDT registry and fetch.
    Returns (step, params) or (None, None) when no version is known."""
    reg = CheckpointRegistry(node, fleet)
    latest = reg.latest()
    if latest is None:
        return None, None
    step, root = latest
    params = yield from fetch_checkpoint(node, root, like, fleet=fleet)
    return step, params
