"""Pure-jnp oracles for every Pallas kernel in this package.

These are deliberately the *naive* formulations (quadratic attention, full
softmax + top_k, stabilized D-matrix mLSTM) — simple enough to trust, used
by tests/test_kernels.py to assert_allclose against the kernels across
shape/dtype sweeps.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True, window: int = 0) -> jax.Array:
    """q,k,v: (B, H, S, hd) — naive masked softmax attention."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(Sq)[:, None] + (Sk - Sq)
        kpos = jnp.arange(Sk)[None, :]
        ok = kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        logits = jnp.where(ok[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def moe_gating_ref(logits: jax.Array, k: int,
                   ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits: (T, E) → (weights (T,k), experts (T,k), probs (T,E))."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, experts = jax.lax.top_k(probs, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9)
    return weights, experts.astype(jnp.int32), probs


def mlstm_chunk_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                    log_i: jax.Array, log_f: jax.Array,
                    C0: jax.Array, n0: jax.Array, m0: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Sequential-recurrence oracle for one (B,H) slice batch.

    q,k,v: (B,H,S,hd) (k pre-scaled by 1/sqrt(hd));
    log_i/log_f: (B,H,S); state C0 (B,H,hd,hd), n0 (B,H,hd), m0 (B,H).
    Returns (h (B,H,S,hd), C_T, n_T, m_T) — the exp(-m)-scaled convention.
    """
    B, H, S, hd = q.shape

    def step(carry, t):
        C, n, m = carry
        m1 = jnp.maximum(log_f[:, :, t] + m, log_i[:, :, t])
        i1 = jnp.exp(log_i[:, :, t] - m1)
        f1 = jnp.exp(log_f[:, :, t] + m - m1)
        kv = k[:, :, t][..., :, None] * v[:, :, t][..., None, :]
        C1 = f1[..., None, None] * C + i1[..., None, None] * kv
        n1 = f1[..., None] * n + i1[..., None] * k[:, :, t]
        num = jnp.einsum("bhij,bhi->bhj", C1, q[:, :, t])
        den = jnp.maximum(jnp.abs(jnp.einsum("bhi,bhi->bh", n1, q[:, :, t])),
                          jnp.exp(-m1))
        return (C1, n1, m1), num / den[..., None]

    (C, n, m), hs = jax.lax.scan(
        step, (C0.astype(jnp.float32), n0.astype(jnp.float32),
               m0.astype(jnp.float32)), jnp.arange(S))
    h = jnp.moveaxis(hs, 0, 2)                      # (B,H,S,hd)
    return h.astype(q.dtype), C, n, m
