"""jit'd public wrappers around the Pallas kernels.

Model code calls these; layout transposes and interpret-mode selection
(CPU = interpret, TPU = compiled Mosaic) live here, so the kernels stay
pure grid/BlockSpec code.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .mlstm_scan import mlstm_scan_bhsd
from .moe_gating import moe_gating_tokens


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """q,k,v: (B, S, H, hd) (kv already head-repeated) → (B, S, H, hd)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               interpret=_interpret())
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("k",))
def moe_gating(logits: jax.Array, k: int,
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits: (T, E) → (weights (T,k), experts (T,k) int32, probs (T,E))."""
    return moe_gating_tokens(logits.astype(jnp.float32), k,
                             interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_scan(q, k, v, log_i, log_f, C0, n0, m0, *, chunk: int = 256):
    """Chunked mLSTM over (B,H,S,hd) inputs (k pre-scaled by 1/sqrt(hd))."""
    return mlstm_scan_bhsd(q, k, v, log_i, log_f, C0, n0, m0,
                           chunk=chunk, interpret=_interpret())
