"""jit'd public wrappers around the Pallas kernels.

Model code calls these; layout transposes and interpret-mode selection
(CPU = interpret, TPU = compiled Mosaic) live here, so the kernels stay
pure grid/BlockSpec code.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .mlstm_scan import mlstm_scan_bhsd
from .moe_gating import moe_gating_tokens
from .paged_attention import paged_attention_jnp, paged_attention_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """q,k,v: (B, S, H, hd) (kv already head-repeated) → (B, S, H, hd)."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               interpret=_interpret())
    return jnp.swapaxes(out, 1, 2)


@functools.partial(jax.jit, static_argnames=("k",))
def moe_gating(logits: jax.Array, k: int,
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """logits: (T, E) → (weights (T,k), experts (T,k) int32, probs (T,E))."""
    return moe_gating_tokens(logits.astype(jnp.float32), k,
                             interpret=_interpret())


@jax.jit
def _paged_pallas(q, k_pool, v_pool, block_tables, lengths, k_new, v_new,
                  k_scales=None, v_scales=None):
    return paged_attention_pallas(q, k_pool, v_pool, block_tables, lengths,
                                  k_new, v_new, k_scales, v_scales,
                                  interpret=_interpret())


@jax.jit
def _paged_jnp(q, k_pool, v_pool, block_tables, lengths, k_new, v_new,
               k_scales=None, v_scales=None):
    return paged_attention_jnp(q, k_pool, v_pool, block_tables, lengths,
                               k_new, v_new, k_scales, v_scales)


def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                           k_new, v_new, k_scales=None, v_scales=None):
    """Single-query decode attention over a paged KV pool.

    q (M,H,hd); pools (P,page,Hk,hd) fp32 or int8 (+ (P,Hk) scales);
    block_tables (M,NP) int32; lengths (M,) cached tokens; k/v_new
    (M,Hk,hd) the current token (attended at position ``lengths``).
    On TPU this runs the Pallas kernel (block-table scalar prefetch);
    on CPU the vectorized gather formulation — interpret-mode pallas is
    orders of magnitude too slow for a serving hot loop.
    """
    fn = _paged_pallas if jax.default_backend() == "tpu" else _paged_jnp
    return fn(q, k_pool, v_pool, block_tables, lengths, k_new, v_new,
              k_scales, v_scales)


@functools.partial(jax.jit, static_argnames=("chunk",))
def mlstm_scan(q, k, v, log_i, log_f, C0, n0, m0, *, chunk: int = 256):
    """Chunked mLSTM over (B,H,S,hd) inputs (k pre-scaled by 1/sqrt(hd))."""
    return mlstm_scan_bhsd(q, k, v, log_i, log_f, C0, n0, m0,
                           chunk=chunk, interpret=_interpret())
