"""Pallas TPU kernels for the compute hot spots (+ ops wrappers + oracles).

* flash_attention — streaming-softmax attention, VMEM (bq,bk) tiles
* moe_gating      — fused router softmax/top-k/renormalize
* mlstm_scan      — chunkwise xLSTM matrix-memory recurrence
* paged_decode_attention — single-query attention over paged KV pools
  (block-table scalar prefetch, fp32 or int8-per-page storage)

Validated in interpret mode on CPU (tests/test_kernels.py and
tests/test_paged_attention.py sweep shapes & dtypes against ref.py); on
TPU the same pallas_call lowers via Mosaic.
"""

from .ops import (flash_attention, mlstm_scan, moe_gating,
                  paged_decode_attention)

__all__ = ["flash_attention", "moe_gating", "mlstm_scan",
           "paged_decode_attention"]
