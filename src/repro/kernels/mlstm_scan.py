"""Chunkwise mLSTM recurrence as a Pallas TPU kernel.

The xLSTM matrix-memory cell, tiled for VMEM: the grid is
(batch, heads, chunks) with the chunk dimension sequential; the running
state (C: hd×hd f32, n: hd, m: scalar) lives in VMEM scratch across chunk
steps, so HBM sees one pass over q/k/v/gates and one (W, hd) output tile
per chunk — never the (S, S) decay matrix (it exists only per-chunk, W×W,
in VMEM).  All gate math is done in log-space with the exp(-m) scaling
convention, matching the decode recurrence bit-for-bit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30
DEFAULT_CHUNK = 256


def _mlstm_kernel(q_ref, k_ref, v_ref, li_ref, lf_ref, C0_ref, n0_ref, m0_ref,
                  h_ref, Cout_ref, nout_ref, mout_ref,
                  C_s, n_s, m_s, *, W: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        C_s[...] = C0_ref[0, 0].astype(jnp.float32)
        n_s[...] = n0_ref[0, 0].astype(jnp.float32).reshape(n_s.shape)
        m_s[...] = m0_ref[0].astype(jnp.float32).reshape(m_s.shape)

    q = q_ref[0, 0].astype(jnp.float32)                  # (W, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    li = li_ref[0, 0].astype(jnp.float32).reshape(W, 1)  # (W,1)
    lf = lf_ref[0, 0].astype(jnp.float32).reshape(W, 1)

    Cp = C_s[...]
    np_ = n_s[...]                                       # (1, hd)
    mp = m_s[...]                                        # (1, 1)

    F = jnp.cumsum(lf, axis=0)                           # (W,1)
    logD = F - F.reshape(1, W) + li.reshape(1, W)        # (W,W): F_t - F_s + i_s
    row = jax.lax.broadcasted_iota(jnp.int32, (W, W), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (W, W), 1)
    logD = jnp.where(col <= row, logD, NEG)
    m_intra = jnp.max(logD, axis=1, keepdims=True)       # (W,1)
    b_inter = F + mp                                     # (W,1)
    m_t = jnp.maximum(m_intra, b_inter)
    Dm = jnp.exp(logD - m_t)
    scores = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * Dm
    num = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))
    den = jnp.sum(scores, axis=1, keepdims=True)         # (W,1)
    w_int = jnp.exp(b_inter - m_t)                       # (W,1)
    num = num + w_int * jax.lax.dot_general(
        q, Cp, (((1,), (0,)), ((), ())))                 # (W,hd)
    den = den + w_int * jnp.sum(q * np_, axis=1, keepdims=True)
    norm = jnp.maximum(jnp.abs(den), jnp.exp(-m_t))
    h_ref[0, 0] = (num / norm).astype(h_ref.dtype)

    # ---- state update ------------------------------------------------------
    Ft = F[W - 1:W]                                      # (1,1)
    inc = Ft - F + li                                    # (W,1): F_T - F_s + i_s
    m_next = jnp.maximum(mp + Ft, jnp.max(inc, axis=0, keepdims=True))
    wk = jnp.exp(inc - m_next)                           # (W,1)
    carry = jnp.exp(mp + Ft - m_next)                    # (1,1)
    C_s[...] = carry * Cp + jax.lax.dot_general(
        k * wk, v, (((0,), (0,)), ((), ())))             # (hd,hd)
    n_s[...] = carry * np_ + jnp.sum(k * wk, axis=0, keepdims=True)
    m_s[...] = m_next

    @pl.when(ic == nc - 1)
    def _final():
        Cout_ref[0, 0] = C_s[...]
        nout_ref[0, 0] = n_s[...].reshape(nout_ref.shape[2:])
        mout_ref[0] = m_s[...].reshape(mout_ref.shape[1:])


def mlstm_scan_bhsd(q: jax.Array, k: jax.Array, v: jax.Array,
                    log_i: jax.Array, log_f: jax.Array,
                    C0: jax.Array, n0: jax.Array, m0: jax.Array, *,
                    chunk: int = DEFAULT_CHUNK, interpret: bool = True):
    """q,k,v: (B,H,S,hd) with k pre-scaled; log_i/log_f: (B,H,S);
    C0: (B,H,hd,hd), n0: (B,H,hd), m0: (B,H).
    Returns (h (B,H,S,hd), C_T, n_T, m_T)."""
    B, H, S, hd = q.shape
    W = min(chunk, S)
    assert S % W == 0, (S, W)
    nc = S // W
    kernel = functools.partial(_mlstm_kernel, W=W, nc=nc)
    grid = (B, H, nc)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, W, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, W, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, W, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, W), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, W), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, h, c: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, W, hd), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, h, c: (b, h, 0)),
            pl.BlockSpec((1, 1), lambda b, h, c: (b, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((hd, hd), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
    )(q, k, v, log_i, log_f, C0, n0, m0)
