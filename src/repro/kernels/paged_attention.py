"""Paged single-query decode attention as a Pallas TPU kernel.

The continuous-batching engine (``serving/batch.py``) keeps every slot's
KV cache in a shared page pool: ``k_pool``/``v_pool`` are ``(P, page,
Hk, hd)`` and each slot owns a list of page ids (its *block table*).
One decode step is then single-query attention per slot over that slot's
pages — the PagedAttention formulation.  The grid is ``(slot, kv_page)``
with the page dimension innermost and sequential; a running
``(acc, m, l)`` online-softmax state lives in VMEM scratch across pages.

Block tables are data-dependent indices, so the pool BlockSpecs index
through a scalar-prefetch operand (``PrefetchScalarGridSpec``): the
index map reads ``block_tables[slot, page]`` and the pipeline fetches
exactly the pages each slot owns — never the whole pool.

The *current* token's ``k/v`` (freshly projected this step, not yet
written back to the pool) is folded into the softmax at page 0 by
initialising the running state with its contribution: ``m = s_self``,
``l = 1``, ``acc = v_new``.  Pool positions ``>= length`` are masked, so
stale page contents (including the just-allocated page the engine will
write this token into *after* the call) never leak into the output.

Two storage formats share the kernel:

* fp32 pools — exact.
* int8 pools with per-(page, kv-head) scales (``k_scales``/``v_scales``
  of shape ``(P, Hk)``) — dequantised inside the kernel, quartering
  pool bytes for a bounded logit error (|x̂-x| <= page_absmax/254).

``paged_attention_jnp`` is the gather-based reference formulation used
on CPU (Pallas interpret mode is far too slow for the serving hot loop)
and by tests; it reproduces ``models/common.attention_scores`` decode
numerics exactly (same additive -1e9 mask, fp32 einsum, softmax) so
greedy decode through the paged path matches the dense-cache path
token-for-token.

Validated with interpret=True on CPU against ``ref.attention_ref``
(this container has no TPU); on TPU the same pallas_call lowers to
Mosaic.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


# ======================================================================
# jnp reference path (CPU serving + test oracle)
# ======================================================================

def paged_attention_jnp(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                        block_tables: jax.Array, lengths: jax.Array,
                        k_new: jax.Array, v_new: jax.Array,
                        k_scales: Optional[jax.Array] = None,
                        v_scales: Optional[jax.Array] = None) -> jax.Array:
    """Gather-based paged decode attention.

    q:            (M, H, hd)   one query per slot
    k/v_pool:     (P, page, Hk, hd)  fp32, or int8 when scales given
    block_tables: (M, NP) int32 pool page ids (padded entries masked out)
    lengths:      (M,) int32   cached tokens per slot (query position)
    k/v_new:      (M, Hk, hd)  this step's k/v, attended at position
                  ``lengths`` (the engine writes it to the pool after)
    k/v_scales:   (P, Hk) fp32 per-page per-kv-head dequant scales

    Returns (M, H, hd).  Matches the dense-cache decode path of
    ``models/common.run_attention`` bit-for-bit for fp32 pools: the
    gathered cache is laid out exactly like the dense cache (new token
    scattered at index ``lengths``), masked additively with -1e9, and
    reduced with the same fp32 einsum/softmax contractions.
    """
    M, H, hd = q.shape
    P, page, Hk, _ = k_pool.shape
    NP = block_tables.shape[1]
    T = NP * page
    kg = k_pool[block_tables]                      # (M, NP, page, Hk, hd)
    vg = v_pool[block_tables]
    if k_scales is not None:
        kg = kg.astype(jnp.float32) * k_scales[block_tables][:, :, None, :, None]
        vg = vg.astype(jnp.float32) * v_scales[block_tables][:, :, None, :, None]
    kg = kg.reshape(M, T, Hk, hd).astype(jnp.float32)
    vg = vg.reshape(M, T, Hk, hd).astype(jnp.float32)
    # place the current token at its true cache index so the layout (and
    # therefore the reduction order) matches the dense decode path
    scatter = jax.vmap(
        lambda c, n, l: jax.lax.dynamic_update_slice(c, n[None], (l, 0, 0)))
    kg = scatter(kg, k_new.astype(jnp.float32), lengths)
    vg = scatter(vg, v_new.astype(jnp.float32), lengths)
    kpos = jnp.arange(T, dtype=jnp.int32)
    amask = jnp.where(kpos[None] <= lengths[:, None], 0.0,
                      -1e9).astype(jnp.float32)    # (M, T)
    rep = H // Hk
    kk = jnp.repeat(kg, rep, axis=2)               # (M, T, H, hd)
    vv = jnp.repeat(vg, rep, axis=2)
    scale = 1.0 / math.sqrt(hd)
    logits = jnp.einsum("mhd,mthd->mht", q.astype(jnp.float32), kk) * scale
    logits = logits + amask[:, None, :]
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("mht,mthd->mhd", probs, vv)
    return out.astype(q.dtype)


# ======================================================================
# Pallas kernel
# ======================================================================

def _paged_kernel(bt_ref, len_ref, q_ref, kn_ref, vn_ref, k_ref, v_ref,
                  *rest, page: int, n_pages: int, rep: int, scale: float,
                  quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = rest
    else:
        o_ref, acc_ref, m_ref, l_ref = rest
        ks_ref = vs_ref = None
    im = pl.program_id(0)
    ip = pl.program_id(1)
    Hk, hd = k_ref.shape[2], k_ref.shape[3]

    q = q_ref[0].astype(jnp.float32) * scale       # (H, hd)
    q3 = q.reshape(Hk, rep, hd)

    @pl.when(ip == 0)
    def _init():
        # fold the current token in as the initial online-softmax state:
        # it is always attended (query position == lengths[im])
        kn = kn_ref[0].astype(jnp.float32)         # (Hk, hd)
        vn = vn_ref[0].astype(jnp.float32)
        m_ref[...] = jnp.sum(q3 * kn[:, None, :], axis=-1)   # (Hk, rep)
        l_ref[...] = jnp.ones_like(l_ref)
        acc_ref[...] = jnp.broadcast_to(vn[:, None, :], acc_ref.shape)

    k = k_ref[0].astype(jnp.float32)               # (page, Hk, hd)
    v = v_ref[0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0][None, :, None]
        v = v * vs_ref[0][None, :, None]
    kT = jnp.transpose(k, (1, 0, 2))               # (Hk, page, hd)
    vT = jnp.transpose(v, (1, 0, 2))
    s = jax.lax.dot_general(q3, kT,
                            (((2,), (2,)), ((0,), (0,))))  # (Hk, rep, page)
    length = len_ref[im]
    kpos = ip * page + jax.lax.broadcasted_iota(jnp.int32, (1, 1, page), 2)
    s = jnp.where(kpos < length, s, NEG_INF)

    m_prev = m_ref[...]                            # (Hk, rep)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * alpha[..., None]
                    + jax.lax.dot_general(p, vT,
                                          (((2,), (1,)), ((0,), (0,)))))
    m_ref[...] = m_new

    @pl.when(ip == n_pages - 1)
    def _finalize():
        out = acc_ref[...] / l_ref[...][..., None]           # (Hk, rep, hd)
        o_ref[0] = out.reshape(Hk * rep, hd).astype(o_ref.dtype)


def paged_attention_pallas(q: jax.Array, k_pool: jax.Array,
                           v_pool: jax.Array, block_tables: jax.Array,
                           lengths: jax.Array, k_new: jax.Array,
                           v_new: jax.Array,
                           k_scales: Optional[jax.Array] = None,
                           v_scales: Optional[jax.Array] = None, *,
                           interpret: bool = True) -> jax.Array:
    """Same contract as :func:`paged_attention_jnp`, as a pallas_call."""
    M, H, hd = q.shape
    P, page, Hk, _ = k_pool.shape
    NP = block_tables.shape[1]
    rep = H // Hk
    assert rep * Hk == H, (H, Hk)
    quantized = k_scales is not None
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _paged_kernel, page=page, n_pages=NP, rep=rep, scale=scale,
        quantized=quantized)
    in_specs = [
        pl.BlockSpec((1, H, hd), lambda m, p, bt, ln: (m, 0, 0)),       # q
        pl.BlockSpec((1, Hk, hd), lambda m, p, bt, ln: (m, 0, 0)),      # k_new
        pl.BlockSpec((1, Hk, hd), lambda m, p, bt, ln: (m, 0, 0)),      # v_new
        pl.BlockSpec((1, page, Hk, hd),
                     lambda m, p, bt, ln: (bt[m, p], 0, 0, 0)),         # k page
        pl.BlockSpec((1, page, Hk, hd),
                     lambda m, p, bt, ln: (bt[m, p], 0, 0, 0)),         # v page
    ]
    args = [q, k_new, v_new, k_pool, v_pool]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, Hk), lambda m, p, bt, ln: (bt[m, p], 0)),
            pl.BlockSpec((1, Hk), lambda m, p, bt, ln: (bt[m, p], 0)),
        ]
        args += [k_scales, v_scales]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(M, NP),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, H, hd), lambda m, p, bt, ln: (m, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((Hk, rep, hd), jnp.float32),   # acc
            pltpu.VMEM((Hk, rep), jnp.float32),       # running max m
            pltpu.VMEM((Hk, rep), jnp.float32),       # running sum l
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((M, H, hd), q.dtype),
        interpret=interpret,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32), *args)
