"""Flash attention as a Pallas TPU kernel.

TPU adaptation of the streaming-softmax algorithm: the grid is
(batch, heads, q_blocks, k_blocks) with the k dimension innermost and
sequential; running (acc, m, l) live in VMEM scratch across k steps, so HBM
traffic is one pass over K/V per q block and the S×S matrix never exists.
Block shapes are MXU-aligned (q/k blocks multiples of 128 on the lane dim,
head_dim on the sublane dim).

Validated with interpret=True on CPU against ``ref.attention_ref``
(this container has no TPU); on TPU the same pallas_call lowers to Mosaic.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 256
DEFAULT_BK = 256


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, window: int, bq: int, bk: int, nk: int,
                  q_offset: int, scale: float):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
    if causal:
        iq = pl.program_id(2)
        qpos = (q_offset + iq * bq
                + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0))
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos <= qpos
        if window > 0:
            ok &= kpos > qpos - window
        s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                               # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                      # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = (acc_ref[...] * alpha
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l_safe = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int = 0,
                         bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                         interpret: bool = True) -> jax.Array:
    """q,k,v: (B, H, S, hd).  Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, Sk, bq, bk)
    nq, nk = Sq // bq, Sk // bk
    q_offset = Sk - Sq if causal else 0
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
        q_offset=q_offset, scale=scale)
    grid = (B, H, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),     # acc
            pltpu.VMEM((bq, 1), jnp.float32),      # running max m
            pltpu.VMEM((bq, 1), jnp.float32),      # running sum l
        ],
        interpret=interpret,
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
    )(q, k, v)
