"""Fused MoE router gating as a Pallas TPU kernel.

softmax → top-k select → renormalize in one VMEM pass over a token block:
the (T, E) logits are read once from HBM and the (T, E) probability matrix
is produced alongside the (T, K) routing decision without re-reading.  The
top-k loop is a K-step argmax-and-mask (K ≤ 8 statically), written
iota-compare style so it maps onto TPU vector units rather than a sort.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BT = 256
NEG = -1e30


def _gating_kernel(logits_ref, w_ref, idx_ref, probs_ref, *, K: int, E: int):
    x = logits_ref[...].astype(jnp.float32)              # (bt, E)
    m = jnp.max(x, axis=1, keepdims=True)
    p = jnp.exp(x - m)
    denom = jnp.sum(p, axis=1, keepdims=True)
    probs = p / denom
    probs_ref[...] = probs

    lane = jax.lax.broadcasted_iota(jnp.int32, probs.shape, 1)
    sel = probs
    total = jnp.zeros((probs.shape[0], 1), jnp.float32)
    ws = []
    ids = []
    for _ in range(K):
        cur = jnp.max(sel, axis=1, keepdims=True)        # (bt,1)
        is_max = sel >= cur                               # ties: take first
        first = jnp.min(jnp.where(is_max, lane, E), axis=1, keepdims=True)
        ws.append(cur)
        ids.append(first)
        sel = jnp.where(lane == first, NEG, sel)
        total = total + cur
    w = jnp.concatenate(ws, axis=1)                      # (bt,K)
    w_ref[...] = w / jnp.maximum(total, 1e-9)
    idx_ref[...] = jnp.concatenate(ids, axis=1).astype(jnp.int32)


def moe_gating_tokens(logits: jax.Array, k: int, *, bt: int = DEFAULT_BT,
                      interpret: bool = True):
    """logits: (T, E) → (weights (T,k), experts (T,k) int32, probs (T,E))."""
    T, E = logits.shape
    bt = min(bt, T)
    assert T % bt == 0, (T, bt)
    kernel = functools.partial(_gating_kernel, K=k, E=E)
    return pl.pallas_call(
        kernel,
        grid=(T // bt,),
        in_specs=[pl.BlockSpec((bt, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, E), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, k), jnp.float32),
            jax.ShapeDtypeStruct((T, k), jnp.int32),
            jax.ShapeDtypeStruct((T, E), jnp.float32),
        ],
        interpret=interpret,
    )(logits)
