"""Sharded inference over the Lattica mesh (paper Fig. 1, Scenario 4).

A model is split into pipeline shards; each shard runs on a peer (possibly
behind a NAT) and serves the ``infer.<fleet>`` RPC.  Shard servers announce
themselves as DHT providers of ``shard/<fleet>/<i>``; the shard-aware client
stub resolves providers per hop, streams activations through the pipeline,
and **transparently fails over** to replica shards via a fresh DHT lookup
when a provider dies — the availability story of the paper's §2 RPC layer.

This module is the mesh-level (cross-NAT) serving path at example scale;
datacenter-scale tensor-parallel serving is ``repro.launch.serve``.
"""

from __future__ import annotations

import hashlib
import itertools
from typing import Any, Dict, Generator, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dht import PeerInfo
from repro.core.node import LatticaNode
from repro.core.rpc import RpcContext, RpcError
from repro.core.service import (RpcStatus, Service, ServiceError,
                                TensorDictCodec, unary)
from repro.core.simnet import DialError
from repro.models import decoder
from repro.models.common import rms_norm
from repro.models.config import ModelConfig

#: assumed accelerator throughput per serving peer, for simulated latency
PEER_FLOPS = 2.0e11

_session_seq = itertools.count(1)


def shard_key(fleet: str, idx: int) -> bytes:
    return hashlib.sha256(f"shard/{fleet}/{idx}".encode()).digest()


def plan_shards(cfg: ModelConfig, n_shards: int) -> List[Tuple[int, int]]:
    """Split layers into contiguous ranges, as even as possible."""
    L = cfg.n_layers
    base, rem = divmod(L, n_shards)
    plan = []
    lo = 0
    for i in range(n_shards):
        hi = lo + base + (1 if i < rem else 0)
        plan.append((lo, hi))
        lo = hi
    return plan


def split_params(cfg: ModelConfig, params: Any,
                 plan: List[Tuple[int, int]]) -> List[Dict[str, Any]]:
    """Per-shard param subsets (first gets embed, last gets norm+head)."""
    shards = []
    for i, (lo, hi) in enumerate(plan):
        sub: Dict[str, Any] = {}
        if cfg.arch == "ssm":
            sub["blocks"] = params["blocks"][lo:hi]
        else:
            sub["blocks"] = jax.tree.map(lambda a: a[lo:hi], params["blocks"])
        if i == 0:
            sub["embed"] = params["embed"]
        if i == len(plan) - 1:
            sub["final_norm"] = params["final_norm"]
            if "lm_head" in params:
                sub["lm_head"] = params["lm_head"]
            elif cfg.tie_embeddings:
                sub["embed_out"] = params["embed"]
        shards.append(sub)
    return shards


class ShardModule:
    """Applies one shard's layer range, with per-session decode caches."""

    def __init__(self, cfg: ModelConfig, params: Dict[str, Any],
                 layer_range: Tuple[int, int], is_first: bool, is_last: bool):
        self.cfg = cfg
        self.params = params
        self.lo, self.hi = layer_range
        self.is_first = is_first
        self.is_last = is_last

    @property
    def n_layers(self) -> int:
        return self.hi - self.lo

    def _layer_params(self, j: int) -> Any:
        if self.cfg.arch == "ssm":
            return self.params["blocks"][j]
        return jax.tree.map(lambda a: a[j], self.params["blocks"])

    def embed(self, tokens: jax.Array) -> jax.Array:
        return jnp.take(self.params["embed"], tokens, axis=0)

    def head(self, x: jax.Array) -> jax.Array:
        x = rms_norm(x, self.params["final_norm"], self.cfg.norm_eps)
        w = self.params.get("lm_head")
        if w is None:
            w = self.params["embed_out"].T
        return x @ w

    def init_cache(self, batch: int, max_len: int) -> Dict[str, Any]:
        full = decoder.init_cache(self.cfg, batch, max_len)
        if self.cfg.arch == "ssm":
            layers = full["layers"][self.lo:self.hi]
        else:
            layers = jax.tree.map(lambda a: a[self.lo:self.hi], full["layers"])
        return {"len": full["len"], "layers": layers}

    def apply(self, x: jax.Array, positions: jax.Array,
              cache: Optional[Dict[str, Any]]) -> Tuple[jax.Array, Optional[Dict[str, Any]]]:
        cache_len = cache["len"] if cache is not None else None
        new_layers: List[Any] = []
        for j in range(self.n_layers):
            lp = self._layer_params(j)
            if cache is not None:
                if self.cfg.arch == "ssm":
                    lc = cache["layers"][j]
                else:
                    lc = jax.tree.map(lambda a: a[j], cache["layers"])
            else:
                lc = None
            x, nc, _ = decoder.run_block(
                self.cfg, lp, x, positions, lc, cache_len,
                layer_idx=self.lo + j)
            new_layers.append(nc)
        new_cache = None
        if cache is not None:
            if self.cfg.arch == "ssm":
                stacked = new_layers
            else:
                stacked = jax.tree.map(
                    lambda *xs: jnp.stack(xs), *new_layers)
            new_cache = {"len": cache_len + x.shape[1], "layers": stacked}
        return x, new_cache

    def flops(self, tokens: int) -> float:
        per_layer = 12 * self.cfg.d_model ** 2
        return 2.0 * tokens * per_layer * self.n_layers


class InferenceService(Service):
    """One pipeline shard's RPC surface.  ``scope`` carries the fleet name
    and shard index, so each shard serves ``infer.<fleet>.<i>``.  The infer
    method is *not* idempotent (decode advances per-session KV caches);
    failover is handled explicitly by :class:`ShardClient`."""

    name = "infer"

    def __init__(self, server: "ShardServer"):
        self.server = server
        self.scope = f"{server.fleet}.{server.shard_idx}"

    @unary("infer", request=TensorDictCodec(), response=TensorDictCodec(),
           timeout=120.0)
    def infer(self, payload: Any, ctx: RpcContext) -> Generator:
        if not self.server.alive:
            raise ServiceError(RpcStatus.UNAVAILABLE,
                               f"shard {self.server.shard_idx} is down")
        resp = yield from self.server._handle(payload, ctx)
        return resp


class ShardServer:
    def __init__(self, node: LatticaNode, cfg: ModelConfig, fleet: str,
                 shard_idx: int, module: ShardModule):
        self.node = node
        self.cfg = cfg
        self.fleet = fleet
        self.shard_idx = shard_idx
        self.module = module
        self.sessions: Dict[Any, Dict[str, Any]] = {}
        self.alive = True
        self.stats = {"prefill": 0, "decode": 0, "score": 0}
        node.serve(InferenceService(self))

    def announce(self) -> Generator:
        yield from self.node.dht.provide(shard_key(self.fleet, self.shard_idx))
        return None

    def stop(self) -> None:
        """Simulate a crash: all subsequent calls fail."""
        self.alive = False

    def _handle(self, payload: Any, ctx: RpcContext) -> Generator:
        op = payload["op"]
        m = self.module
        if op == "prefill":
            self.stats["prefill"] += 1
            x = jnp.asarray(payload["x"])
            if m.is_first and x.dtype == jnp.int32:
                x = m.embed(x)
            B, S = x.shape[0], x.shape[1]
            cache = m.init_cache(B, payload["max_len"])
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            if self.cfg.mrope:
                positions = jnp.broadcast_to(positions[None], (3, B, S))
            out, cache = m.apply(x, positions, cache)
            self.sessions[payload["session"]] = cache
            if m.is_last:
                out = m.head(out[:, -1:])[:, 0]
            else:
                out = out
            yield ctx.cpu(m.flops(B * S) / PEER_FLOPS)
            return {"x": np.asarray(out)}
        if op == "decode":
            self.stats["decode"] += 1
            cache = self.sessions[payload["session"]]
            x = jnp.asarray(payload["x"])
            if m.is_first and x.dtype == jnp.int32:
                x = m.embed(x[:, None])
            B = x.shape[0]
            pos = jnp.broadcast_to(
                cache["len"][None, None], (B, 1)).astype(jnp.int32)
            if self.cfg.mrope:
                pos = jnp.broadcast_to(pos[None], (3, B, 1))
            out, cache = m.apply(x, pos, cache)
            self.sessions[payload["session"]] = cache
            if m.is_last:
                out = m.head(out)[:, 0]
            yield ctx.cpu(m.flops(B) / PEER_FLOPS)
            return {"x": np.asarray(out)}
        if op == "score":
            self.stats["score"] += 1
            x = jnp.asarray(payload["x"])
            if m.is_first and x.dtype == jnp.int32:
                x = m.embed(x)
            B, S = x.shape[0], x.shape[1]
            positions = jnp.broadcast_to(
                jnp.arange(S, dtype=jnp.int32)[None], (B, S))
            if self.cfg.mrope:
                positions = jnp.broadcast_to(positions[None], (3, B, S))
            out, _ = m.apply(x, positions, None)
            if m.is_last:
                out = m.head(out)
            yield ctx.cpu(m.flops(B * S) / PEER_FLOPS)
            return {"x": np.asarray(out)}
        raise ServiceError(RpcStatus.NOT_FOUND, f"unknown op {op}")


class ShardClient:
    """Shard-aware stub: DHT provider resolution + transparent failover."""

    def __init__(self, node: LatticaNode, cfg: ModelConfig, fleet: str,
                 n_shards: int):
        self.node = node
        self.cfg = cfg
        self.fleet = fleet
        self.n_shards = n_shards
        self._providers: Dict[int, List[PeerInfo]] = {}
        self.stats = {"failovers": 0, "calls": 0}

    def _resolve(self, idx: int, refresh: bool = False) -> Generator:
        if refresh or idx not in self._providers or not self._providers[idx]:
            provs = yield from self.node.dht.find_providers(
                shard_key(self.fleet, idx))
            self._providers[idx] = [
                p for p in provs if p.peer_id != self.node.peer_id]
        return self._providers[idx]

    def _call_shard(self, idx: int, payload: Dict[str, Any]) -> Generator:
        provs = yield from self._resolve(idx)
        last: Optional[Exception] = None
        for round_ in range(2):
            for info in list(provs):
                self.stats["calls"] += 1
                try:
                    stub = self.node.stub(InferenceService, info,
                                          scope=f"{self.fleet}.{idx}")
                    resp = yield from stub.infer(payload)
                    return resp
                except (RpcError, DialError) as e:
                    last = e
                    self.stats["failovers"] += 1
                    if info in provs:
                        provs.remove(info)
            provs = yield from self._resolve(idx, refresh=True)
        raise RpcError(f"all providers for shard {idx} failed: {last}")

    # -- pipeline ops --------------------------------------------------------
    def prefill(self, tokens: np.ndarray, max_len: int) -> Generator:
        session = (self.node.host.name, next(_session_seq))
        x: Any = tokens
        for i in range(self.n_shards):
            payload = {"op": "prefill", "session": session, "x": x,
                       "max_len": max_len}
            resp = yield from self._call_shard(i, payload)
            x = resp["x"]
        return session, x                        # x = last-position logits

    def decode_step(self, session: Any, token: np.ndarray) -> Generator:
        x: Any = token
        for i in range(self.n_shards):
            payload = {"op": "decode", "session": session, "x": x}
            resp = yield from self._call_shard(i, payload)
            x = resp["x"]
        return x

    def score(self, tokens: np.ndarray) -> Generator:
        x: Any = tokens
        for i in range(self.n_shards):
            payload = {"op": "score", "x": x}
            resp = yield from self._call_shard(i, payload)
            x = resp["x"]
        return x

    def generate(self, tokens: np.ndarray, n_tokens: int) -> Generator:
        session, logits = yield from self.prefill(
            tokens, tokens.shape[1] + n_tokens + 1)
        out = []
        for _ in range(n_tokens):
            tok = np.argmax(logits, axis=-1).astype(np.int32)
            out.append(tok)
            logits = yield from self.decode_step(session, tok)
        return np.stack(out, axis=1)


def deploy_sharded(nodes: List[LatticaNode], cfg: ModelConfig, params: Any,
                   fleet: str, replicas: int = 1) -> List[ShardServer]:
    """Place ``n_shards = len(nodes) // replicas`` pipeline shards, each
    replicated ``replicas`` times across the given nodes."""
    n_shards = len(nodes) // replicas
    plan = plan_shards(cfg, n_shards)
    parts = split_params(cfg, params, plan)
    servers = []
    for r in range(replicas):
        for i, (lo, hi) in enumerate(plan):
            node = nodes[r * n_shards + i]
            module = ShardModule(cfg, parts[i], (lo, hi),
                                 is_first=(i == 0), is_last=(i == n_shards - 1))
            servers.append(ShardServer(node, cfg, fleet, i, module))
    return servers
